"""Workload subsystem (DESIGN.md §10): trace-style request generation,
chained-service traversal, live-ops scenarios, and SLO tail reporting.

  * ``generators``  — seeded arrival processes (Poisson / bursty ON-OFF /
    diurnal), heavy-tailed service-time samplers (lognormal / Pareto), and
    the ``Workload`` request factory that emits engine-compatible
    ``RequestBatch``es.  Everything is keyed by ``(seed, tick)`` or
    ``(seed, hop, req_id)`` — stateless draws, bit-identical replays.
  * ``chain``       — the chained-service scenario: a completion at service
    k synchronously admits at service k+1, the balancer is traversed once
    per hop, end-to-end latency = sum of per-hop tick latencies.
  * ``scenarios``   — declarative live-ops driver replaying timed
    ControlPlane transactions mid-load (canary, blue-green, rolling
    restart, elastic scale), composable with the fault injector.
  * ``slo``         — p50/p99/p999 tail tables from per-request tick
    samples + the validated BENCH_TREND.jsonl scenario-row schema.
"""

from repro.workload.chain import ChainResult, ChainRunner
from repro.workload.generators import (BurstyArrivals, DiurnalArrivals,
                                       FixedServiceTimes,
                                       LognormalServiceTimes,
                                       ParetoServiceTimes, PoissonArrivals,
                                       ServiceTimeShaper, Workload)
from repro.workload.scenarios import Op, ScenarioDriver, rolling_restart
from repro.workload.slo import (append_scenario_row, chaos_row, percentiles,
                                scenario_row, validate_chaos_row,
                                validate_scenario_row)

__all__ = [
    "PoissonArrivals", "BurstyArrivals", "DiurnalArrivals",
    "LognormalServiceTimes", "ParetoServiceTimes", "FixedServiceTimes",
    "ServiceTimeShaper", "Workload", "ChainRunner", "ChainResult",
    "Op", "ScenarioDriver", "rolling_restart", "percentiles",
    "scenario_row", "append_scenario_row", "validate_scenario_row",
    "chaos_row", "validate_chaos_row",
]
