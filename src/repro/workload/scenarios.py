"""Declarative live-ops scenarios: timed ControlPlane transactions mid-load.

A scenario is a list of :class:`Op` records — *when* (global tick), *where*
(chain hop), *what* (operation + kwargs) — and the :class:`ScenarioDriver`
replays them against the per-hop ControlPlanes while the workload is in
flight.  Each op commits as ONE ControlPlane transaction (one version bump,
one live splice into every attached consumer), exactly how an operator or a
rollout controller would drive the system; the driver never touches engine
state directly.

Operations:

  ``set_weight``       — one endpoint's weight (instance, weight)
  ``canary``           — %-shift: the canary instance takes ``pct``% of a
                         WEIGHTED cluster, peers split the rest evenly
  ``drain``/``undrain``— graceful connection drain / restore (instance)
  ``blue_green``       — cutover: ``green`` instances to full weight,
                         ``blue`` instances drained, one transaction
  ``scale``            — elastic scale-up/down to ``target`` endpoints via
                         ``runtime.elastic.scale_fleet``
  ``add_endpoint``     — grow the cluster by one standby instance
                         (instance, weight — weight 0 = blue-green standby)

``rolling_restart`` expands the classic staggered drain→dwell→undrain
sequence into primitive ops at construction, so the schedule itself stays
declarative and replayable.

Scenarios compose with fault injection and service-time shaping: those act
on pool *progress* inside each ``Service``; the driver acts on *config*.
The same tick may carry both — the flap-during-scale regression in
tests/test_workload.py pins that composition.
"""

from __future__ import annotations

import dataclasses

from repro.runtime import elastic


@dataclasses.dataclass(frozen=True)
class Op:
    """One timed operation.  ``args`` are the operation's kwargs."""

    tick: int
    op: str
    hop: int = 0
    cluster: str = "pool"
    args: dict = dataclasses.field(default_factory=dict)


def rolling_restart(instances, *, start: int, dwell: int, gap: int | None
                    = None, hop: int = 0, cluster: str = "pool",
                    weight: float = 1.0) -> list[Op]:
    """Staggered restart: instance j drains at ``start + j·gap`` and
    returns at full weight ``dwell`` ticks later (gap defaults to dwell,
    so at most one instance is ever down)."""
    gap = dwell if gap is None else gap
    ops: list[Op] = []
    for j, inst in enumerate(instances):
        t = start + j * gap
        ops.append(Op(t, "drain", hop=hop, cluster=cluster,
                      args={"instance": inst}))
        ops.append(Op(t + dwell, "undrain", hop=hop, cluster=cluster,
                      args={"instance": inst, "weight": weight}))
    return ops


class ScenarioDriver:
    """Replay a scenario against the per-hop ControlPlanes.

    ``apply(tick)`` runs every op due at or before ``tick`` (in (tick,
    hop) order).  ``txns`` counts committed ControlPlane transactions and
    ``log`` is the audit trail — both deterministic, so a replayed
    scenario matches its first run exactly."""

    def __init__(self, cps, ops, *, max_instances: int | list | None = None):
        self.cps = list(cps)
        self.ops = sorted(ops, key=lambda o: (o.tick, o.hop))
        self._next = 0
        self.max_instances = max_instances
        self.txns = 0
        self.log: list[tuple] = []

    def done(self) -> bool:
        return self._next >= len(self.ops)

    def _cap(self, hop: int) -> int:
        if isinstance(self.max_instances, (list, tuple)):
            return int(self.max_instances[hop])
        if self.max_instances is None:
            raise ValueError("scale ops need max_instances (the pool's "
                             "instance-lane capacity)")
        return int(self.max_instances)

    def apply(self, tick: int) -> list[Op]:
        ran: list[Op] = []
        while self._next < len(self.ops) and self.ops[self._next].tick <= tick:
            op = self.ops[self._next]
            self._next += 1
            self._run(op, tick)
            ran.append(op)
        return ran

    # ------------------------------------------------------------------ #
    def _run(self, op: Op, tick: int) -> None:
        cp = self.cps[op.hop]
        v0 = cp.version
        a = op.args
        if op.op == "set_weight":
            cp.set_weight(op.cluster, a["instance"], a["weight"])
        elif op.op == "canary":
            self._canary(cp, op.cluster, a["instance"], a["pct"])
        elif op.op == "drain":
            cp.drain_endpoint(op.cluster, a["instance"])
        elif op.op == "undrain":
            self._undrain(cp, op.cluster, a["instance"],
                          a.get("weight", 1.0))
        elif op.op == "blue_green":
            self._blue_green(cp, op.cluster, a["blue"], a["green"])
        elif op.op == "scale":
            elastic.scale_fleet(cp, op.cluster, a["target"],
                                max_instances=self._cap(op.hop),
                                weight=a.get("weight", 1.0))
        elif op.op == "add_endpoint":
            cp.add_endpoint(op.cluster, a["instance"],
                            weight=a.get("weight", 1.0))
        else:
            raise ValueError(f"unknown scenario op {op.op!r}")
        self.txns += cp.version - v0
        # audit trail carries the post-op config version so a transport
        # replay can be checked op-for-op against the journal history
        self.log.append((tick, op.hop, op.op, cp.version, tuple(sorted(
            (k, tuple(v) if isinstance(v, list) else v)
            for k, v in a.items()))))

    @staticmethod
    def _undrain(cp, cluster: str, instance: int, weight: float) -> None:
        """Restore a drained endpoint.  If the reaper already removed the
        row (its in-flight load hit zero while drained — the normal end of
        a restart), the instance rejoins via ``add_endpoint``: same
        observable result, still one transaction."""
        if any(i == instance for _, i in cp.cluster_members(cluster)):
            cp.undrain_endpoint(cluster, instance, weight=weight)
        else:
            cp.add_endpoint(cluster, instance, weight=weight)

    @staticmethod
    def _canary(cp, cluster: str, instance: int, pct: float) -> None:
        """The canary takes ``pct``% of a WEIGHTED cluster's traffic; its
        *serving* peers split the remainder evenly.  Draining members are
        skipped — re-weighting one would silently cancel a pending
        operator drain as a side effect.  One transaction."""
        if cp.drain_reason(cluster, instance) is not None:
            raise ValueError(f"canary target {instance} in {cluster!r} "
                             "is draining")
        members = cp.cluster_members(cluster)
        peers = [i for _, i in members if i != instance
                 and cp.drain_reason(cluster, i) is None]
        if not peers:
            raise ValueError(f"canary needs peers in {cluster!r}")
        share = (100.0 - pct) / (100.0 * len(peers))
        with cp.transaction():
            cp.set_weight(cluster, instance, pct / 100.0)
            for p in peers:
                cp.set_weight(cluster, p, share)

    @staticmethod
    def _blue_green(cp, cluster: str, blue, green) -> None:
        """Cutover in one transaction: green to full weight (standby
        weight-0 endpoints go live), blue drained — new connections land
        on green this very tick, blue finishes its in-flight work and is
        reaped once its load hits zero."""
        with cp.transaction():
            for g in green:
                ScenarioDriver._undrain(cp, cluster, g, 1.0)
            for b in blue:
                cp.drain_endpoint(cluster, b)
