"""Trace-style workload generators: arrival processes + service-time laws.

The paper's evaluation regime is heavy traffic from very many clients —
not the uniform one-shot waves the early benchmarks drove.  This module
synthesizes that regime deterministically:

  * **Arrival processes** give the number of new requests per engine tick:
    ``PoissonArrivals`` (memoryless steady load), ``BurstyArrivals``
    (ON-OFF modulation — the flash-crowd / batch-job pattern), and
    ``DiurnalArrivals`` (a raised-cosine day curve).  All share a ``scale``
    knob that multiplies the offered rate, so one scenario definition
    sweeps from a smoke test toward the millions-of-users regime without
    changing shape.
  * **Service-time laws** give each request its occupancy in engine ticks:
    ``LognormalServiceTimes`` / ``ParetoServiceTimes`` (the heavy tails of
    real RPC latency) and ``FixedServiceTimes`` (the legacy deterministic
    setting).  ``ServiceTimeShaper`` enforces a sampled time on a live
    connection pool through the same progress-rollback model the fault
    injector uses — per *request* instead of per instance — so it works
    unchanged on the XLB jax pools and the sidecars' numpy pools.
  * ``Workload`` ties both to a request factory that emits
    ``RequestBatch``es any engine admits directly (diverse flow features,
    so hash-keyed policies see real key entropy).

Determinism contract: every draw is keyed — arrivals by ``(seed, tick)``,
service times by ``(seed, hop, req_id)``, features by ``(seed, req_id)`` —
never by call order.  Two runs of the same scenario produce bit-identical
request streams, which is what makes the chain/scenario rows in
BENCH_TREND.jsonl replayable and gateable.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.balancer import RequestBatch
from repro.core.routing_table import N_FEATURES


def _rng(*key: int) -> np.random.Generator:
    """A fresh PCG64 stream for one keyed draw — stateless, order-free."""
    return np.random.default_rng([int(k) & 0x7FFFFFFF for k in key])


# --------------------------------------------------------------------------- #
# Arrival processes
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Base: ``arrivals(tick)`` = Poisson draw at the process's rate(tick),
    scaled by ``scale`` and keyed by ``(seed, tick)``."""

    rate: float = 1.0
    scale: float = 1.0
    seed: int = 0

    def rate_at(self, tick: int) -> float:
        return self.rate

    def arrivals(self, tick: int) -> int:
        lam = self.rate_at(tick) * self.scale
        if lam <= 0.0:
            return 0
        return int(_rng(self.seed, tick).poisson(lam))


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Memoryless arrivals at a constant offered rate (requests/tick)."""


@dataclasses.dataclass(frozen=True)
class BurstyArrivals(ArrivalProcess):
    """ON-OFF modulated Poisson: ``on_ticks`` at ``rate``, then
    ``off_ticks`` at ``off_rate`` (default silent) — the flash-crowd
    stressor for admission capacity and the retry/backoff path."""

    on_ticks: int = 8
    off_ticks: int = 8
    off_rate: float = 0.0
    phase: int = 0

    def rate_at(self, tick: int) -> float:
        period = self.on_ticks + self.off_ticks
        return (self.rate if (tick + self.phase) % period < self.on_ticks
                else self.off_rate)


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Raised-cosine day curve between ``rate`` (trough) and ``peak`` over
    ``period`` ticks — the slow swell elastic scaling rides."""

    peak: float = 4.0
    period: int = 64

    def rate_at(self, tick: int) -> float:
        frac = 0.5 * (1.0 - math.cos(2.0 * math.pi * tick / self.period))
        return self.rate + (self.peak - self.rate) * frac


# --------------------------------------------------------------------------- #
# Service-time laws
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ServiceTimes:
    """Base: ``ticks(req_id, hop)`` = per-request occupancy in engine
    ticks, keyed by ``(seed, hop, req_id)`` — the same request re-sampled
    at a different hop draws independently."""

    seed: int = 0
    floor: int = 1
    cap: int = 64

    def _raw(self, rng: np.random.Generator) -> float:
        return float(self.floor)

    def ticks(self, req_id: int, hop: int = 0) -> int:
        raw = self._raw(_rng(self.seed, hop, req_id))
        return int(np.clip(round(raw), self.floor, self.cap))


@dataclasses.dataclass(frozen=True)
class FixedServiceTimes(ServiceTimes):
    """Every request takes exactly ``floor`` ticks (the legacy setting)."""


@dataclasses.dataclass(frozen=True)
class LognormalServiceTimes(ServiceTimes):
    """ticks ~ median · exp(sigma·Z) — the body of real RPC latency."""

    median: float = 2.0
    sigma: float = 0.8

    def _raw(self, rng) -> float:
        return self.median * math.exp(self.sigma * float(rng.normal()))


@dataclasses.dataclass(frozen=True)
class ParetoServiceTimes(ServiceTimes):
    """ticks ~ xm · (1-U)^(-1/alpha) — the heavy tail (alpha ≤ 2 has
    infinite variance; the ``cap`` bound keeps scenarios finite)."""

    xm: float = 1.0
    alpha: float = 1.5

    def _raw(self, rng) -> float:
        u = float(rng.random())
        return self.xm * (1.0 - u) ** (-1.0 / self.alpha)


class ServiceTimeShaper:
    """Enforce sampled per-request service times on a live pool.

    Same mechanism as ``runtime.serve_loop.FaultInjector`` — roll back
    ``pool.length`` so a decode step nets to zero progress — but keyed by
    *request* instead of instance: a request whose sampled time exceeds the
    fleet's base occupancy (``base_ticks``) is held for the difference, one
    rollback per extra tick.  A hold is only charged when it actually took
    effect (``length > 0``), so the delay is exact in ticks.  Works on
    both pool representations (numpy in-place, jax functional)."""

    def __init__(self, service: ServiceTimes, base_ticks: int, hop: int = 0):
        self.service = service
        self.base_ticks = base_ticks
        self.hop = hop
        self._rem: dict[int, int] = {}      # req_id → extra ticks left

    def _extra(self, rid: int) -> int:
        if rid not in self._rem:
            self._rem[rid] = max(
                0, self.service.ticks(rid, self.hop) - self.base_ticks)
        return self._rem[rid]

    def apply(self, pool, tick: int):
        req = np.asarray(pool.req_id)
        act = np.asarray(pool.active)
        length = np.asarray(pool.length)
        hold = np.zeros_like(act)
        for i, c in zip(*np.nonzero(act & (length > 0))):
            rid = int(req[i, c])
            if rid >= 0 and self._extra(rid) > 0:
                hold[i, c] = True
                self._rem[rid] -= 1
        if not hold.any():
            return pool
        if isinstance(pool.length, np.ndarray):
            pool.length[hold] -= 1
            return pool
        import jax.numpy as jnp
        return pool._replace(
            length=pool.length - jnp.asarray(hold).astype(jnp.int32))


# --------------------------------------------------------------------------- #
# The request factory
# --------------------------------------------------------------------------- #


@dataclasses.dataclass
class Workload:
    """One generated request stream: arrivals + service law + features.

    ``wave(tick, next_id)`` gives the req_ids arriving at ``tick`` (clipped
    to the ``n_requests`` budget); ``request_batch(ids, pad_to)`` packs
    them into an engine-admittable ``RequestBatch`` with per-flow feature
    entropy (hash-keyed policies select on these) and per-request prompt
    tokens.  ``vocab`` bounds the token ids like the bench harness does."""

    arrivals: ArrivalProcess
    service: ServiceTimes | None = None
    n_requests: int | None = None
    seed: int = 0
    vocab: int = 256

    def wave(self, tick: int, next_id: int) -> list[int]:
        n = self.arrivals.arrivals(tick)
        if self.n_requests is not None:
            n = min(n, self.n_requests - next_id)
        return list(range(next_id, next_id + max(0, n)))

    def features(self, req_id: int) -> np.ndarray:
        f = _rng(self.seed, req_id).integers(
            0, 1 << 30, size=(N_FEATURES,), dtype=np.int64)
        return f.astype(np.int32)

    def request_batch(self, req_ids, pad_to: int) -> RequestBatch:
        import jax.numpy as jnp
        rid = np.full((pad_to,), -1, np.int32)
        svc = np.zeros((pad_to,), np.int32)
        feats = np.zeros((pad_to, N_FEATURES), np.int32)
        tok = np.zeros((pad_to,), np.int32)
        nbytes = np.full((pad_to,), 128, np.int32)
        n = min(len(req_ids), pad_to)
        for i in range(n):
            r = int(req_ids[i])
            rid[i] = r
            feats[i] = self.features(r)
            tok[i] = 3 + r % max(1, self.vocab - 3)
        return RequestBatch(
            req_id=jnp.asarray(rid), svc=jnp.asarray(svc),
            features=jnp.asarray(feats), token=jnp.asarray(tok),
            msg_bytes=jnp.asarray(nbytes))

    def shaper(self, base_ticks: int, hop: int = 0):
        """A per-hop ServiceTimeShaper (None when the law is fixed/absent —
        the pool's own length-driven completion already enforces it)."""
        if self.service is None or isinstance(self.service,
                                              FixedServiceTimes):
            return None
        return ServiceTimeShaper(self.service, base_ticks, hop=hop)
