"""SLO tail reporting: percentile tables from per-request tick samples +
the validated BENCH_TREND.jsonl scenario-row schema.

Latency samples are *engine ticks* (admit tick → done tick), the
deterministic clock every scenario runs on — immune to host jitter, so the
same seed reproduces the same row bit-for-bit and the chain gate can
compare engines exactly.  Wall-clock numbers are advisory and never enter
a scenario row.

A scenario row is the one record format every workload driver appends to
BENCH_TREND.jsonl (``bench: "scenario"``).  ``validate_scenario_row``
rejects malformed rows *before* they reach the append-only trend file —
a schema break fails the producing run, not a later reader.
"""

from __future__ import annotations

import json
import time

import numpy as np

PCTS = (50.0, 99.0, 99.9)

# Required fields of a BENCH_TREND scenario row and their types.  ``ts`` and
# ``commit`` are stamped at append time and excluded from the deterministic
# payload (replay tests compare rows without them).
SCENARIO_ROW_REQUIRED = {
    "bench": str, "scenario": str, "mode": str, "depth": int, "seed": int,
    "arrivals": str, "n_requests": int, "completed": int, "dropped": int,
    "ticks": int, "p50_ticks": float, "p99_ticks": float,
    "p999_ticks": float,
}
SCENARIO_ROW_OPTIONAL = {
    "service": str, "scale": float, "ops": int, "txns": int,
    "held_first": int, "rate": float, "shards": int,
    "mean_ticks": float, "per_hop_p99_ticks": list,
}


def percentiles(samples) -> dict:
    """p50/p99/p999 (+ mean, n) of a latency sample set, NaN when empty."""
    xs = np.asarray(list(samples), np.float64)
    if xs.size == 0:
        return {"n": 0, "mean": float("nan"), "p50": float("nan"),
                "p99": float("nan"), "p999": float("nan")}
    p50, p99, p999 = (float(np.percentile(xs, p)) for p in PCTS)
    return {"n": int(xs.size), "mean": float(xs.mean()),
            "p50": p50, "p99": p99, "p999": p999}


def scenario_row(scenario: str, mode: str, *, depth: int, seed: int,
                 arrivals: str, n_requests: int, completed: int,
                 dropped: int, ticks: int, samples, **extra) -> dict:
    """Build a canonical (deterministic, schema-valid) scenario row from
    raw end-to-end tick samples.  Extra fields must be in the optional
    schema — unknown keys are a validation error, not silent baggage."""
    p = percentiles(samples)
    row = {"bench": "scenario", "scenario": scenario, "mode": mode,
           "depth": int(depth), "seed": int(seed), "arrivals": arrivals,
           "n_requests": int(n_requests), "completed": int(completed),
           "dropped": int(dropped), "ticks": int(ticks),
           "p50_ticks": p["p50"], "p99_ticks": p["p99"],
           "p999_ticks": p["p999"], "mean_ticks": p["mean"]}
    row.update(extra)
    validate_scenario_row(row)
    return row


def validate_scenario_row(row: dict) -> None:
    """Raise ValueError on any schema violation (missing/extra/mistyped
    fields, impossible counts, unordered percentiles)."""
    errs = []
    for k, t in SCENARIO_ROW_REQUIRED.items():
        if k not in row:
            errs.append(f"missing field {k!r}")
        elif t is float:
            if not isinstance(row[k], (int, float)) \
                    or isinstance(row[k], bool):
                errs.append(f"field {k!r} wants float, got "
                            f"{type(row[k]).__name__}")
        elif not isinstance(row[k], t) or isinstance(row[k], bool):
            errs.append(f"field {k!r} wants {t.__name__}, got "
                        f"{type(row[k]).__name__}")
    allowed = (set(SCENARIO_ROW_REQUIRED) | set(SCENARIO_ROW_OPTIONAL)
               | {"ts", "commit"})
    for k in row:
        if k not in allowed:
            errs.append(f"unknown field {k!r}")
        elif k in SCENARIO_ROW_OPTIONAL:
            t = SCENARIO_ROW_OPTIONAL[k]
            ok = isinstance(row[k], (int, float)) if t is float \
                else isinstance(row[k], t)
            if not ok or isinstance(row[k], bool):
                errs.append(f"field {k!r} wants {t.__name__}, got "
                            f"{type(row[k]).__name__}")
    if not errs:
        if row["bench"] != "scenario":
            errs.append(f'bench must be "scenario", got {row["bench"]!r}')
        if row["completed"] + row["dropped"] > row["n_requests"]:
            errs.append("completed + dropped exceeds n_requests")
        ps = [row["p50_ticks"], row["p99_ticks"], row["p999_ticks"]]
        fin = [p for p in ps if not np.isnan(p)]
        if fin != sorted(fin):
            errs.append("percentiles not monotone (p50 <= p99 <= p999)")
    if errs:
        raise ValueError("invalid scenario row: " + "; ".join(errs))


def _git_commit() -> str:
    import subprocess
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True,
                              timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def append_scenario_row(row: dict, path: str = "BENCH_TREND.jsonl") -> dict:
    """Validate, stamp (ts, commit), and append one scenario row to the
    trend file.  Returns the stamped row."""
    validate_scenario_row(row)
    stamped = {"ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
               "commit": _git_commit()}
    stamped.update(row)
    with open(path, "a") as f:
        f.write(json.dumps(stamped) + "\n")
    return stamped


def format_slo_table(rows: list[dict]) -> str:
    """Markdown SLO table for a list of scenario rows (make_report.py)."""
    lines = ["| scenario | mode | depth | arrivals | done/req | "
             "p50 | p99 | p999 (ticks) |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['scenario']} | {r['mode']} | {r['depth']} | "
            f"{r['arrivals']} | {r['completed']}/{r['n_requests']} | "
            f"{r['p50_ticks']:.1f} | {r['p99_ticks']:.1f} | "
            f"{r['p999_ticks']:.1f} |")
    return "\n".join(lines)
