"""SLO tail reporting: percentile tables from per-request tick samples +
the validated BENCH_TREND.jsonl scenario-row schema.

Latency samples are *engine ticks* (admit tick → done tick), the
deterministic clock every scenario runs on — immune to host jitter, so the
same seed reproduces the same row bit-for-bit and the chain gate can
compare engines exactly.  Wall-clock numbers are advisory and never enter
a scenario row.

A scenario row is the one record format every workload driver appends to
BENCH_TREND.jsonl (``bench: "scenario"``).  ``validate_scenario_row``
rejects malformed rows *before* they reach the append-only trend file —
a schema break fails the producing run, not a later reader.
"""

from __future__ import annotations

import json
import time

import numpy as np

PCTS = (50.0, 99.0, 99.9)

# Required fields of a BENCH_TREND scenario row and their types.  ``ts`` and
# ``commit`` are stamped at append time and excluded from the deterministic
# payload (replay tests compare rows without them).
SCENARIO_ROW_REQUIRED = {
    "bench": str, "scenario": str, "mode": str, "depth": int, "seed": int,
    "arrivals": str, "n_requests": int, "completed": int, "dropped": int,
    "ticks": int, "p50_ticks": float, "p99_ticks": float,
    "p999_ticks": float,
}
SCENARIO_ROW_OPTIONAL = {
    "service": str, "scale": float, "ops": int, "txns": int,
    "held_first": int, "rate": float, "shards": int,
    "mean_ticks": float, "per_hop_p99_ticks": list,
    "health_txns": int, "end_weights": list,
}

# The chaos-bench row (``bench: "chaos"``): one transport-chaos run —
# workload SLO windows + channel/consumer protocol counters + the
# convergence verdict.  Same validate-before-append discipline.
CHAOS_ROW_REQUIRED = {
    "bench": str, "scenario": str, "mode": str, "seed": int,
    "n_requests": int, "completed": int, "dropped": int, "ticks": int,
    "flush_ticks": int, "versions": int, "consumers": int,
    "resyncs": int, "crashes": int, "converged": bool,
    "healthy_p99_ticks": float, "chaos_p99_ticks": float,
    "recovered_p99_ticks": float, "recovery_ratio": float,
    "msgs_sent": int, "msgs_dropped": int, "msgs_duped": int,
    "msgs_delivered": int,
}
CHAOS_ROW_OPTIONAL = {
    "msgs_partitioned": int, "stale": int, "held": int, "rejected": int,
    "plan_sends": int, "snap_sends": int, "ops": int, "txns": int,
    "rate": float, "baseline_p99_ticks": float,
}


def percentiles(samples) -> dict:
    """p50/p99/p999 (+ mean, n) of a latency sample set, NaN when empty."""
    xs = np.asarray(list(samples), np.float64)
    if xs.size == 0:
        return {"n": 0, "mean": float("nan"), "p50": float("nan"),
                "p99": float("nan"), "p999": float("nan")}
    p50, p99, p999 = (float(np.percentile(xs, p)) for p in PCTS)
    return {"n": int(xs.size), "mean": float(xs.mean()),
            "p50": p50, "p99": p99, "p999": p999}


def scenario_row(scenario: str, mode: str, *, depth: int, seed: int,
                 arrivals: str, n_requests: int, completed: int,
                 dropped: int, ticks: int, samples, **extra) -> dict:
    """Build a canonical (deterministic, schema-valid) scenario row from
    raw end-to-end tick samples.  Extra fields must be in the optional
    schema — unknown keys are a validation error, not silent baggage."""
    p = percentiles(samples)
    row = {"bench": "scenario", "scenario": scenario, "mode": mode,
           "depth": int(depth), "seed": int(seed), "arrivals": arrivals,
           "n_requests": int(n_requests), "completed": int(completed),
           "dropped": int(dropped), "ticks": int(ticks),
           "p50_ticks": p["p50"], "p99_ticks": p["p99"],
           "p999_ticks": p["p999"], "mean_ticks": p["mean"]}
    row.update(extra)
    validate_scenario_row(row)
    return row


def _type_errs(row: dict, required: dict, optional: dict) -> list[str]:
    """Field-presence + type errors for one row schema.  ``bool`` fields
    accept only bool; ``float`` fields accept int-or-float (never bool)."""
    def ok(v, t):
        if t is bool:
            return isinstance(v, bool)
        if isinstance(v, bool):
            return False
        if t is float:
            return isinstance(v, (int, float))
        return isinstance(v, t)

    errs = []
    for k, t in required.items():
        if k not in row:
            errs.append(f"missing field {k!r}")
        elif not ok(row[k], t):
            errs.append(f"field {k!r} wants {t.__name__}, got "
                        f"{type(row[k]).__name__}")
    allowed = set(required) | set(optional) | {"ts", "commit"}
    for k in row:
        if k not in allowed:
            errs.append(f"unknown field {k!r}")
        elif k in optional and not ok(row[k], optional[k]):
            errs.append(f"field {k!r} wants {optional[k].__name__}, got "
                        f"{type(row[k]).__name__}")
    return errs


def validate_scenario_row(row: dict) -> None:
    """Raise ValueError on any schema violation (missing/extra/mistyped
    fields, impossible counts, unordered percentiles)."""
    errs = _type_errs(row, SCENARIO_ROW_REQUIRED, SCENARIO_ROW_OPTIONAL)
    if not errs:
        if row["bench"] != "scenario":
            errs.append(f'bench must be "scenario", got {row["bench"]!r}')
        if row["completed"] + row["dropped"] > row["n_requests"]:
            errs.append("completed + dropped exceeds n_requests")
        ps = [row["p50_ticks"], row["p99_ticks"], row["p999_ticks"]]
        fin = [p for p in ps if not np.isnan(p)]
        if fin != sorted(fin):
            errs.append("percentiles not monotone (p50 <= p99 <= p999)")
    if errs:
        raise ValueError("invalid scenario row: " + "; ".join(errs))


def chaos_row(scenario: str, mode: str, *, seed: int, **fields) -> dict:
    """Build a validated ``bench="chaos"`` trend row (run_chaos output)."""
    row = {"bench": "chaos", "scenario": scenario, "mode": mode,
           "seed": int(seed)}
    row.update(fields)
    validate_chaos_row(row)
    return row


def validate_chaos_row(row: dict) -> None:
    """Raise ValueError on any chaos-row schema violation.  A
    non-converged run still validates — the row records the truth; the
    chaos *gate* (benchmarks/run.py) is what fails on it."""
    errs = _type_errs(row, CHAOS_ROW_REQUIRED, CHAOS_ROW_OPTIONAL)
    if not errs:
        if row["bench"] != "chaos":
            errs.append(f'bench must be "chaos", got {row["bench"]!r}')
        if row["completed"] + row["dropped"] > row["n_requests"]:
            errs.append("completed + dropped exceeds n_requests")
        for k in ("versions", "consumers", "resyncs", "crashes",
                  "msgs_sent", "msgs_dropped", "msgs_duped",
                  "msgs_delivered"):
            if row[k] < 0:
                errs.append(f"field {k!r} negative")
        if row["msgs_delivered"] > row["msgs_sent"] + row["msgs_duped"]:
            errs.append("delivered exceeds sent + duplicated")
        if not np.isnan(row["recovery_ratio"]) and row["recovery_ratio"] < 0:
            errs.append("recovery_ratio negative")
    if errs:
        raise ValueError("invalid chaos row: " + "; ".join(errs))


_VALIDATORS = {"scenario": validate_scenario_row,
               "chaos": validate_chaos_row}


def _git_commit() -> str:
    import subprocess
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True,
                              timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def append_scenario_row(row: dict, path: str = "BENCH_TREND.jsonl") -> dict:
    """Validate, stamp (ts, commit), and append one trend row (scenario
    or chaos — dispatched on ``bench``).  Returns the stamped row."""
    validator = _VALIDATORS.get(row.get("bench"))
    if validator is None:
        raise ValueError(f"no validator for bench {row.get('bench')!r}")
    validator(row)
    stamped = {"ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
               "commit": _git_commit()}
    stamped.update(row)
    with open(path, "a") as f:
        f.write(json.dumps(stamped) + "\n")
    return stamped


def format_slo_table(rows: list[dict]) -> str:
    """Markdown SLO table for a list of scenario rows (make_report.py)."""
    lines = ["| scenario | mode | depth | arrivals | done/req | "
             "p50 | p99 | p999 (ticks) |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['scenario']} | {r['mode']} | {r['depth']} | "
            f"{r['arrivals']} | {r['completed']}/{r['n_requests']} | "
            f"{r['p50_ticks']:.1f} | {r['p99_ticks']:.1f} | "
            f"{r['p999_ticks']:.1f} |")
    return "\n".join(lines)
