"""SLO tail reporting: percentile tables from per-request tick samples +
the validated BENCH_TREND.jsonl scenario-row schema.

Latency samples are *engine ticks* (admit tick → done tick), the
deterministic clock every scenario runs on — immune to host jitter, so the
same seed reproduces the same row bit-for-bit and the chain gate can
compare engines exactly.  Wall-clock numbers are advisory and never enter
a scenario row.

A scenario row is the one record format every workload driver appends to
BENCH_TREND.jsonl (``bench: "scenario"``).  ``validate_scenario_row``
rejects malformed rows *before* they reach the append-only trend file —
a schema break fails the producing run, not a later reader.

The schemas themselves live in :mod:`repro.analysis.invariants` — one
declarative field-spec engine shared with the plan wire format — and this
module re-exports the public names every driver (and test) imports.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.analysis.invariants import (CHAOS_ROW_OPTIONAL,  # noqa: F401
                                       CHAOS_ROW_REQUIRED,
                                       SCENARIO_ROW_OPTIONAL,
                                       SCENARIO_ROW_REQUIRED, validate_row)

PCTS = (50.0, 99.0, 99.9)


def percentiles(samples) -> dict:
    """p50/p99/p999 (+ mean, n) of a latency sample set, NaN when empty."""
    xs = np.asarray(list(samples), np.float64)
    if xs.size == 0:
        return {"n": 0, "mean": float("nan"), "p50": float("nan"),
                "p99": float("nan"), "p999": float("nan")}
    p50, p99, p999 = (float(np.percentile(xs, p)) for p in PCTS)
    return {"n": int(xs.size), "mean": float(xs.mean()),
            "p50": p50, "p99": p99, "p999": p999}


def scenario_row(scenario: str, mode: str, *, depth: int, seed: int,
                 arrivals: str, n_requests: int, completed: int,
                 dropped: int, ticks: int, samples, **extra) -> dict:
    """Build a canonical (deterministic, schema-valid) scenario row from
    raw end-to-end tick samples.  Extra fields must be in the optional
    schema — unknown keys are a validation error, not silent baggage."""
    p = percentiles(samples)
    row = {"bench": "scenario", "scenario": scenario, "mode": mode,
           "depth": int(depth), "seed": int(seed), "arrivals": arrivals,
           "n_requests": int(n_requests), "completed": int(completed),
           "dropped": int(dropped), "ticks": int(ticks),
           "p50_ticks": p["p50"], "p99_ticks": p["p99"],
           "p999_ticks": p["p999"], "mean_ticks": p["mean"]}
    row.update(extra)
    validate_scenario_row(row)
    return row


def validate_scenario_row(row: dict) -> None:
    """Raise ValueError on any schema violation (missing/extra/mistyped
    fields, impossible counts, unordered percentiles)."""
    validate_row(row, "scenario")


def chaos_row(scenario: str, mode: str, *, seed: int, **fields) -> dict:
    """Build a validated ``bench="chaos"`` trend row (run_chaos output)."""
    row = {"bench": "chaos", "scenario": scenario, "mode": mode,
           "seed": int(seed)}
    row.update(fields)
    validate_chaos_row(row)
    return row


def validate_chaos_row(row: dict) -> None:
    """Raise ValueError on any chaos-row schema violation.  A
    non-converged run still validates — the row records the truth; the
    chaos *gate* (benchmarks/run.py) is what fails on it."""
    validate_row(row, "chaos")


_VALIDATORS = {"scenario": validate_scenario_row,
               "chaos": validate_chaos_row}


def _git_commit() -> str:
    import subprocess
    try:
        return subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                              capture_output=True, text=True,
                              timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def append_scenario_row(row: dict, path: str = "BENCH_TREND.jsonl") -> dict:
    """Validate, stamp (ts, commit), and append one trend row (scenario
    or chaos — dispatched on ``bench``).  Returns the stamped row."""
    validator = _VALIDATORS.get(row.get("bench"))
    if validator is None:
        raise ValueError(f"no validator for bench {row.get('bench')!r}")
    validator(row)
    stamped = {"ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
               "commit": _git_commit()}
    stamped.update(row)
    with open(path, "a") as f:
        f.write(json.dumps(stamped) + "\n")
    return stamped


def format_slo_table(rows: list[dict]) -> str:
    """Markdown SLO table for a list of scenario rows (make_report.py)."""
    lines = ["| scenario | mode | depth | arrivals | done/req | "
             "p50 | p99 | p999 (ticks) |",
             "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['scenario']} | {r['mode']} | {r['depth']} | "
            f"{r['arrivals']} | {r['completed']}/{r['n_requests']} | "
            f"{r['p50_ticks']:.1f} | {r['p99_ticks']:.1f} | "
            f"{r['p999_ticks']:.1f} |")
    return "\n".join(lines)
