"""Chained-service traversal: the paper's long-service-chain regime.

A depth-D chain is D independent service fleets, each behind its own
balancer; a request completing at service k is *synchronously* admitted at
service k+1 — same global tick, so the forwarding itself is free and the
measured end-to-end latency is exactly the sum of the per-hop admit→done
tick latencies.  The balancer is traversed once per hop: this is the
regime where per-hop sidecar interposition compounds (PAPERS.md, *Sidecars
on the Central Lane*) and where the in-graph datapath must at least hold
even — the chain gate in benchmarks/run.py pins that.

``ChainRunner`` is engine-agnostic: a hop is anything with the small
service-fleet protocol ``submit(ids)`` / ``tick() -> finished ids`` /
``busy`` / ``dropped`` (``benchmarks.common.Service`` for all three
engines).  Per-request chain position lives in ``ChainRunner.position``
and advances only on completion-forwarding, so a held or retried request
keeps its hop.  Live-ops scenarios (``scenarios.ScenarioDriver``) apply at
the top of every global tick, before any hop runs — an operator
transaction at tick T is visible to every admission at tick T.

All bookkeeping is in deterministic engine ticks; wall time is recorded
but advisory.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.analysis.invariants import assert_host, sanitize_enabled


@dataclasses.dataclass
class ChainResult:
    """Everything one chain run measured, in ticks."""

    depth: int
    completed: int
    dropped: int
    ticks: int
    wall_s: float
    submit_tick: dict            # req_id → tick entering hop 0
    done_tick: dict              # req_id → tick completing the last hop
    hop_submit: list             # [hop] req_id → tick admitted at hop
    hop_done: list               # [hop] req_id → tick completed at hop
    n_submitted: int = 0

    def samples(self) -> np.ndarray:
        """End-to-end latency samples (ticks) for every completed request."""
        return np.array([self.done_tick[r] - self.submit_tick[r]
                         for r in sorted(self.done_tick)], np.int64)

    def hop_samples(self, k: int) -> np.ndarray:
        """Per-hop admit→done tick samples at hop ``k``."""
        return np.array([self.hop_done[k][r] - self.hop_submit[k][r]
                         for r in sorted(self.hop_done[k])], np.int64)


class ChainRunner:
    """Drive a workload through a chain of service fleets."""

    def __init__(self, hops, workload, *, scenario=None, on_tick=None,
                 max_ticks: int = 4000, drain_ticks: int = 2000):
        self.hops = list(hops)
        self.workload = workload
        self.scenario = scenario
        self.on_tick = on_tick       # called with the tick after hops run
        self.max_ticks = max_ticks
        self.drain_ticks = drain_ticks
        self.position: dict[int, int] = {}   # req_id → current hop

    def run(self) -> ChainResult:
        D = len(self.hops)
        submit_tick: dict[int, int] = {}
        done_tick: dict[int, int] = {}
        hop_submit = [dict() for _ in range(D)]
        hop_done = [dict() for _ in range(D)]
        next_id = 0
        tick = 0
        idle_budget = self.drain_ticks
        t0 = time.perf_counter()
        while tick < self.max_ticks:
            if self.scenario is not None:
                self.scenario.apply(tick)
            wave = self.workload.wave(tick, next_id)
            next_id += len(wave)
            for r in wave:
                submit_tick[r] = tick
                hop_submit[0][r] = tick
                self.position[r] = 0
            if wave:
                self.hops[0].submit(wave)
            any_busy = False
            for k, hop in enumerate(self.hops):
                if not hop.busy:                 # event-driven: idle hops
                    continue                     # launch no program
                any_busy = True
                finished = hop.tick()
                for r in finished:
                    hop_done[k][r] = tick
                if k + 1 < D:
                    for r in finished:
                        hop_submit[k + 1][r] = tick
                        self.position[r] = k + 1
                    if finished:
                        self.hops[k + 1].submit(finished)
                else:
                    for r in finished:
                        done_tick[r] = tick
                        self.position.pop(r, None)
            if self.on_tick is not None:     # daemon seam: health epochs,
                self.on_tick(tick)           # transport pumps, chaos probes
            if sanitize_enabled():
                assert_host("chain", dict(
                    positions=list(self.position.values()), depth=D,
                    positions_ids=list(self.position), done_ids=done_tick))
            tick += 1
            exhausted = (self.workload.n_requests is not None
                         and next_id >= self.workload.n_requests)
            if exhausted and not any_busy \
                    and (self.scenario is None or self.scenario.done()):
                break
            if exhausted and not any_busy:
                idle_budget -= 1                 # scenario tail still pending
                if idle_budget <= 0:
                    break
        dropped = sum(len(h.dropped) for h in self.hops)
        return ChainResult(depth=D, completed=len(done_tick),
                           dropped=dropped, ticks=tick,
                           wall_s=time.perf_counter() - t0,
                           submit_tick=submit_tick, done_tick=done_tick,
                           hop_submit=hop_submit, hop_done=hop_done,
                           n_submitted=next_id)
