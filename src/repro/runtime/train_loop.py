"""Fault-tolerant training loop.

Large-scale runnability features (graded surface):
  * checkpoint/restart: atomic async checkpoints every ``ckpt_every`` steps;
    on any step failure the loop restores the last checkpoint and replays —
    the step-indexed pipeline regenerates identical batches.
  * straggler mitigation: per-step deadline watchdog; a step exceeding
    ``straggler_factor``× the trailing-median wall time is recorded and (on a
    real multi-host fleet) would trigger the slow host's eviction — here the
    hook logs and continues (single-process container).
  * MoE least-request bias (XLB policy) updated outside autodiff each step.
  * optional grad accumulation (microbatching) for the big-arch memory knee.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.transformer import RunCtx
from repro.optim import adamw, schedules
from repro.runtime.checkpoint import Checkpointer


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro-ckpt"
    microbatch: int = 0              # 0 = no accumulation
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    warmup: int = 20
    straggler_factor: float = 3.0
    log_every: int = 10


def make_train_step(cfg: ModelConfig, ctx: RunCtx, tcfg: TrainConfig,
                    donate: bool = True):
    """Build the jitted train step: fwd+bwd (+accumulation) + AdamW + bias."""

    def loss(params, batch):
        return M.loss_fn(cfg, params, batch, ctx=ctx)

    def step_fn(params, opt_state, router_bias, batch):
        if tcfg.microbatch > 1:
            def micro(carry, mb):
                (gacc, lacc) = carry
                (l, aux), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, gacc, g), lacc + l), aux
            B = jax.tree.leaves(batch)[0].shape[0]
            mbs = jax.tree.map(
                lambda a: a.reshape((tcfg.microbatch, B // tcfg.microbatch)
                                    + a.shape[1:]), batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, ltot), auxs = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / tcfg.microbatch, grads)
            aux = jax.tree.map(lambda a: a[-1], auxs)
            lval = ltot / tcfg.microbatch
        else:
            (lval, aux), grads = jax.value_and_grad(loss, has_aux=True)(
                params, batch)
        lr_scale = schedules.warmup_cosine(opt_state.step, warmup=tcfg.warmup,
                                           total=tcfg.steps)
        params, opt_state, stats = adamw.apply(params, grads, opt_state,
                                               tcfg.opt, lr_scale)
        router_bias = adamw.update_router_bias(router_bias,
                                               aux["expert_load"])
        metrics = {"loss": lval, **stats, "overflow": aux["overflow"]}
        return params, opt_state, router_bias, metrics

    return jax.jit(step_fn, donate_argnums=(0, 1, 2) if donate else ())


def run(cfg: ModelConfig, pipeline, tcfg: TrainConfig,
        ctx: RunCtx = None, params=None, key=None,
        fail_injector: Optional[Callable[[int], None]] = None) -> dict:
    """The driver loop with checkpoint/restart + straggler watchdog.

    ``fail_injector(step)`` may raise to simulate node failure (tests use it);
    the loop restores and replays.
    """
    ctx = ctx or RunCtx()
    key = key if key is not None else jax.random.PRNGKey(0)
    if params is None:
        params = M.init_params(cfg, key)
    opt_state = adamw.init(params)
    router_bias = jnp.zeros((max(cfg.moe.n_experts, 1),), jnp.float32)
    ckpt = Checkpointer(tcfg.ckpt_dir)
    train_step = make_train_step(cfg, ctx, tcfg, donate=False)

    state = {"params": params, "opt": opt_state, "bias": router_bias}
    start = 0
    if ckpt.latest_step() is not None:
        state, start = ckpt.restore(state)
        print(f"[train] restored checkpoint step={start}")

    history, durations = [], []
    step = start
    restarts = 0
    while step < tcfg.steps:
        try:
            batch = jax.tree.map(jnp.asarray, pipeline.batch_at(step))
            t0 = time.perf_counter()
            if fail_injector is not None:
                fail_injector(step)
            p, o, b, metrics = train_step(state["params"], state["opt"],
                                          state["bias"], batch)
            metrics = jax.tree.map(float, metrics)
            dt = time.perf_counter() - t0
            state = {"params": p, "opt": o, "bias": b}
            durations.append(dt)
            med = float(np.median(durations[-20:]))
            if len(durations) > 5 and dt > tcfg.straggler_factor * med:
                print(f"[train] straggler: step {step} took {dt:.3f}s "
                      f"(median {med:.3f}s) — would evict/reschedule host")
            history.append({"step": step, **metrics, "wall_s": dt})
            if step % tcfg.log_every == 0:
                print(f"[train] step {step} loss={metrics['loss']:.4f} "
                      f"gnorm={metrics['grad_norm']:.3f} {dt*1e3:.0f}ms")
            step += 1
            if step % tcfg.ckpt_every == 0 or step == tcfg.steps:
                ckpt.save(step, state)
        except KeyboardInterrupt:
            raise
        except Exception as e:                 # node failure → restore+replay
            restarts += 1
            print(f"[train] step {step} failed ({type(e).__name__}: {e}); "
                  f"restoring last checkpoint")
            if restarts > 10:
                raise
            last = ckpt.latest_step()
            if last is None:
                state = {"params": M.init_params(cfg, key),
                         "opt": adamw.init(params), "bias": router_bias}
                step = 0
            else:
                ckpt.wait()
                state, step = ckpt.restore(state)
    ckpt.wait()
    return {"history": history, "state": state, "restarts": restarts}
