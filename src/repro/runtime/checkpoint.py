"""Checkpointing: atomic manifests, async writes, reshard-on-restore.

Fault-tolerance contract (DESIGN.md §5):
  * A checkpoint is only *visible* once its manifest is atomically renamed in
    place — a job killed mid-write can never restore a torn checkpoint.
  * Writes happen on a background thread (training continues; the arrays are
    snapshotted to host first).
  * Restore takes target *shardings*: the same checkpoint restores onto a
    different mesh (elastic scaling) — leaves are laid out by NamedSharding at
    device_put time, so dp-degree changes are free.
  * Leaf addressing is by flattened key-path, so partial restores (e.g. params
    but not optimizer state) and schema evolution are possible.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "fiub":      # ml_dtypes (bf16) → fp32 widen
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Snapshot to host, then write+rename on a background thread."""
        host = _flatten(tree)                  # device→host copy happens here
        if self._thread is not None:
            self._thread.join()                # one outstanding write max

        def write():
            tmp = tempfile.mkdtemp(dir=self.dir, prefix=f".tmp-{step}-")
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            manifest = {"step": step, "time": time.time(),
                        "keys": sorted(host), "format": 1}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(self.dir, f"step-{step:09d}")
            os.rename(tmp, final)              # atomic visibility
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self._thread.join()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step-{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------ #
    def list_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step-") and os.path.exists(
                    os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d.split("-")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Restore into the structure of ``tree_like``; place leaves by
        ``shardings`` (pytree of NamedSharding) if given — this is the
        elastic-rescale path."""
        step = step if step is not None else self.latest_step()
        assert step is not None, "no checkpoint found"
        path = os.path.join(self.dir, f"step-{step:09d}")
        data = np.load(os.path.join(path, "arrays.npz"))
        flat = jax.tree_util.tree_flatten_with_path(tree_like)
        leaves = []
        shard_flat = (jax.tree.leaves(shardings) if shardings is not None
                      else [None] * len(flat[0]))
        for (kp, like), sh in zip(flat[0], shard_flat):
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in kp)
            arr = data[key]
            assert arr.shape == like.shape, (key, arr.shape, like.shape)
            arr = arr.astype(like.dtype)
            leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(flat[1], leaves), step
