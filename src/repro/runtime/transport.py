"""Fault-tolerant plan transport — shipping RefreshPlans over a channel
that drops, reorders, duplicates, partitions, and loses whole consumers.

The paper's control daemon retargets in-kernel maps from userspace; in any
real deployment those two halves talk over a network that fails.  This
module is that network plus the protocol that survives it:

  * :class:`LossyChannel` — a seeded, deterministic message channel in the
    spirit of ``serve_loop.FaultInjector``: per-message fate (drop /
    duplicate / random delay → reorder) is drawn from a keyed RNG, and
    :class:`ChannelFault` windows model partitions (every send inside the
    window is lost).  Same seed → same fate for every message, so any chaos
    schedule replays bit-identically.
  * :class:`RemoteConsumer` — the far end.  Applies packed plans
    *idempotently keyed by version*: a plan carries ``base_version`` and
    ``version``; it applies iff ``base_version`` equals the consumer's
    current version (out-of-order plans are held and chained once the gap
    closes), duplicates and stale versions are no-ops, and a snapshot
    message resyncs the full config (load-preserving: rows are matched by
    (cluster, instance) against the live state).  Heartbeats — carrying the
    consumer's applied version and its live ``ep_load`` vote for the drain
    reaper — ride the same lossy channel, so the PR 6 lease reaper and the
    transport agree on who is alive.
  * :class:`PlanPublisher` — the ControlPlane end.  Attaches one proxy per
    registered node (so commits fan out into the cp's bounded plan
    *journal* and the reaper sees each node's last-reported load), tracks
    per-node acks from heartbeats, and retries unacked suffixes with the
    ServeLoop capped-exponential backoff shape.  A node whose ack predates
    the journal floor — or that rejoined at version -1 after a crash —
    gets a full ``packed_snapshot`` resync.  A node whose liveness lease
    expired gets nothing until its heartbeats return (rejoin → resync,
    re-lease, resume).
  * :func:`convergence_report` / :meth:`Transport.assert_converged` — the
    invariant checker: after any chaos schedule every live consumer's
    RoutingState config must be bit-exact with ``cp.snapshot()``, its
    version must equal ``cp.version``, and its applied-version history must
    be strictly monotone with contiguous plan chaining (no lost bumps; a
    jump is only ever a counted resync).

Everything is tick-driven and seeded — no wall clock, no global RNG — so
the chaos benchmark's convergence gate replays byte-identically.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.core import control
from repro.core.routing_table import (MAX_CLUSTERS, MAX_ENDPOINTS,
                                      RoutingState, empty_state)

#: channel address of the publisher (heartbeats go here)
CP_NODE = "cp"

# the wire fields a snapshot message carries (full config, no permutation)
_SNAP_FIELDS = control.CONFIG_FIELDS


# --------------------------------------------------------------------------- #
# The lossy channel
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class ChannelFault:
    """A partition window: every message sent in ``[start, end)`` to ``dst``
    (or to anyone, if ``dst`` is None) is lost.  Heartbeats *from* a node
    are messages to :data:`CP_NODE` — partition both directions by listing
    two faults."""

    start: int
    end: int
    dst: str | None = None

    def hits(self, dst: str, tick: int) -> bool:
        return (self.start <= tick < self.end
                and (self.dst is None or self.dst == dst))


class LossyChannel:
    """Seeded lossy datagram channel.  Message fate (drop / duplicate /
    delay) is drawn from ``default_rng((seed, send_seq))`` at send time, so
    a replay with the same seed and the same send sequence is bit-exact.
    Random per-copy delays produce reordering; delivery order is the
    deterministic heap order (deliver_tick, send_seq, copy)."""

    def __init__(self, *, seed: int = 0, p_drop: float = 0.0,
                 p_dup: float = 0.0, delay_min: int = 1,
                 delay_max: int | None = None, faults=()):
        if delay_min < 0:
            raise ValueError("delay_min must be >= 0")
        self.seed = int(seed)
        self.p_drop = float(p_drop)
        self.p_dup = float(p_dup)
        self.delay_min = int(delay_min)
        self.delay_max = int(delay_min if delay_max is None else delay_max)
        if self.delay_max < self.delay_min:
            raise ValueError("delay_max must be >= delay_min")
        self.faults = tuple(faults)
        self._q: dict[str, list] = {}
        self._seq = 0
        self.sent = 0
        self.dropped = 0          # random drops
        self.partitioned = 0      # partition-window losses
        self.duped = 0
        self.delivered = 0

    def send(self, dst: str, msg: dict, tick: int) -> bool:
        """Queue ``msg`` for ``dst``; returns False if the channel ate it
        (the sender cannot tell — retries live above this layer)."""
        seq = self._seq
        self._seq += 1
        self.sent += 1
        if any(f.hits(dst, tick) for f in self.faults):
            self.partitioned += 1
            return False
        rng = np.random.default_rng((self.seed, seq))
        if self.p_drop > 0.0 and rng.random() < self.p_drop:
            self.dropped += 1
            return False
        copies = 1
        if self.p_dup > 0.0 and rng.random() < self.p_dup:
            copies = 2
            self.duped += 1
        q = self._q.setdefault(dst, [])
        for copy_i in range(copies):
            span = self.delay_max - self.delay_min
            delay = self.delay_min + (int(rng.integers(0, span + 1))
                                      if span > 0 else 0)
            heapq.heappush(q, (tick + delay, seq, copy_i, msg))
        return True

    def recv(self, dst: str, tick: int) -> list[dict]:
        """Every message matured for ``dst`` by ``tick``, in deterministic
        delivery order."""
        q = self._q.get(dst)
        out: list[dict] = []
        while q and q[0][0] <= tick:
            out.append(heapq.heappop(q)[3])
            self.delivered += 1
        return out

    def stats(self) -> dict:
        return {"sent": self.sent, "dropped": self.dropped,
                "partitioned": self.partitioned, "duped": self.duped,
                "delivered": self.delivered}


# --------------------------------------------------------------------------- #
# Snapshot resync
# --------------------------------------------------------------------------- #


def _validate_snapshot(packed: dict) -> tuple[dict, int]:
    """Shape/dtype-check a ``packed_snapshot`` payload (same discipline as
    ``unpack_plan``) and return (canonical config arrays, version)."""
    if not isinstance(packed, dict):
        raise ValueError(f"snapshot payload must be a dict, got "
                         f"{type(packed).__name__}")
    missing = [k for k in (*_SNAP_FIELDS, "version") if k not in packed]
    if missing:
        raise ValueError(f"snapshot payload missing fields: {missing}")
    cfg: dict = {}
    for k in _SNAP_FIELDS:
        shape, kind = control._WIRE_SPECS[k]
        a = np.asarray(packed[k])
        if a.shape != shape:
            raise ValueError(f"snapshot field {k!r} has shape {a.shape}, "
                             f"expected {shape}")
        want = np.integer if kind == "i" else np.floating
        if not np.issubdtype(a.dtype, want):
            raise ValueError(f"snapshot field {k!r} has dtype {a.dtype}")
        cfg[k] = a.astype(np.int32 if kind == "i" else np.float32)
    version = control._wire_scalar(packed, "version")
    if version < 0:
        raise ValueError(f"snapshot payload has bad version: {version}")
    return cfg, version


def snapshot_state(packed: dict) -> RoutingState:
    """A cold RoutingState at the snapshot's config — the boot state of a
    consumer that joins (or rejoins) with no live datapath counters."""
    cfg, version = _validate_snapshot(packed)
    base = empty_state()
    return base._replace(
        version=np.int32(version),
        **{k: np.asarray(cfg[k]) for k in _SNAP_FIELDS})


def snapshot_plan(packed: dict, live: RoutingState) -> control.RefreshPlan:
    """Turn a full-config snapshot into a RefreshPlan against ``live``.

    The slot permutation is recovered by matching (cluster id, instance)
    rows between the live config and the snapshot config, so a consumer
    that resyncs over a *gap* (rather than a cold restart) keeps the live
    load / EWMA counters of every endpoint that survived — exactly what a
    chained journal replay would have preserved.  ``base_version`` is -1:
    a snapshot applies on any current version."""
    cfg, version = _validate_snapshot(packed)
    old_start = np.asarray(live.cluster_ep_start)
    old_count = np.asarray(live.cluster_ep_count)
    old_inst = np.asarray(live.ep_instance)
    old_pos: dict[tuple[int, int], int] = {}
    for c in range(MAX_CLUSTERS):
        for j in range(int(old_count[c])):
            s = int(old_start[c]) + j
            old_pos[(c, int(old_inst[s]))] = s
    ep_src = np.full((MAX_ENDPOINTS,), -1, np.int32)
    for c in range(MAX_CLUSTERS):
        for j in range(int(cfg["cluster_ep_count"][c])):
            s = int(cfg["cluster_ep_start"][c]) + j
            ep_src[s] = old_pos.get((c, int(cfg["ep_instance"][s])), -1)
    ep_dst = np.full((MAX_ENDPOINTS,), -1, np.int32)
    occupied = ep_src >= 0
    ep_dst[ep_src[occupied]] = np.nonzero(occupied)[0]
    return control.RefreshPlan(
        config=tuple(cfg[k] for k in _SNAP_FIELDS),
        ep_src=ep_src, ep_dst=ep_dst, base_version=-1, version=version)


# --------------------------------------------------------------------------- #
# The consumer end
# --------------------------------------------------------------------------- #


class RoutingView:
    """The minimal plan sink: a bare RoutingState replica (a remote ingress
    host's routing table, sans datapath).  Anything with ``routing`` +
    ``apply_refresh`` — a ServeLoop, a benchmark Service — plugs into
    :class:`RemoteConsumer` the same way."""

    def __init__(self, routing: RoutingState | None = None):
        self.routing = empty_state() if routing is None else routing

    def apply_refresh(self, plan: control.RefreshPlan) -> None:
        self.routing = control.apply_plan(self.routing, plan)


class RemoteConsumer:
    """The far end of the transport: idempotent versioned plan application,
    snapshot resync, heartbeats, and a crash/restart fault model.

    ``pump(tick)`` drains the channel — plans apply iff their
    ``base_version`` matches the current version (stale/duplicate → no-op,
    out-of-order → held until the gap closes, corrupt → rejected whole) —
    then heartbeats the publisher with the applied version and the sink's
    live ``ep_load``.  ``crash()`` silences it (messages queue up
    undelivered); ``restart()`` models a process restart: a fresh
    incarnation at version -1 whose first heartbeat triggers exactly one
    snapshot resync."""

    def __init__(self, node: str, channel: LossyChannel, *,
                 sink=None, snapshot: dict | None = None):
        self.node = node
        self.channel = channel
        self.alive = True
        self.incarnation = 0
        self._hb_seq = 0
        # channel clock: monotone across restarts.  A restarted sink (a
        # fresh ServeLoop) pumps with its own tick counter reset to zero;
        # the channel's time only moves forward, so the consumer keeps the
        # larger of (its own clock + 1, the caller's tick).
        self.clock = -1
        self.version = -1
        self.boot_routing = empty_state()
        if snapshot is not None:
            self.boot_routing = snapshot_state(snapshot)
            self.version = int(snapshot["version"])
        self.sink = RoutingView(self.boot_routing) if sink is None else sink
        self._pending: dict[int, control.RefreshPlan] = {}
        self.history: list[tuple] = []   # (tick, kind, base, version)
        self.resyncs = 0
        self.stale = 0       # duplicate / already-applied messages ignored
        self.held = 0        # out-of-order plans parked for later
        self.rejected = 0    # corrupt payloads refused by validation
        self.crashes = 0

    def bind(self, sink) -> None:
        """Attach the real plan sink (e.g. the ServeLoop built around this
        consumer); it must carry the boot state this consumer was seeded
        with."""
        self.sink = sink

    @property
    def routing(self) -> RoutingState:
        return self.sink.routing

    # -- fault model --------------------------------------------------- #
    def crash(self) -> None:
        """The consumer process dies: no pumps, no heartbeats.  In-flight
        messages stay queued and deliver to the restarted incarnation as
        stale no-ops."""
        self.alive = False
        self.crashes += 1

    def restart(self, sink=None) -> None:
        """A fresh process: version -1, cold state, new incarnation (so the
        publisher discards reordered heartbeats of the dead one)."""
        self.alive = True
        self.incarnation += 1
        self.version = -1
        self._pending.clear()
        self.boot_routing = empty_state()
        self.sink = RoutingView(self.boot_routing) if sink is None else sink

    # -- the protocol --------------------------------------------------- #
    def pump(self, tick: int) -> None:
        if not self.alive:
            return
        tick = self.clock = max(self.clock + 1, int(tick))
        for msg in self.channel.recv(self.node, tick):
            kind = msg.get("kind")
            if kind == "plan":
                self._on_plan(msg, tick)
            elif kind == "snapshot":
                self._on_snapshot(msg, tick)
        self._hb_seq += 1
        self.channel.send(CP_NODE, {
            "kind": "hb", "node": self.node, "inc": self.incarnation,
            "seq": self._hb_seq, "version": self.version,
            "ep_load": np.asarray(self.sink.routing.ep_load).copy()}, tick)

    def _on_plan(self, msg: dict, tick: int) -> None:
        try:
            plan = control.unpack_plan(msg)
        except ValueError:
            self.rejected += 1
            return
        if plan.version < 0:               # unversioned plan has no place
            self.rejected += 1             # on the wire
            return
        if plan.version <= self.version:
            self.stale += 1
            return
        if plan.base_version != self.version:
            self._pending[int(plan.base_version)] = plan
            self.held += 1
            return
        self._apply(plan, tick, "plan")
        self._drain_pending(tick)

    def _on_snapshot(self, msg: dict, tick: int) -> None:
        try:
            plan = snapshot_plan(msg, self.sink.routing)
        except ValueError:
            self.rejected += 1
            return
        if plan.version <= self.version:
            self.stale += 1
            return
        self._apply(plan, tick, "resync")
        self.resyncs += 1
        self._drain_pending(tick)

    def _apply(self, plan: control.RefreshPlan, tick: int,
               kind: str) -> None:
        self.sink.apply_refresh(plan)
        self.history.append((tick, kind, int(plan.base_version),
                             int(plan.version)))
        self.version = int(plan.version)

    def _drain_pending(self, tick: int) -> None:
        """Chain any held out-of-order plans that now fit, and purge ones
        the applied prefix has overtaken."""
        while True:
            plan = self._pending.pop(self.version, None)
            if plan is None:
                break
            if plan.version <= self.version:
                continue
            self._apply(plan, tick, "plan")
        self._pending = {b: p for b, p in self._pending.items()
                         if p.version > self.version}


# --------------------------------------------------------------------------- #
# The publisher end
# --------------------------------------------------------------------------- #


class _LoadView:
    """What the drain reaper reads off a transport proxy: the node's last
    heartbeat-reported in-flight load."""

    def __init__(self):
        self.ep_load = np.zeros((MAX_ENDPOINTS,), np.int32)


class _NodeProxy:
    """The ControlPlane-attached stand-in for a remote node: commits fan
    out to it (a no-op — the journal is the delivery queue), the reaper
    reads its last-reported load, and its lease is the node's lease."""

    def __init__(self, node: str):
        self.node = node
        self.routing = _LoadView()

    def apply_refresh(self, plan) -> None:
        pass                               # shipped from the journal instead


@dataclasses.dataclass
class _NodeState:
    proxy: _NodeProxy
    idx: int                               # stable per-node backoff key
    acked: int = -1
    last_hb: tuple = (-1, -1)              # (incarnation, seq) high-water
    attempt: int = 0
    next_send: int = 0
    plan_sends: int = 0
    snap_sends: int = 0


class PlanPublisher:
    """Ships the ControlPlane's journal to registered nodes with ack
    tracking and capped-exponential retry (the ServeLoop backoff shape:
    ``min(base << (attempt-1), cap)`` plus seeded jitter)."""

    def __init__(self, cp: control.ControlPlane, channel: LossyChannel, *,
                 retry_base: int = 1, retry_cap: int = 16, seed: int = 0):
        self.cp = cp
        self.channel = channel
        self.retry_base = int(retry_base)
        self.retry_cap = int(retry_cap)
        self.seed = int(seed)
        self.nodes: dict[str, _NodeState] = {}

    def register(self, node: str, *, boot_version: int = -1) -> None:
        """Add a node.  ``boot_version`` is the version it was seeded at
        (-1 = cold: the first exchange is a snapshot resync)."""
        if node in self.nodes:
            raise ValueError(f"node {node!r} already registered")
        proxy = _NodeProxy(node)
        self.cp.attach(proxy)
        self.nodes[node] = _NodeState(proxy=proxy, idx=len(self.nodes),
                                      acked=int(boot_version))

    def pump(self, tick: int) -> None:
        """Process arrived heartbeats (ack + lease + load vote), then ship
        whatever each live, behind, retry-mature node is missing."""
        for msg in self.channel.recv(CP_NODE, tick):
            if msg.get("kind") != "hb":
                continue
            st = self.nodes.get(msg.get("node"))
            if st is None:
                continue
            hb = (int(msg["inc"]), int(msg["seq"]))
            if hb <= st.last_hb:           # reordered stale heartbeat
                continue
            st.last_hb = hb
            self.cp.heartbeat(st.proxy)
            st.proxy.routing.ep_load = np.asarray(
                msg["ep_load"]).astype(np.int32)
            v = int(msg["version"])
            if v != st.acked:              # progress OR a restarted node
                st.acked = v               # announcing itself at -1
                st.attempt = 0
                st.next_send = tick
        head = self.cp.version
        journal = self.cp.journal
        floor = int(journal[0]["base_version"]) if journal else head
        for node, st in self.nodes.items():
            if st.acked >= head:
                st.attempt = 0             # converged: next commit ships
                st.next_send = tick        # immediately
                continue
            if not self.cp.lease_live(st.proxy):
                continue                   # dead node: plans stop shipping
            if tick < st.next_send:
                continue
            if st.acked < 0 or st.acked < floor:
                self.channel.send(
                    node, {"kind": "snapshot", **self.cp.packed_snapshot()},
                    tick)
                st.snap_sends += 1
            else:
                for entry in journal:
                    if int(entry["version"]) > st.acked:
                        self.channel.send(node, {"kind": "plan", **entry},
                                          tick)
                        st.plan_sends += 1
            st.attempt += 1
            delay = min(self.retry_base << (st.attempt - 1), self.retry_cap)
            rng = np.random.default_rng((self.seed, st.idx, st.attempt))
            delay += int(rng.integers(0, delay)) if delay > 0 else 0
            st.next_send = tick + max(1, delay)

    def stats(self) -> dict:
        return {n: {"acked": st.acked, "plan_sends": st.plan_sends,
                    "snap_sends": st.snap_sends}
                for n, st in self.nodes.items()}


# --------------------------------------------------------------------------- #
# Convergence invariants
# --------------------------------------------------------------------------- #


def convergence_report(cp: control.ControlPlane, consumers) -> dict:
    """Check the transport's end-state invariants.

    For every *live* consumer: config bit-exact with ``cp.snapshot()``,
    applied version == ``cp.version`` (both the protocol counter and the
    RoutingState's own version field), and an applied-version history that
    is strictly monotone where every plain-plan hop chains exactly on the
    previous version — a version jump is only ever a counted resync.  Also
    checks the cp journal itself is a contiguous suffix of commits ending
    at ``cp.version`` (no lost bumps at the source)."""
    snap = cp.snapshot()
    issues: list[str] = []
    entries: list[dict] = []
    jv = [int(e["version"]) for e in cp.journal]
    if jv and (jv != list(range(jv[0], jv[0] + len(jv)))
               or jv[-1] != cp.version):
        issues.append(f"journal versions not a contiguous suffix: {jv} "
                      f"(head {cp.version})")
    for rc in consumers:
        e = {"node": rc.node, "alive": rc.alive, "version": rc.version,
             "resyncs": rc.resyncs, "crashes": rc.crashes,
             "stale": rc.stale, "rejected": rc.rejected}
        entries.append(e)
        if not rc.alive:
            continue
        if rc.version != cp.version:
            issues.append(f"{rc.node}: at version {rc.version}, control "
                          f"plane at {cp.version}")
        r = rc.sink.routing
        state_v = int(np.asarray(r.version))
        if state_v != cp.version:
            issues.append(f"{rc.node}: RoutingState.version {state_v} != "
                          f"control plane {cp.version}")
        diff = [k for k in control.CONFIG_FIELDS
                if not np.array_equal(np.asarray(getattr(r, k)),
                                      np.asarray(getattr(snap, k)))]
        if diff:
            issues.append(f"{rc.node}: config fields differ from control "
                          f"plane: {diff}")
        prev = None
        for (tick, kind, base, version) in rc.history:
            if prev is not None and version <= prev:
                issues.append(f"{rc.node}: non-monotone history at tick "
                              f"{tick}: {prev} -> {version}")
            if kind == "plan" and prev is not None and base != prev:
                issues.append(f"{rc.node}: lost bump at tick {tick}: plan "
                              f"base {base} after version {prev}")
            prev = version
        if rc.resyncs > rc.crashes + 1:
            issues.append(f"{rc.node}: {rc.resyncs} resyncs for "
                          f"{rc.crashes} crashes")
    return {"converged": not issues, "issues": issues,
            "head": cp.version, "consumers": entries}


def assert_converged(cp: control.ControlPlane, consumers) -> dict:
    rep = convergence_report(cp, consumers)
    if not rep["converged"]:
        raise AssertionError("transport did not converge:\n  "
                             + "\n  ".join(rep["issues"]))
    return rep


# --------------------------------------------------------------------------- #
# Convenience wiring
# --------------------------------------------------------------------------- #


class Transport:
    """One channel + one publisher + N consumers, wired.

    >>> hub = Transport(cp, LossyChannel(seed=3, p_drop=0.2))
    >>> rc = hub.consumer("ingress-0")          # boots at cp's snapshot
    >>> loop = ServeLoop(engine, params, rc)    # binds rc to the loop
    >>> ... each tick: hub.pump(t); loop.tick() ...
    >>> hub.assert_converged()
    """

    def __init__(self, cp: control.ControlPlane,
                 channel: LossyChannel | None = None, *,
                 retry_base: int = 1, retry_cap: int = 16, seed: int = 0):
        self.cp = cp
        self.channel = LossyChannel() if channel is None else channel
        self.publisher = PlanPublisher(cp, self.channel,
                                       retry_base=retry_base,
                                       retry_cap=retry_cap, seed=seed)
        self.consumers: list[RemoteConsumer] = []

    def consumer(self, node: str, *, sink=None,
                 boot: bool = True) -> RemoteConsumer:
        """Create + register a consumer.  ``boot=True`` seeds it from the
        cp's current snapshot (a provisioned host); ``boot=False`` starts
        it cold at version -1 (its first exchange is a resync)."""
        snap = self.cp.packed_snapshot() if boot else None
        rc = RemoteConsumer(node, self.channel, sink=sink, snapshot=snap)
        self.publisher.register(node, boot_version=rc.version)
        self.consumers.append(rc)
        return rc

    def pump(self, tick: int) -> None:
        self.publisher.pump(tick)

    def report(self) -> dict:
        return convergence_report(self.cp, self.consumers)

    def assert_converged(self) -> dict:
        return assert_converged(self.cp, self.consumers)
