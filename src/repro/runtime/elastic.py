"""Elastic scaling: reshard a live state pytree onto a different mesh.

Because (a) checkpoints are mesh-agnostic (host npz + key paths) and (b) the
data pipeline is step-indexed, scaling from e.g. (data=16, model=16) to
(data=8, model=16) is: build the new MeshSpec → recompute shardings →
device_put every leaf.  No collective resharding program is required on CPU;
on a real fleet this is the jax.device_put cross-mesh path.
"""

from __future__ import annotations

from typing import Any

import jax

from repro.sharding.specs import MeshSpec


def reshard_params(params: Any, new_ms: MeshSpec) -> Any:
    shardings = new_ms.params_shardings(params)
    return jax.tree.map(jax.device_put, params, shardings)


def reshard_tree(tree: Any, shardings: Any) -> Any:
    return jax.tree.map(jax.device_put, tree, shardings)


def validate_divisibility(cfg, ms: MeshSpec, global_batch: int) -> list[str]:
    """Pre-flight checks when the mesh changes shape (elastic event)."""
    problems = []
    dp = 1
    for a in ms.dp:
        dp *= ms.mesh.shape[a]
    if global_batch % dp:
        problems.append(f"global_batch {global_batch} % dp {dp} != 0")
    if cfg.moe.enabled and cfg.moe.n_experts % ms.mesh.shape["model"]:
        problems.append(
            f"n_experts {cfg.moe.n_experts} not divisible by model axis "
            f"{ms.mesh.shape['model']} — EP relay needs even ownership")
    return problems
