"""Elastic scaling: reshard a live state pytree onto a different mesh, and
scale a serving fleet's endpoint set through the ControlPlane.

Because (a) checkpoints are mesh-agnostic (host npz + key paths) and (b) the
data pipeline is step-indexed, scaling from e.g. (data=16, model=16) to
(data=8, model=16) is: build the new MeshSpec → recompute shardings →
device_put every leaf.  No collective resharding program is required on CPU;
on a real fleet this is the jax.device_put cross-mesh path.

``scale_fleet`` is the serving-side elastic event (workload scenarios,
DESIGN.md §10): grow or shrink one cluster to a target endpoint count in a
single ControlPlane transaction — scale-up revives draining endpoints
before allocating fresh instance lanes, scale-down drains gracefully (the
reaper removes the rows once their in-flight load clears)."""

from __future__ import annotations

from typing import Any

import jax

from repro.sharding.specs import MeshSpec


def reshard_params(params: Any, new_ms: MeshSpec) -> Any:
    shardings = new_ms.params_shardings(params)
    return jax.tree.map(jax.device_put, params, shardings)


def reshard_tree(tree: Any, shardings: Any) -> Any:
    return jax.tree.map(jax.device_put, tree, shardings)


def scale_fleet(cp, cluster: str, target: int, *, max_instances: int,
                weight: float = 1.0) -> list[tuple]:
    """Scale ``cluster`` to ``target`` serving endpoints in ONE transaction.

    Scale-up first lifts pending drains (a just-scaled-down instance comes
    back without a table splice), then adds endpoints on unused instance
    lanes — never past ``max_instances``, the engine pool's lane capacity.
    Scale-down drains the highest-numbered serving instances (graceful:
    weight 0 + drained bit now, row reaped when its load clears).  Returns
    the action list [("undrain"|"add"|"drain", instance), ...]."""
    if not 1 <= target <= max_instances:
        raise ValueError(f"target {target} outside [1, {max_instances}] "
                         f"(pool instance-lane capacity)")
    acts: list[tuple] = []
    with cp.transaction():
        members = cp.cluster_members(cluster)
        draining = sorted(i for _, i in members
                          if cp.drain_reason(cluster, i) is not None)
        serving = sorted(i for _, i in members if i not in draining)
        if target > len(serving):
            need = target - len(serving)
            for i in draining[:need]:
                cp.undrain_endpoint(cluster, i, weight=weight)
                acts.append(("undrain", i))
            need -= len(acts)
            used = {i for _, i in members}
            fresh = [i for i in range(max_instances) if i not in used]
            if need > len(fresh):
                raise ValueError(
                    f"cannot scale {cluster!r} to {target}: only "
                    f"{len(fresh)} free instance lanes of {max_instances}")
            for i in fresh[:need]:
                cp.add_endpoint(cluster, i, weight=weight)
                acts.append(("add", i))
        elif target < len(serving):
            for i in serving[target - len(serving):]:
                cp.drain_endpoint(cluster, i)
                acts.append(("drain", i))
    return acts


def validate_divisibility(cfg, ms: MeshSpec, global_batch: int) -> list[str]:
    """Pre-flight checks when the mesh changes shape (elastic event)."""
    problems = []
    dp = 1
    for a in ms.dp:
        dp *= ms.mesh.shape[a]
    if global_batch % dp:
        problems.append(f"global_batch {global_batch} % dp {dp} != 0")
    if cfg.moe.enabled and cfg.moe.n_experts % ms.mesh.shape["model"]:
        problems.append(
            f"n_experts {cfg.moe.n_experts} not divisible by model axis "
            f"{ms.mesh.shape['model']} — EP relay needs even ownership")
    return problems
