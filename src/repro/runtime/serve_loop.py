"""Host serving driver: ingress parsing + continuous batching around the
in-graph XLB engine (core/interpose.py).

The host does exactly what the paper leaves outside eBPF (its helper
functions): byte-level protocol parsing — here hashing L7 header fields into
the fixed int32 feature vector — and queueing.  Everything else (routing,
balancing, slot allocation, decode) runs inside one compiled program.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import interpose
from repro.core.routing_table import N_FEATURES, RoutingState, fnv1a


@dataclasses.dataclass
class Request:
    req_id: int
    service: int
    headers: dict[str, str]
    prompt_token: int
    msg_bytes: int = 128
    t_submit: float = 0.0
    t_done: float = 0.0
    retries: int = 0
    tokens: list = dataclasses.field(default_factory=list)


def parse_features(headers: dict[str, str]) -> np.ndarray:
    """Host ingress 'protocol parse': hash selected header fields into the
    feature vector the in-graph router matches on."""
    feats = np.zeros((N_FEATURES,), np.int32)
    for i, field in enumerate(("path", "user", "version", "tenant",
                               "method", "content-type", "region", "abtest")):
        if field in headers:
            feats[i] = fnv1a(headers[field])
    return feats


class ServeLoop:
    """Continuous batching driver for one service fleet."""

    def __init__(self, engine: interpose.Engine, params, routing: RoutingState,
                 admit_batch: int = 8, dtype=jnp.float32):
        self.engine = engine
        self.params = params
        self.admit_batch = admit_batch
        self.state = engine.init_state(routing, dtype=dtype)
        self.serve_step = engine.make_jitted(donate=False)
        self.queue: collections.deque[Request] = collections.deque()
        self.inflight: dict[int, Request] = {}
        self.done: list[Request] = []
        self.dropped: list[Request] = []    # gave up after max retries

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _next_admission(self) -> tuple[interpose.RequestBatch, list]:
        R = self.admit_batch
        rid = np.full((R,), -1, np.int32)
        svc = np.zeros((R,), np.int32)
        feats = np.zeros((R, N_FEATURES), np.int32)
        tok = np.zeros((R,), np.int32)
        nbytes = np.zeros((R,), np.int32)
        taken = []
        for i in range(R):
            if not self.queue:
                break
            r = self.queue.popleft()
            rid[i], svc[i] = r.req_id, r.service
            feats[i] = parse_features(r.headers)
            tok[i], nbytes[i] = r.prompt_token, r.msg_bytes
            self.inflight[r.req_id] = r
            taken.append(r)
        return interpose.RequestBatch(
            req_id=jnp.asarray(rid), svc=jnp.asarray(svc),
            features=jnp.asarray(feats), token=jnp.asarray(tok),
            msg_bytes=jnp.asarray(nbytes)), taken

    # ------------------------------------------------------------------ #
    def tick(self) -> dict:
        """One engine step: admit waiting requests + decode every lane."""
        reqs, taken = self._next_admission()
        self.state, out = self.serve_step(self.params, self.state, reqs)
        emitted = np.asarray(out["emitted"])
        done = np.asarray(out["done"])
        ids = np.asarray(out["req_id"])          # ids serviced this tick
        I, C = emitted.shape
        serviced = set()
        for i in range(I):
            for s in range(C):
                rid = int(ids[i, s])
                if rid >= 0 and rid in self.inflight:
                    serviced.add(rid)
                    self.inflight[rid].tokens.append(int(emitted[i, s]))
                    if done[i, s]:
                        r = self.inflight.pop(rid)
                        r.t_done = time.perf_counter()
                        self.done.append(r)
        # held requests (pool exhausted / unroutable this tick) re-queue —
        # the paper's bounded hold queue lives on the host ingress
        for r in taken:
            if r.req_id not in serviced and r.req_id in self.inflight:
                self.inflight.pop(r.req_id)
                r.retries += 1
                if r.retries < 64:
                    self.queue.appendleft(r)
                else:                            # unroutable requests drop,
                    r.t_done = time.perf_counter()   # but stay accounted:
                    self.dropped.append(r)       # submitted == done+dropped
        return {"active": int(out["active"]), "queued": len(self.queue),
                "done": len(self.done), "dropped": len(self.dropped)}

    def drain(self, max_ticks: int = 10_000) -> list[Request]:
        t = 0
        while (self.queue or self.inflight) and t < max_ticks:
            self.tick()
            t += 1
        return self.done
