"""Host serving driver: ingress parsing + continuous batching around any
:class:`repro.core.balancer.Balancer` — the XLB in-graph engine or either
sidecar baseline, with zero per-engine glue.

The host does exactly what the paper leaves outside eBPF (its helper
functions): byte-level protocol parsing — here hashing L7 header fields into
the fixed int32 feature vector — and queueing.  Everything else (routing,
balancing, slot allocation, decode) runs wherever the engine places it.

Routing can be given as a plain ``RoutingState`` snapshot or as a
``ControlPlane``; with a ControlPlane the loop attaches itself, so every
committed transaction reaches the live engine state mid-serve (config swap,
load migration, pool remap) without recompiling the datapath.
"""

from __future__ import annotations

import collections
import dataclasses
import heapq
import time
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.analysis.invariants import assert_host, sanitize_enabled
from repro.core import control
from repro.core.balancer import Balancer, RequestBatch
from repro.core.routing_table import N_FEATURES, RoutingState, fnv1a
from repro.runtime import transport


@dataclasses.dataclass
class Request:
    req_id: int
    service: int
    headers: dict[str, str]
    prompt_token: int
    msg_bytes: int = 128
    t_submit: float = 0.0
    t_done: float = 0.0
    retries: int = 0
    hop: int = 0                # chain position (workload/chain.py): which
    #                             service of a call chain this admission is
    tokens: list = dataclasses.field(default_factory=list)
    # per-request tick samples (workload/slo.py): wall clocks above are
    # advisory; these are the deterministic engine-tick measurements
    submit_tick: int = -1       # loop tick the request entered the ingress
    admit_tick: int = -1        # first tick it actually held a pool slot
    done_tick: int = -1         # tick its final token completed


class DrainReport(NamedTuple):
    """What a drain actually left behind — not just the completions."""

    done: list            # completed Requests (all-time, == loop.done)
    dropped: list         # gave up after max retries (== loop.dropped)
    queued: int           # still waiting at the ingress (ready queue +
    #                       backoff set) when draining ended
    inflight: int         # still holding a pool slot when draining ended
    held_first: int = 0   # DISTINCT requests ever re-queued (held or
    #                       unroutable) — each counts once, however many
    #                       attempts it took; the engine's metrics.overflow
    #                       counts per-ATTEMPT hold events (FlowMetrics)


# --------------------------------------------------------------------------- #
# Fault injection — the degraded-scenario harness (DESIGN.md §8)
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injected endpoint fault, in engine ticks.

    Faults act on *progress*, not on routing: on a held tick the instance's
    active slots have their decode position rolled back by one, so the step
    the engine just took (or is about to take) nets to zero — requests pile
    up, occupancy rises, completions stop.  That is exactly what a slow or
    wedged backend looks like from the datapath, and it is invisible to any
    per-request length bookkeeping — only the occupancy/throughput EWMAs
    (kernels/completion.py::health_update) can see it.

      slow   — the instance makes net progress on 1 tick in ``factor``
               (a ×factor slowdown)
      stall  — no progress at all while the fault is active
      flap   — alternates ``period`` stalled ticks / ``period`` healthy
               ticks (the breaker-hysteresis stressor)
    """

    instance: int
    kind: str = "slow"          # slow | stall | flap
    factor: int = 10
    start: int = 0
    end: int | None = None      # None = never clears
    period: int = 8             # flap half-cycle, in ticks

    def holds(self, tick: int) -> bool:
        """Does this fault hold the instance's progress at ``tick``?"""
        if tick < self.start or (self.end is not None and tick >= self.end):
            return False
        if self.kind == "stall":
            return True
        if self.kind == "slow":
            return (tick - self.start) % self.factor != 0
        if self.kind == "flap":
            return ((tick - self.start) // self.period) % 2 == 0
        raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultInjector:
    """Applies a set of :class:`Fault` schedules to a live pool.

    ``apply`` runs on the host between engine ticks and rolls back
    ``pool.length`` on the held instances' active slots (floored at 0).
    Works on both pool representations: the XLB engine's jax arrays
    (functional update) and the sidecar's numpy pool (in-place)."""

    def __init__(self, faults):
        self.faults = list(faults)

    def active(self, tick: int) -> list[int]:
        return [f.instance for f in self.faults if f.holds(tick)]

    def clear_tick(self) -> int | None:
        """Last tick at which any fault clears (None if one never does)."""
        ends = [f.end for f in self.faults]
        return None if any(e is None for e in ends) else max(ends, default=0)

    def apply(self, pool, tick: int):
        # clamp against the live instance window: a fault schedule written
        # for a larger fleet (or racing an elastic scale event on the same
        # tick) may name an instance lane the pool no longer has — numpy
        # pools would IndexError, jax pools would silently clip to the last
        # lane and hold the wrong instance.  Out-of-window faults are inert.
        I = pool.length.shape[0]
        held = [i for i in self.active(tick) if 0 <= i < I]
        if not held:
            return pool
        if isinstance(pool.length, np.ndarray):
            for i in held:
                m = pool.active[i] & (pool.length[i] > 0)
                pool.length[i, m] -= 1
            return pool
        length = pool.length
        for i in held:
            m = pool.active[i] & (length[i] > 0)
            length = length.at[i].add(jnp.where(m, -1, 0))
        return pool._replace(length=length)


def parse_features(headers: dict[str, str]) -> np.ndarray:
    """Host ingress 'protocol parse': hash selected header fields into the
    feature vector the in-graph router matches on."""
    feats = np.zeros((N_FEATURES,), np.int32)
    for i, field in enumerate(("path", "user", "version", "tenant",
                               "method", "content-type", "region", "abtest")):
        if field in headers:
            feats[i] = fnv1a(headers[field])
    return feats


class ServeLoop:
    """Continuous batching driver for one service fleet."""

    def __init__(self, balancer: Balancer, params,
                 routing: RoutingState | control.ControlPlane
                 | transport.RemoteConsumer,
                 admit_batch: int = 8, dtype=jnp.float32,
                 max_retries: int = 64, backoff_base: int = 1,
                 backoff_cap: int = 16, backoff_seed: int = 0,
                 fault: FaultInjector | None = None):
        self.balancer = balancer
        self.params = params
        self.admit_batch = admit_batch
        self.cp = None
        self.remote = None
        if isinstance(routing, control.ControlPlane):
            cp, routing = routing, routing.snapshot()
            cp.attach(self)
            self.cp = cp
        elif isinstance(routing, transport.RemoteConsumer):
            # attach through the plan transport instead of in-process: the
            # consumer pumps its lossy channel each tick (plans in,
            # heartbeat + live load report out) and calls apply_refresh
            # here; the loop boots at whatever snapshot the consumer was
            # seeded with (runtime/transport.py).
            rc, routing = routing, routing.boot_routing
            rc.bind(self)
            self.remote = rc
        self.state = balancer.init_state(routing, dtype=dtype)
        self.serve_step = balancer.make_jitted(donate=False)
        self.queue: collections.deque[Request] = collections.deque()
        self.inflight: dict[int, Request] = {}
        self.done: list[Request] = []
        self.dropped: list[Request] = []    # gave up after max retries
        self.held_first = 0                 # distinct requests ever re-queued
        #                                     (first attempt only — the
        #                                     engine's overflow metric counts
        #                                     every attempt, FlowMetrics doc)
        # Held/unroutable requests back off with capped exponential delay +
        # deterministic jitter instead of hammering the admit path every
        # tick: delay_k = min(base·2^(k-1), cap) + U[0, delay_k), the jitter
        # drawn from a PRNG seeded by (seed, req_id, attempt) so replays are
        # bit-identical while concurrent requests still de-synchronize.
        self.max_retries = max_retries
        self.backoff_base = backoff_base
        self.backoff_cap = backoff_cap
        self.backoff_seed = backoff_seed
        self._waiting: list[tuple[int, int, Request]] = []   # backoff heap:
        self._wseq = 0                      # (eligible_tick, seq, Request)
        self.ticks = 0                      # engine ticks driven so far
        self.fault = fault                  # optional FaultInjector
        self.submitted = 0                  # all-time submit() count (the
        #                                     queue-conservation law input)

    # ------------------------------------------------------------------ #
    # control-plane seam
    # ------------------------------------------------------------------ #
    @property
    def routing(self) -> RoutingState:
        """The live routing tables the engine is reading right now."""
        return self.balancer.get_routing(self.state)

    def apply_refresh(self, plan: control.RefreshPlan) -> None:
        """ControlPlane consumer hook: splice a committed transaction into
        the live engine state (same compiled datapath, new tables)."""
        self.state = self.balancer.apply_refresh(self.state, plan)

    # ------------------------------------------------------------------ #
    @property
    def n_queued(self) -> int:
        """Everything still at the ingress: ready queue + backoff set.
        ``submitted == done + dropped + n_queued + inflight`` at all times."""
        return len(self.queue) + len(self._waiting)

    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        if req.submit_tick < 0:
            req.submit_tick = self.ticks
        self.submitted += 1
        self.queue.append(req)

    def latency_samples(self) -> dict:
        """Per-request tick samples over the completed set (workload/slo.py
        consumes these): ``admit_to_done`` is the engine-tick service
        latency, ``submit_to_done`` includes ingress queueing + backoff,
        ``retries`` is the per-request hold count.  Arrays align by row."""
        done = [r for r in self.done if r.done_tick >= 0]
        return {
            "req_id": np.array([r.req_id for r in done], np.int64),
            "admit_to_done": np.array(
                [r.done_tick - r.admit_tick for r in done], np.int64),
            "submit_to_done": np.array(
                [r.done_tick - r.submit_tick for r in done], np.int64),
            "retries": np.array([r.retries for r in done], np.int64),
        }

    def _backoff(self, req: Request) -> None:
        """Park a held request until its retry matures (or drop it)."""
        if req.retries >= self.max_retries:
            req.t_done = time.perf_counter()     # unroutable requests drop,
            self.dropped.append(req)             # but stay accounted
            return
        delay = min(self.backoff_base << (req.retries - 1), self.backoff_cap)
        rng = np.random.default_rng(
            (self.backoff_seed, req.req_id, req.retries))
        delay += int(rng.integers(0, delay))
        heapq.heappush(self._waiting,
                       (self.ticks + delay, self._wseq, req))
        self._wseq += 1

    def _release_matured(self) -> None:
        """Move matured backoff entries to the FRONT of the ready queue
        (oldest eligible first) — held work keeps priority over new
        arrivals, as with the old immediate re-queue."""
        batch = []
        while self._waiting and self._waiting[0][0] <= self.ticks:
            batch.append(heapq.heappop(self._waiting)[2])
        self.queue.extendleft(reversed(batch))

    def _next_admission(self) -> tuple[RequestBatch, list]:
        R = self.admit_batch
        rid = np.full((R,), -1, np.int32)
        svc = np.zeros((R,), np.int32)
        feats = np.zeros((R, N_FEATURES), np.int32)
        tok = np.zeros((R,), np.int32)
        nbytes = np.zeros((R,), np.int32)
        taken = []
        for i in range(R):
            if not self.queue:
                break
            r = self.queue.popleft()
            rid[i], svc[i] = r.req_id, r.service
            feats[i] = parse_features(r.headers)
            tok[i], nbytes[i] = r.prompt_token, r.msg_bytes
            self.inflight[r.req_id] = r
            taken.append(r)
        return RequestBatch(
            req_id=jnp.asarray(rid), svc=jnp.asarray(svc),
            features=jnp.asarray(feats), token=jnp.asarray(tok),
            msg_bytes=jnp.asarray(nbytes)), taken

    # ------------------------------------------------------------------ #
    def tick(self) -> dict:
        """One engine step: admit waiting requests + decode every lane."""
        if self.cp is not None:
            self.cp.heartbeat(self)          # liveness lease (core/control)
        elif self.remote is not None:        # transport-attached: plans in,
            self.remote.pump(self.ticks)     # heartbeat + load report out
        if self.fault is not None:           # injected faults roll progress
            pool = self.fault.apply(self.state.pool, self.ticks)
            if pool is not self.state.pool:  # back BEFORE the step so a
                self.state = self.state._replace(pool=pool)  # held slot
        self._release_matured()              # can't complete this tick
        reqs, taken = self._next_admission()
        self.state, out = self.serve_step(self.params, self.state, reqs)
        emitted = np.asarray(out["emitted"])
        done = np.asarray(out["done"])
        ids = np.asarray(out["req_id"])          # ids serviced this tick
        I, C = emitted.shape
        serviced = set()
        for i in range(I):
            for s in range(C):
                rid = int(ids[i, s])
                if rid >= 0 and rid in self.inflight:
                    serviced.add(rid)
                    req = self.inflight[rid]
                    if req.admit_tick < 0:    # first tick holding a slot
                        req.admit_tick = self.ticks
                    req.tokens.append(int(emitted[i, s]))
                    if done[i, s]:
                        r = self.inflight.pop(rid)
                        r.t_done = time.perf_counter()
                        r.done_tick = self.ticks
                        self.done.append(r)
        # held requests (pool exhausted / unroutable this tick) re-queue —
        # the paper's bounded hold queue lives on the host ingress
        for r in taken:
            if r.req_id not in serviced and r.req_id in self.inflight:
                self.inflight.pop(r.req_id)
                if r.retries == 0:          # first hold: count the REQUEST
                    self.held_first += 1    # (attempts land in overflow)
                r.retries += 1
                self._backoff(r)            # park (or drop at max_retries);
                #                             submitted == done + dropped +
                #                             n_queued + inflight throughout
        self.ticks += 1
        if sanitize_enabled():
            assert_host("loop", dict(
                submitted=self.submitted, done=len(self.done),
                dropped=len(self.dropped), queued=self.n_queued,
                inflight=len(self.inflight)))
        return {"active": int(out["active"]), "queued": self.n_queued,
                "done": len(self.done), "dropped": len(self.dropped)}

    def drain(self, max_ticks: int = 10_000) -> DrainReport:
        """Tick until idle (or the budget runs out) and report everything —
        a drain that strands queued/inflight work says so instead of
        silently returning only the completions."""
        t = 0
        while (self.queue or self._waiting or self.inflight) \
                and t < max_ticks:
            self.tick()
            t += 1
        return DrainReport(done=self.done, dropped=self.dropped,
                           queued=self.n_queued,
                           inflight=len(self.inflight),
                           held_first=self.held_first)
