"""Host serving driver: ingress parsing + continuous batching around any
:class:`repro.core.balancer.Balancer` — the XLB in-graph engine or either
sidecar baseline, with zero per-engine glue.

The host does exactly what the paper leaves outside eBPF (its helper
functions): byte-level protocol parsing — here hashing L7 header fields into
the fixed int32 feature vector — and queueing.  Everything else (routing,
balancing, slot allocation, decode) runs wherever the engine places it.

Routing can be given as a plain ``RoutingState`` snapshot or as a
``ControlPlane``; with a ControlPlane the loop attaches itself, so every
committed transaction reaches the live engine state mid-serve (config swap,
load migration, pool remap) without recompiling the datapath.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import control
from repro.core.balancer import Balancer, RequestBatch
from repro.core.routing_table import N_FEATURES, RoutingState, fnv1a


@dataclasses.dataclass
class Request:
    req_id: int
    service: int
    headers: dict[str, str]
    prompt_token: int
    msg_bytes: int = 128
    t_submit: float = 0.0
    t_done: float = 0.0
    retries: int = 0
    tokens: list = dataclasses.field(default_factory=list)


class DrainReport(NamedTuple):
    """What a drain actually left behind — not just the completions."""

    done: list            # completed Requests (all-time, == loop.done)
    dropped: list         # gave up after max retries (== loop.dropped)
    queued: int           # still waiting at the ingress when draining ended
    inflight: int         # still holding a pool slot when draining ended
    held_first: int = 0   # DISTINCT requests ever re-queued (held or
    #                       unroutable) — each counts once, however many
    #                       attempts it took; the engine's metrics.overflow
    #                       counts per-ATTEMPT hold events (FlowMetrics)


def parse_features(headers: dict[str, str]) -> np.ndarray:
    """Host ingress 'protocol parse': hash selected header fields into the
    feature vector the in-graph router matches on."""
    feats = np.zeros((N_FEATURES,), np.int32)
    for i, field in enumerate(("path", "user", "version", "tenant",
                               "method", "content-type", "region", "abtest")):
        if field in headers:
            feats[i] = fnv1a(headers[field])
    return feats


class ServeLoop:
    """Continuous batching driver for one service fleet."""

    def __init__(self, balancer: Balancer, params,
                 routing: RoutingState | control.ControlPlane,
                 admit_batch: int = 8, dtype=jnp.float32):
        self.balancer = balancer
        self.params = params
        self.admit_batch = admit_batch
        if isinstance(routing, control.ControlPlane):
            cp, routing = routing, routing.snapshot()
            cp.attach(self)
        self.state = balancer.init_state(routing, dtype=dtype)
        self.serve_step = balancer.make_jitted(donate=False)
        self.queue: collections.deque[Request] = collections.deque()
        self.inflight: dict[int, Request] = {}
        self.done: list[Request] = []
        self.dropped: list[Request] = []    # gave up after max retries
        self.held_first = 0                 # distinct requests ever re-queued
        #                                     (first attempt only — the
        #                                     engine's overflow metric counts
        #                                     every attempt, FlowMetrics doc)

    # ------------------------------------------------------------------ #
    # control-plane seam
    # ------------------------------------------------------------------ #
    @property
    def routing(self) -> RoutingState:
        """The live routing tables the engine is reading right now."""
        return self.balancer.get_routing(self.state)

    def apply_refresh(self, plan: control.RefreshPlan) -> None:
        """ControlPlane consumer hook: splice a committed transaction into
        the live engine state (same compiled datapath, new tables)."""
        self.state = self.balancer.apply_refresh(self.state, plan)

    # ------------------------------------------------------------------ #
    def submit(self, req: Request) -> None:
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _next_admission(self) -> tuple[RequestBatch, list]:
        R = self.admit_batch
        rid = np.full((R,), -1, np.int32)
        svc = np.zeros((R,), np.int32)
        feats = np.zeros((R, N_FEATURES), np.int32)
        tok = np.zeros((R,), np.int32)
        nbytes = np.zeros((R,), np.int32)
        taken = []
        for i in range(R):
            if not self.queue:
                break
            r = self.queue.popleft()
            rid[i], svc[i] = r.req_id, r.service
            feats[i] = parse_features(r.headers)
            tok[i], nbytes[i] = r.prompt_token, r.msg_bytes
            self.inflight[r.req_id] = r
            taken.append(r)
        return RequestBatch(
            req_id=jnp.asarray(rid), svc=jnp.asarray(svc),
            features=jnp.asarray(feats), token=jnp.asarray(tok),
            msg_bytes=jnp.asarray(nbytes)), taken

    # ------------------------------------------------------------------ #
    def tick(self) -> dict:
        """One engine step: admit waiting requests + decode every lane."""
        reqs, taken = self._next_admission()
        self.state, out = self.serve_step(self.params, self.state, reqs)
        emitted = np.asarray(out["emitted"])
        done = np.asarray(out["done"])
        ids = np.asarray(out["req_id"])          # ids serviced this tick
        I, C = emitted.shape
        serviced = set()
        for i in range(I):
            for s in range(C):
                rid = int(ids[i, s])
                if rid >= 0 and rid in self.inflight:
                    serviced.add(rid)
                    self.inflight[rid].tokens.append(int(emitted[i, s]))
                    if done[i, s]:
                        r = self.inflight.pop(rid)
                        r.t_done = time.perf_counter()
                        self.done.append(r)
        # held requests (pool exhausted / unroutable this tick) re-queue —
        # the paper's bounded hold queue lives on the host ingress
        for r in taken:
            if r.req_id not in serviced and r.req_id in self.inflight:
                self.inflight.pop(r.req_id)
                if r.retries == 0:          # first hold: count the REQUEST
                    self.held_first += 1    # (attempts land in overflow)
                r.retries += 1
                if r.retries < 64:
                    self.queue.appendleft(r)
                else:                            # unroutable requests drop,
                    r.t_done = time.perf_counter()   # but stay accounted:
                    self.dropped.append(r)       # submitted == done+dropped
        return {"active": int(out["active"]), "queued": len(self.queue),
                "done": len(self.done), "dropped": len(self.dropped)}

    def drain(self, max_ticks: int = 10_000) -> DrainReport:
        """Tick until idle (or the budget runs out) and report everything —
        a drain that strands queued/inflight work says so instead of
        silently returning only the completions."""
        t = 0
        while (self.queue or self.inflight) and t < max_ticks:
            self.tick()
            t += 1
        return DrainReport(done=self.done, dropped=self.dropped,
                           queued=len(self.queue),
                           inflight=len(self.inflight),
                           held_first=self.held_first)
