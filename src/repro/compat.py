"""jax version compatibility shims (installed floor: jax 0.4.x).

Every cross-version difference the repo touches lives here — don't spot-fix
call sites.  Current shims:

  * ``shard_map``  — top-level export (>= 0.6) vs ``jax.experimental``;
    the old keyword ``check_rep`` is exposed under its new name
    ``check_vma``.
  * ``axis_size``  — ``jax.lax.axis_size`` (>= 0.5) vs ``psum(1, axis)``
    (static under shard_map tracing on 0.4.x).
  * ``make_mesh``  — drops the ``axis_types=`` kwarg on versions without
    ``jax.sharding.AxisType`` (0.4.x treats every axis as Auto).
"""

from __future__ import annotations

import functools

import jax

try:                                    # jax >= 0.6: top-level export
    from jax import shard_map
except ImportError:                     # jax 0.4.x: experimental namespace,
    from jax.experimental.shard_map import shard_map as _shard_map_legacy

    @functools.wraps(_shard_map_legacy)
    def shard_map(f, /, *, check_vma: bool = True, **kwargs):
        return _shard_map_legacy(f, check_rep=check_vma, **kwargs)


def axis_size(axis) -> int:
    """Static size of a mapped mesh axis (callable inside shard_map)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)        # jax 0.4.x: psum of 1 is static


def make_mesh(shape, axes, *, auto: bool = True):
    """jax.make_mesh with explicit-Auto axis types where supported."""
    try:
        from jax.sharding import AxisType
    except ImportError:                 # jax 0.4.x: every axis is Auto
        return jax.make_mesh(shape, axes)
    types = (AxisType.Auto if auto else AxisType.Explicit,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=types)
