"""Mixture-of-Experts FFN driven by the XLB relay (core.relay).

Token→expert routing *is* L7 load balancing: content-based destination
selection (router logits = the route match), a balancing policy (gate-greedy
top-k, optionally least-request bias — the paper's LB algorithms), capacity =
the i-sock connection-pool size, and the relay hop = the socket relay
(all-to-all over the expert-parallel mesh axis).

Supports the assigned MoE shapes:
  * deepseek-v2: 2 shared experts + 160 routed top-6, first layer dense
  * arctic: 128 routed top-2 with a parallel dense residual MLP
  * jamba: 16 routed top-2 on alternate layers
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.core import relay
from repro.models.layers import Params, dense_init, ffn, init_ffn, split_keys


class MoEMetrics(NamedTuple):
    aux_loss: jax.Array        # load-balancing loss (Switch-style)
    z_loss: jax.Array          # router logit z-loss
    overflow_frac: jax.Array   # dropped-token fraction (pool exhaustion)
    load: jax.Array            # (E,) tokens routed per expert (pre-drop)

    @staticmethod
    def zero(n_experts: int) -> "MoEMetrics":
        z = jnp.zeros(())
        return MoEMetrics(z, z, z, jnp.zeros((n_experts,), jnp.int32))


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    D, E, Fe = cfg.d_model, m.n_experts, m.d_ff_expert
    ks = split_keys(key, 6)
    p: Params = {
        "router": dense_init(ks[0], (D, E), jnp.float32),
        "w_in": dense_init(ks[1], (E, D, Fe), dtype),
        "w_gate": dense_init(ks[2], (E, D, Fe), dtype),
        "w_out": dense_init(ks[3], (E, Fe, D), dtype),
    }
    if m.n_shared_experts:
        p["shared"] = init_ffn(ks[4], D, m.n_shared_experts * Fe, cfg.ffn_act, dtype)
    if m.dense_residual:
        p["residual"] = init_ffn(ks[5], D, cfg.d_ff, cfg.ffn_act, dtype)
    return p


def capacity_for(n_tokens: int, cfg: ModelConfig) -> int:
    """Connection-pool size per expert given ``n_tokens`` routed tokens."""
    m = cfg.moe
    c = math.ceil(n_tokens * m.top_k * m.capacity_factor / m.n_experts)
    return max(8, -(-c // 8) * 8)          # round up to a multiple of 8


def _expert_ffn(w, pool):
    """pool: (E, C, D) → (E, C, D); swiglu per expert."""
    h = jnp.einsum("ecd,edf->ecf", pool, w["w_in"])
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", pool, w["w_gate"]))
    return jnp.einsum("ecf,efd->ecd", h * g, w["w_out"])


def route(cfg: ModelConfig, p: Params, xf: jax.Array,
          router_bias: Optional[jax.Array] = None):
    """Router: logits → (top-k weights (T,k), expert ids (T,k), aux, z).

    ``router_bias``: optional (E,) least-request bias (aux-loss-free balancing
    — the XLB least-request policy applied to experts).  Bias shifts
    *selection* only; combine weights use unbiased gates.
    """
    m = cfg.moe
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (T,E)
    gates = jax.nn.softmax(logits, axis=-1)
    sel = gates if router_bias is None else gates + router_bias[None, :]
    _, idx = jax.lax.top_k(sel, m.top_k)                       # (T,k)
    weights = jnp.take_along_axis(gates, idx, axis=-1)         # (T,k)
    weights = weights / (jnp.sum(weights, axis=-1, keepdims=True) + 1e-9)
    # Switch aux loss: E * sum_e f_e * P_e
    me = jnp.mean(gates, axis=0)                               # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32).sum(1), axis=0)
    aux = m.n_experts * jnp.sum(me * ce)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    return weights, idx.astype(jnp.int32), aux, z


def moe_ffn(cfg: ModelConfig, p: Params, x: jax.Array, *,
            method: str = "sort",
            ep: Optional[tuple] = None,
            router_bias: Optional[jax.Array] = None,
            explicit_fsdp: bool = False,
            ) -> tuple[jax.Array, MoEMetrics]:
    """MoE FFN. x: (B, S, D).

    ``ep=(mesh, tok_axes)`` enables the expert-parallel a2a relay via
    shard_map; ``tok_axes`` is the tuple of mesh axes the flattened token
    stream is sharded over (must include "model", the expert-owner axis).

    ``explicit_fsdp``: gather the dp-sharded expert weights *inside* the
    shard_map with an explicit bf16 ``all_gather`` (transpose = bf16
    reduce-scatter for the weight grads) instead of letting GSPMD insert the
    gather outside — on the CPU backend GSPMD converts to f32 first (2×
    wire bytes), and on any backend this pins gather-per-layer-per-pass.
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    weights, idx, aux, z = route(cfg, p, xf, router_bias)
    k = m.top_k
    N = T * k
    x_rep = jnp.repeat(xf, k, axis=0)                          # (N,D) t-major
    idx_flat = idx.reshape(N)
    w_flat = weights.reshape(N)

    if ep is not None:
        mesh, tok_axes = ep
        dp_axes = tuple(a for a in tok_axes if a != "model")
        n_shards = math.prod(mesh.shape[a] for a in tok_axes)
        cap = capacity_for(T // n_shards, cfg)
        use_exp = explicit_fsdp and bool(dp_axes)

        def body(xx, ii, ww, pp):
            if use_exp:
                # explicit ZeRO-3 gather, bf16 on the wire (fwd AG, bwd RS)
                pp = {
                    "w_in": jax.lax.all_gather(pp["w_in"], dp_axes, axis=1,
                                               tiled=True),
                    "w_gate": jax.lax.all_gather(pp["w_gate"], dp_axes,
                                                 axis=1, tiled=True),
                    "w_out": jax.lax.all_gather(pp["w_out"], dp_axes, axis=2,
                                                tiled=True),
                }
            out, meta = relay.sharded_apply(
                xx, ii, ww, n_dest=m.n_experts, capacity=cap, axis="model",
                backend_fn=_expert_ffn, backend_params=pp)
            # sharded_apply already reduces meta over its relay axis
            # ("model"): load is global pre-drop, overflow_frac the axis
            # mean — only the data axes remain to fold in here
            ovf = (jax.lax.pmean(meta.overflow_frac, dp_axes) if dp_axes
                   else meta.overflow_frac)
            load = (jax.lax.psum(meta.load, dp_axes) if dp_axes
                    else meta.load)
            return out, ovf, load

        wdict = {n: p[n] for n in ("w_in", "w_gate", "w_out")}
        if use_exp:
            wspecs = {"w_in": P("model", dp_axes, None),
                      "w_gate": P("model", dp_axes, None),
                      "w_out": P("model", None, dp_axes)}
        else:
            wspecs = {n: P("model", None, None) for n in wdict}
        out_flat, overflow, load = shard_map(
            body, mesh=mesh,
            in_specs=(P(tok_axes, None), P(tok_axes), P(tok_axes), wspecs),
            out_specs=(P(tok_axes, None), P(), P()),
            check_vma=False,
        )(x_rep, idx_flat, w_flat, wdict)
    else:
        cap = capacity_for(T, cfg)
        if method == "einsum":
            buf, meta, d_oh = relay.relay_dispatch_einsum(x_rep, idx_flat,
                                                          m.n_experts, cap)
            out_buf = _expert_ffn(p, buf)
            out_flat = relay.relay_combine_einsum(out_buf, d_oh, w_flat)
        else:
            buf, meta = relay.relay_dispatch(x_rep, idx_flat, m.n_experts, cap,
                                             method=method)
            out_buf = _expert_ffn(p, buf)
            out_flat = relay.relay_combine(out_buf, meta, w_flat)
        overflow, load = meta.overflow_frac, meta.load

    out = out_flat.reshape(T, k, D).sum(axis=1).reshape(B, S, D)

    if "shared" in p:
        out = out + ffn(p["shared"], x, cfg.ffn_act)
    if "residual" in p:
        out = out + ffn(p["residual"], x, cfg.ffn_act)
    return out, MoEMetrics(aux, z, overflow, load)
