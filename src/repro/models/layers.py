"""Shared layer primitives (pure JAX, no framework deps).

Conventions:
  * params are nested dicts of jnp arrays; per-layer weights are STACKED on a
    leading axis so layer stacks can be ``lax.scan``ned (O(1) HLO in depth).
  * weights live in ``cfg.dtype`` (bf16); normalization statistics and logits
    are computed in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Params = dict


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------- #
# Initializers
# --------------------------------------------------------------------------- #


def dense_init(key, shape, dtype, scale: float | None = None):
    """Truncated-normal fan-in init (matches llama-family practice)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# --------------------------------------------------------------------------- #
# Normalization
# --------------------------------------------------------------------------- #


def rms_norm(x, scale, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def gated_rms_norm(x, gate, scale, eps: float = 1e-5):
    """Mamba-2 gated RMSNorm: norm(x * silu(z))."""
    return rms_norm(x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype), scale, eps)


# --------------------------------------------------------------------------- #
# Rotary position embedding
# --------------------------------------------------------------------------- #


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd) or (..., S, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, hd/2)
    if x.ndim == angles.ndim + 1:                            # head axis present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_positions(n_pos: int, d_model: int) -> jnp.ndarray:
    """Fixed sinusoidal embeddings (whisper encoder)."""
    pos = np.arange(n_pos)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    inv = 1.0 / (10000 ** (2 * dim / d_model))
    ang = pos * inv
    return jnp.asarray(
        np.concatenate([np.sin(ang), np.cos(ang)], axis=-1), dtype=jnp.float32
    )


# --------------------------------------------------------------------------- #
# FFN
# --------------------------------------------------------------------------- #


def init_ffn(key, d_model: int, d_ff: int, act: str, dtype) -> Params:
    ks = split_keys(key, 3)
    p = {
        "w_in": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_out": dense_init(ks[1], (d_ff, d_model), dtype),
    }
    if act == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def ffn(params: Params, x, act: str):
    h = x @ params["w_in"]
    if act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        raise ValueError(f"unknown activation {act!r}")
    return h @ params["w_out"]
