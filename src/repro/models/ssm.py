"""Mamba-2 (SSD — state-space duality) mixer.  [arXiv:2405.21060]

TPU adaptation note (DESIGN.md §2): the GPU reference implements SSD with a
fused Triton scan over warps; on TPU we keep the paper's *chunked dual form*,
which turns the recurrence into MXU-shaped matmuls (Q×Q intra-chunk scores,
hd×N outer-product states) plus a tiny inter-chunk ``associative_scan`` — the
layout the ``kernels/ssd_scan`` Pallas kernel tiles into VMEM.

Layout: x:(B,S,nh,hd), B/C:(B,S,G,N) groups broadcast over heads,
dt:(B,S,nh) post-softplus, A:(nh,) negative.
Decode state: ssm (B,nh,hd,N) + rolling conv window (B,conv_dim,W-1).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, SSMConfig
from repro.models.layers import (Params, dense_init, gated_rms_norm,
                                 split_keys)


class SSMState(NamedTuple):
    ssm: jax.Array    # (B, nh, hd, N) fp32
    conv: jax.Array   # (B, conv_dim, W-1) model dtype


# --------------------------------------------------------------------------- #
# Init
# --------------------------------------------------------------------------- #


def init_mamba(key, cfg: ModelConfig, dtype) -> Params:
    s: SSMConfig = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    nh = s.n_heads(D)
    conv_dim = di + 2 * s.n_groups * s.d_state
    ks = split_keys(key, 5)
    # A in [1,16] log-uniform; dt bias = softplus^{-1}(dt), dt in [1e-3, 0.1]
    a0 = np.exp(np.random.RandomState(0).uniform(np.log(1.0), np.log(16.0), nh))
    dt0 = np.exp(np.random.RandomState(1).uniform(np.log(1e-3), np.log(0.1), nh))
    dt_bias = dt0 + np.log(-np.expm1(-dt0))
    return {
        "w_in": dense_init(ks[0], (D, 2 * di + 2 * s.n_groups * s.d_state + nh),
                           dtype),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_dim), dtype,
                             scale=1.0 / np.sqrt(s.conv_width)),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.asarray(np.log(a0), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.asarray(dt_bias, jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "w_out": dense_init(ks[2], (di, D), dtype),
    }


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    s = cfg.ssm
    D = cfg.d_model
    di, nh = s.d_inner(D), s.n_heads(D)
    conv_dim = di + 2 * s.n_groups * s.d_state
    return SSMState(
        ssm=jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
        conv=jnp.zeros((batch, conv_dim, s.conv_width - 1), dtype),
    )


# --------------------------------------------------------------------------- #
# Chunked SSD (train / prefill)
# --------------------------------------------------------------------------- #


def _segsum(a):
    """a: (..., Q) → (..., Q, Q) with out[i,j] = sum_{j<k<=i} a_k (i>=j), -inf else."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(xdt, a_log, Bm, Cm, chunk: int, h0=None):
    """SSD dual form.

    xdt:(B,S,nh,hd) = dt⊙x;  a_log:(B,S,nh) = dt*A;  Bm/Cm:(B,S,nh,N)
    (already broadcast from groups).  Returns (y:(B,S,nh,hd), h_last fp32).
    Pure-jnp oracle for kernels/ssd_scan.
    """
    B, S, nh, hd = xdt.shape
    N = Bm.shape[-1]
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q
    xc = xdt.reshape(B, nc, Q, nh, hd)
    ac = a_log.reshape(B, nc, Q, nh).transpose(0, 3, 1, 2)     # (B,nh,nc,Q)
    Bc = Bm.reshape(B, nc, Q, nh, N)
    Cc = Cm.reshape(B, nc, Q, nh, N)
    ac = ac.astype(jnp.float32)
    A_cum = jnp.cumsum(ac, axis=-1)                            # (B,nh,nc,Q)

    # 1) intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(ac))                                   # (B,nh,nc,Q,Q)
    Y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        Cc, Bc, L.astype(Cc.dtype), xc)

    # 2) chunk states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)            # (B,nh,nc,Q)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn",
                        Bc, decay_states.astype(Bc.dtype), xc)  # (B,nc,nh,hd,N)

    # 3) inter-chunk recurrence (associative scan over nc)
    chunk_decay = jnp.exp(A_cum[..., -1]).transpose(0, 2, 1)   # (B,nc,nh)
    states = states.astype(jnp.float32)
    if h0 is not None:
        states = jnp.concatenate([h0[:, None].astype(jnp.float32), states], 1)
        chunk_decay = jnp.concatenate(
            [jnp.ones_like(chunk_decay[:, :1]), chunk_decay], 1)

    def comb(a, b):
        da, ha = a                     # decay (B,nc,nh,1,1), state (B,nc,…)
        db, hb = b
        return da * db, hb + db * ha

    dec, hs = jax.lax.associative_scan(
        comb, (chunk_decay[..., None, None] * 1.0, states), axis=1)
    if h0 is not None:
        hs = hs[:, 1:]
    h_last = hs[:, -1]                                         # (B,nh,hd,N)
    h_prev = jnp.concatenate(
        [h0[:, None].astype(jnp.float32) if h0 is not None
         else jnp.zeros_like(hs[:, :1]), hs[:, :-1]], axis=1)  # (B,nc,nh,hd,N)

    # 4) inter-chunk output
    state_decay = jnp.exp(A_cum)                               # (B,nh,nc,Q)
    Y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp",
                       Cc, h_prev.astype(Cc.dtype),
                       state_decay.astype(Cc.dtype))
    y = (Y_diag + Y_off).reshape(B, S, nh, hd)
    return y, h_last


# --------------------------------------------------------------------------- #
# Full mixer
# --------------------------------------------------------------------------- #


def _split_proj(cfg: ModelConfig, h):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    gn = 2 * s.n_groups * s.d_state
    nh = s.n_heads(cfg.d_model)
    z, xBC, dt = jnp.split(h, [di, di + di + gn], axis=-1)
    assert dt.shape[-1] == nh
    return z, xBC, dt


def _causal_conv(xBC, w, b, conv_state=None):
    """Depthwise causal conv1d.  xBC:(B,S,C); w:(W,C).  Returns (y, new_state)."""
    B, S, C = xBC.shape
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((B, W - 1, C), xBC.dtype)
    else:
        pad = conv_state.transpose(0, 2, 1)                    # (B,W-1,C)
    xp = jnp.concatenate([pad, xBC], axis=1)                   # (B,S+W-1,C)
    y = sum(xp[:, i:i + S, :] * w[i][None, None, :] for i in range(W))
    y = y + b[None, None, :]
    new_state = xp[:, -(W - 1):, :].transpose(0, 2, 1)         # (B,C,W-1)
    return jax.nn.silu(y.astype(jnp.float32)).astype(xBC.dtype), new_state


def mamba_mixer(cfg: ModelConfig, p: Params, x, *, state: SSMState | None = None,
                return_state: bool = False):
    """Full-sequence SSD mixer (train/prefill).  x:(B,S,D)."""
    s = cfg.ssm
    B, S, D = x.shape
    di, nh, N, G = s.d_inner(D), s.n_heads(D), s.d_state, s.n_groups
    h = x @ p["w_in"]
    z, xBC, dt = _split_proj(cfg, h)
    xBC, conv_state = _causal_conv(
        xBC, p["conv_w"], p["conv_b"],
        None if state is None else state.conv)
    xs, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, S, nh, s.head_dim)
    rep = nh // G
    Bm = jnp.repeat(Bm.reshape(B, S, G, N), rep, axis=2)
    Cm = jnp.repeat(Cm.reshape(B, S, G, N), rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])                                   # (nh,)
    y, h_last = ssd_chunked(
        (xs * dt[..., None].astype(xs.dtype)), dt * A[None, None],
        Bm, Cm, chunk=min(s.chunk, S),
        h0=None if state is None else state.ssm)
    y = y + xs * p["D"][None, None, :, None].astype(xs.dtype)
    y = gated_rms_norm(y.reshape(B, S, di), z, p["norm"], cfg.norm_eps)
    out = y @ p["w_out"]
    if return_state:
        return out, SSMState(ssm=h_last, conv=conv_state)
    return out, None


def mamba_decode(cfg: ModelConfig, p: Params, x, state: SSMState):
    """Single-token recurrent step.  x:(B,1,D) → (out, new_state)."""
    s = cfg.ssm
    B, _, D = x.shape
    di, nh, N, G = s.d_inner(D), s.n_heads(D), s.d_state, s.n_groups
    h = x[:, 0] @ p["w_in"]                                    # (B, ·)
    z, xBC, dt = _split_proj(cfg, h[:, None, :])
    z, xBC, dt = z[:, 0], xBC[:, 0], dt[:, 0]
    # rolling conv window
    win = jnp.concatenate([state.conv, xBC[:, :, None]], axis=-1)  # (B,C,W)
    conv_out = jnp.einsum("bcw,wc->bc", win, p["conv_w"]) + p["conv_b"]
    xBC_t = jax.nn.silu(conv_out.astype(jnp.float32)).astype(xBC.dtype)
    new_conv = win[:, :, 1:]
    xs, Bm, Cm = jnp.split(xBC_t, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, nh, s.head_dim)
    rep = nh // G
    Bm = jnp.repeat(Bm.reshape(B, G, N), rep, axis=1)          # (B,nh,N)
    Cm = jnp.repeat(Cm.reshape(B, G, N), rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None])                                  # (B,nh)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt, xs.astype(jnp.float32),
                     Bm.astype(jnp.float32))
    new_ssm = a[..., None, None] * state.ssm + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, Cm.astype(jnp.float32))
    y = y.astype(xs.dtype) + xs * p["D"][None, :, None].astype(xs.dtype)
    y = gated_rms_norm(y.reshape(B, di), z, p["norm"], cfg.norm_eps)
    out = (y @ p["w_out"])[:, None, :]
    return out, SSMState(ssm=new_ssm, conv=new_conv)
