"""Block composition + scan-over-depth for every assigned architecture.

Every arch is expressed as a stack of identical *scan blocks* (plus optionally
a few unrolled leading layers), so the HLO is O(1) in depth:

  dense / vlm       block = [attn + dense FFN]            × L
  moe (deepseek)    unrolled [attn + dense FFN] × first_dense,
                    block = [MLA attn + MoE FFN]           × (L - first_dense)
  moe (arctic)      block = [attn + MoE ∥ dense residual]  × L
  ssm (mamba2)      block = [mamba mixer]                  × L   (no FFN)
  hybrid (jamba)    block = 8-layer period (7×mamba + 1×attn at pos 4;
                    FFN alternates dense/MoE by layer parity)    × L/8
  audio (whisper)   encoder block = [bidir attn + FFN] × n_enc,
                    decoder block = [causal attn + cross-attn + FFN] × L

Caches are pytrees whose leaves are stacked on the block axis so the decode
path scans over (block_params, cache_block) pairs.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (Params, dense_init, embed_init, ffn,
                                 init_ffn, rms_norm, sinusoid_positions,
                                 split_keys)

Identity = lambda x, kind=None: x


@dataclasses.dataclass(frozen=True)
class RunCtx:
    """Per-call runtime knobs threaded through the stack (not traced)."""

    shard: Callable = Identity          # (x, kind) -> x  sharding constraints
    remat: str = "none"                 # none | block
    moe_method: str = "sort"            # sort | cumsum | einsum
    ep: Optional[tuple] = None          # (mesh, tok_axes) expert-parallel relay
    scan_unroll: int = 1
    q_chunk: int = 0                    # 0 = auto (memory-efficient attention)
    tp_size: int = 1                    # model-axis size (layout decisions)
    explicit_fsdp: bool = False         # bf16 expert-weight AG inside relay


DEFAULT_CTX = RunCtx()


def _is_moe_layer(cfg: ModelConfig, i: int) -> bool:
    m = cfg.moe
    return (m.enabled and i >= m.first_dense
            and i % m.moe_every == m.moe_offset)


# --------------------------------------------------------------------------- #
# Layer init (single layer / period); stacked via vmap over keys
# --------------------------------------------------------------------------- #


def _init_attn_layer(key, cfg: ModelConfig, dtype, is_moe: bool,
                     cross: bool = False) -> Params:
    ks = split_keys(key, 5)
    p: Params = {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attn(ks[0], cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
    }
    if is_moe:
        p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"] = init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_act, dtype)
    if cross:
        p["norm_x"] = jnp.ones((cfg.d_model,), dtype)
        p["cross"] = attn.init_gqa(ks[2], cfg, dtype)
    return p


def _init_mamba_layer(key, cfg: ModelConfig, dtype, with_ffn: bool,
                      is_moe: bool) -> Params:
    ks = split_keys(key, 2)
    p: Params = {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "mamba": ssm_mod.init_mamba(ks[0], cfg, dtype),
    }
    if with_ffn:
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        if is_moe:
            p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
        else:
            p["ffn"] = init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_act, dtype)
    return p


def _init_jamba_period(key, cfg: ModelConfig, dtype) -> Params:
    """One 8-layer period: mamba at pos != attn_pos, attn at attn_pos;
    FFN parity: even=dense, odd=MoE (matching moe_every=2, moe_offset=1)."""
    P_ = cfg.attn_period
    ks = split_keys(key, P_)
    layers = []
    for pos in range(P_):
        is_moe = _is_moe_layer(cfg, pos)               # parity matches global
        if pos == cfg.attn_pos:
            layers.append(("attn", _init_attn_layer(ks[pos], cfg, dtype, is_moe)))
        else:
            layers.append(("mamba", _init_mamba_layer(ks[pos], cfg, dtype,
                                                      with_ffn=True,
                                                      is_moe=is_moe)))
    return {f"pos{i}": p for i, (_, p) in enumerate(layers)}


def _stack_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


# --------------------------------------------------------------------------- #
# Layer apply — full-sequence (train/prefill) and decode
# --------------------------------------------------------------------------- #


class BlockOut(NamedTuple):
    x: jax.Array
    cache: Any
    metrics: moe_mod.MoEMetrics


def _apply_ffn(cfg, lp, x, ctx: RunCtx):
    if "moe" in lp:
        out, metrics = moe_mod.moe_ffn(cfg, lp["moe"], x, method=ctx.moe_method,
                                       ep=ctx.ep,
                                       explicit_fsdp=ctx.explicit_fsdp)
    else:
        out, metrics = ffn(lp["ffn"], x, cfg.ffn_act), None
    return out, metrics


def _auto_q_chunk(ctx: RunCtx, Sq: int) -> int:
    if ctx.q_chunk:
        return ctx.q_chunk
    if Sq < 4096:
        return 0
    return 512 if Sq <= 8192 else 256


def _expand_kv(cfg, ctx: RunCtx) -> int:
    """GQA→MHA expansion (to a tp-multiple head count) when neither K nor G
    divides the model axis (keeps the score slab head-shardable end-to-end;
    see attention.sdpa).  Returns the target head count, 0 = off."""
    tp = ctx.tp_size
    if tp <= 1 or cfg.mla is not None or cfg.n_heads == 0:
        return 0
    K, H = cfg.n_kv_heads, cfg.n_heads
    G = H // max(K, 1)
    if K % tp == 0 or G % tp == 0:
        return 0
    return -(-H // tp) * tp


def _attn_layer_full(cfg, lp, x, positions, ctx, cache=None, enc_out=None,
                     causal=True):
    qc = _auto_q_chunk(ctx, x.shape[1])
    ekv = _expand_kv(cfg, ctx)
    h, new_kv = attn.attn_full(cfg, lp["attn"], rms_norm(x, lp["norm1"],
                                                         cfg.norm_eps),
                               positions, cache=cache, shard=ctx.shard,
                               q_chunk=qc, expand_kv=ekv) \
        if causal else \
        attn.gqa_full(cfg, lp["attn"], rms_norm(x, lp["norm1"], cfg.norm_eps),
                      positions, causal=False, cache=cache, shard=ctx.shard,
                      q_chunk=qc, expand_kv=ekv)
    # constrain the projection output BEFORE the add: turns the row-parallel
    # all-reduce into a reduce-scatter onto the sequence-sharded residual
    x = ctx.shard(x + ctx.shard(h, "resid"), "resid")
    new_cache = {"self": new_kv} if new_kv is not None else None
    if enc_out is not None:                            # whisper cross-attn
        hx, _ = attn.gqa_full(cfg, lp["cross"],
                              rms_norm(x, lp["norm_x"], cfg.norm_eps),
                              positions, causal=False, kv_x=enc_out)
        x = ctx.shard(x + ctx.shard(hx, "resid"), "resid")
        if new_cache is not None:
            # precompute cross K/V once for decode
            B, Se, _ = enc_out.shape
            K, hd = cfg.n_kv_heads, cfg.head_dim
            new_cache["cross_k"] = (enc_out @ lp["cross"]["wk"]).reshape(
                B, Se, K, hd)
            new_cache["cross_v"] = (enc_out @ lp["cross"]["wv"]).reshape(
                B, Se, K, hd)
    h, metrics = _apply_ffn(cfg, lp, rms_norm(x, lp["norm2"], cfg.norm_eps), ctx)
    x = ctx.shard(x + ctx.shard(h, "resid"), "resid")
    return x, new_cache, metrics


def _attn_layer_decode(cfg, lp, x, lengths, ctx, cache):
    h, new_kv = attn.attn_decode(cfg, lp["attn"],
                                 rms_norm(x, lp["norm1"], cfg.norm_eps),
                                 lengths, cache["self"])
    x = x + h
    new_cache = dict(cache)
    new_cache["self"] = new_kv
    if "cross_k" in cache:                             # whisper
        hx = attn.gqa_cross_decode(cfg, lp["cross"],
                                   rms_norm(x, lp["norm_x"], cfg.norm_eps),
                                   cache["cross_k"], cache["cross_v"])
        x = x + hx
    h, metrics = _apply_ffn(cfg, lp, rms_norm(x, lp["norm2"], cfg.norm_eps), ctx)
    return x + h, new_cache, metrics


def _mamba_layer_full(cfg, lp, x, ctx, want_state: bool, state=None):
    h, new_state = ssm_mod.mamba_mixer(
        cfg, lp["mamba"], rms_norm(x, lp["norm1"], cfg.norm_eps),
        state=state, return_state=want_state)
    x = ctx.shard(x + ctx.shard(h, "resid"), "resid")
    metrics = None
    if "norm2" in lp:
        h, metrics = _apply_ffn(cfg, lp, rms_norm(x, lp["norm2"], cfg.norm_eps),
                                ctx)
        x = ctx.shard(x + ctx.shard(h, "resid"), "resid")
    return x, new_state, metrics


def _mamba_layer_decode(cfg, lp, x, ctx, state):
    h, new_state = ssm_mod.mamba_decode(
        cfg, lp["mamba"], rms_norm(x, lp["norm1"], cfg.norm_eps), state)
    x = x + h
    metrics = None
    if "norm2" in lp:
        h, metrics = _apply_ffn(cfg, lp, rms_norm(x, lp["norm2"], cfg.norm_eps),
                                ctx)
        x = x + h
    return x, new_state, metrics


def _merge_metrics(cfg, ms):
    ms = [m for m in ms if m is not None]
    if not ms:
        return moe_mod.MoEMetrics.zero(max(cfg.moe.n_experts, 1))
    return moe_mod.MoEMetrics(
        aux_loss=sum(m.aux_loss for m in ms) / len(ms),
        z_loss=sum(m.z_loss for m in ms) / len(ms),
        overflow_frac=sum(m.overflow_frac for m in ms) / len(ms),
        load=sum(m.load for m in ms),
    )


# --------------------------------------------------------------------------- #
# Block apply (one scan step).  mode: train | prefill | decode
# --------------------------------------------------------------------------- #


def block_apply(cfg: ModelConfig, bp: Params, x, *, mode: str, ctx: RunCtx,
                positions=None, lengths=None, cache=None, enc_out=None,
                encoder: bool = False):
    """Apply one scan block.  Returns BlockOut(x, cache_out, metrics)."""
    want_cache = mode != "train"
    if cfg.family == "ssm":
        if mode == "decode":
            x, st, m = _mamba_layer_decode(cfg, bp, x, ctx, cache)
            return BlockOut(x, st, _merge_metrics(cfg, [m]))
        x, st, m = _mamba_layer_full(cfg, bp, x, ctx, want_state=want_cache)
        return BlockOut(x, st, _merge_metrics(cfg, [m]))

    if cfg.is_hybrid:
        ms, new_cache = [], {"attn": None, "ssm": []}
        for pos in range(cfg.attn_period):
            lp = bp[f"pos{pos}"]
            if pos == cfg.attn_pos:
                if mode == "decode":
                    x, c, m = _attn_layer_decode(cfg, lp, x, lengths, ctx,
                                                 {"self": cache["attn"]})
                    new_cache["attn"] = c["self"]
                else:
                    x, c, m = _attn_layer_full(
                        cfg, lp, x, positions, ctx,
                        cache=cache["attn"] if want_cache else None)
                    new_cache["attn"] = c["self"] if c else None
            else:
                midx = pos if pos < cfg.attn_pos else pos - 1
                if mode == "decode":
                    st = jax.tree.map(lambda a: a[midx], cache["ssm"])
                    x, st, m = _mamba_layer_decode(cfg, lp, x, ctx, st)
                else:
                    x, st, m = _mamba_layer_full(cfg, lp, x, ctx,
                                                 want_state=want_cache)
                new_cache["ssm"].append(st)
            ms.append(m)
        if new_cache["ssm"] and new_cache["ssm"][0] is not None:
            new_cache["ssm"] = jax.tree.map(
                lambda *a: jnp.stack(a), *new_cache["ssm"])
        else:
            new_cache = None
        return BlockOut(x, new_cache, _merge_metrics(cfg, ms))

    # plain attention block (dense / moe / vlm / whisper enc+dec)
    if mode == "decode":
        x, c, m = _attn_layer_decode(cfg, bp, x, lengths, ctx, cache)
        return BlockOut(x, c, _merge_metrics(cfg, [m]))
    kv_cache = None
    if want_cache and not encoder:
        kv_cache = cache["self"]
    x, c, m = _attn_layer_full(cfg, bp, x, positions, ctx, cache=kv_cache,
                               enc_out=enc_out, causal=not encoder)
    return BlockOut(x, c, _merge_metrics(cfg, [m]))


# --------------------------------------------------------------------------- #
# Stack apply: scan over blocks
# --------------------------------------------------------------------------- #


def stack_apply(cfg: ModelConfig, stacked: Params, x, *, mode: str,
                ctx: RunCtx, positions=None, lengths=None, caches=None,
                enc_out=None, encoder: bool = False):
    """Scan ``block_apply`` over stacked block params (+ stacked caches).

    Returns (x, stacked_caches_out, metrics).
    """

    def body(carry, xs):
        bp, cache = xs
        out = block_apply(cfg, bp, carry, mode=mode, ctx=ctx,
                          positions=positions, lengths=lengths, cache=cache,
                          enc_out=enc_out, encoder=encoder)
        return out.x, (out.cache, out.metrics)

    if ctx.remat == "block":
        body = jax.checkpoint(body)

    # ``caches=None`` (train / encoder) is a valid empty pytree for scan xs.
    x, (caches_out, metrics) = jax.lax.scan(body, x, (stacked, caches),
                                            unroll=ctx.scan_unroll)
    # reduce stacked per-block metrics: means for scalars, sum for load
    metrics = moe_mod.MoEMetrics(
        aux_loss=metrics.aux_loss.mean(0),
        z_loss=metrics.z_loss.mean(0),
        overflow_frac=metrics.overflow_frac.mean(0),
        load=metrics.load.sum(0),
    )
    return x, caches_out, metrics
