"""Model factory: init / train forward / loss / prefill / decode for every
assigned architecture, built from the block machinery in transformer.py."""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import transformer as tfm
from repro.models.layers import (Params, dense_init, embed_init, rms_norm,
                                 sinusoid_positions, split_keys)
from repro.models.transformer import DEFAULT_CTX, RunCtx

Cache = Any


def n_scan_blocks(cfg: ModelConfig) -> int:
    if cfg.is_hybrid:
        assert cfg.n_layers % cfg.attn_period == 0
        return cfg.n_layers // cfg.attn_period
    return cfg.n_layers - cfg.moe.first_dense


def _block_init_fn(cfg: ModelConfig, dtype):
    fam = cfg.family
    if fam == "ssm":
        return lambda k: tfm._init_mamba_layer(k, cfg, dtype,
                                               with_ffn=cfg.d_ff > 0,
                                               is_moe=False)
    if cfg.is_hybrid:
        return lambda k: tfm._init_jamba_period(k, cfg, dtype)
    if fam == "moe":
        return lambda k: tfm._init_attn_layer(k, cfg, dtype, is_moe=True)
    # dense / vlm / audio decoder
    return lambda k: tfm._init_attn_layer(k, cfg, dtype, is_moe=False,
                                          cross=cfg.is_encdec)


def init_params(cfg: ModelConfig, key, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.dtype)
    D, Vp = cfg.d_model, cfg.vocab_padded
    ks = split_keys(key, 8)
    p: Params = {
        "embed": embed_init(ks[0], (Vp, D), dtype),
        "head": dense_init(ks[1], (D, Vp), dtype),
        "norm_f": jnp.ones((D,), dtype),
        "blocks": tfm._stack_init(_block_init_fn(cfg, dtype), ks[2],
                                  n_scan_blocks(cfg)),
    }
    if cfg.moe.first_dense:
        fk = split_keys(ks[3], cfg.moe.first_dense)
        p["first"] = [tfm._init_attn_layer(fk[i], cfg, dtype, is_moe=False)
                      for i in range(cfg.moe.first_dense)]
    if cfg.is_encdec:
        p["enc"] = {
            "blocks": tfm._stack_init(
                lambda k: tfm._init_attn_layer(k, cfg, dtype, is_moe=False),
                ks[4], cfg.n_enc_layers),
            "norm_f": jnp.ones((D,), dtype),
        }
    return p


# --------------------------------------------------------------------------- #
# Cache init
# --------------------------------------------------------------------------- #


def _stack_zeros(tree, n: int):
    return jax.tree.map(lambda a: jnp.zeros((n,) + a.shape, a.dtype), tree)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Cache:
    dtype = dtype or jnp.dtype(cfg.dtype)
    fam = cfg.family
    if fam == "ssm":
        st = ssm_mod.init_ssm_state(cfg, batch, dtype)
        return _stack_zeros(st, cfg.n_layers)
    if cfg.is_hybrid:
        st = ssm_mod.init_ssm_state(cfg, batch, dtype)
        per = {
            "attn": attn.init_attn_cache(cfg, batch, max_len, dtype),
            "ssm": _stack_zeros(st, cfg.attn_period - 1),
        }
        return _stack_zeros(per, n_scan_blocks(cfg))
    per = {"self": attn.init_attn_cache(cfg, batch, max_len, dtype)}
    if cfg.is_encdec:
        K, hd = cfg.n_kv_heads, cfg.head_dim
        per["cross_k"] = jnp.zeros((batch, cfg.enc_frames, K, hd), dtype)
        per["cross_v"] = jnp.zeros((batch, cfg.enc_frames, K, hd), dtype)
    # attention-family archs use a {"blocks": ...} wrapper (+ optional "first")
    cache = {"blocks": _stack_zeros(per, n_scan_blocks(cfg))}
    if cfg.moe.first_dense:
        cache["first"] = [
            {"self": attn.init_attn_cache(cfg, batch, max_len, dtype)}
            for _ in range(cfg.moe.first_dense)]
    return cache


# --------------------------------------------------------------------------- #
# Encoder (whisper)
# --------------------------------------------------------------------------- #


def encode(cfg: ModelConfig, params: Params, enc_frames, ctx: RunCtx):
    """enc_frames: (B, F, D) precomputed conv-frontend embeddings (stub)."""
    B, F, D = enc_frames.shape
    x = enc_frames.astype(params["embed"].dtype)
    x = x + sinusoid_positions(F, D)[None].astype(x.dtype)
    x = ctx.shard(x, "resid")
    x, _, _ = tfm.stack_apply(cfg, params["enc"]["blocks"], x, mode="train",
                              ctx=ctx, positions=jnp.arange(F)[None],
                              caches=None, encoder=True)
    return rms_norm(x, params["enc"]["norm_f"], cfg.norm_eps)


# --------------------------------------------------------------------------- #
# Train forward / loss
# --------------------------------------------------------------------------- #


def forward(cfg: ModelConfig, params: Params, tokens, *, enc_frames=None,
            ctx: RunCtx = DEFAULT_CTX):
    """Full causal forward → (logits fp32 (B,S,Vp), metrics)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    x = ctx.shard(x, "resid")
    positions = jnp.arange(S)[None]
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(cfg, params, enc_frames, ctx)
    for lp in params.get("first", []):
        x, _, _ = tfm._attn_layer_full(cfg, lp, x, positions, ctx)
    x, _, metrics = tfm.stack_apply(cfg, params["blocks"], x, mode="train",
                                    ctx=ctx, positions=positions, caches=None,
                                    enc_out=enc_out)
    x = rms_norm(x, params["norm_f"], cfg.norm_eps)
    logits = (x @ params["head"]).astype(jnp.float32)
    logits = ctx.shard(logits, "logits")
    return logits, metrics


def loss_fn(cfg: ModelConfig, params: Params, batch: dict, *,
            ctx: RunCtx = DEFAULT_CTX, aux_coef: float = 0.01,
            z_coef: float = 1e-4):
    """batch: tokens (B,S) int32, labels (B,S) int32 (-1 = masked),
    [enc_frames (B,F,D)].  Returns (loss, metrics-dict)."""
    logits, m = forward(cfg, params, batch["tokens"],
                        enc_frames=batch.get("enc_frames"), ctx=ctx)
    labels = batch["labels"]
    Vp = cfg.vocab_padded
    mask = (labels >= 0).astype(jnp.float32)
    lbl = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ce = -jnp.take_along_axis(logp, lbl[..., None], axis=-1)[..., 0]
    ce = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce + aux_coef * m.aux_loss + z_coef * m.z_loss
    return loss, {"loss": loss, "ce": ce, "aux": m.aux_loss, "z": m.z_loss,
                  "overflow": m.overflow_frac, "expert_load": m.load}


# --------------------------------------------------------------------------- #
# Serving: prefill / decode
# --------------------------------------------------------------------------- #


def prefill(cfg: ModelConfig, params: Params, tokens, cache: Cache, *,
            enc_frames=None, ctx: RunCtx = DEFAULT_CTX):
    """Run the full prompt, writing KV/SSM state into ``cache``.

    Returns (last-token logits (B,Vp), cache).
    """
    B, S = tokens.shape
    x = params["embed"][tokens]
    x = ctx.shard(x, "resid")
    positions = jnp.arange(S)[None]
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(cfg, params, enc_frames, ctx)
    new_cache = {}
    if cfg.moe.first_dense:
        new_cache["first"] = []
        for lp, c in zip(params["first"], cache["first"]):
            x, c_out, _ = tfm._attn_layer_full(cfg, lp, x, positions, ctx,
                                               cache=c["self"])
            new_cache["first"].append(c_out)
    blocks_cache = cache["blocks"] if isinstance(cache, dict) and "blocks" in cache else cache
    x, cache_out, metrics = tfm.stack_apply(
        cfg, params["blocks"], x, mode="prefill", ctx=ctx,
        positions=positions, caches=blocks_cache, enc_out=enc_out)
    x = rms_norm(x, params["norm_f"], cfg.norm_eps)
    logits = (x[:, -1] @ params["head"]).astype(jnp.float32)
    if isinstance(cache, dict) and "blocks" in cache:
        new_cache["blocks"] = cache_out
        return logits, new_cache
    return logits, cache_out


def decode_step(cfg: ModelConfig, params: Params, token, lengths,
                cache: Cache, *, ctx: RunCtx = DEFAULT_CTX):
    """One decode step.  token (B,1) int32; lengths (B,) int32 — the position
    each sequence writes at (continuous batching: per-sequence offsets).

    Returns (logits (B,Vp) fp32, cache).
    """
    x = params["embed"][token]                         # (B,1,D)
    new_cache = {}
    positions = lengths[:, None]
    if cfg.moe.first_dense:
        new_cache["first"] = []
        for lp, c in zip(params["first"], cache["first"]):
            x, c_out, _ = tfm._attn_layer_decode(cfg, lp, x, lengths, ctx, c)
            new_cache["first"].append(c_out)
    blocks_cache = cache["blocks"] if isinstance(cache, dict) and "blocks" in cache else cache
    x, cache_out, _ = tfm.stack_apply(
        cfg, params["blocks"], x, mode="decode", ctx=ctx, lengths=lengths,
        caches=blocks_cache)
    x = rms_norm(x, params["norm_f"], cfg.norm_eps)
    logits = (x[:, -1] @ params["head"]).astype(jnp.float32)
    logits = ctx.shard(logits, "logits")
    if isinstance(cache, dict) and "blocks" in cache:
        new_cache["blocks"] = cache_out
        return logits, new_cache
    return logits, cache_out
