"""Attention mixers: GQA/MQA, MLA (DeepSeek-V2), full + cached-decode paths.

Layout conventions
------------------
* Weights are kept FLAT on the head axis — ``wq: (D, H*hd)`` — so explicit
  shardings stay divisible even when ``H`` is not (yi-34b: 56 heads over a
  16-way model axis; 56*128 = 7168 is divisible).  Reshape to heads happens
  inside the mixer where only the compiler sees it.
* ``lengths: (B,)`` int32 — per-sequence valid length.  Full paths mask with a
  causal+length mask; decode paths write KV at ``lengths`` (continuous
  batching: every sequence may sit at a different position).
* Caches are bf16 dicts; decode returns the functionally-updated cache.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.layers import Params, apply_rope, dense_init, split_keys

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# Parameter init
# --------------------------------------------------------------------------- #


def init_gqa(key, cfg: ModelConfig, dtype) -> Params:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    return {
        "wq": dense_init(ks[0], (D, H * hd), dtype),
        "wk": dense_init(ks[1], (D, K * hd), dtype),
        "wv": dense_init(ks[2], (D, K * hd), dtype),
        "wo": dense_init(ks[3], (H * hd, D), dtype),
    }


def init_mla(key, cfg: ModelConfig, dtype) -> Params:
    m: MLAConfig = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    ks = split_keys(key, 6)
    p: Params = {
        # kv down-projection: latent + decoupled rope key
        "w_dkv": dense_init(ks[0], (D, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        # up-projections out of the latent
        "w_uk": dense_init(ks[1], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype),
        "w_uv": dense_init(ks[2], (m.kv_lora_rank, H * m.v_head_dim), dtype),
        "wo": dense_init(ks[3], (H * m.v_head_dim, D), dtype),
    }
    if m.q_lora_rank:
        p["w_dq"] = dense_init(ks[4], (D, m.q_lora_rank), dtype)
        p["w_uq"] = dense_init(ks[5], (m.q_lora_rank, H * m.qk_head_dim), dtype)
    else:
        p["w_uq"] = dense_init(ks[5], (D, H * m.qk_head_dim), dtype)
    return p


def init_attn(key, cfg: ModelConfig, dtype) -> Params:
    if cfg.mla is not None:
        return init_mla(key, cfg, dtype)
    return init_gqa(key, cfg, dtype)


# --------------------------------------------------------------------------- #
# Cache init
# --------------------------------------------------------------------------- #


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    K, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, K, hd), dtype),
        "v": jnp.zeros((batch, max_len, K, hd), dtype),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Params:
    if cfg.mla is not None:
        return init_mla_cache(cfg, batch, max_len, dtype)
    return init_gqa_cache(cfg, batch, max_len, dtype)


# --------------------------------------------------------------------------- #
# Core scaled-dot-product helpers (pure jnp reference path; the Pallas
# flash kernel in kernels/flash_attention is numerically checked against this)
# --------------------------------------------------------------------------- #


def sdpa(q, k, v, scale: float, *, causal: bool = False, mask=None,
         shard=None, q_chunk: int = 0, expand_kv: bool = False):
    """q:(B,Sq,H,hd) k/v:(B,Skv,K,·) grouped-query attention, fp32 softmax.

    ``causal``: build the causal mask on the fly (per chunk — never
    materialised at (Sq, Skv)).  ``mask``: optional explicit mask
    broadcastable to (B, 1, Sq, Skv) (decode path); mutually exclusive with
    ``causal`` chunking.
    ``q_chunk``: >0 → memory-efficient attention: scan over query chunks so
    only a (B, H, CQ, Skv) score slab is alive at a time (the jnp analogue of
    the Pallas flash kernel's VMEM streaming; the dry-run lowers this path).
    ``shard``: optional (x, kind) sharding-constraint callback.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    K = k.shape[2]
    G = H // K
    con = shard or (lambda x, kind: x)
    H_real = H
    if expand_kv:
        # GQA→MHA expansion (+ zero-padding to ``expand_kv`` heads): when
        # neither K nor G divides the model axis (chameleon 8×8, internlm2
        # 8×6, yi 8×7→pad 64, whisper 20×1→pad 32), replicating KV heads
        # makes the whole attention head-shardable end-to-end — trading KV
        # reads (and padded-head FLOPs) for zero score-slab resharding.
        if K < H:
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
            K, G = H, 1
        if expand_kv > H:
            pad = [(0, 0), (0, 0), (0, expand_kv - H), (0, 0)]
            q = jnp.pad(q, pad)
            k = jnp.pad(k, pad)
            v = jnp.pad(v, pad)
            K = H = expand_kv

    def block(q_blk, q_off):
        """q_blk: (B, CQ, K, G, hd); q_off: absolute offset of the chunk."""
        CQ = q_blk.shape[1]
        scores = jnp.einsum("bqkgh,bskh->bkgqs", q_blk, k)
        scores = con(scores.astype(jnp.float32), "scores") * scale
        if causal:
            qpos = q_off + jax.lax.broadcasted_iota(
                jnp.int32, (CQ, Skv), 0)
            kpos = jax.lax.broadcasted_iota(jnp.int32, (CQ, Skv), 1)
            scores = jnp.where((kpos <= qpos)[None, None, None], scores,
                               NEG_INF)
        elif mask is not None:
            scores = jnp.where(mask[:, :, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)

    qg = con(q.reshape(B, Sq, K, G, hd), "heads")
    if q_chunk and Sq > q_chunk and Sq % q_chunk == 0:
        # materialise K/V once per layer OUTSIDE the chunk loop (otherwise the
        # tp all-gather of K/V re-runs every chunk iteration — Megatron-SP's
        # "gather once, reduce-scatter after" pattern)
        k = con(k, "kv_full")
        v = con(v, "kv_full")
        nc = Sq // q_chunk
        qc = qg.reshape(B, nc, q_chunk, K, G, hd).transpose(1, 0, 2, 3, 4, 5)
        offs = jnp.arange(nc, dtype=jnp.int32) * q_chunk
        # checkpoint the chunk: otherwise grad-of-map stores the fp32 score
        # slab of EVERY chunk simultaneously (flash-bwd recompute tradeoff)
        out = jax.lax.map(lambda args: jax.checkpoint(block)(*args),
                          (qc, offs))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, K, G,
                                                      v.shape[-1])
    else:
        out = block(qg, jnp.int32(0))
    out = out.reshape(B, Sq, H, v.shape[-1])
    return out[:, :, :H_real] if H != H_real else out


def make_causal_mask(Sq: int, Skv: int, q_offset: int = 0):
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Skv)[None, :]
    return (kpos <= qpos)[None, None]                  # (1,1,Sq,Skv)


def make_decode_mask(lengths, Skv: int):
    """Decode: new token at position ``lengths`` attends to kpos <= lengths."""
    kpos = jnp.arange(Skv)[None, :]
    return (kpos <= lengths[:, None])[:, None, None]   # (B,1,1,Skv)


# --------------------------------------------------------------------------- #
# GQA/MQA mixer
# --------------------------------------------------------------------------- #


def gqa_full(cfg: ModelConfig, p: Params, x, positions, *, causal: bool = True,
             kv_x=None, kv_positions=None, cache: Optional[Params] = None,
             shard=None, q_chunk: int = 0, expand_kv: bool = False):
    """Full-sequence attention (train / prefill / encoder / cross).

    ``kv_x`` != None → cross-attention (no causal mask, no rope on whisper-style
    cross path is still applied for simplicity of a shared code path: we use
    rope only when kv_x is None, matching whisper's learned-pos stub).
    Returns (out, new_cache); new_cache is None unless ``cache`` given.
    """
    B, Sq, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, Sq, H, hd)
    src = kv_x if kv_x is not None else x
    Skv = src.shape[1]
    k = (src @ p["wk"]).reshape(B, Skv, K, hd)
    v = (src @ p["wv"]).reshape(B, Skv, K, hd)
    if kv_x is None:                                   # self-attention: rope
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions if kv_positions is None else kv_positions,
                       cfg.rope_theta)
    out = sdpa(q, k, v, 1.0 / jnp.sqrt(hd).astype(jnp.float32),
               causal=causal, shard=shard, q_chunk=q_chunk,
               expand_kv=expand_kv)
    new_cache = None
    if cache is not None:
        S = cache["k"].shape[1]
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
            if Skv <= S else cache["k"],
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
            if Skv <= S else cache["v"],
        }
    return out.reshape(B, Sq, H * hd) @ p["wo"], new_cache


def gqa_decode(cfg: ModelConfig, p: Params, x, lengths, cache: Params):
    """One-token decode. x:(B,1,D); cache k/v:(B,S,K,hd); lengths:(B,)."""
    B, _, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    k = (x @ p["wk"]).reshape(B, 1, K, hd)
    v = (x @ p["wv"]).reshape(B, 1, K, hd)
    q = apply_rope(q, lengths[:, None], cfg.rope_theta)
    k = apply_rope(k, lengths[:, None], cfg.rope_theta)
    b = jnp.arange(B)
    ck = cache["k"].at[b, lengths].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[b, lengths].set(v[:, 0].astype(cache["v"].dtype))
    mask = make_decode_mask(lengths, ck.shape[1])
    out = sdpa(q, ck, cv, 1.0 / jnp.sqrt(hd).astype(jnp.float32), mask=mask)
    return out.reshape(B, 1, H * hd) @ p["wo"], {"k": ck, "v": cv}


def gqa_cross_decode(cfg: ModelConfig, p: Params, x, cross_k, cross_v):
    """Cross-attention decode against precomputed encoder K/V (whisper)."""
    B, Sq, D = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, Sq, H, hd)
    out = sdpa(q, cross_k, cross_v, 1.0 / jnp.sqrt(hd).astype(jnp.float32))
    return out.reshape(B, Sq, H * hd) @ p["wo"]


# --------------------------------------------------------------------------- #
# MLA mixer (DeepSeek-V2)
# --------------------------------------------------------------------------- #


def _mla_sdpa(q_nope, q_rope, k_nope, k_rope, v, scale, *, shard=None,
              q_chunk: int = 0):
    """Chunked causal attention with decoupled-rope split scores.

    q_nope/k_nope: (B,S,H,dn); q_rope: (B,S,H,dr); k_rope: (B,S,dr) shared
    across heads; v: (B,S,H,dv).  Head-sharded throughout (H=128 divides any
    sane model axis).
    """
    B, Sq, H, dn = q_nope.shape
    Skv = k_nope.shape[1]
    con = shard or (lambda x, kind: x)

    def block(qn, qr, off):
        s = jnp.einsum("bqhd,bshd->bhqs", qn, k_nope).astype(jnp.float32)
        s = s + jnp.einsum("bqhd,bsd->bhqs", qr, k_rope).astype(jnp.float32)
        s = con(s, "scores4") * scale
        CQ = qn.shape[1]
        qpos = off + jax.lax.broadcasted_iota(jnp.int32, (CQ, Skv), 0)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (CQ, Skv), 1)
        s = jnp.where((kpos <= qpos)[None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqs,bshd->bqhd", w.astype(v.dtype), v)

    q_nope = con(q_nope, "heads4")
    q_rope = con(q_rope, "heads4")
    if q_chunk and Sq > q_chunk and Sq % q_chunk == 0:
        k_nope = con(k_nope, "heads4")         # full-S, head-sharded: fixed
        nc = Sq // q_chunk
        qn = q_nope.reshape(B, nc, q_chunk, H, dn).transpose(1, 0, 2, 3, 4)
        qr = q_rope.reshape(B, nc, q_chunk, H, -1).transpose(1, 0, 2, 3, 4)
        offs = jnp.arange(nc, dtype=jnp.int32) * q_chunk
        out = jax.lax.map(lambda a: jax.checkpoint(block)(*a),
                          (qn, qr, offs))
        return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, v.shape[-1])
    return block(q_nope, q_rope, jnp.int32(0))


def _mla_q(cfg, p, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    hq = x @ p["w_dq"] if "w_dq" in p else x
    q = (hq @ p["w_uq"]).reshape(B, S, H, m.qk_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_full(cfg: ModelConfig, p: Params, x, positions, *,
             cache: Optional[Params] = None, shard=None, q_chunk: int = 0):
    """Full-sequence MLA: materialise k/v from the latent (train/prefill).

    The decoupled-rope split is packed into a single (qk_nope + rope)-wide
    head so the chunked/flash SDPA path is shared with GQA (K=H, G=1).
    """
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    dkv = x @ p["w_dkv"]                               # (B,S,r+rope)
    ckv, k_rope = dkv[..., : m.kv_lora_rank], dkv[..., m.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    k_nope = (ckv @ p["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    v = (ckv @ p["w_uv"]).reshape(B, S, H, m.v_head_dim)
    scale = 1.0 / jnp.sqrt(m.qk_head_dim).astype(jnp.float32)
    # split-score attention: scoring the decoupled rope part against the
    # SHARED (B,S,dr) rope key keeps every wide tensor head-sharded — never
    # concat k_nope with a broadcast k_rope (GSPMD materialises + gathers the
    # (B,S,H,dn+dr) result: 2×380 GB/step on deepseek-v2 train, measured)
    out = _mla_sdpa(q_nope, q_rope, k_nope, k_rope, v, scale, shard=shard,
                    q_chunk=q_chunk)
    out = out.reshape(B, S, H * m.v_head_dim) @ p["wo"]
    new_cache = None
    if cache is not None:
        new_cache = {
            "ckv": jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, axis=1),
            "krope": jax.lax.dynamic_update_slice_in_dim(
                cache["krope"], k_rope.astype(cache["krope"].dtype), 0, axis=1),
        }
    return out, new_cache


def mla_decode(cfg: ModelConfig, p: Params, x, lengths, cache: Params):
    """Absorbed MLA decode: score/accumulate directly in the latent space.

    Per-token cache is only (kv_lora_rank + rope) wide — the paper-relevant
    serving trick that makes deepseek-v2 decode memory tiny.
    """
    m = cfg.mla
    B, _, D = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(cfg, p, x, lengths[:, None])
    # absorb W_UK into q: q_lat[b,h,r] = sum_d q_nope[b,h,d] * w_uk[r, h*d]
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
    dkv = x @ p["w_dkv"]
    ckv_new, krope_new = dkv[..., : m.kv_lora_rank], dkv[..., m.kv_lora_rank:]
    krope_new = apply_rope(krope_new[:, :, None, :], lengths[:, None],
                           cfg.rope_theta)[:, :, 0]
    b = jnp.arange(B)
    ckv = cache["ckv"].at[b, lengths].set(ckv_new[:, 0].astype(cache["ckv"].dtype))
    krope = cache["krope"].at[b, lengths].set(
        krope_new[:, 0].astype(cache["krope"].dtype))
    scale = 1.0 / jnp.sqrt(m.qk_head_dim).astype(jnp.float32)
    s_lat = jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv).astype(jnp.float32)
    s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope, krope).astype(jnp.float32)
    mask = make_decode_mask(lengths, ckv.shape[1])
    scores = jnp.where(mask, (s_lat + s_rope) * scale, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhqs,bsr->bqhr", w.astype(ckv.dtype), ckv)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bqhr,rhd->bqhd", out_lat, w_uv)
    out = out.reshape(B, 1, H * m.v_head_dim) @ p["wo"]
    return out, {"ckv": ckv, "krope": krope}


# --------------------------------------------------------------------------- #
# Unified entry points used by the transformer blocks
# --------------------------------------------------------------------------- #


def attn_full(cfg, p, x, positions, *, cache=None, shard=None,
              q_chunk: int = 0, expand_kv: bool = False):
    if cfg.mla is not None:
        return mla_full(cfg, p, x, positions, cache=cache, shard=shard,
                        q_chunk=q_chunk)
    return gqa_full(cfg, p, x, positions, cache=cache, shard=shard,
                    q_chunk=q_chunk, expand_kv=expand_kv)


def attn_decode(cfg, p, x, lengths, cache):
    if cfg.mla is not None:
        return mla_decode(cfg, p, x, lengths, cache)
    return gqa_decode(cfg, p, x, lengths, cache)
