"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains the *reduced* (smoke) config of the selected
architecture end-to-end (the full configs are exercised via dryrun.py); on a
real fleet the same entry point takes ``--full`` and the production mesh.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import ASSIGNED_ARCHS, get_config, smoke_config
from repro.data.pipeline import DataConfig, Pipeline
from repro.optim import adamw
from repro.runtime import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ASSIGNED_ARCHS
                    + ["xlb-service-model"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (requires a real TPU fleet)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = smoke_config(cfg)
    print(f"training {cfg.name}: {cfg.param_count()/1e6:.1f}M params on "
          f"{len(jax.devices())} device(s)")

    pipe = Pipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.global_batch,
        enc_frames=cfg.enc_frames if cfg.is_encdec else 0,
        d_model=cfg.d_model))
    tcfg = train_loop.TrainConfig(
        steps=args.steps, ckpt_every=max(args.steps // 4, 10),
        ckpt_dir=args.ckpt_dir or f"/tmp/repro-{cfg.name}",
        microbatch=args.microbatch,
        opt=adamw.AdamWConfig(lr=args.lr), log_every=10)
    out = train_loop.run(cfg, pipe, tcfg)
    losses = [h["loss"] for h in out["history"]]
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
