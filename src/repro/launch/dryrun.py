import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, on the single-pod (16,16) mesh
AND the 2-pod (2,16,16) mesh:

    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…,
                           donate_argnums=…).lower(**input_specs(arch, shape))
        compiled = lowered.compile()
        compiled.memory_analysis()   → proves the cell fits per-device HBM
        compiled.cost_analysis()     → HLO FLOPs/bytes for §Roofline
        compiled.as_text()           → collective schedule (parsed, not stored)

Results are cached as JSON under experiments/dryrun/ so the sweep is
resumable; `python -m repro.launch.dryrun --arch X --shape Y [--multi-pod]`
runs one cell, `--all` sweeps everything.

NOTE the first two lines of this file: jax locks the device count at first
init, and ONLY the dry-run should see 512 placeholder CPU devices.
"""

import argparse
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.transformer import RunCtx
from repro.optim import adamw, schedules
from repro.roofline import analysis as RA
from repro.sharding.specs import MeshSpec

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# --------------------------------------------------------------------------- #
# Input specs (ShapeDtypeStruct stand-ins; zero allocation)
# --------------------------------------------------------------------------- #


def input_specs(cfg, shape) -> dict:
    """Abstract inputs for the step function of a given shape kind."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
        if cfg.is_encdec:
            batch["enc_frames"] = sds((B, cfg.enc_frames, cfg.d_model),
                                      jnp.float32)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32)}
        if cfg.is_encdec:
            out["enc_frames"] = sds((B, cfg.enc_frames, cfg.d_model),
                                    jnp.float32)
        return out
    # decode: one new token against a seq_len cache
    return {"token": sds((B, 1), jnp.int32),
            "lengths": sds((B,), jnp.int32)}


def abstract_state(cfg, shape, moment_dtype=jnp.float32):
    """Abstract params / optimizer / cache trees via eval_shape."""
    params = jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    out = {"params": params}
    if shape.kind == "train":
        out["opt"] = jax.eval_shape(partial(_init_opt, moment_dtype), params)
        out["bias"] = sds((max(cfg.moe.n_experts, 1),), jnp.float32)
    else:
        out["cache"] = jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    return out


def _init_opt(moment_dtype, params):
    z = lambda p: jnp.zeros(p.shape, moment_dtype)
    return adamw.AdamWState(step=jnp.zeros((), jnp.int32),
                            m=jax.tree.map(z, params),
                            v=jax.tree.map(z, params))


# --------------------------------------------------------------------------- #
# Step builders
# --------------------------------------------------------------------------- #


def make_ctx(cfg, ms: MeshSpec, shape, *, use_ep=True,
             explicit_fsdp=False) -> RunCtx:
    tok_axes = ms.dp + (ms.tp,)
    n_sh = 1
    for a in tok_axes:
        n_sh *= ms.mesh.shape[a]
    T = shape.global_batch * shape.seq_len
    ep = None
    if (use_ep and cfg.moe.enabled and shape.kind != "decode"
            and T * cfg.moe.top_k % n_sh == 0
            and cfg.moe.n_experts % ms.mesh.shape[ms.tp] == 0):
        ep = (ms.mesh, tok_axes)
    return RunCtx(shard=ms.constrain,
                  remat="block" if shape.kind == "train" else "none",
                  moe_method="sort", ep=ep,
                  tp_size=ms.mesh.shape[ms.tp],
                  explicit_fsdp=explicit_fsdp)


def build_train_step(cfg, ms, shape, moment_dtype, variant=""):
    ctx = make_ctx(cfg, ms, shape, explicit_fsdp=(variant == "exp_fsdp"))
    mb = 4 if variant.startswith("mb") else 0

    def train_step(params, opt_state, bias, batch):
        def loss_fn(p, b):
            return M.loss_fn(cfg, p, b, ctx=ctx)

        if mb:
            # gradient accumulation: trades activation memory for repeated
            # per-microbatch weight gathers (measured in the variant cell)
            B = batch["tokens"].shape[0]
            mbs = jax.tree.map(
                lambda a: a.reshape((mb, B // mb) + a.shape[1:]), batch)
            zeros = jax.tree.map(lambda q: jnp.zeros(q.shape, q.dtype), params)

            def micro(c, one):
                g_acc, l_acc, aux_prev = c
                (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, one)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l, aux), None

            aux0 = jax.eval_shape(lambda: loss_fn(params, jax.tree.map(
                lambda a: a[0], mbs))[1])
            aux0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), aux0)
            (grads, ltot, aux), _ = jax.lax.scan(
                micro, (zeros, jnp.zeros(()), aux0), mbs)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = ltot / mb
        else:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        lr = schedules.warmup_cosine(opt_state.step, warmup=100, total=10_000)
        params, opt_state, stats = adamw.apply(params, grads, opt_state,
                                               adamw.AdamWConfig(), lr)
        bias = adamw.update_router_bias(bias, aux["expert_load"])
        return params, opt_state, bias, {"loss": loss, **stats}

    return train_step, ctx


def build_prefill_step(cfg, ms, shape):
    ctx = make_ctx(cfg, ms, shape)

    def serve_prefill(params, cache, tokens, enc_frames=None):
        logits, cache = M.prefill(cfg, params, tokens, cache,
                                  enc_frames=enc_frames, ctx=ctx)
        return logits, cache

    return serve_prefill, ctx


def build_decode_step(cfg, ms, shape):
    ctx = make_ctx(cfg, ms, shape, use_ep=False)

    def serve_step(params, cache, token, lengths):
        logits, cache = M.decode_step(cfg, params, token, lengths, cache,
                                      ctx=ctx)
        return logits, cache

    return serve_step, ctx


# --------------------------------------------------------------------------- #
# One cell
# --------------------------------------------------------------------------- #


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               moment_dtype_str: str = "auto", variant: str = ""):
    """Lower + compile one (arch × shape × mesh) cell.

    ``variant``: hillclimb layouts — "serve_tp" = pure-TP serving params
    (replicated over dp; each dp slice is an XLB instance lane).
    Returns (compiled, lowered, report_dict).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return None, None, {"skipped": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    ms = MeshSpec(mesh, params_tp_only=(variant == "serve_tp"))
    # Moment dtype: bf16 for the two ≥200B trains on the single pod (fits in
    # 16 GB HBM; recorded in the report), fp32 otherwise.
    if moment_dtype_str == "auto":
        big = cfg.param_count() > 2e11 and not multi_pod
        moment_dtype = jnp.bfloat16 if big else jnp.float32
    else:
        moment_dtype = jnp.dtype(moment_dtype_str)

    state = abstract_state(cfg, shape, moment_dtype)
    inputs = input_specs(cfg, shape)
    p_sh = ms.params_shardings(state["params"])

    with mesh:
        if shape.kind == "train":
            fn, ctx = build_train_step(cfg, ms, shape, moment_dtype, variant)
            opt_sh = adamw.AdamWState(
                step=ms.named(jax.sharding.PartitionSpec()),
                m=jax.tree.map(lambda s: s, p_sh), v=jax.tree.map(lambda s: s, p_sh))
            bias_sh = ms.named(jax.sharding.PartitionSpec())
            batch_sh = ms.batch_shardings(inputs["batch"])
            jitted = jax.jit(fn,
                             in_shardings=(p_sh, opt_sh, bias_sh, batch_sh),
                             out_shardings=(p_sh, opt_sh, bias_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(state["params"], state["opt"],
                                   state["bias"], inputs["batch"])
        elif shape.kind == "prefill":
            fn, ctx = build_prefill_step(cfg, ms, shape)
            c_sh = ms.cache_shardings(cfg, state["cache"])
            tok_sh = ms.named(ms.batch_spec("tokens", inputs["tokens"].shape))
            args = [state["params"], state["cache"], inputs["tokens"]]
            in_sh = [p_sh, c_sh, tok_sh]
            if cfg.is_encdec:
                args.append(inputs["enc_frames"])
                in_sh.append(ms.named(ms.batch_spec(
                    "enc_frames", inputs["enc_frames"].shape)))
            jitted = jax.jit(fn, in_shardings=tuple(in_sh),
                             out_shardings=(None, c_sh), donate_argnums=(1,))
            lowered = jitted.lower(*args)
        else:
            fn, ctx = build_decode_step(cfg, ms, shape)
            c_sh = ms.cache_shardings(cfg, state["cache"])
            tok_sh = ms.named(ms.batch_spec("token", inputs["token"].shape))
            len_sh = ms.named(ms.batch_spec("lengths",
                                            inputs["lengths"].shape))
            jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, tok_sh, len_sh),
                             out_shardings=(None, c_sh), donate_argnums=(1,))
            lowered = jitted.lower(state["params"], state["cache"],
                                   inputs["token"], inputs["lengths"])

        t0 = time.perf_counter()
        compiled = lowered.compile()
        compile_s = time.perf_counter() - t0

    report = RA.analyze_compiled(cfg, shape, ms, compiled,
                                 multi_pod=multi_pod)
    report.update({
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant or "baseline",
        "compile_s": round(compile_s, 1),
        "moment_dtype": str(jnp.dtype(moment_dtype)) if shape.kind == "train"
        else None,
        "ep_relay": ctx.ep is not None,
    })
    return compiled, lowered, report


# --------------------------------------------------------------------------- #
# Sweep driver (JSON-cached, resumable)
# --------------------------------------------------------------------------- #


def cell_path(arch, shape_name, multi_pod, variant=""):
    mesh = "2x16x16" if multi_pod else "16x16"
    suffix = f"__{variant}" if variant else ""
    return os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh}{suffix}.json")


def run_cell(arch, shape_name, multi_pod, force=False, variant="") -> dict:
    path = cell_path(arch, shape_name, multi_pod, variant)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    print(f"=== dry-run {arch} × {shape_name} × "
          f"{'2x16x16' if multi_pod else '16x16'} {variant} ===", flush=True)
    try:
        compiled, lowered, report = lower_cell(arch, shape_name, multi_pod,
                                               variant=variant)
        if compiled is not None:
            print(compiled.memory_analysis())
            ca = compiled.cost_analysis()
            print({k: ca[k] for k in ("flops", "bytes accessed")
                   if k in ca})
    except Exception as e:
        report = {"arch": arch, "shape": shape_name,
                  "mesh": "2x16x16" if multi_pod else "16x16",
                  "error": f"{type(e).__name__}: {e}",
                  "trace": traceback.format_exc()[-2000:]}
        print(f"FAILED: {report['error']}", flush=True)
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="")
    args = ap.parse_args()

    jax.config.update("jax_compilation_cache_dir", "/tmp/jax-cache")
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 5)

    cells = []
    archs = [args.arch] if args.arch else ASSIGNED_ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    failures = 0
    for a, s, mp in cells:
        rep = run_cell(a, s, mp, force=args.force, variant=args.variant)
        if "error" in rep:
            failures += 1
    print(f"\n{len(cells)} cells, {failures} failures")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
