"""Production mesh construction.

Kept as FUNCTIONS (not module constants) so importing this module never
touches jax device state; dryrun.py sets XLA_FLAGS before calling.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.compat import make_mesh
from repro.sharding.specs import MeshSpec


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Target: TPU v5e pods — 16×16 (256 chips) per pod; 2 pods = 512 chips.

    Axes: "pod" (slow DCI hop), "data" (DP/FSDP), "model" (TP/EP/SP).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_spec(*, multi_pod: bool = False) -> MeshSpec:
    return MeshSpec(make_production_mesh(multi_pod=multi_pod))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return make_mesh((data, model), ("data", "model"))


def make_shard_mesh(shards: int, axis: str = "shard") -> Mesh:
    """1-D mesh for the sharded admission datapath (many ingress hosts
    feeding one fleet — ops.admit_commit_sharded, DESIGN.md §7).

    Needs ``shards`` addressable devices; off-hardware runs get them via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before jax
    initializes (cf. tests/test_distributed.py)."""
    n = len(jax.devices())
    if shards > n:
        raise RuntimeError(
            f"{shards}-way admission sharding needs {shards} devices, "
            f"found {n}; off-TPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{shards} before jax initializes")
    return make_mesh((shards,), (axis,))
