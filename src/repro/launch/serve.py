"""Serving launcher: ``python -m repro.launch.serve --arch <id>
--engine xlb|istio|cilium [...]``.

Boots the selected serving engine (the XLB in-graph datapath or either
sidecar baseline — all behind the one Balancer protocol) for the selected
architecture's smoke config, builds routing through a ControlPlane, and
drives a synthetic request stream through the continuous-batching loop.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, smoke_config
from repro.core.balancer import ENGINE_KINDS, make_balancer
from repro.core.control import ControlPlane
from repro.core.routing_table import (POLICY_NAMES, Cluster, Rule,
                                      ServiceConfig)
from repro.models import model as M
from repro.runtime.serve_loop import Request, ServeLoop


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlb-service-model",
                    choices=ASSIGNED_ARCHS + ["xlb-service-model"])
    ap.add_argument("--engine", default="xlb", choices=ENGINE_KINDS)
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=24)
    ap.add_argument("--policy", default="least_request",
                    choices=sorted(POLICY_NAMES),
                    help="load-balancing policy for the serving cluster "
                    "(the registry in core/policy_defs.py)")
    ap.add_argument("--shards", type=int, default=1,
                    help="shard the admission batch + pool over an M-way "
                    "mesh axis (xlb engine only; needs M devices — off-TPU "
                    "set XLA_FLAGS=--xla_force_host_platform_device_count)")
    args = ap.parse_args(argv)

    cfg = smoke_config(get_config(args.arch))
    if cfg.is_encdec:
        raise SystemExit("enc-dec serving needs prompt frames; use the "
                         "dry-run decode cells for whisper")
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    cp = ControlPlane(
        [ServiceConfig("svc", rules=[Rule(0, None, "pool")])],
        [Cluster("pool", endpoints=list(range(args.instances)),
                 policy=POLICY_NAMES[args.policy])])
    kw = {}
    if args.shards > 1:
        if args.engine != "xlb":
            raise SystemExit("--shards needs the in-graph engine "
                             "(--engine xlb); the sidecar baselines route "
                             "on the host")
        if args.instances % args.shards:
            raise SystemExit(f"--instances {args.instances} must divide "
                             f"over --shards {args.shards}")
        from repro.launch.mesh import make_shard_mesh
        kw = dict(shards=args.shards,
                  shard_mesh=make_shard_mesh(args.shards))
    eng = make_balancer(args.engine, cfg, args.instances, args.slots,
                        args.max_len, **kw)
    loop = ServeLoop(eng, params, cp, admit_batch=8, dtype=jnp.float32)

    t0 = time.perf_counter()
    for i in range(args.requests):
        loop.submit(Request(req_id=i, service=0,
                            headers={"path": f"/api/{i % 4}"},
                            prompt_token=3 + i % (cfg.vocab - 3)))
    rep = loop.drain()
    wall = time.perf_counter() - t0
    lat = [r.t_done - r.t_submit for r in rep.done] or [float("nan")]
    print(f"{cfg.name} [{args.engine}]: {len(rep.done)} requests in "
          f"{wall:.2f}s ({len(rep.done)/wall:.1f} req/s), avg latency "
          f"{1e3*np.mean(lat):.1f} ms, p99 {1e3*np.percentile(lat, 99):.1f} ms")
    if rep.queued or rep.inflight or rep.dropped:
        print(f"drain left: queued={rep.queued} inflight={rep.inflight} "
              f"dropped={len(rep.dropped)}")
    m = loop.state.metrics
    print(f"metrics: tx={int(m.tx_bytes.sum())}B rx={int(m.rx_bytes.sum())}B "
          f"no_route={int(m.no_route_match)} overflow={int(m.overflow)}")
    return len(rep.done)


if __name__ == "__main__":
    main()
