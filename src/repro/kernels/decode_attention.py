"""GQA decode attention (one token vs a long KV cache) as a Pallas kernel.

TPU adaptation: FlashDecoding's split-K over SMs becomes KV-block streaming
along the minor (sequential) grid dimension with running (m, l, acc) state in
VMEM scratch, exactly like the prefill kernel but with Sq = 1 packed as the
G axis: the (G, BK) score tile keeps the MXU busy even at batch-1 decode
(G = q-heads-per-kv-head, e.g. 6–8 for GQA; the paper-assigned archs make
this the dominant serving shape, decode_32k).

Per-sequence lengths (continuous batching) mask the tail blocks; blocks past
the longest length still stream but are masked (static grid — the verifier-
friendly bounded loop, cf. eBPF).

Grid: (B·K, S/BK).  q: (B, K, G, hd) packed; cache k/v: (B, S, K, hd).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, block_k: int):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (G, hd)
    k = k_ref[0].astype(jnp.float32)                  # (BK, hd)
    v = v_ref[0].astype(jnp.float32)                  # (BK, hd)
    length = len_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos <= length, s, NEG_INF)         # per-seq causal bound

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, block_k: int = 512,
                     scale: float | None = None,
                     interpret: bool | None = None):
    """q: (B, H, hd); k/v_cache: (B, S, K, hd); lengths: (B,) — new token sits
    at position ``lengths[b]`` (already written into the cache).

    Returns (B, H, hd).
    """
    B, H, hd = q.shape
    _, S, K, _ = k_cache.shape
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_k = min(block_k, S)
    assert S % block_k == 0

    qp = q.reshape(B, K, G, hd).reshape(B * K, G, hd)
    kt = k_cache.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    vt = v_cache.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    lens = jnp.repeat(lengths.astype(jnp.int32), K)

    grid = (B * K, S // block_k)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bk, ki: (bk,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, hd), lambda bk, ki: (bk, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bk, ki: (bk, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bk, ki: (bk, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda bk, ki: (bk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(lens, qp, kt, vt)
    return out.reshape(B, K * G, hd)
