"""Relay slot assignment (counting-sort rank) as a Pallas TPU kernel.

The socket-relay dispatch needs, per payload row, its *stable rank among rows
with the same destination* (→ pool slot).  The GShard form is a (N, E)
one-hot cumsum — O(N·E) memory traffic.  This kernel tiles it: a (BN, E)
one-hot tile is built in VMEM, ranks within the tile come from a local
cumsum, and a running per-destination base counter (E,) carried in VMEM
scratch across the sequential grid provides the global offset.  HBM traffic
drops from O(N·E) to O(N + E) per tile — the difference between streaming
the whole dispatch matrix and streaming only the index vector.

Grid: (N / BN,) sequential.  idx: (N,) int32 destinations.
Outputs: slot (N,) int32 rank-within-destination; load (E,) int32 totals.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _relay_kernel(idx_ref, slot_ref, load_ref, counts_ref, *, n_dest: int,
                  block_n: int):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    idx = idx_ref[...]                                  # (BN,)
    oh = (idx[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block_n, n_dest), 1)).astype(jnp.int32)
    local_rank = jnp.cumsum(oh, axis=0) - oh            # rank before self
    base = counts_ref[...]                              # (E,)
    # padding rows carry the sentinel destination n_dest: no one-hot lane
    # matches them (no rank, no load), and the base gather clamps in-range
    slot_ref[...] = (base[jnp.minimum(idx, n_dest - 1)]
                     + jnp.sum(local_rank * oh, axis=1)).astype(jnp.int32)
    counts_ref[...] = base + jnp.sum(oh, axis=0)

    @pl.when(i == n - 1)
    def _emit():
        load_ref[...] = counts_ref[...]


def relay_slots(idx, n_dest: int, *, block_n: int = 1024,
                interpret: bool | None = None):
    """idx: (N,) int32 → (slot (N,), load (E,)).  Oracle: relay.positions_*.

    Any ``N`` works: non-tile-divisible batches pad up to the block multiple
    with the sentinel destination ``n_dest`` (inert in-kernel — matches no
    one-hot lane, counts no load) and the padded slots are sliced off."""
    N = idx.shape[0]
    if N == 0:
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((n_dest,), jnp.int32))
    block_n = min(block_n, N)
    Np = -(-N // block_n) * block_n
    idx = idx.astype(jnp.int32)
    if Np != N:
        idx = jnp.concatenate([idx, jnp.full((Np - N,), n_dest, jnp.int32)])
    grid = (Np // block_n,)
    slot, load = pl.pallas_call(
        functools.partial(_relay_kernel, n_dest=n_dest, block_n=block_n),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((n_dest,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((Np,), jnp.int32),
                   jax.ShapeDtypeStruct((n_dest,), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((n_dest,), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(idx)
    return slot[:N], load
