"""Relay slot assignment (counting-sort rank) as a Pallas TPU kernel.

The socket-relay dispatch needs, per payload row, its *stable rank among rows
with the same destination* (→ pool slot).  The GShard form is a (N, E)
one-hot cumsum — O(N·E) memory traffic.  This kernel tiles it: a (BN, E)
one-hot tile is built in VMEM, ranks within the tile come from a local
cumsum, and a running per-destination base counter (E,) carried in VMEM
scratch across the sequential grid provides the global offset.  HBM traffic
drops from O(N·E) to O(N + E) per tile — the difference between streaming
the whole dispatch matrix and streaming only the index vector.

Grid: (N / BN,) sequential.  idx: (N,) int32 destinations.
Outputs: slot (N,) int32 rank-within-destination; load (E,) int32 totals.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _relay_kernel(idx_ref, slot_ref, load_ref, counts_ref, *, n_dest: int,
                  block_n: int):
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    idx = idx_ref[...]                                  # (BN,)
    oh = (idx[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block_n, n_dest), 1)).astype(jnp.int32)
    local_rank = jnp.cumsum(oh, axis=0) - oh            # rank before self
    base = counts_ref[...]                              # (E,)
    slot_ref[...] = (base[idx] + jnp.sum(local_rank * oh, axis=1)
                     ).astype(jnp.int32)
    counts_ref[...] = base + jnp.sum(oh, axis=0)

    @pl.when(i == n - 1)
    def _emit():
        load_ref[...] = counts_ref[...]


def relay_slots(idx, n_dest: int, *, block_n: int = 1024,
                interpret: bool | None = None):
    """idx: (N,) int32 → (slot (N,), load (E,)).  Oracle: relay.positions_*."""
    N = idx.shape[0]
    block_n = min(block_n, N)
    assert N % block_n == 0
    grid = (N // block_n,)
    slot, load = pl.pallas_call(
        functools.partial(_relay_kernel, n_dest=n_dest, block_n=block_n),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                   pl.BlockSpec((n_dest,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((N,), jnp.int32),
                   jax.ShapeDtypeStruct((n_dest,), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((n_dest,), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(idx.astype(jnp.int32))
    return slot, load
