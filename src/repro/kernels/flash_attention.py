"""Flash attention (prefill, causal, GQA) as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): FlashAttention's GPU formulation is built
around warp-level softmax rescaling in SRAM; on TPU the same IO-aware idea
becomes *block streaming through VMEM with MXU-shaped tiles*: q tiles of
(BQ=128, hd) stay resident, K/V stream in (BK=128, hd) tiles along the minor
(sequential) grid dimension, and the online-softmax running max/denominator
live in VMEM scratch that persists across the KV grid steps.  All matmul
dims are multiples of 128 to keep the MXU systolic array full.

Grid: (B·H, S/BQ, S/BK), minor-most (KV) iterated sequentially per TPU core.
Causal blocks above the diagonal are skipped with ``pl.when`` (no FLOPs, no
HBM reads beyond the prefetch of the block — matches the ~2× causal saving).

GQA: the index_map folds the q-head → kv-head mapping (H = K·G), so no
KV replication is materialised.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip blocks strictly above the diagonal
    run = (qi * block_q >= ki * block_k) if causal else True

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)                   # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)                   # (BK, hd)
        v = v_ref[0].astype(jnp.float32)                   # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, scale: float | None = None,
                    interpret: bool | None = None):
    """q: (B, S, H, hd); k/v: (B, S, K, hd) with H = K·G.  → (B, S, H, hd).

    VMEM working set per program:
      q tile BQ·hd·4 + k/v tiles 2·BK·hd·4 + acc BQ·hd·4 + m/l ≈ 0.4 MB at
      (128, 128) — far under the ~16 MB VMEM budget, leaving room for the
      compiler's double buffering of the streamed K/V tiles.
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)

    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * K, S, hd)

    grid = (B * H, S // block_q, S // block_k)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                          block_k=block_k, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max
            pltpu.VMEM((block_q,), jnp.float32),       # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),    # output accumulator
        ],
        interpret=resolve_interpret(interpret),
    )(qt, kt, vt)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
