"""Block-size autotuner for the datapath kernels (admit / completion).

The right tile shape for the fused Pallas programs is backend- and
shape-dependent: under the CPU interpreter the grid is a sequential loop, so
small tiles multiply per-op dispatch overhead while huge tiles blow up the
per-tile intermediates (the least-request water-fill is O(BR·WE·log BR));
on a real TPU the trade is VMEM footprint vs pipeline occupancy.  Rather
than hard-coding one ``block_r``, the ops wrappers ask this module for a
plan at first use: the sweep times the actual kernel on synthetic
shape-matched inputs for a handful of candidate tile sizes, picks the
fastest, and caches the choice per (kernel, backend, shape) for the life of
the process.  Everything flows through ``kernels/ops.py``'s
``static_argnames`` seam, so a plan is just a pair of compile-time
constants.

Environment overrides (CI determinism — a pinned run never sweeps):

  ``XLB_AUTOTUNE=0``   disable sweeping entirely: heuristic defaults
  ``XLB_BLOCK_R=n``    pin the admit/admit_commit tile rows
  ``XLB_BLOCK_I=n``    pin the completion tile lanes
  ``XLB_FOLD=name``    pin the aggregation strategy (``onehot``/``segment``)

Explicit keyword arguments at a call site outrank the environment; the
environment outranks the cache/sweep; the sweep outranks the static
defaults.  The fold strategy itself is categorical per backend
(``backend.default_fold``) — the sweep only searches tile sizes.
"""

from __future__ import annotations

import math
import os
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import backend

ENV_AUTOTUNE = "XLB_AUTOTUNE"
ENV_BLOCK_R = "XLB_BLOCK_R"
ENV_BLOCK_I = "XLB_BLOCK_I"
ENV_FOLD = "XLB_FOLD"

DEFAULT_BLOCK_R = 256
DEFAULT_BLOCK_I = 8
BLOCK_R_CANDIDATES = (64, 256, 1024)
BLOCK_I_CANDIDATES = (1, 4, 8, 16)

# (kernel, backend, *shape) → chosen block size
_cache: dict[tuple, int] = {}
_log: list[tuple] = []     # sweep history, for tests/inspection


def clear_cache() -> None:
    _cache.clear()
    _log.clear()


def autotune_enabled() -> bool:
    return os.environ.get(ENV_AUTOTUNE, "1").lower() not in ("0", "false",
                                                             "off")


def _env_int(name: str) -> int | None:
    v = os.environ.get(name, "").strip()
    return int(v) if v else None


def resolve_fold(fold: str | None) -> str:
    """Explicit arg > XLB_FOLD > backend default."""
    if fold is not None:
        return backend.resolve_fold(fold)
    return backend.resolve_fold(os.environ.get(ENV_FOLD, "").strip() or None)


def _time_best(fn, *args, reps: int = 3, trials: int = 3) -> float:
    """Min-of-trials per-call seconds (min, not median: the sweep wants the
    noise floor, and candidates share the same noisy machine)."""
    out = fn(*args)                        # compile outside timing
    jax.block_until_ready(out)
    best = math.inf
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / reps)
    return best


def _sweep(key: tuple, candidates, make_fn) -> int:
    """Time each candidate block size, cache and return the fastest.

    Runs under ``jax.core.eval_context()``: plans are usually requested
    while an outer program (the engine's ``serve_step``, a benchmark
    closure) is being traced, and modern JAX stages every op issued during
    tracing — the eval context escapes the ambient trace so the synthetic
    runs compile and execute concretely (``ensure_compile_time_eval``
    is not enough: it inlines the inner jit, which breaks pallas_call)."""
    if key in _cache:
        return _cache[key]
    timings = {}
    with jax.core.eval_context():
        for cand in candidates:
            timings[cand] = _time_best(make_fn(cand))
    best = min(timings, key=timings.get)
    _cache[key] = best
    _log.append((key, best, timings))
    return best


# --------------------------------------------------------------------------- #
# admit / admit_commit
# --------------------------------------------------------------------------- #


def _admit_candidates(R: int) -> list[int]:
    return sorted({min(b, R) for b in BLOCK_R_CANDIDATES})


def _synthetic_admit(R: int, I: int, C: int, fold: str, commit: bool):
    """A shape-matched workload for the sweep.  The segment fold gates
    per-policy work with runtime ``lax.cond`` on the cluster table, so the
    synthetic config routes traffic to a LEAST_REQUEST cluster *and* keeps
    a WEIGHTED cluster in the table — both heavy branches (water-fill and
    Gumbel argmax) execute, timing the conservative cost curve.  Drains
    are left off (the steady state the serving path runs)."""
    from repro.core.routing_table import (MAX_EPS_PER_CLUSTER, N_FEATURES,
                                          POLICY_LEAST_REQUEST,
                                          POLICY_WEIGHTED, Cluster, Rule,
                                          ServiceConfig, build_state)
    from repro.kernels import route_match as _rm

    eps = [i % max(I, 1) for i in range(min(8, I))]
    state, _ = build_state(
        [ServiceConfig("t", rules=[Rule(0, None, "pool")])],
        [Cluster("pool", endpoints=eps, policy=POLICY_LEAST_REQUEST),
         Cluster("alt", endpoints=eps[:1], policy=POLICY_WEIGHTED)])
    rid = jnp.arange(R, dtype=jnp.int32)
    z = jnp.zeros((R,), jnp.int32)
    feats = jnp.zeros((R, N_FEATURES), jnp.int32)
    gum = jnp.zeros((R, MAX_EPS_PER_CLUSTER), jnp.float32)
    if commit:
        pool = [jnp.full((I, C), -1, jnp.int32), jnp.full((I, C), -1,
                                                          jnp.int32),
                jnp.zeros((I, C), jnp.int32), jnp.zeros((I, C), jnp.int32),
                jnp.zeros((I, C), jnp.int32), jnp.zeros((I, C), jnp.int32)]

        def make_fn(block_r):
            return jax.jit(partial(_rm.admit_commit, block_r=block_r,
                                   fold=fold)), (rid, z, feats, z, z, state,
                                                 *pool, z, gum)
    else:
        free = jnp.ones((I, C), jnp.int32)

        def make_fn(block_r):
            return jax.jit(partial(_rm.admit, block_r=block_r,
                                   fold=fold)), (rid, z, feats, z, state,
                                                 free, z, gum)
    return make_fn


def plan_admit(R: int, pool_shape: tuple, *, block_r: int | None = None,
               fold: str | None = None,
               commit: bool = False) -> tuple[int, str]:
    """Resolve (block_r, fold) for an admit/admit_commit launch of ``R``
    requests over an (I, C) pool.  Shapes only — safe to call mid-trace
    (the sweep runs on synthetic concrete inputs)."""
    fold = resolve_fold(fold)
    if block_r is not None:
        return block_r, fold
    env = _env_int(ENV_BLOCK_R)
    if env is not None:
        return env, fold
    if R <= 0:
        return DEFAULT_BLOCK_R, fold
    I, C = pool_shape
    key = ("admit_commit" if commit else "admit",
           backend.backend_kind(), fold, R, I, C)
    if key in _cache:
        return _cache[key], fold
    cands = _admit_candidates(R)
    if not autotune_enabled() or len(cands) == 1:
        return min(DEFAULT_BLOCK_R, R), fold

    def make_fn(b):     # called under _sweep's compile-time-eval guard
        fn, args = _synthetic_admit(R, I, C, fold, commit)(b)
        return partial(fn, *args)

    return _sweep(key, cands, make_fn), fold


# --------------------------------------------------------------------------- #
# complete
# --------------------------------------------------------------------------- #


def _complete_candidates(I: int) -> list[int]:
    return sorted({math.gcd(I, max(1, b)) for b in BLOCK_I_CANDIDATES + (I,)})


def plan_complete(pool_shape: tuple, *, block_i: int | None = None,
                  fold: str | None = None) -> tuple[int, str]:
    """Resolve (block_i, fold) for a completion launch over an (I, C) pool."""
    from repro.core.routing_table import MAX_ENDPOINTS, MAX_SERVICES
    from repro.kernels import completion as _cp

    fold = resolve_fold(fold)
    if block_i is not None:
        return block_i, fold
    env = _env_int(ENV_BLOCK_I)
    if env is not None:
        return env, fold
    I, C = pool_shape
    key = ("complete", backend.backend_kind(), fold, I, C)
    if key in _cache:
        return _cache[key], fold
    cands = _complete_candidates(I)
    if not autotune_enabled() or len(cands) == 1:
        return math.gcd(I, DEFAULT_BLOCK_I), fold

    def make_fn(b):     # called under _sweep's compile-time-eval guard
        pool = [jnp.full((I, C), -1, jnp.int32),
                jnp.full((I, C), -1, jnp.int32),
                jnp.zeros((I, C), jnp.int32), jnp.zeros((I, C), jnp.int32),
                jnp.zeros((I, C), jnp.int32), jnp.ones((I, C), jnp.int32)]
        nxt = jnp.zeros((I, C), jnp.int32)
        load = jnp.zeros((MAX_ENDPOINTS,), jnp.int32)
        rx = jnp.zeros((MAX_SERVICES,), jnp.int32)
        fn = jax.jit(partial(_cp.complete, eos=1, max_len=16, block_i=b,
                             fold=fold))
        return partial(fn, *pool, nxt, load, rx)

    return _sweep(key, cands, make_fn), fold
