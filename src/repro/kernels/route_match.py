"""XLB datapath hot loop — rule match + least-request select — as one fused
Pallas kernel (the paper's filter_manager → route_manager → load_balancer
tail-call chain, Figure 4).

The eBPF version walks ROUTE_MAX_NUM rules per request and scans endpoint
load counters; the TPU version processes a (BR) tile of requests against the
full (bounded) rule window and endpoint window in VMEM with masked vector
ops — the verifier's static bounds become the static block shapes.

Per request r:
  1. rules[svc_start[svc_r] .. +count]: first i where field matches → cluster
  2. endpoints[cluster_start .. +count]: argmin load (least-request)
Outputs: cluster id (-1 = no_route_match), endpoint id (-1 = unroutable).

Grid: (R / BR,).  Tables are small (≤ 64×… int32) and stay VMEM-resident
across the whole grid — they are the eBPF maps pinned in kernel memory.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.routing_table import (MAX_EPS_PER_CLUSTER, MAX_RULES_PER_SVC,
                                      WILDCARD)

BIG = 2**30        # python literal — a jnp scalar here would be captured as
                   # a constant by the Pallas kernel (verifier-rejected)


def _route_kernel(svc_ref, feat_ref, rs_ref, rc_ref, rf_ref, rv_ref,
                  rcl_ref, cs_ref, cc_ref, load_ref, cluster_ref, ep_ref, *,
                  block_r: int):
    svc = svc_ref[...]                                 # (BR,)
    feats = feat_ref[...]                              # (BR, F)
    W = MAX_RULES_PER_SVC

    start = rs_ref[svc]                                # (BR,)
    count = rc_ref[svc]
    win = jax.lax.broadcasted_iota(jnp.int32, (block_r, W), 1)
    idx = jnp.clip(start[:, None] + win, 0, rf_ref.shape[0] - 1)
    in_range = win < count[:, None]
    fields = rf_ref[idx]                               # (BR, W)
    expect = rv_ref[idx]
    actual = jnp.take_along_axis(feats, fields, axis=1)
    hit = in_range & ((expect == WILDCARD) | (expect == actual))
    any_hit = hit.any(axis=1)
    first = jnp.argmax(hit, axis=1)
    rix = jnp.take_along_axis(idx, first[:, None], axis=1)[:, 0]
    cluster = jnp.where(any_hit, rcl_ref[rix], -1)
    cluster_ref[...] = cluster

    # least-request over the endpoint window (paper: full scan; small N)
    WE = MAX_EPS_PER_CLUSTER
    cl = jnp.maximum(cluster, 0)
    estart = cs_ref[cl]
    ecount = cc_ref[cl]
    ewin = jax.lax.broadcasted_iota(jnp.int32, (block_r, WE), 1)
    eidx = jnp.clip(estart[:, None] + ewin, 0, load_ref.shape[0] - 1)
    eok = ewin < ecount[:, None]
    load = jnp.where(eok, load_ref[eidx], BIG)
    best = jnp.argmin(load, axis=1)
    ep = jnp.take_along_axis(eidx, best[:, None], axis=1)[:, 0]
    ep_ref[...] = jnp.where((cluster >= 0) & (ecount > 0), ep, -1)


def route_match(svc, features, state, *, block_r: int = 256,
                interpret: bool = True):
    """svc: (R,) i32; features: (R, F) i32; state: RoutingState.

    Returns (cluster (R,), endpoint (R,)) — least-request selection.
    """
    R, F = features.shape
    block_r = min(block_r, R)
    assert R % block_r == 0
    grid = (R // block_r,)
    tables = [state.svc_rule_start, state.svc_rule_count, state.rule_field,
              state.rule_value, state.rule_cluster, state.cluster_ep_start,
              state.cluster_ep_count, state.ep_load]
    table_specs = [
        pl.BlockSpec(t.shape, lambda r, _n=len(t.shape): (0,) * _n)
        for t in tables]
    cluster, ep = pl.pallas_call(
        functools.partial(_route_kernel, block_r=block_r),
        grid=grid,
        in_specs=[pl.BlockSpec((block_r,), lambda r: (r,)),
                  pl.BlockSpec((block_r, F), lambda r: (r, 0))] + table_specs,
        out_specs=[pl.BlockSpec((block_r,), lambda r: (r,)),
                   pl.BlockSpec((block_r,), lambda r: (r,))],
        out_shape=[jax.ShapeDtypeStruct((R,), jnp.int32),
                   jax.ShapeDtypeStruct((R,), jnp.int32)],
        interpret=interpret,
    )(svc, features, *tables)
    return cluster, ep
