"""XLB datapath hot loop as fused Pallas kernels (the paper's filter_manager
→ route_manager → load_balancer tail-call chain, Figure 4).

Two entry points:

``route_match``
  rule match + least-request endpoint scan only (the original kernel, kept
  as the small building block and for the kernel test sweeps).

``admit``
  the full in-kernel admission datapath: rule match → per-cluster policy
  dispatch (round-robin / random / least-request / weighted) → endpoint
  selection with *sequentially consistent* load counters → free-slot
  allocation → fused per-service metrics.  The mutable LB state (``ep_load``,
  ``rr_cursor``, per-instance slot cursors) is carried in VMEM scratch across
  the sequential grid — the same running-counter trick as
  ``kernels/relay_dispatch.py`` — so a request admitted in tile ``i`` is
  visible to every decision in tile ``i+1``, exactly like the eBPF map a
  per-packet program updates in place.

``admit_commit``
  ``admit`` plus the pool-commit stage: admitted requests write all six
  per-(instance, slot) connection-state fields (req_id, endpoint, svc,
  length, token, active) directly inside the kernel, so ``Engine.admit``
  needs no post-pass scatters at all — the whole connect path is one Pallas
  program.  The (I, C) pool rides in the revisited whole-array output
  blocks; each tile folds its writes in with a one-hot mask (slots are
  collision-free by construction: the slot allocator hands out each free
  slot at most once per batch).

Sequential least-request without a per-request scan: request ``r`` with
in-tile cluster rank ``ρ`` takes the endpoint owning the ``ρ``-th smallest
"ticket" of the multiset ``{load_j + t : t ≥ 0}`` ordered by (value, j) —
the water-filling closed form of "argmin then increment" — found by a
static-depth binary search over ticket values.  This replaces the three
full-batch argsorts of the staged jnp path with O(B·W·log B) vector ops.

Grid: (R / BR,) sequential.  Tables are small (≤ 512 int32) and stay
VMEM-resident across the whole grid — the eBPF maps pinned in kernel memory.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret
from repro.core.routing_table import (MAX_EPS_PER_CLUSTER, MAX_RULES_PER_SVC,
                                      POLICY_LEAST_REQUEST, POLICY_RANDOM,
                                      POLICY_RR, POLICY_WEIGHTED, WILDCARD)

BIG = 2**30        # python literal — a jnp scalar here would be captured as
                   # a constant by the Pallas kernel (verifier-rejected)


def _table_spec(shape: tuple) -> pl.BlockSpec:
    """Whole-array BlockSpec for a VMEM-resident table: every grid step maps
    block (0, ..., 0) with rank matching the table (a closure per table, so a
    2-D table can never silently bind a 1-D index map)."""

    def index_map(r):
        return (0,) * len(shape)

    return pl.BlockSpec(shape, index_map)


def _match_stage(svc, feats, rs_ref, rc_ref, rf_ref, rv_ref, rcl_ref, *,
                 block_r: int):
    """Vectorised bounded rule-chain walk: first matching rule → cluster."""
    W = MAX_RULES_PER_SVC
    start = rs_ref[svc]                                # (BR,)
    count = rc_ref[svc]
    win = jax.lax.broadcasted_iota(jnp.int32, (block_r, W), 1)
    idx = jnp.clip(start[:, None] + win, 0, rf_ref.shape[0] - 1)
    in_range = win < count[:, None]
    fields = rf_ref[idx]                               # (BR, W)
    expect = rv_ref[idx]
    actual = jnp.take_along_axis(feats, fields, axis=1)
    hit = in_range & ((expect == WILDCARD) | (expect == actual))
    any_hit = hit.any(axis=1)
    first = jnp.argmax(hit, axis=1)
    rix = jnp.take_along_axis(idx, first[:, None], axis=1)[:, 0]
    return jnp.where(any_hit, rcl_ref[rix], -1)


# --------------------------------------------------------------------------- #
# route_match: match + least-request scan (stateless building block)
# --------------------------------------------------------------------------- #


def _route_kernel(svc_ref, feat_ref, rs_ref, rc_ref, rf_ref, rv_ref,
                  rcl_ref, cs_ref, cc_ref, load_ref, cluster_ref, ep_ref, *,
                  block_r: int):
    svc = svc_ref[...]                                 # (BR,)
    cluster = _match_stage(svc, feat_ref[...], rs_ref, rc_ref, rf_ref,
                           rv_ref, rcl_ref, block_r=block_r)
    cluster_ref[...] = cluster

    # least-request over the endpoint window (paper: full scan; small N)
    WE = MAX_EPS_PER_CLUSTER
    cl = jnp.maximum(cluster, 0)
    estart = cs_ref[cl]
    ecount = cc_ref[cl]
    ewin = jax.lax.broadcasted_iota(jnp.int32, (block_r, WE), 1)
    eidx = jnp.clip(estart[:, None] + ewin, 0, load_ref.shape[0] - 1)
    eok = ewin < ecount[:, None]
    load = jnp.where(eok, load_ref[eidx], BIG)
    best = jnp.argmin(load, axis=1)
    ep = jnp.take_along_axis(eidx, best[:, None], axis=1)[:, 0]
    ep_ref[...] = jnp.where((cluster >= 0) & (ecount > 0), ep, -1)


def route_match(svc, features, state, *, block_r: int = 256,
                interpret: bool | None = None):
    """svc: (R,) i32; features: (R, F) i32; state: RoutingState.

    Returns (cluster (R,), endpoint (R,)) — least-request selection.
    """
    R, F = features.shape
    block_r = min(block_r, R)
    assert R % block_r == 0
    grid = (R // block_r,)
    tables = [state.svc_rule_start, state.svc_rule_count, state.rule_field,
              state.rule_value, state.rule_cluster, state.cluster_ep_start,
              state.cluster_ep_count, state.ep_load]
    cluster, ep = pl.pallas_call(
        functools.partial(_route_kernel, block_r=block_r),
        grid=grid,
        in_specs=[pl.BlockSpec((block_r,), lambda r: (r,)),
                  pl.BlockSpec((block_r, F), lambda r: (r, 0))]
                 + [_table_spec(t.shape) for t in tables],
        out_specs=[pl.BlockSpec((block_r,), lambda r: (r,)),
                   pl.BlockSpec((block_r,), lambda r: (r,))],
        out_shape=[jax.ShapeDtypeStruct((R,), jnp.int32),
                   jax.ShapeDtypeStruct((R,), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(svc, features, *tables)
    return cluster, ep


# --------------------------------------------------------------------------- #
# admit: the fused route → balance → slot-allocate datapath
# --------------------------------------------------------------------------- #


class AdmitResult(NamedTuple):
    """Everything ``Engine.admit`` needs from one fused kernel launch."""

    cluster: jax.Array       # (R,) i32 destination cluster (-1 = no match)
    endpoint: jax.Array      # (R,) i32 global endpoint (-1 = unroutable)
    instance: jax.Array      # (R,) i32 instance lane (-1 = unroutable)
    slot: jax.Array          # (R,) i32 pool slot (-1 = held / unroutable)
    ok: jax.Array            # (R,) i32 1 = admitted into a pool slot
    ep_load: jax.Array       # (E,) i32 updated outstanding-request counters
    rr_cursor: jax.Array     # (CL,) i32 updated round-robin cursors
    svc_requests: jax.Array  # (S,) i32 admitted requests per service
    svc_tx_bytes: jax.Array  # (S,) i32 admitted payload bytes per service
    no_route: jax.Array      # () i32 valid requests with no rule match
    held: jax.Array          # () i32 routable requests without a free slot


def _admit_kernel(*refs, block_r: int, commit: bool):
    if commit:
        (rid_ref, svc_ref, feat_ref, bytes_ref, rnd_ref, gum_ref, tok_ref,
         rs_ref, rc_ref, rf_ref, rv_ref, rcl_ref,
         cs_ref, cc_ref, cp_ref, einst_ref, ew_ref,
         load0_ref, cur0_ref, free_ref,
         preq0_ref, pep0_ref, psvc0_ref, plen0_ref, ptok0_ref,
         cluster_ref, ep_ref, inst_ref, slot_ref, ok_ref,
         loadout_ref, curout_ref, sreq_ref, stx_ref, cnt_ref,
         preq_ref, pep_ref, psvc_ref, plen_ref, ptok_ref, pact_ref,
         load_s, held_s, cur_s, icnt_s, sreq_s, stx_s, cnt_s) = refs
    else:
        (rid_ref, svc_ref, feat_ref, bytes_ref, rnd_ref, gum_ref,
         rs_ref, rc_ref, rf_ref, rv_ref, rcl_ref,
         cs_ref, cc_ref, cp_ref, einst_ref, ew_ref,
         load0_ref, cur0_ref, free_ref,
         cluster_ref, ep_ref, inst_ref, slot_ref, ok_ref,
         loadout_ref, curout_ref, sreq_ref, stx_ref, cnt_ref,
         load_s, held_s, cur_s, icnt_s, sreq_s, stx_s, cnt_s) = refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        load_s[...] = load0_ref[...]
        held_s[...] = jnp.zeros_like(held_s)
        cur_s[...] = cur0_ref[...]
        icnt_s[...] = jnp.zeros_like(icnt_s)
        sreq_s[...] = jnp.zeros_like(sreq_s)
        stx_s[...] = jnp.zeros_like(stx_s)
        cnt_s[...] = jnp.zeros_like(cnt_s)
        if commit:
            # the pool rides in whole-array output blocks revisited by every
            # grid step: seed from the incoming pool, fold writes per tile
            preq_ref[...] = preq0_ref[...]
            pep_ref[...] = pep0_ref[...]
            psvc_ref[...] = psvc0_ref[...]
            plen_ref[...] = plen0_ref[...]
            ptok_ref[...] = ptok0_ref[...]
            pact_ref[...] = 1 - free_ref[...]

    S = rs_ref.shape[0]
    CL = cc_ref.shape[0]
    E = load0_ref.shape[0]
    I, C = free_ref.shape
    WE = MAX_EPS_PER_CLUSTER

    # ---- stage 1: content match (vectorised rule-chain walk) ---------- #
    valid = rid_ref[...] >= 0
    svc = jnp.clip(svc_ref[...], 0, S - 1)
    cluster = _match_stage(svc, feat_ref[...], rs_ref, rc_ref, rf_ref,
                           rv_ref, rcl_ref, block_r=block_r)
    cluster = jnp.where(valid, cluster, -1)

    cl = jnp.maximum(cluster, 0)
    count = cc_ref[cl]                                 # (BR,)
    estart = cs_ref[cl]
    policy = cp_ref[cl]
    routable = valid & (cluster >= 0) & (count > 0)
    count1 = jnp.maximum(count, 1)

    ewin = jax.lax.broadcasted_iota(jnp.int32, (block_r, WE), 1)
    eidx = jnp.clip(estart[:, None] + ewin, 0, E - 1)  # (BR, WE)
    eok = ewin < count[:, None]

    # in-tile arrival rank within each cluster (counting-sort one-hot
    # cumsum, cf. relay_dispatch) — only routable requests consume ranks
    oh_c = (routable[:, None] & (cl[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block_r, CL), 1))).astype(jnp.int32)
    rank_c = jnp.sum((jnp.cumsum(oh_c, axis=0) - oh_c) * oh_c, axis=1)

    # ---- stage 2: policy dispatch ------------------------------------- #
    # round-robin: carried cursor + arrival rank ≡ cursor++ per request
    rr_off = (cur_s[...][cl] + rank_c) % count1
    # random: host-precomputed draw (keeps the host PRNG stream)
    rnd_off = rnd_ref[...] % count1
    # weighted: Gumbel-max over log-weights (noise precomputed on host)
    w = jnp.where(eok, ew_ref[eidx], 0.0)
    wt_off = jnp.argmax(jnp.where(eok, jnp.log(w + 1e-9) + gum_ref[...],
                                  -jnp.inf), axis=1).astype(jnp.int32)
    # least-request, sequentially consistent: request with cluster rank ρ
    # owns the ρ-th smallest ticket of {load_j + t : t ≥ 0} ordered by
    # (value, j) — binary-search the ticket value v, then take the m-th
    # endpoint among those with load_j <= v
    load = jnp.where(eok, load_s[...][eidx], BIG)      # (BR, WE)
    lo = jnp.min(load, axis=1)                         # (BR,)
    hi = lo + rank_c
    tgt = rank_c + 1
    for _ in range(max(block_r, 2).bit_length()):
        mid = (lo + hi) // 2
        n_mid = jnp.sum(jnp.maximum(mid[:, None] - load + 1, 0), axis=1)
        ge = n_mid >= tgt
        hi = jnp.where(ge, mid, hi)
        lo = jnp.where(ge, lo, mid + 1)
    v = lo
    n_prev = jnp.sum(jnp.maximum(v[:, None] - load, 0), axis=1)
    m = rank_c - n_prev                                # rank among value-v ties
    elig = (load <= v[:, None])
    ec = jnp.cumsum(elig.astype(jnp.int32), axis=1)
    lr_off = jnp.argmax(elig & (ec == (m + 1)[:, None]),
                        axis=1).astype(jnp.int32)

    off = jnp.select(
        [policy == POLICY_RR, policy == POLICY_RANDOM,
         policy == POLICY_LEAST_REQUEST, policy == POLICY_WEIGHTED],
        [rr_off, rnd_off, lr_off, wt_off], rr_off).astype(jnp.int32)
    ep = jnp.take_along_axis(eidx, off[:, None], axis=1)[:, 0]
    ep = jnp.where(routable, ep, -1)
    epc = jnp.maximum(ep, 0)
    inst = jnp.where(routable, einst_ref[epc], -1)
    instc = jnp.clip(inst, 0, I - 1)

    # ---- stage 3: free-slot allocation (counting-sort fold) ----------- #
    oh_i = (routable[:, None] & (instc[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block_r, I), 1))).astype(jnp.int32)
    rank_i = (icnt_s[...][instc]
              + jnp.sum((jnp.cumsum(oh_i, axis=0) - oh_i) * oh_i, axis=1))
    rows = free_ref[...][instc]                        # (BR, C) free=1
    prefix = jnp.cumsum(rows, axis=1)
    n_free = prefix[:, C - 1]
    ok = routable & (rank_i < n_free)
    hit = (rows > 0) & (prefix == (rank_i + 1)[:, None])
    slot = jnp.where(ok, jnp.argmax(hit, axis=1).astype(jnp.int32), -1)
    held = routable & ~ok

    # ---- per-request outputs ------------------------------------------ #
    cluster_ref[...] = cluster
    ep_ref[...] = ep
    inst_ref[...] = inst
    slot_ref[...] = slot
    ok_ref[...] = ok.astype(jnp.int32)

    # ---- stage 4 (commit mode): pool writeback ------------------------ #
    if commit:
        # one-hot over flattened (I*C) pool cells; the slot allocator never
        # hands the same (inst, slot) to two requests in one batch, so each
        # cell has at most one writer and a plain sum recovers its value
        flat = instc * C + jnp.where(ok, slot, 0)
        oh_p = (ok[:, None] & (flat[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (block_r, I * C), 1))).astype(jnp.int32)
        wrote = jnp.sum(oh_p, axis=0).reshape(I, C) > 0

        def fold(ref, vals):
            v = jnp.sum(oh_p * vals[:, None], axis=0).reshape(I, C)
            ref[...] = jnp.where(wrote, v, ref[...])

        fold(preq_ref, rid_ref[...])
        fold(pep_ref, ep)
        fold(psvc_ref, svc_ref[...])        # raw svc, as the engine stores it
        fold(plen_ref, jnp.zeros_like(slot))
        fold(ptok_ref, tok_ref[...])
        pact_ref[...] = jnp.where(wrote, 1, pact_ref[...])

    # ---- carried LB state + fused metrics ----------------------------- #
    oh_e = (routable[:, None] & (epc[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block_r, E), 1))).astype(jnp.int32)
    load_s[...] = load_s[...] + jnp.sum(oh_e, axis=0)
    held_s[...] = held_s[...] + jnp.sum(
        oh_e * held.astype(jnp.int32)[:, None], axis=0)
    cur_s[...] = (cur_s[...] + jnp.sum(oh_c, axis=0)) % jnp.maximum(
        cc_ref[...], 1)
    icnt_s[...] = icnt_s[...] + jnp.sum(oh_i, axis=0)
    # per-service metrics drop svc >= S (the staged scatter's mode="drop")
    # instead of folding rogue ids into service S-1 via the table clip
    oh_s = ((ok & (svc_ref[...] < S))[:, None]
            & (svc[:, None] == jax.lax.broadcasted_iota(
                jnp.int32, (block_r, S), 1))).astype(jnp.int32)
    sreq_s[...] = sreq_s[...] + jnp.sum(oh_s, axis=0)
    stx_s[...] = stx_s[...] + jnp.sum(oh_s * bytes_ref[...][:, None], axis=0)
    cnt_s[...] = cnt_s[...] + jnp.stack(
        [jnp.sum((valid & (cluster < 0)).astype(jnp.int32)),
         jnp.sum(held.astype(jnp.int32))])

    @pl.when(i == pl.num_programs(0) - 1)
    def _emit():
        # held requests release their counter (connection close of the
        # paper's hold queue) — folded into the final emit
        loadout_ref[...] = load_s[...] - held_s[...]
        curout_ref[...] = cur_s[...]
        sreq_ref[...] = sreq_s[...]
        stx_ref[...] = stx_s[...]
        cnt_ref[...] = cnt_s[...]


class AdmitCommitResult(NamedTuple):
    """``AdmitResult`` plus the committed (I, C) connection pools."""

    cluster: jax.Array
    endpoint: jax.Array
    instance: jax.Array
    slot: jax.Array
    ok: jax.Array
    ep_load: jax.Array
    rr_cursor: jax.Array
    svc_requests: jax.Array
    svc_tx_bytes: jax.Array
    no_route: jax.Array
    held: jax.Array
    pool_req_id: jax.Array   # (I, C) i32
    pool_endpoint: jax.Array
    pool_svc: jax.Array
    pool_length: jax.Array
    pool_token: jax.Array
    pool_active: jax.Array   # (I, C) i32 (0/1)


def _pad_rows(block_r: int, req_id, svc, features, msg_bytes, rnd, gumbel,
              token=None):
    """Pad ragged batches with req_id=-1 rows (inert in-kernel: no counter,
    metric or pool touches); callers slice per-request outputs back."""
    R0, F = features.shape
    R = -(-R0 // block_r) * block_r
    if R != R0:
        pad = R - R0
        req_id = jnp.concatenate([req_id, jnp.full((pad,), -1, jnp.int32)])
        svc = jnp.concatenate([svc, jnp.zeros((pad,), svc.dtype)])
        features = jnp.concatenate(
            [features, jnp.zeros((pad, F), features.dtype)])
        msg_bytes = jnp.concatenate(
            [msg_bytes, jnp.zeros((pad,), msg_bytes.dtype)])
        rnd = jnp.concatenate([rnd, jnp.zeros((pad,), rnd.dtype)])
        gumbel = jnp.concatenate(
            [gumbel, jnp.zeros((pad, gumbel.shape[1]), gumbel.dtype)])
        if token is not None:
            token = jnp.concatenate([token, jnp.zeros((pad,), token.dtype)])
    return R, req_id, svc, features, msg_bytes, rnd, gumbel, token


def _launch_admit(req_id, svc, features, msg_bytes, state, free_i32, rnd,
                  gumbel, token, pool, *, block_r: int,
                  interpret: bool | None):
    """Shared pallas_call plumbing for ``admit`` (pool=None) and
    ``admit_commit`` (pool = 5 incoming (I, C) i32 arrays)."""
    commit = pool is not None
    R0, F = features.shape
    R, req_id, svc, features, msg_bytes, rnd, gumbel, token = _pad_rows(
        block_r, req_id, svc, features, msg_bytes, rnd, gumbel, token)
    grid = (R // block_r,)
    tables = [state.svc_rule_start, state.svc_rule_count, state.rule_field,
              state.rule_value, state.rule_cluster, state.cluster_ep_start,
              state.cluster_ep_count, state.cluster_policy,
              state.ep_instance, state.ep_weight, state.ep_load,
              state.rr_cursor, free_i32]
    S = state.svc_rule_start.shape[0]
    CL = state.cluster_ep_count.shape[0]
    E = state.ep_load.shape[0]
    I, C = free_i32.shape
    req = pl.BlockSpec((block_r,), lambda r: (r,))
    in_arrays = [req_id.astype(jnp.int32), svc.astype(jnp.int32), features,
                 msg_bytes.astype(jnp.int32), rnd.astype(jnp.int32),
                 gumbel.astype(jnp.float32)]
    in_specs = [req, req,
                pl.BlockSpec((block_r, F), lambda r: (r, 0)),
                req, req,
                pl.BlockSpec((block_r, MAX_EPS_PER_CLUSTER),
                             lambda r: (r, 0))]
    if commit:
        in_arrays.append(token.astype(jnp.int32))
        in_specs.append(req)
    in_arrays += tables
    in_specs += [_table_spec(t.shape) for t in tables]
    if commit:
        in_arrays += list(pool)
        in_specs += [_table_spec((I, C))] * 5
    out_specs = [req] * 5 + [_table_spec((E,)), _table_spec((CL,)),
                             _table_spec((S,)), _table_spec((S,)),
                             _table_spec((2,))]
    out_shape = [jax.ShapeDtypeStruct((R,), jnp.int32)] * 5 \
        + [jax.ShapeDtypeStruct((E,), jnp.int32),
           jax.ShapeDtypeStruct((CL,), jnp.int32),
           jax.ShapeDtypeStruct((S,), jnp.int32),
           jax.ShapeDtypeStruct((S,), jnp.int32),
           jax.ShapeDtypeStruct((2,), jnp.int32)]
    if commit:
        out_specs += [_table_spec((I, C))] * 6
        out_shape += [jax.ShapeDtypeStruct((I, C), jnp.int32)] * 6
    o = pl.pallas_call(
        functools.partial(_admit_kernel, block_r=block_r, commit=commit),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((E,), jnp.int32),
                        pltpu.VMEM((E,), jnp.int32),
                        pltpu.VMEM((CL,), jnp.int32),
                        pltpu.VMEM((I,), jnp.int32),
                        pltpu.VMEM((S,), jnp.int32),
                        pltpu.VMEM((S,), jnp.int32),
                        pltpu.VMEM((2,), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(*in_arrays)
    head = (o[0][:R0], o[1][:R0], o[2][:R0], o[3][:R0], o[4][:R0],
            o[5], o[6], o[7], o[8], o[9][0], o[9][1])
    return head + tuple(o[10:])


def admit(req_id, svc, features, msg_bytes, state, free_mask, rnd, gumbel, *,
          block_r: int = 256, interpret: bool | None = None) -> AdmitResult:
    """Fused admission datapath over a request batch.

    req_id/svc/msg_bytes/rnd: (R,) i32 (req_id < 0 = padding; rnd = host
    PRNG draws for the random policy); features: (R, F) i32;
    gumbel: (R, MAX_EPS_PER_CLUSTER) f32 noise for the weighted policy;
    state: RoutingState; free_mask: (I, C) — nonzero/True = free slot.

    Sequential semantics (cross-checked bit-exactly against
    ``kernels.ref.admit_ref``): requests are processed in arrival order;
    every routable request advances its cluster's rr cursor and increments
    its endpoint's load counter immediately; requests that find no free pool
    slot are *held* and release their counter at the end of the batch.
    """
    R0, F = features.shape
    if R0 == 0:                         # empty batch: nothing to admit
        z = jnp.zeros((0,), jnp.int32)
        zs = jnp.zeros_like(state.svc_rule_start)
        return AdmitResult(
            z, z, z, z, z, state.ep_load,
            state.rr_cursor % jnp.maximum(state.cluster_ep_count, 1),
            zs, zs, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    block_r = min(block_r, R0)
    # booleanize: the kernel cumsums the mask as per-slot counts, so an
    # integer mask cell > 1 would double-count free slots
    o = _launch_admit(req_id, svc, features, msg_bytes, state,
                      (free_mask != 0).astype(jnp.int32), rnd, gumbel,
                      None, None, block_r=block_r, interpret=interpret)
    return AdmitResult(*o)


def admit_commit(req_id, svc, features, msg_bytes, token, state,
                 pool_req_id, pool_endpoint, pool_svc, pool_length,
                 pool_token, pool_active, rnd, gumbel, *,
                 block_r: int = 256,
                 interpret: bool | None = None) -> AdmitCommitResult:
    """``admit`` + in-kernel pool commit (the paper's full connect path).

    Same contract as ``admit`` with the free-slot mask derived from
    ``pool_active`` (~active = free); admitted requests additionally write
    req_id/endpoint/svc/length=0/token/active=1 at their (instance, slot)
    inside the kernel — no ``scatter_to_pool`` post-pass.  Bit-exact against
    ``kernels.ref.admit_commit_ref``.
    """
    R0, F = features.shape
    active_i32 = (pool_active != 0).astype(jnp.int32)   # booleanized 0/1
    pool = (pool_req_id.astype(jnp.int32), pool_endpoint.astype(jnp.int32),
            pool_svc.astype(jnp.int32), pool_length.astype(jnp.int32),
            pool_token.astype(jnp.int32))
    if R0 == 0:                         # empty batch: pool passes through
        z = jnp.zeros((0,), jnp.int32)
        zs = jnp.zeros_like(state.svc_rule_start)
        return AdmitCommitResult(
            z, z, z, z, z, state.ep_load,
            state.rr_cursor % jnp.maximum(state.cluster_ep_count, 1),
            zs, zs, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            *pool, active_i32)
    block_r = min(block_r, R0)
    o = _launch_admit(req_id, svc, features, msg_bytes, state,
                      1 - active_i32, rnd, gumbel, token, pool,
                      block_r=block_r, interpret=interpret)
    return AdmitCommitResult(*o)
