"""XLB datapath hot loop as fused Pallas kernels (the paper's filter_manager
→ route_manager → load_balancer tail-call chain, Figure 4).

Two entry points:

``route_match``
  rule match + least-request endpoint scan only (the original kernel, kept
  as the small building block and for the kernel test sweeps).

``admit``
  the full in-kernel admission datapath: rule match → per-cluster policy
  dispatch (round-robin / random / least-request / weighted) → endpoint
  selection with *sequentially consistent* load counters → free-slot
  allocation → fused per-service metrics.  The mutable LB state (``ep_load``,
  ``rr_cursor``, per-instance slot cursors) is carried in VMEM scratch across
  the sequential grid — the same running-counter trick as
  ``kernels/relay_dispatch.py`` — so a request admitted in tile ``i`` is
  visible to every decision in tile ``i+1``, exactly like the eBPF map a
  per-packet program updates in place.

``admit_commit``
  ``admit`` plus the pool-commit stage: admitted requests write all six
  per-(instance, slot) connection-state fields (req_id, endpoint, svc,
  length, token, active) directly inside the kernel, so ``Engine.admit``
  needs no post-pass scatters at all — the whole connect path is one Pallas
  program.  The (I, C) pool rides in the revisited whole-array output
  blocks; each tile folds its writes in with a one-hot mask (slots are
  collision-free by construction: the slot allocator hands out each free
  slot at most once per batch).

Sequential least-request without a per-request scan: request ``r`` with
in-tile cluster rank ``ρ`` takes the endpoint owning the ``ρ``-th smallest
"ticket" of the multiset ``{load_j + t : t ≥ 0}`` ordered by (value, j) —
the water-filling closed form of "argmin then increment".  The onehot fold
finds the level by a static-depth binary search (Mosaic-friendly); the
segment fold reads it from per-cluster sorted-prefix tables (one (CL, WE)
sort shared by every request of the tile).

Every aggregation (LB counters, rr cursors, slot ranks, metrics, pool
commit) goes through the tiled segment-fold seam at the top of this module
(``_seg_sum`` / ``_seg_rank``, DESIGN.md §5): ``fold="onehot"`` keeps the
dense Mosaic-lowerable dispatch matrices, ``fold="segment"`` scatter-adds
and sorts in O(rows + buckets) per tile — the CPU-interpreter default.

Selection consults the control plane's ``ep_drained`` mask under EVERY
policy: drained endpoints leave the eligible set at once (rr/random cycle
over the k-th *eligible* endpoint, least-request sees their load as BIG,
weighted masks their Gumbel score), and a fully-drained cluster is
unroutable like an empty one.

Grid: (R / BR,) sequential.  Tables are small (≤ 512 int32) and stay
VMEM-resident across the whole grid — the eBPF maps pinned in kernel memory.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import policy_defs
from repro.core.policy_defs import BIG  # noqa: F401  (re-export: the
# sentinel and the policy enum live in core/policy_defs.py — ONE
# definition site for kernel, oracle and staged chain, DESIGN.md §9)
from repro.core.routing_table import (MAX_EPS_PER_CLUSTER, MAX_RULES_PER_SVC,
                                      POLICY_AFFINITY, POLICY_RR, WILDCARD)
from repro.kernels.backend import resolve_fold, resolve_interpret


def _table_spec(shape: tuple) -> pl.BlockSpec:
    """Whole-array BlockSpec for a VMEM-resident table: every grid step maps
    block (0, ..., 0) with rank matching the table (a closure per table, so a
    2-D table can never silently bind a 1-D index map)."""

    def index_map(r):
        return (0,) * len(shape)

    return pl.BlockSpec(shape, index_map)


# --------------------------------------------------------------------------- #
# Tiled segment folds — the aggregation strategy seam (DESIGN.md §5)
#
# Every aggregation in the datapath kernels is "fold per-row values into a
# small carried vector, bucketed by a per-row id" (LB load counters, rr
# cursors, per-service metrics, pool commit).  Two implementations share one
# contract, selected by the static ``fold`` argument:
#
#   fold="onehot"   materializes the (rows, buckets) dispatch matrix — pure
#                   iota/compare/cumsum, the Mosaic-lowerable form (on TPU
#                   the sum is an MXU matmul in disguise); O(rows·buckets)
#                   VPU work per tile.
#   fold="segment"  scatter-adds straight into the carried vector and ranks
#                   via one stable sort — O(rows + buckets) per tile, the
#                   form XLA:CPU executes in linear time.  This is what the
#                   CPU interpreter runs by default; it is also the layout
#                   that psums cleanly for the mesh-sharded admission plan
#                   (per-shard (E,) partials, no dispatch matrices).
#
# Rows a caller wants dropped are steered to bucket id == width: the one-hot
# comparison never matches it, the scatter drops it via mode="drop".
# --------------------------------------------------------------------------- #


def _seg_sum(vec, ids, vals, *, fold: str):
    """Fold ``vals`` (rows,) into ``vec`` (K,) at buckets ``ids``; ids >= K
    are dropped.  Returns the updated vector."""
    K = vec.shape[0]
    if fold == "segment":
        return vec.at[ids].add(vals, mode="drop")
    oh = ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (ids.shape[0], K), 1)
    return vec + jnp.sum(jnp.where(oh, vals[:, None], 0), axis=0)


def _seg_rank(ids, mask, n_seg: int, *, fold: str, block_r: int):
    """In-tile arrival rank of each row among rows sharing its id (the
    counting sort of relay_dispatch), plus the per-id row counts.  Rows
    with mask=False get an arbitrary rank and count nothing — callers gate
    on the mask.  fold="onehot": (BR, K) one-hot cumsum; fold="segment":
    one stable argsort + a segmented iota, with the counts read off the
    sorted keys by searchsorted (no scatter).  Returns (rank (BR,),
    counts (K,))."""
    if fold == "segment":
        key = jnp.where(mask, ids, n_seg)              # masked → sentinel
        order = jnp.argsort(key)                       # stable: arrival order
        sk = key[order]
        iota = jax.lax.iota(jnp.int32, block_r)
        first = sk != jnp.concatenate([jnp.full((1,), -1, sk.dtype),
                                       sk[:-1]])       # segment boundaries
        start = jax.lax.cummax(jnp.where(first, iota, 0))
        rank = jnp.zeros((block_r,), jnp.int32).at[order].set(
            iota - start, mode="drop")
        edges = jnp.searchsorted(sk, jnp.arange(n_seg + 1, dtype=jnp.int32))
        return rank, (edges[1:] - edges[:-1]).astype(jnp.int32)
    oh = (mask[:, None] & (ids[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block_r, n_seg), 1))).astype(jnp.int32)
    return jnp.sum((jnp.cumsum(oh, axis=0) - oh) * oh, axis=1), \
        jnp.sum(oh, axis=0)


def _match_stage(svc, feats, rs_ref, rc_ref, rf_ref, rv_ref, rcl_ref, *,
                 block_r: int):
    """Vectorised bounded rule-chain walk: first matching rule → cluster."""
    W = MAX_RULES_PER_SVC
    start = rs_ref[svc]                                # (BR,)
    count = rc_ref[svc]
    win = jax.lax.broadcasted_iota(jnp.int32, (block_r, W), 1)
    idx = jnp.clip(start[:, None] + win, 0, rf_ref.shape[0] - 1)
    in_range = win < count[:, None]
    fields = rf_ref[idx]                               # (BR, W)
    expect = rv_ref[idx]
    actual = jnp.take_along_axis(feats, fields, axis=1)
    hit = in_range & ((expect == WILDCARD) | (expect == actual))
    any_hit = hit.any(axis=1)
    first = jnp.argmax(hit, axis=1)
    rix = jnp.take_along_axis(idx, first[:, None], axis=1)[:, 0]
    return jnp.where(any_hit, rcl_ref[rix], -1)


# --------------------------------------------------------------------------- #
# route_match: match + least-request scan (stateless building block)
# --------------------------------------------------------------------------- #


def _route_kernel(svc_ref, feat_ref, rs_ref, rc_ref, rf_ref, rv_ref,
                  rcl_ref, cs_ref, cc_ref, load_ref, cluster_ref, ep_ref, *,
                  block_r: int):
    # clamp like _admit_kernel: a hostile/garbage svc id must not walk the
    # rule tables out of window (refs have no OOB semantics once compiled)
    svc = jnp.clip(svc_ref[...], 0, rs_ref.shape[0] - 1)   # (BR,)
    cluster = _match_stage(svc, feat_ref[...], rs_ref, rc_ref, rf_ref,
                           rv_ref, rcl_ref, block_r=block_r)
    cluster_ref[...] = cluster

    # least-request over the endpoint window (paper: full scan; small N)
    WE = MAX_EPS_PER_CLUSTER
    cl = jnp.maximum(cluster, 0)
    estart = cs_ref[cl]
    ecount = cc_ref[cl]
    ewin = jax.lax.broadcasted_iota(jnp.int32, (block_r, WE), 1)
    eidx = jnp.clip(estart[:, None] + ewin, 0, load_ref.shape[0] - 1)
    eok = ewin < ecount[:, None]
    load = jnp.where(eok, load_ref[eidx], BIG)
    best = jnp.argmin(load, axis=1)
    ep = jnp.take_along_axis(eidx, best[:, None], axis=1)[:, 0]
    ep_ref[...] = jnp.where((cluster >= 0) & (ecount > 0), ep, -1)


def route_match(svc, features, state, *, block_r: int = 256,
                interpret: bool | None = None):
    """svc: (R,) i32; features: (R, F) i32; state: RoutingState.

    Returns (cluster (R,), endpoint (R,)) — least-request selection.
    """
    R, F = features.shape
    block_r = min(block_r, R)
    assert R % block_r == 0
    grid = (R // block_r,)
    tables = [state.svc_rule_start, state.svc_rule_count, state.rule_field,
              state.rule_value, state.rule_cluster, state.cluster_ep_start,
              state.cluster_ep_count, state.ep_load]
    cluster, ep = pl.pallas_call(
        functools.partial(_route_kernel, block_r=block_r),
        grid=grid,
        in_specs=[pl.BlockSpec((block_r,), lambda r: (r,)),
                  pl.BlockSpec((block_r, F), lambda r: (r, 0))]
                 + [_table_spec(t.shape) for t in tables],
        out_specs=[pl.BlockSpec((block_r,), lambda r: (r,)),
                   pl.BlockSpec((block_r,), lambda r: (r,))],
        out_shape=[jax.ShapeDtypeStruct((R,), jnp.int32),
                   jax.ShapeDtypeStruct((R,), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(svc, features, *tables)
    return cluster, ep


# --------------------------------------------------------------------------- #
# admit: the fused route → balance → slot-allocate datapath
# --------------------------------------------------------------------------- #


class AdmitResult(NamedTuple):
    """Everything ``Engine.admit`` needs from one fused kernel launch."""

    cluster: jax.Array       # (R,) i32 destination cluster (-1 = no match)
    endpoint: jax.Array      # (R,) i32 global endpoint (-1 = unroutable)
    instance: jax.Array      # (R,) i32 instance lane (-1 = unroutable)
    slot: jax.Array          # (R,) i32 pool slot (-1 = held / unroutable)
    ok: jax.Array            # (R,) i32 1 = admitted into a pool slot
    ep_load: jax.Array       # (E,) i32 updated outstanding-request counters
    rr_cursor: jax.Array     # (CL,) i32 updated round-robin cursors
    svc_requests: jax.Array  # (S,) i32 admitted requests per service
    svc_tx_bytes: jax.Array  # (S,) i32 admitted payload bytes per service
    no_route: jax.Array      # () i32 valid requests with no rule match
    held: jax.Array          # () i32 routable requests without a free slot
    aff_key: jax.Array       # (AFFINITY_SLOTS,) i32 updated affinity cache
    aff_ep: jax.Array        # (AFFINITY_SLOTS,) i32


def _admit_kernel(*refs, block_r: int, commit: bool, fold: str):
    if commit:
        (rid_ref, svc_ref, feat_ref, bytes_ref, rnd_ref, gum_ref, fkey_ref,
         tok_ref,
         rs_ref, rc_ref, rf_ref, rv_ref, rcl_ref,
         cs_ref, cc_ref, cp_ref, einst_ref, ew_ref, ed_ref,
         load0_ref, cur0_ref, mg_ref, affk0_ref, affe0_ref, free_ref,
         preq0_ref, pep0_ref, psvc0_ref, plen0_ref, ptok0_ref,
         cluster_ref, ep_ref, inst_ref, slot_ref, ok_ref,
         loadout_ref, curout_ref, sreq_ref, stx_ref, cnt_ref,
         affk_ref, affe_ref,
         preq_ref, pep_ref, psvc_ref, plen_ref, ptok_ref, pact_ref,
         load_s, held_s, cur_s, icnt_s, sreq_s, stx_s, cnt_s,
         affk_s, affe_s) = refs
    else:
        (rid_ref, svc_ref, feat_ref, bytes_ref, rnd_ref, gum_ref, fkey_ref,
         rs_ref, rc_ref, rf_ref, rv_ref, rcl_ref,
         cs_ref, cc_ref, cp_ref, einst_ref, ew_ref, ed_ref,
         load0_ref, cur0_ref, mg_ref, affk0_ref, affe0_ref, free_ref,
         cluster_ref, ep_ref, inst_ref, slot_ref, ok_ref,
         loadout_ref, curout_ref, sreq_ref, stx_ref, cnt_ref,
         affk_ref, affe_ref,
         load_s, held_s, cur_s, icnt_s, sreq_s, stx_s, cnt_s,
         affk_s, affe_s) = refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        load_s[...] = load0_ref[...]
        held_s[...] = jnp.zeros_like(held_s)
        cur_s[...] = cur0_ref[...]
        icnt_s[...] = jnp.zeros_like(icnt_s)
        sreq_s[...] = jnp.zeros_like(sreq_s)
        stx_s[...] = jnp.zeros_like(stx_s)
        cnt_s[...] = jnp.zeros_like(cnt_s)
        # session-affinity cache rides in VMEM scratch across the grid —
        # the same carried-map trick as the load counters, so a flow
        # pinned in tile i sticks for every request of tile i+1
        affk_s[...] = affk0_ref[...]
        affe_s[...] = affe0_ref[...]
        if commit:
            # the pool rides in whole-array output blocks revisited by every
            # grid step: seed from the incoming pool, fold writes per tile
            preq_ref[...] = preq0_ref[...]
            pep_ref[...] = pep0_ref[...]
            psvc_ref[...] = psvc0_ref[...]
            plen_ref[...] = plen0_ref[...]
            ptok_ref[...] = ptok0_ref[...]
            pact_ref[...] = 1 - free_ref[...]

    S = rs_ref.shape[0]
    CL = cc_ref.shape[0]
    E = load0_ref.shape[0]
    I, C = free_ref.shape
    WE = MAX_EPS_PER_CLUSTER

    # ---- stage 1: content match (vectorised rule-chain walk) ---------- #
    valid = rid_ref[...] >= 0
    svc = jnp.clip(svc_ref[...], 0, S - 1)
    cluster = _match_stage(svc, feat_ref[...], rs_ref, rc_ref, rf_ref,
                           rv_ref, rcl_ref, block_r=block_r)
    cluster = jnp.where(valid, cluster, -1)

    cl = jnp.maximum(cluster, 0)
    count = cc_ref[cl]                                 # (BR,)
    estart = cs_ref[cl]
    policy = cp_ref[cl]

    ewin = jax.lax.broadcasted_iota(jnp.int32, (block_r, WE), 1)
    eidx = jnp.clip(estart[:, None] + ewin, 0, E - 1)  # (BR, WE)
    eok_w = ewin < count[:, None]
    zoff = lambda: jnp.zeros((block_r,), jnp.int32)

    # eligibility: inside the window AND not draining — the control plane's
    # datapath-visible drain mask gates selection under EVERY policy; a
    # cluster whose endpoints are all draining (or gone) is unroutable.
    # The segment fold branches at RUNTIME on "anything draining at all"
    # (an (E,) table scan): the no-drain steady state skips the per-request
    # mask gather and the k-th-eligible remap entirely — both are identity
    # then, so the branches are bit-equal.  The onehot fold stays branch-
    # free (Mosaic prefers one straight-line vector program).
    if fold == "segment":
        any_dr = jnp.any(ed_ref[...] != 0)
        eok = jax.lax.cond(any_dr, lambda: eok_w & (ed_ref[eidx] == 0),
                           lambda: eok_w)
        cnt2 = jax.lax.cond(
            any_dr, lambda: jnp.sum(eok.astype(jnp.int32), axis=1),
            lambda: jnp.clip(count, 0, WE))
    else:
        eok = eok_w & (ed_ref[eidx] == 0)
        cnt2 = jnp.sum(eok.astype(jnp.int32), axis=1)  # eligible endpoints
    cnt1 = jnp.maximum(cnt2, 1)
    routable = valid & (cluster >= 0) & (cnt2 > 0)

    # in-tile arrival rank within each cluster (segment-fold counting sort,
    # cf. relay_dispatch) — only routable requests consume ranks; the
    # per-cluster counts ride along for the cursor fold (no extra scatter)
    rank_c, counts_c = _seg_rank(cl, routable, CL, fold=fold,
                                 block_r=block_r)

    def kth(k):
        """Window offset of the k-th *eligible* endpoint (== k itself when
        nothing is draining, so the pre-mask selection is unchanged)."""
        cum_e = jnp.cumsum(eok.astype(jnp.int32), axis=1)
        return jnp.argmax(eok & (cum_e == (k + 1)[:, None]),
                          axis=1).astype(jnp.int32)

    if fold == "segment":
        # the k-th-eligible remap is skipped while nothing drains (kth is
        # the identity on modular indices then — branches are bit-equal)
        def cyc(k):
            return jax.lax.cond(any_dr, lambda: kth(k), lambda: k)
    else:
        cyc = kth

    # ---- stage 2: policy dispatch (the registry seam, DESIGN.md §9) --- #
    # every policy's selection math lives in core/policy_defs.py as ONE
    # ``kernel_offset`` hook serving both folds; this kernel only builds
    # the ctx (eligibility windows, fold helpers, carried counters) and
    # folds the per-policy window offsets through one jnp.select.  Under
    # the segment fold, gated policies no cluster uses are skipped at
    # runtime (the taken lax.cond branch only).
    ctx = policy_defs.KernelCtx(
        fold=fold, block_r=block_r, policy=policy, cl=cl,
        routable=routable, rank_c=rank_c, estart=estart, count=count,
        cnt1=cnt1, cnt2=cnt2, eidx=eidx, eok=eok,
        rnd=rnd_ref[...], fkey=fkey_ref[...], gum=gum_ref[...],
        loads=load_s[...], ew=ew_ref[...], ed=ed_ref[...],
        cs_vec=cs_ref[...], cc_vec=cc_ref[...], cur_cl=cur_s[...][cl],
        mg_tab=mg_ref[...], aff_key=affk_s[...], aff_ep=affe_s[...],
        kth=kth, cyc=cyc,
        seg_rank=functools.partial(_seg_rank, fold=fold, block_r=block_r))

    default_off = None
    conds, offs = [], []
    for p in policy_defs.REGISTRY:
        fn = (lambda p=p: p.kernel_offset(ctx).astype(jnp.int32))
        if fold == "segment" and p.gate:
            o_p = jax.lax.cond(jnp.any(cp_ref[...] == p.enum), fn, zoff)
        else:
            o_p = fn()
        if p.enum == POLICY_RR:         # rr doubles as the unknown-policy
            default_off = o_p           # fallback (oracle parity)
        else:
            conds.append(policy == p.enum)
            offs.append(o_p)

    off = jnp.select(conds, offs, default_off).astype(jnp.int32)
    ep = jnp.take_along_axis(eidx, off[:, None], axis=1)[:, 0]
    ep = jnp.where(routable, ep, -1)
    epc = jnp.maximum(ep, 0)
    inst = jnp.where(routable, einst_ref[epc], -1)
    instc = jnp.clip(inst, 0, I - 1)

    # ---- stage 3: free-slot allocation (counting-sort fold) ----------- #
    rank_i0, counts_i = _seg_rank(instc, routable, I, fold=fold,
                                  block_r=block_r)
    rank_i = icnt_s[...][instc] + rank_i0
    # per-INSTANCE free-slot prefix (I·C elements, once per tile) gathered
    # per request — not a (BR, C) row cumsum
    fprefix = jnp.cumsum(free_ref[...], axis=1)        # (I, C)
    rows = free_ref[...][instc]                        # (BR, C) free=1
    prefix = fprefix[instc]
    n_free = fprefix[:, C - 1][instc]
    ok = routable & (rank_i < n_free)
    hit = (rows > 0) & (prefix == (rank_i + 1)[:, None])
    slot = jnp.where(ok, jnp.argmax(hit, axis=1).astype(jnp.int32), -1)
    held = routable & ~ok

    # ---- per-request outputs ------------------------------------------ #
    cluster_ref[...] = cluster
    ep_ref[...] = ep
    inst_ref[...] = inst
    slot_ref[...] = slot
    ok_ref[...] = ok.astype(jnp.int32)

    # ---- stage 4 (commit mode): pool writeback ------------------------ #
    if commit:
        # the slot allocator never hands the same (inst, slot) to two
        # requests in one batch, so each pool cell has at most one writer
        if fold == "segment":
            # scatter-set straight into the revisited output blocks;
            # un-admitted rows steer to an out-of-bounds lane and drop
            ii = jnp.where(ok, instc, I)
            ss = jnp.where(ok, slot, 0)

            def commit_fold(ref, vals):
                ref[...] = ref[...].at[ii, ss].set(vals, mode="drop")

            commit_fold(pact_ref, jnp.ones_like(slot))
        else:
            # dense one-hot over flattened (I*C) cells: a plain sum
            # recovers each cell's single writer (Mosaic-lowerable form)
            flat = instc * C + jnp.where(ok, slot, 0)
            oh_p = (ok[:, None] & (flat[:, None] == jax.lax.broadcasted_iota(
                jnp.int32, (block_r, I * C), 1))).astype(jnp.int32)
            wrote = jnp.sum(oh_p, axis=0).reshape(I, C) > 0

            def commit_fold(ref, vals):
                v = jnp.sum(oh_p * vals[:, None], axis=0).reshape(I, C)
                ref[...] = jnp.where(wrote, v, ref[...])

            pact_ref[...] = jnp.where(wrote, 1, pact_ref[...])

        commit_fold(preq_ref, rid_ref[...])
        commit_fold(pep_ref, ep)
        commit_fold(psvc_ref, svc_ref[...])  # raw svc, as the engine stores it
        commit_fold(plen_ref, jnp.zeros_like(slot))
        commit_fold(ptok_ref, tok_ref[...])

    # ---- session-affinity cache fold (policy_defs owns the write rule:
    # first writer per slot, never evicting a live flow) — gated like the
    # other policies under the segment fold ---------------------------- #
    if fold == "segment":
        affk_new, affe_new = jax.lax.cond(
            jnp.any(cp_ref[...] == POLICY_AFFINITY),
            lambda: policy_defs.affinity_kernel_update(ctx, ep),
            lambda: (affk_s[...], affe_s[...]))
    else:
        affk_new, affe_new = policy_defs.affinity_kernel_update(ctx, ep)
    affk_s[...] = affk_new
    affe_s[...] = affe_new

    # ---- carried LB state + fused metrics (tiled segment folds) ------- #
    one = jnp.ones((block_r,), jnp.int32)
    ep_ids = jnp.where(routable, epc, E)               # masked rows drop
    load_s[...] = _seg_sum(load_s[...], ep_ids, one, fold=fold)
    held_s[...] = _seg_sum(held_s[...], jnp.where(held, epc, E), one,
                           fold=fold)
    # the cursor carries RAW counts across tiles (reduced modulo only at
    # emit): a per-tile modulo by the cluster size would make the k-th-
    # eligible offset depend on the tile boundary whenever endpoints are
    # draining (cnt2 < count), breaking block_r-independence.  Both count
    # vectors fall out of the rank sorts — no extra fold.
    cur_s[...] = cur_s[...] + counts_c
    icnt_s[...] = icnt_s[...] + counts_i
    # per-service metrics drop svc >= S (the staged scatter's mode="drop")
    # instead of folding rogue ids into service S-1 via the table clip
    svc_ids = jnp.where(ok & (svc_ref[...] < S), svc, S)
    sreq_s[...] = _seg_sum(sreq_s[...], svc_ids, one, fold=fold)
    stx_s[...] = _seg_sum(stx_s[...], svc_ids, bytes_ref[...], fold=fold)
    cnt_s[...] = cnt_s[...] + jnp.stack(
        [jnp.sum((valid & (cluster < 0)).astype(jnp.int32)),
         jnp.sum(held.astype(jnp.int32))])

    @pl.when(i == pl.num_programs(0) - 1)
    def _emit():
        # held requests release their counter (connection close of the
        # paper's hold queue) — folded into the final emit
        loadout_ref[...] = load_s[...] - held_s[...]
        curout_ref[...] = cur_s[...] % jnp.maximum(cc_ref[...], 1)
        sreq_ref[...] = sreq_s[...]
        stx_ref[...] = stx_s[...]
        cnt_ref[...] = cnt_s[...]
        affk_ref[...] = affk_s[...]
        affe_ref[...] = affe_s[...]


class AdmitCommitResult(NamedTuple):
    """``AdmitResult`` plus the committed (I, C) connection pools."""

    cluster: jax.Array
    endpoint: jax.Array
    instance: jax.Array
    slot: jax.Array
    ok: jax.Array
    ep_load: jax.Array
    rr_cursor: jax.Array
    svc_requests: jax.Array
    svc_tx_bytes: jax.Array
    no_route: jax.Array
    held: jax.Array
    aff_key: jax.Array       # (AFFINITY_SLOTS,) i32
    aff_ep: jax.Array        # (AFFINITY_SLOTS,) i32
    pool_req_id: jax.Array   # (I, C) i32
    pool_endpoint: jax.Array
    pool_svc: jax.Array
    pool_length: jax.Array
    pool_token: jax.Array
    pool_active: jax.Array   # (I, C) i32 (0/1)


def _pad_rows(block_r: int, req_id, svc, features, msg_bytes, rnd, gumbel,
              token=None):
    """Pad ragged batches with req_id=-1 rows (inert in-kernel: no counter,
    metric or pool touches); callers slice per-request outputs back."""
    R0, F = features.shape
    R = -(-R0 // block_r) * block_r
    if R != R0:
        pad = R - R0
        req_id = jnp.concatenate([req_id, jnp.full((pad,), -1, jnp.int32)])
        svc = jnp.concatenate([svc, jnp.zeros((pad,), svc.dtype)])
        features = jnp.concatenate(
            [features, jnp.zeros((pad, F), features.dtype)])
        msg_bytes = jnp.concatenate(
            [msg_bytes, jnp.zeros((pad,), msg_bytes.dtype)])
        rnd = jnp.concatenate([rnd, jnp.zeros((pad,), rnd.dtype)])
        gumbel = jnp.concatenate(
            [gumbel, jnp.zeros((pad, gumbel.shape[1]), gumbel.dtype)])
        if token is not None:
            token = jnp.concatenate([token, jnp.zeros((pad,), token.dtype)])
    return R, req_id, svc, features, msg_bytes, rnd, gumbel, token


def _launch_admit(req_id, svc, features, msg_bytes, state, free_i32, rnd,
                  gumbel, token, pool, *, block_r: int, fold: str,
                  interpret: bool | None):
    """Shared pallas_call plumbing for ``admit`` (pool=None) and
    ``admit_commit`` (pool = 5 incoming (I, C) i32 arrays)."""
    commit = pool is not None
    R0, F = features.shape
    R, req_id, svc, features, msg_bytes, rnd, gumbel, token = _pad_rows(
        block_r, req_id, svc, features, msg_bytes, rnd, gumbel, token)
    grid = (R // block_r,)
    # flow ids are derived OUTSIDE the kernel (plain jnp, padded rows
    # included) so the kernel, the staged chain, the oracle and the host
    # router all hash through the one policy_defs.flow_hash
    fkey = policy_defs.flow_hash(features).astype(jnp.int32)
    tables = [state.svc_rule_start, state.svc_rule_count, state.rule_field,
              state.rule_value, state.rule_cluster, state.cluster_ep_start,
              state.cluster_ep_count, state.cluster_policy,
              state.ep_instance, state.ep_weight, state.ep_drained,
              state.ep_load, state.rr_cursor, state.maglev_table,
              state.aff_key, state.aff_ep, free_i32]
    S = state.svc_rule_start.shape[0]
    CL = state.cluster_ep_count.shape[0]
    E = state.ep_load.shape[0]
    A = state.aff_key.shape[0]
    I, C = free_i32.shape
    req = pl.BlockSpec((block_r,), lambda r: (r,))
    in_arrays = [req_id.astype(jnp.int32), svc.astype(jnp.int32), features,
                 msg_bytes.astype(jnp.int32), rnd.astype(jnp.int32),
                 gumbel.astype(jnp.float32), fkey]
    in_specs = [req, req,
                pl.BlockSpec((block_r, F), lambda r: (r, 0)),
                req, req,
                pl.BlockSpec((block_r, MAX_EPS_PER_CLUSTER),
                             lambda r: (r, 0)), req]
    if commit:
        in_arrays.append(token.astype(jnp.int32))
        in_specs.append(req)
    in_arrays += tables
    in_specs += [_table_spec(t.shape) for t in tables]
    if commit:
        in_arrays += list(pool)
        in_specs += [_table_spec((I, C))] * 5
    out_specs = [req] * 5 + [_table_spec((E,)), _table_spec((CL,)),
                             _table_spec((S,)), _table_spec((S,)),
                             _table_spec((2,)),
                             _table_spec((A,)), _table_spec((A,))]
    out_shape = [jax.ShapeDtypeStruct((R,), jnp.int32)] * 5 \
        + [jax.ShapeDtypeStruct((E,), jnp.int32),
           jax.ShapeDtypeStruct((CL,), jnp.int32),
           jax.ShapeDtypeStruct((S,), jnp.int32),
           jax.ShapeDtypeStruct((S,), jnp.int32),
           jax.ShapeDtypeStruct((2,), jnp.int32),
           jax.ShapeDtypeStruct((A,), jnp.int32),
           jax.ShapeDtypeStruct((A,), jnp.int32)]
    if commit:
        out_specs += [_table_spec((I, C))] * 6
        out_shape += [jax.ShapeDtypeStruct((I, C), jnp.int32)] * 6
    o = pl.pallas_call(
        functools.partial(_admit_kernel, block_r=block_r, commit=commit,
                          fold=fold),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((E,), jnp.int32),
                        pltpu.VMEM((E,), jnp.int32),
                        pltpu.VMEM((CL,), jnp.int32),
                        pltpu.VMEM((I,), jnp.int32),
                        pltpu.VMEM((S,), jnp.int32),
                        pltpu.VMEM((S,), jnp.int32),
                        pltpu.VMEM((2,), jnp.int32),
                        pltpu.VMEM((A,), jnp.int32),
                        pltpu.VMEM((A,), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(*in_arrays)
    head = (o[0][:R0], o[1][:R0], o[2][:R0], o[3][:R0], o[4][:R0],
            o[5], o[6], o[7], o[8], o[9][0], o[9][1])
    return head + tuple(o[10:])


def admit(req_id, svc, features, msg_bytes, state, free_mask, rnd, gumbel, *,
          block_r: int = 256, fold: str | None = None,
          interpret: bool | None = None) -> AdmitResult:
    """Fused admission datapath over a request batch.

    req_id/svc/msg_bytes/rnd: (R,) i32 (req_id < 0 = padding; rnd = host
    PRNG draws for the random policy); features: (R, F) i32;
    gumbel: (R, MAX_EPS_PER_CLUSTER) f32 noise for the weighted policy;
    state: RoutingState; free_mask: (I, C) — nonzero/True = free slot.

    Sequential semantics (cross-checked bit-exactly against
    ``kernels.ref.admit_ref``): requests are processed in arrival order;
    every routable request advances its cluster's rr cursor and increments
    its endpoint's load counter immediately; requests that find no free pool
    slot are *held* and release their counter at the end of the batch.
    """
    R0, F = features.shape
    if R0 == 0:                         # empty batch: nothing to admit
        z = jnp.zeros((0,), jnp.int32)
        zs = jnp.zeros_like(state.svc_rule_start)
        return AdmitResult(
            z, z, z, z, z, state.ep_load,
            state.rr_cursor % jnp.maximum(state.cluster_ep_count, 1),
            zs, zs, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            state.aff_key, state.aff_ep)
    block_r = min(block_r, R0)
    # booleanize: the kernel cumsums the mask as per-slot counts, so an
    # integer mask cell > 1 would double-count free slots
    o = _launch_admit(req_id, svc, features, msg_bytes, state,
                      (free_mask != 0).astype(jnp.int32), rnd, gumbel,
                      None, None, block_r=block_r, fold=resolve_fold(fold),
                      interpret=interpret)
    return AdmitResult(*o)


def admit_commit(req_id, svc, features, msg_bytes, token, state,
                 pool_req_id, pool_endpoint, pool_svc, pool_length,
                 pool_token, pool_active, rnd, gumbel, *,
                 block_r: int = 256, fold: str | None = None,
                 interpret: bool | None = None) -> AdmitCommitResult:
    """``admit`` + in-kernel pool commit (the paper's full connect path).

    Same contract as ``admit`` with the free-slot mask derived from
    ``pool_active`` (~active = free); admitted requests additionally write
    req_id/endpoint/svc/length=0/token/active=1 at their (instance, slot)
    inside the kernel — no ``scatter_to_pool`` post-pass.  Bit-exact against
    ``kernels.ref.admit_commit_ref``.
    """
    R0, F = features.shape
    active_i32 = (pool_active != 0).astype(jnp.int32)   # booleanized 0/1
    pool = (pool_req_id.astype(jnp.int32), pool_endpoint.astype(jnp.int32),
            pool_svc.astype(jnp.int32), pool_length.astype(jnp.int32),
            pool_token.astype(jnp.int32))
    if R0 == 0:                         # empty batch: pool passes through
        z = jnp.zeros((0,), jnp.int32)
        zs = jnp.zeros_like(state.svc_rule_start)
        return AdmitCommitResult(
            z, z, z, z, z, state.ep_load,
            state.rr_cursor % jnp.maximum(state.cluster_ep_count, 1),
            zs, zs, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            state.aff_key, state.aff_ep, *pool, active_i32)
    block_r = min(block_r, R0)
    o = _launch_admit(req_id, svc, features, msg_bytes, state,
                      1 - active_i32, rnd, gumbel, token, pool,
                      block_r=block_r, fold=resolve_fold(fold),
                      interpret=interpret)
    return AdmitCommitResult(*o)
