"""Mesh-sharded admission: many ingress hosts feed one fleet (ROADMAP
scale-out).

The paper's headline scenario is 50+ co-located instances fed from multiple
hosts; here the admission batch is split ``(R/M,)`` over a mesh axis and the
fused admit kernel (``route_match.admit``) runs per shard against replicated
routing tables, followed by ONE collective reconciliation pass.  The result
is **bit-exact** against single-shard ``admit_commit`` on the concatenated
batch — the deterministic merge rule is *shard-major order*: shard 0's rows
are "first", shard 1's follow, exactly as if one host had ingested the
concatenation (``kernels/ref.py::admit_sharded_ref`` pins this contract).

How sequential consistency survives the fan-out (DESIGN.md §7): the fused
kernel's carried VMEM counters make request ``i`` visible to request
``i+1`` *within* a shard; across shards the same effect comes from offsetting
each shard's kernel *inputs* by a closed form of the preceding shards'
per-cluster routable counts (one cheap match pass + ``all_gather``):

  * **rr cursors** carry raw counts (the PR-4 trick): shard ``s`` starts from
    ``rr_cursor + prev_counts`` and the final cursor is reconciled as
    ``(rr_cursor + Σ counts) mod window`` — shard-count independent.
  * **least-request loads** advance by a *water-fill*: admitting ``k``
    requests to a cluster produces a load multiset that depends only on
    ``k`` (request ``ρ`` takes the ``ρ``-th smallest ticket of
    ``{load_j + t}``, ties by window offset), so shard ``s`` water-fills
    ``prev_counts`` into the initial loads analytically and its local kernel
    continues bit-exactly where shard ``s-1`` "left off".
  * **random / weighted** consume per-request host PRNG draws — row-aligned
    with the batch split, order-free already.
  * **slot allocation** runs the local kernel against an all-free mask so
    its ``slot`` output *is* the local per-instance arrival rank; global
    ranks (prev-shard instance counts + local rank, one more ``all_gather``)
    are then matched against the true global free mask, which also decides
    held requests globally.

Everything the datapath owns reconciles in one collective pass:
``jax.lax.psum`` over per-shard ``ep_load`` deltas, held releases,
per-service metrics and the ``no_route``/``held`` counts; pool commits are
relayed to their owner shards (the pool is ``(I/M,)``-sharded) through the
``relay_dispatch`` counting-sort + ``all_to_all`` hop of ``core/relay.py`` —
the same collective schedule ``sharded_apply`` uses for the i-sock relay.

Each shard's kernel launch is gated by ``lax.cond`` on "any valid local
rows", so an all-padding shard (an idle ingress host) skips the kernel
entirely; the collectives always run on every shard (SPMD-uniform).
"""

from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp

from repro.compat import axis_size, shard_map
from repro.core import policy_defs, relay, router
from repro.core.routing_table import MAX_EPS_PER_CLUSTER, RoutingState
from repro.kernels import completion as _cp
from repro.kernels import route_match as _rm
from repro.kernels.backend import resolve_fold, resolve_interpret
from repro.kernels.completion import CompleteResult
from repro.kernels.route_match import (BIG, AdmitCommitResult, AdmitResult)


def cluster_windows(state: RoutingState) -> tuple[jax.Array, jax.Array]:
    """Per-cluster endpoint window gathers: (ceidx, ceok) both (CL, WE).
    ``ceok`` marks lanes that are in-window AND not draining — the eligible
    set every selection path uses."""
    E = state.ep_load.shape[0]
    CL = state.cluster_ep_count.shape[0]
    WE = MAX_EPS_PER_CLUSTER
    cwin = jax.lax.broadcasted_iota(jnp.int32, (CL, WE), 1)
    ceidx = jnp.clip(state.cluster_ep_start[:, None] + cwin, 0, E - 1)
    ceok = (cwin < state.cluster_ep_count[:, None]) \
        & (state.ep_drained[ceidx] == 0)
    return ceidx, ceok


def waterfill_lr(state: RoutingState, k_cl: jax.Array) -> jax.Array:
    """``ep_load`` after sequentially admitting ``k_cl[c]`` requests into
    each LEAST_REQUEST cluster ``c`` — the closed form of "argmin then
    increment" repeated k times (ticket multiset ``{load_j + t}`` ordered by
    (value, window offset); the k taken tickets raise every engaged endpoint
    to the water level ``v`` and the first ``m`` at-level endpoints one
    higher).  Non-LR clusters pass through untouched: their loads are never
    read by selection, so only the LR multiset must match the sequential
    reference.  Bit-exact vs ``ref.admit_ref`` processing k requests."""
    E = state.ep_load.shape[0]
    ceidx, ceok = cluster_windows(state)
    load = jnp.where(ceok, state.ep_load[ceidx], BIG)   # (CL, WE)
    k = jnp.maximum(k_cl.astype(jnp.int32), 0)
    lo = jnp.min(load, axis=1)
    # lanes above lo+k never engage for k requests; clamping keeps the
    # ticket counts far from int32 range when ineligible lanes read BIG
    lcl = jnp.minimum(load, (lo + k)[:, None])
    hi = lo + k
    # smallest v with #tickets(value <= v) >= k  (static-depth search; the
    # k = 0 case degenerates to v = lo and an identity update)
    for _ in range(32):
        mid = lo + (hi - lo) // 2
        n_le = jnp.sum(jnp.maximum(mid[:, None] - lcl + 1, 0), axis=1)
        ge = n_le >= k
        hi = jnp.where(ge, mid, hi)
        lo = jnp.where(ge, lo, mid + 1)
    v = lo
    n_below = jnp.sum(jnp.maximum(v[:, None] - lcl, 0), axis=1)
    m_rem = k - n_below                    # value-v tickets taken
    engaged = ceok & (lcl <= v[:, None])   # v < min+k, so clamp never lies
    cum = jnp.cumsum(engaged.astype(jnp.int32), axis=1)
    extra = (engaged & (cum <= m_rem[:, None])).astype(jnp.int32)
    real = jnp.where(ceok, state.ep_load[ceidx], 0)
    newl = jnp.maximum(real, v[:, None]) + extra
    # registry merge rule: every policy whose shard_merge is "waterfill"
    # carries its load counters through this closed form (policy_defs)
    is_wf = jnp.zeros_like(state.cluster_policy, dtype=bool)
    for _e in policy_defs.WATERFILL_ENUMS:
        is_wf = is_wf | (state.cluster_policy == _e)
    apply = ceok & is_wf[:, None] & (k > 0)[:, None]
    # windows are disjoint, so every applied lane owns a unique slot
    tgt = jnp.where(apply, ceidx, E).reshape(-1)
    return state.ep_load.at[tgt].set(newl.reshape(-1), mode="drop")


def _bincount(ids, vals, length: int):
    """Masked scatter-add fold (ids >= length drop), (length,) i32."""
    return jnp.zeros((length,), jnp.int32).at[ids].add(
        vals.astype(jnp.int32), mode="drop")


def _prefix_before(gathered: jax.Array, m) -> jax.Array:
    """Sum of the per-shard rows strictly before shard ``m``: the exclusive
    scan giving each shard its carried-counter offset."""
    M = gathered.shape[0]
    mask = jnp.arange(M) < m
    return jnp.sum(jnp.where(mask[:, None], gathered, 0), axis=0)


def _shard_body(rid, sv, feats, mb, tok, rnd, gum, state: RoutingState,
                preq, pep, psvc, plen, ptok, pact, *, axis: str,
                block_r: int, fold: str, interpret: bool):
    """shard_map body: local fused admit + the collective reconciliation."""
    M = axis_size(axis)
    m = jax.lax.axis_index(axis)
    E = state.ep_load.shape[0]
    CL = state.cluster_ep_count.shape[0]
    S = state.svc_rule_start.shape[0]
    I_loc, C = preq.shape
    I = I_loc * M
    R_loc = rid.shape[0]

    # ---- phase 1: match + eligibility -> per-cluster routable counts ---- #
    valid = rid >= 0
    svc_c = jnp.clip(sv, 0, S - 1)
    cluster = jnp.where(valid, router.match_cluster(state, svc_c, feats), -1)
    _, ceok = cluster_windows(state)
    ecnt = jnp.sum(ceok.astype(jnp.int32), axis=1)          # (CL,)
    clm = jnp.maximum(cluster, 0)
    routable = valid & (cluster >= 0) & (ecnt[clm] > 0)
    cnt_cl = _bincount(jnp.where(routable, clm, CL), jnp.ones_like(clm), CL)
    all_cl = jax.lax.all_gather(cnt_cl, axis)               # (M, CL)
    prev_cl = _prefix_before(all_cl, m)
    total_cl = jnp.sum(all_cl, axis=0)

    # ---- phase 2: offset the carried-counter inputs --------------------- #
    adj_load = waterfill_lr(state, prev_cl)
    adj_cur = state.rr_cursor + prev_cl        # raw carry; modulo at emit
    st_local = state._replace(ep_load=adj_load, rr_cursor=adj_cur)

    # ---- phase 3: local fused admit kernel (all-free mask) -------------- #
    # n_free = R_loc >= any local instance count, so nothing is held inside
    # the kernel and its ``slot`` output IS the local per-instance arrival
    # rank; held/slots resolve globally in phase 4.  An all-padding shard
    # skips the kernel (the collectives below still run on every shard).
    free_all = jnp.ones((I, R_loc), jnp.int32)

    def run(_):
        return _rm.admit(rid, sv, feats, mb, st_local, free_all, rnd, gum,
                         block_r=block_r, fold=fold, interpret=interpret)

    def skip(_):
        neg = jnp.full((R_loc,), -1, jnp.int32)
        z = jnp.zeros((R_loc,), jnp.int32)
        zs = jnp.zeros((S,), jnp.int32)
        return AdmitResult(
            neg, neg, neg, neg, z, adj_load,
            adj_cur % jnp.maximum(state.cluster_ep_count, 1), zs, zs,
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            state.aff_key, state.aff_ep)

    res = jax.lax.cond(jnp.any(valid), run, skip, 0)

    # ---- phase 4: global slot allocation + psum reconciliation ---------- #
    rt = res.ok > 0                            # == routable (all-free mask)
    instc = jnp.clip(res.instance, 0, I - 1)
    local_rank = jnp.where(rt, res.slot, 0)
    cnt_i = _bincount(jnp.where(rt, instc, I), jnp.ones_like(instc), I)
    prev_i = _prefix_before(jax.lax.all_gather(cnt_i, axis), m)
    g_rank = prev_i[instc] + local_rank

    act_all = jax.lax.all_gather(pact, axis).reshape(I, C)
    free = (act_all == 0).astype(jnp.int32)
    fprefix = jnp.cumsum(free, axis=1)                      # (I, C)
    ok = rt & (g_rank < fprefix[:, C - 1][instc])
    hit = (free[instc] > 0) & (fprefix[instc] == (g_rank + 1)[:, None])
    slot = jnp.where(ok, jnp.argmax(hit, axis=1).astype(jnp.int32), -1)
    held = rt & ~ok

    epc = jnp.maximum(res.endpoint, 0)
    one = jnp.ones((R_loc,), jnp.int32)
    delta = res.ep_load - adj_load             # local increments, no release
    held_rel = _bincount(jnp.where(held, epc, E), one, E)
    ep_load = state.ep_load + jax.lax.psum(delta, axis) \
        - jax.lax.psum(held_rel, axis)

    # the kernel counted every routable request (nothing held locally);
    # subtract the globally-held ones before the metric psum
    held_svc = jnp.where(held & (sv < S), svc_c, S)
    sreq = jax.lax.psum(res.svc_requests - _bincount(held_svc, one, S), axis)
    stx = jax.lax.psum(res.svc_tx_bytes - _bincount(held_svc, mb, S), axis)
    no_route = jax.lax.psum(res.no_route, axis)
    held_n = jax.lax.psum(jnp.sum(held.astype(jnp.int32)), axis)
    rr_cursor = (state.rr_cursor + total_cl) \
        % jnp.maximum(state.cluster_ep_count, 1)

    # affinity-cache reconciliation: each shard's local kernel wrote its
    # cache against the same replicated snapshot, and the miss fallback is a
    # pure function of the flow key (policy_defs: snapshot-pure semantics),
    # so concurrent proposals for one slot agree on the value whenever the
    # sequential reference would have produced a hit.  Shard-major merge:
    # the lowest shard proposing a change to a slot wins — exactly the
    # first-writer rule of the concatenated sequential batch.
    gk = jax.lax.all_gather(res.aff_key, axis)              # (M, A)
    ge = jax.lax.all_gather(res.aff_ep, axis)
    prop = (gk != state.aff_key[None, :]) | (ge != state.aff_ep[None, :])
    has = jnp.any(prop, axis=0)
    m1 = jnp.argmax(prop, axis=0)              # first shard with a proposal
    aff_key = jnp.where(has,
                        jnp.take_along_axis(gk, m1[None, :], axis=0)[0],
                        state.aff_key)
    aff_ep = jnp.where(has,
                       jnp.take_along_axis(ge, m1[None, :], axis=0)[0],
                       state.aff_ep)

    # ---- phase 5: relay pool commits to their owner shards -------------- #
    # payload rows (req_id, endpoint, svc, token, slot, ok) counting-sorted
    # into per-instance pools, one all_to_all hop moves each pool to the
    # shard owning that instance slice (cf. relay.sharded_apply); admitted
    # global ranks are < C, so capacity C per source never drops a commit.
    x = jnp.stack([rid, res.endpoint, sv, tok, slot,
                   ok.astype(jnp.int32)], axis=1)           # (R_loc, 6)
    buf, _ = relay.relay_dispatch(x, jnp.where(ok, instc, I), I, C)
    recv = jax.lax.all_to_all(buf.reshape(M, I_loc, C, 6), axis,
                              split_axis=0, concat_axis=0, tiled=False)
    rows = recv.reshape(M * I_loc * C, 6)
    jj = jax.lax.broadcasted_iota(jnp.int32, (M, I_loc, C), 1).reshape(-1)
    rok = rows[:, 5] > 0
    jx = jnp.where(rok, jj, I_loc)                          # invalid -> drop
    sx = jnp.where(rok, rows[:, 4], 0)
    preq = preq.at[jx, sx].set(rows[:, 0], mode="drop")
    pep = pep.at[jx, sx].set(rows[:, 1], mode="drop")
    psvc = psvc.at[jx, sx].set(rows[:, 2], mode="drop")
    plen = plen.at[jx, sx].set(jnp.zeros_like(rows[:, 0]), mode="drop")
    ptok = ptok.at[jx, sx].set(rows[:, 3], mode="drop")
    pact = pact.at[jx, sx].set(jnp.ones_like(rows[:, 0]), mode="drop")

    return (cluster, res.endpoint, res.instance, slot, ok.astype(jnp.int32),
            ep_load, rr_cursor, sreq, stx, no_route, held_n,
            aff_key, aff_ep,
            preq, pep, psvc, plen, ptok, pact)


@lru_cache(maxsize=None)
def _build(mesh, axis: str, R_loc: int, block_r: int, fold: str,
           interpret: bool):
    """One compiled shard_map program per (mesh, axis, plan, local shape)."""
    from functools import partial

    from jax.sharding import PartitionSpec as P

    body = partial(_shard_body, axis=axis, block_r=block_r, fold=fold,
                   interpret=interpret)
    sh = P(axis)
    rep = P()
    f = shard_map(
        body, mesh=mesh,
        in_specs=(sh, sh, sh, sh, sh, sh, sh, rep) + (sh,) * 6,
        out_specs=(sh,) * 5 + (rep,) * 8 + (sh,) * 6,
        check_vma=False)
    return jax.jit(f)


def admit_commit_sharded(req_id, svc, features, msg_bytes, token,
                         state: RoutingState, pool_req_id, pool_endpoint,
                         pool_svc, pool_length, pool_token, pool_active,
                         rnd, gumbel, *, mesh, axis: str = "shard",
                         block_r: int = 256, fold: str | None = None,
                         interpret: bool | None = None) -> AdmitCommitResult:
    """``admit_commit`` sharded ``(R/M,)`` over mesh axis ``axis``.

    Same flat-array contract as ``route_match.admit_commit``; the pool is
    ``(I/M,)``-sharded over the axis (instance ``i`` lives on shard
    ``i // (I/M)``), the routing tables are replicated, and the result is
    bit-exact vs single-shard ``admit_commit`` on the same (concatenated)
    batch — see ``ref.admit_sharded_ref`` for the shard-major merge rule.
    Ragged batches pad to a multiple of the shard count with inert
    ``req_id = -1`` rows (an all-padding shard takes the ``lax.cond`` skip
    path).  Requires ``I % M == 0``.
    """
    M = mesh.shape[axis]
    I, C = pool_req_id.shape
    if I % M:
        raise ValueError(f"pool instances ({I}) must divide over the "
                         f"{M}-way mesh axis {axis!r}")
    R0, F = features.shape
    active_i32 = (pool_active != 0).astype(jnp.int32)
    pool = (pool_req_id.astype(jnp.int32), pool_endpoint.astype(jnp.int32),
            pool_svc.astype(jnp.int32), pool_length.astype(jnp.int32),
            pool_token.astype(jnp.int32))
    if R0 == 0:                          # empty batch: pool passes through
        z = jnp.zeros((0,), jnp.int32)
        zs = jnp.zeros_like(state.svc_rule_start)
        return AdmitCommitResult(
            z, z, z, z, z, state.ep_load,
            state.rr_cursor % jnp.maximum(state.cluster_ep_count, 1),
            zs, zs, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            state.aff_key, state.aff_ep,
            *pool, active_i32)
    R = -(-R0 // M) * M
    token = jnp.zeros((R0,), jnp.int32) if token is None else token
    R, req_id, svc, features, msg_bytes, rnd, gumbel, token = _rm._pad_rows(
        R, req_id, svc, features, msg_bytes, rnd, gumbel, token)
    R_loc = R // M
    fn = _build(mesh, axis, R_loc, min(block_r, R_loc), resolve_fold(fold),
                resolve_interpret(interpret))
    o = fn(req_id.astype(jnp.int32), svc.astype(jnp.int32), features,
           msg_bytes.astype(jnp.int32), token.astype(jnp.int32),
           rnd.astype(jnp.int32), gumbel.astype(jnp.float32), state,
           *pool, active_i32)
    return AdmitCommitResult(o[0][:R0], o[1][:R0], o[2][:R0], o[3][:R0],
                             o[4][:R0], *o[5:])


# --------------------------------------------------------------------------- #
# Sharded completion: the close path over an (I/M,)-sharded pool.
# --------------------------------------------------------------------------- #


def _complete_body(preq, pep, psvc, plen, ptok, pact, nxt, load0, rx0,
                   ewl0, ewt0, *, axis: str, eos: int, max_len: int,
                   block_i: int, fold: str, interpret: bool,
                   alpha_inflight: float, alpha_tput: float):
    """shard_map body: local fused completion with ZERO table bases so the
    kernel's (E,)/(S,) outputs are pure per-shard integer deltas, then one
    psum reconciles them against the replicated global bases.  The nonlinear
    f32 EWMA epilogue runs AFTER the psum, on the global integer counts —
    identical inputs to the single-shard kernel's in-kernel epilogue, so the
    accumulators are bit-exact for any shard count."""
    E, S = load0.shape[0], rx0.shape[0]
    res = _cp.complete(preq, pep, psvc, plen, ptok, pact, nxt,
                       jnp.zeros((E,), jnp.int32), jnp.zeros((S,), jnp.int32),
                       jnp.zeros((E,), jnp.float32),
                       jnp.zeros((E,), jnp.float32),
                       eos=eos, max_len=max_len, block_i=block_i, fold=fold,
                       interpret=interpret)
    cnt = jax.lax.psum(res.done_cnt, axis)                  # global releases
    ep_load = load0 - cnt
    rx = rx0 + jax.lax.psum(res.rx_bytes, axis)
    ewl, ewt = _cp.health_update(ewl0, ewt0, load0, cnt,
                                 alpha_inflight=alpha_inflight,
                                 alpha_tput=alpha_tput)
    return (res.req_id, res.endpoint, res.svc, res.length, res.token,
            res.active, res.done, ep_load, rx, cnt, ewl, ewt)


@lru_cache(maxsize=None)
def _build_complete(mesh, axis: str, eos: int, max_len: int, block_i: int,
                    fold: str, interpret: bool, alpha_inflight: float,
                    alpha_tput: float):
    """One compiled shard_map program per (mesh, axis, plan)."""
    from functools import partial

    from jax.sharding import PartitionSpec as P

    body = partial(_complete_body, axis=axis, eos=eos, max_len=max_len,
                   block_i=block_i, fold=fold, interpret=interpret,
                   alpha_inflight=alpha_inflight, alpha_tput=alpha_tput)
    sh = P(axis)
    rep = P()
    f = shard_map(
        body, mesh=mesh,
        in_specs=(sh,) * 7 + (rep,) * 4,
        out_specs=(sh,) * 7 + (rep,) * 5,
        check_vma=False)
    return jax.jit(f)


def complete_sharded(pool_req_id, pool_endpoint, pool_svc, pool_length,
                     pool_token, pool_active, nxt, ep_load, rx_bytes,
                     ep_inflight_ewma, ep_tput_ewma, *, mesh,
                     axis: str = "shard", eos: int, max_len: int,
                     block_i: int = 8, fold: str | None = None,
                     alpha_inflight: float = _cp.ALPHA_INFLIGHT,
                     alpha_tput: float = _cp.ALPHA_TPUT,
                     interpret: bool | None = None) -> CompleteResult:
    """``completion.complete`` over an ``(I/M,)``-sharded pool.

    Same flat-array contract; the (E,) load / EWMA tables and (S,) rx table
    are replicated, each shard folds its own pool slice, and one psum pass
    reconciles the integer counts before the shared ``health_update``
    epilogue — bit-exact vs single-shard ``complete`` on the whole pool.
    Requires ``I % M == 0``.
    """
    M = mesh.shape[axis]
    I, C = pool_req_id.shape
    if I % M:
        raise ValueError(f"pool instances ({I}) must divide over the "
                         f"{M}-way mesh axis {axis!r}")
    block_i = min(block_i, max(I // M, 1))
    fn = _build_complete(mesh, axis, eos, max_len, block_i,
                         resolve_fold(fold), resolve_interpret(interpret),
                         alpha_inflight, alpha_tput)
    o = fn(pool_req_id.astype(jnp.int32), pool_endpoint.astype(jnp.int32),
           pool_svc.astype(jnp.int32), pool_length.astype(jnp.int32),
           pool_token.astype(jnp.int32), (pool_active != 0).astype(jnp.int32),
           nxt.astype(jnp.int32), ep_load.astype(jnp.int32),
           rx_bytes.astype(jnp.int32),
           ep_inflight_ewma.astype(jnp.float32),
           ep_tput_ewma.astype(jnp.float32))
    return CompleteResult(*o)
