"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): the reference CUDA kernel parallelises the
recurrence with warp-level scans; the TPU formulation uses the *state-space
duality*: per chunk, Y = ((C·Bᵀ)⊙L)·X (an MXU matmul over the chunk) plus a
rank-N state correction carried across chunks.  The chunk axis is the
minor-most (sequential) grid dimension, and the running state h (hd × N,
fp32) lives in VMEM scratch across grid steps — the inter-chunk recurrence
costs one (hd, N) FMA per chunk, everything else is systolic matmul.

Grid: (B·nh, S/Q) with Q the chunk length (multiple of 128 for the MXU).
Inputs are pre-split per head: xdt (B·nh, S, hd), a_log (B·nh, S),
Bm/Cm (B·nh, S, N).  Output (B·nh, S, hd).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, o_ref, h_ref, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, hd)  = dt ⊙ X
    a = a_ref[0].astype(jnp.float32)          # (Q,)     = dt · A (negative)
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)

    acum = jnp.cumsum(a)                      # (Q,)
    # intra-chunk decay matrix L[i,j] = exp(acum_i - acum_j - a_j ... )
    seg = acum[:, None] - acum[None, :]       # sum_{j<k<=i} a_k  (i≥j)
    Q = a.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)

    # diagonal block: ((C Bᵀ) ⊙ L) X
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    y = jax.lax.dot_general(scores * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (Q,hd)

    # inter-chunk: contribution of the carried state, then state update
    h = h_ref[...]                            # (hd, N)
    decay_in = jnp.exp(acum)[:, None]         # (Q,1) decay from chunk start
    y = y + decay_in * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # (Q,N)·(hd,N)ᵀ → (Q,hd)

    total = acum[-1]
    decay_out = jnp.exp(total - acum)[:, None]           # (Q,1)
    new_state = jax.lax.dot_general(
        x * decay_out, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (hd, N)
    h_ref[...] = jnp.exp(total) * h + new_state
    o_ref[0] = y.astype(o_ref.dtype)


def ssd_scan(xdt, a_log, Bm, Cm, *, chunk: int = 128,
             interpret: bool | None = None):
    """xdt: (B, S, nh, hd) (= dt⊙x); a_log: (B, S, nh); Bm/Cm: (B, S, nh, N).

    Returns y: (B, S, nh, hd).  VMEM per program at (Q=128, hd=64, N=128):
    x/y 2·Q·hd·4 + B/C 2·Q·N·4 + L/scores 2·Q²·4 + h hd·N·4 ≈ 0.4 MB.
    """
    B, S, nh, hd = xdt.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0

    xt = xdt.transpose(0, 2, 1, 3).reshape(B * nh, S, hd)
    at = a_log.transpose(0, 2, 1).reshape(B * nh, S)
    bt = Bm.transpose(0, 2, 1, 3).reshape(B * nh, S, N)
    ct = Cm.transpose(0, 2, 1, 3).reshape(B * nh, S, N)

    grid = (B * nh, S // chunk)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk), lambda h, c: (h, c)),
            pl.BlockSpec((1, chunk, N), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda h, c: (h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hd), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * nh, S, hd), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((hd, N), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(xt, at, bt, ct)
    return out.reshape(B, nh, S, hd).transpose(0, 2, 1, 3)
