"""jit'd public wrappers for the Pallas kernels.

Backend selection is shared (``kernels.backend``): every kernel defaults to
``interpret=None``, which the wrapper resolves to the Pallas interpreter
off-TPU (bit-accurate against the BlockSpec pipeline) and to a real Mosaic
compile on TPU backends.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels import (completion as _cp, decode_attention as _da,
                           flash_attention as _fa, relay_dispatch as _rd,
                           route_match as _rm, ssd_scan as _ss)
from repro.kernels.backend import default_interpret  # re-export  # noqa: F401
from repro.kernels.completion import CompleteResult  # re-export  # noqa: F401
from repro.kernels.route_match import (AdmitCommitResult,  # noqa: F401
                                       AdmitResult)


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k)


@partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k_cache, v_cache, lengths, *, block_k: int = 512):
    return _da.decode_attention(q, k_cache, v_cache, lengths, block_k=block_k)


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(xdt, a_log, Bm, Cm, *, chunk: int = 128):
    return _ss.ssd_scan(xdt, a_log, Bm, Cm, chunk=chunk)


@partial(jax.jit, static_argnames=("block_r",))
def route_match(svc, features, state, *, block_r: int = 256):
    return _rm.route_match(svc, features, state, block_r=block_r)


@partial(jax.jit, static_argnames=("block_r",))
def admit(req_id, svc, features, msg_bytes, state, free_mask, rnd, gumbel, *,
          block_r: int = 256) -> AdmitResult:
    """Fused admission datapath: match → balance → slot-allocate → metrics."""
    return _rm.admit(req_id, svc, features, msg_bytes, state, free_mask,
                     rnd, gumbel, block_r=block_r)


@partial(jax.jit, static_argnames=("block_r",))
def admit_commit(req_id, svc, features, msg_bytes, token, state,
                 pool_req_id, pool_endpoint, pool_svc, pool_length,
                 pool_token, pool_active, rnd, gumbel, *,
                 block_r: int = 256) -> AdmitCommitResult:
    """Fused admission + in-kernel pool commit (no post-pass scatters)."""
    return _rm.admit_commit(req_id, svc, features, msg_bytes, token, state,
                            pool_req_id, pool_endpoint, pool_svc, pool_length,
                            pool_token, pool_active, rnd, gumbel,
                            block_r=block_r)


@partial(jax.jit, static_argnames=("eos", "max_len", "block_i"))
def complete(pool_req_id, pool_endpoint, pool_svc, pool_length, pool_token,
             pool_active, nxt, ep_load, rx_bytes, *, eos: int, max_len: int,
             block_i: int = 8) -> CompleteResult:
    """Fused completion: done detect → load release → rx metrics → free."""
    return _cp.complete(pool_req_id, pool_endpoint, pool_svc, pool_length,
                        pool_token, pool_active, nxt, ep_load, rx_bytes,
                        eos=eos, max_len=max_len, block_i=block_i)


@partial(jax.jit, static_argnames=("n_dest", "block_n"))
def relay_slots(idx, n_dest: int, *, block_n: int = 1024):
    return _rd.relay_slots(idx, n_dest, block_n=block_n)
