"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernels execute their bodies in
Python through the Pallas interpreter — bit-accurate against the BlockSpec
pipeline), and to False on real TPU backends where they lower to Mosaic.
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels import (decode_attention as _da, flash_attention as _fa,
                           relay_dispatch as _rd, route_match as _rm,
                           ssd_scan as _ss)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k,
                               interpret=_default_interpret())


@partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k_cache, v_cache, lengths, *, block_k: int = 512):
    return _da.decode_attention(q, k_cache, v_cache, lengths,
                                block_k=block_k,
                                interpret=_default_interpret())


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(xdt, a_log, Bm, Cm, *, chunk: int = 128):
    return _ss.ssd_scan(xdt, a_log, Bm, Cm, chunk=chunk,
                        interpret=_default_interpret())


@partial(jax.jit, static_argnames=("block_r",))
def route_match(svc, features, state, *, block_r: int = 256):
    return _rm.route_match(svc, features, state, block_r=block_r,
                           interpret=_default_interpret())


@partial(jax.jit, static_argnames=("n_dest", "block_n"))
def relay_slots(idx, n_dest: int, *, block_n: int = 1024):
    return _rd.relay_slots(idx, n_dest, block_n=block_n,
                           interpret=_default_interpret())
