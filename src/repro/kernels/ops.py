"""jit'd public wrappers for the Pallas kernels.

Backend selection is shared (``kernels.backend``): every kernel defaults to
``interpret=None``, which the wrapper resolves to the Pallas interpreter
off-TPU (bit-accurate against the BlockSpec pipeline) and to a real Mosaic
compile on TPU backends.

The XLB datapath wrappers (``admit`` / ``admit_commit`` / ``complete``) take
*pytrees* — ``RequestBatch``, ``RoutingState``, ``PoolState`` — and return
typed results with the updated pytrees inside, so engine state flows through
the kernels as NamedTuples end-to-end instead of a dozen positional arrays.
The kernel modules themselves (``route_match.py`` / ``completion.py``) keep
flat array signatures: that is the pallas_call boundary.

Tile shapes and the aggregation strategy are *plans*, not hard-coded
constants: when a caller leaves ``block_r``/``block_i``/``fold`` at None,
``kernels/tune.py`` resolves them — per backend, per shape, swept at first
use and cached, pinnable via XLB_BLOCK_R / XLB_BLOCK_I / XLB_FOLD /
XLB_AUTOTUNE=0 for deterministic CI.  The resolution happens in the thin
python wrapper *outside* the inner jit, and the plan enters the compiled
program through ``static_argnames`` — so each distinct plan is its own
specialization and a cached plan costs one dict lookup per call.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.analysis.invariants import guard, sanitize_enabled
from repro.core.balancer import PoolState, RequestBatch
from repro.kernels import (completion as _cp, decode_attention as _da,
                           flash_attention as _fa, relay_dispatch as _rd,
                           route_match as _rm, shard_admit as _sa,
                           ssd_scan as _ss, tune)
from repro.kernels.backend import default_interpret  # re-export  # noqa: F401
from repro.kernels.route_match import AdmitResult  # re-export  # noqa: F401


class AdmitCommitOut(NamedTuple):
    """Fused connect path: per-request decisions + updated LB state + the
    committed connection pool."""

    cluster: jax.Array       # (R,) i32 destination cluster (-1 = no match)
    endpoint: jax.Array      # (R,) i32 global endpoint (-1 = unroutable)
    instance: jax.Array      # (R,) i32 instance lane (-1 = unroutable)
    slot: jax.Array          # (R,) i32 pool slot (-1 = held / unroutable)
    ok: jax.Array            # (R,) i32 1 = admitted into a pool slot
    ep_load: jax.Array       # (E,) i32 updated outstanding-request counters
    rr_cursor: jax.Array     # (CL,) i32 updated round-robin cursors
    svc_requests: jax.Array  # (S,) i32 admitted requests per service
    svc_tx_bytes: jax.Array  # (S,) i32 admitted payload bytes per service
    no_route: jax.Array      # () i32 valid requests with no rule match
    held: jax.Array          # () i32 routable requests without a free slot
    aff_key: jax.Array       # (AFFINITY_SLOTS,) i32 updated affinity cache
    aff_ep: jax.Array        # (AFFINITY_SLOTS,) i32
    pool: PoolState          # (I, C) committed pool (active as bool)


class CompleteOut(NamedTuple):
    """Fused close path: freed pool + released counters + rx metrics +
    updated health EWMAs (DESIGN.md §8)."""

    pool: PoolState          # (I, C) pool after completion (active as bool)
    done: jax.Array          # (I, C) bool finished this step
    ep_load: jax.Array       # (E,) i32 counters after release
    rx_bytes: jax.Array      # (S,) i32 per-service rx metric
    done_cnt: jax.Array      # (E,) i32 completions this step
    ep_inflight_ewma: jax.Array  # (E,) f32 in-flight EWMA after this step
    ep_tput_ewma: jax.Array  # (E,) f32 completions-per-step EWMA


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k)


@partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k_cache, v_cache, lengths, *, block_k: int = 512):
    return _da.decode_attention(q, k_cache, v_cache, lengths, block_k=block_k)


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(xdt, a_log, Bm, Cm, *, chunk: int = 128):
    return _ss.ssd_scan(xdt, a_log, Bm, Cm, chunk=chunk)


@partial(jax.jit, static_argnames=("block_r",))
def route_match(svc, features, state, *, block_r: int = 256):
    return _rm.route_match(svc, features, state, block_r=block_r)


@partial(jax.jit, static_argnames=("block_r", "fold"))
def _admit(reqs: RequestBatch, routing, free_mask, rnd, gumbel, *,
           block_r: int, fold: str) -> AdmitResult:
    return _rm.admit(reqs.req_id, reqs.svc, reqs.features, reqs.msg_bytes,
                     routing, free_mask, rnd, gumbel, block_r=block_r,
                     fold=fold)


def admit(reqs: RequestBatch, routing, free_mask, rnd, gumbel, *,
          block_r: int | None = None,
          fold: str | None = None) -> AdmitResult:
    """Fused admission datapath: match → balance → slot-allocate → metrics.

    ``reqs.token`` is unused here — commit-free admission never touches the
    pool (see ``admit_commit`` for the full connect path).  ``block_r`` /
    ``fold`` default to the autotuned plan (``kernels/tune.py``)."""
    block_r, fold = tune.plan_admit(reqs.req_id.shape[0], free_mask.shape,
                                    block_r=block_r, fold=fold)
    res = _admit(reqs, routing, free_mask, rnd, gumbel, block_r=block_r,
                 fold=fold)
    if sanitize_enabled():
        guard("admit", dict(load_before=routing.ep_load,
                            load_after=res.ep_load, ok=res.ok,
                            held=res.held, endpoint=res.endpoint))
    return res


@partial(jax.jit, static_argnames=("block_r", "fold"))
def _admit_commit(reqs: RequestBatch, routing, pool: PoolState, rnd, gumbel,
                  *, block_r: int, fold: str) -> AdmitCommitOut:
    res = _rm.admit_commit(reqs.req_id, reqs.svc, reqs.features,
                           reqs.msg_bytes, reqs.token, routing,
                           pool.req_id, pool.endpoint, pool.svc, pool.length,
                           pool.token, pool.active, rnd, gumbel,
                           block_r=block_r, fold=fold)
    return AdmitCommitOut(
        res.cluster, res.endpoint, res.instance, res.slot, res.ok,
        res.ep_load, res.rr_cursor, res.svc_requests, res.svc_tx_bytes,
        res.no_route, res.held, res.aff_key, res.aff_ep,
        PoolState(res.pool_req_id, res.pool_endpoint, res.pool_svc,
                  res.pool_length, res.pool_token, res.pool_active > 0))


def admit_commit(reqs: RequestBatch, routing, pool: PoolState, rnd, gumbel,
                 *, block_r: int | None = None,
                 fold: str | None = None) -> AdmitCommitOut:
    """Fused admission + in-kernel pool commit (no post-pass scatters)."""
    block_r, fold = tune.plan_admit(reqs.req_id.shape[0],
                                    pool.req_id.shape, block_r=block_r,
                                    fold=fold, commit=True)
    out = _admit_commit(reqs, routing, pool, rnd, gumbel, block_r=block_r,
                        fold=fold)
    if sanitize_enabled():
        guard("admit", dict(load_before=routing.ep_load,
                            load_after=out.ep_load, ok=out.ok,
                            held=out.held, endpoint=out.endpoint,
                            instance=out.instance, slot=out.slot,
                            req_id=reqs.req_id,
                            pool_req_id=out.pool.req_id,
                            pool_active=out.pool.active))
    return out


def admit_commit_sharded(reqs: RequestBatch, routing, pool: PoolState, rnd,
                         gumbel, *, mesh, axis: str = "shard",
                         block_r: int | None = None,
                         fold: str | None = None) -> AdmitCommitOut:
    """``admit_commit`` sharded over mesh axis ``axis``: the batch splits
    ``(R/M,)``, the pool ``(I/M,)``, routing tables replicate, and ONE
    collective pass reconciles the datapath-owned state (psum'd loads /
    metrics / counts, modulo-merged rr cursors, pool commits relayed to
    their owner shards) — bit-exact vs single-shard ``admit_commit`` on the
    concatenated batch (``kernels/shard_admit.py``, DESIGN.md §7).  The
    jit + shard_map program is cached per (mesh, plan, local shape)."""
    M = mesh.shape[axis]
    R_loc = -(-max(reqs.req_id.shape[0], 1) // M)
    block_r, fold = tune.plan_admit(R_loc, pool.req_id.shape,
                                    block_r=block_r, fold=fold, commit=True)
    res = _sa.admit_commit_sharded(
        reqs.req_id, reqs.svc, reqs.features, reqs.msg_bytes, reqs.token,
        routing, pool.req_id, pool.endpoint, pool.svc, pool.length,
        pool.token, pool.active, rnd, gumbel, mesh=mesh, axis=axis,
        block_r=block_r, fold=fold)
    return AdmitCommitOut(
        res.cluster, res.endpoint, res.instance, res.slot, res.ok,
        res.ep_load, res.rr_cursor, res.svc_requests, res.svc_tx_bytes,
        res.no_route, res.held, res.aff_key, res.aff_ep,
        PoolState(res.pool_req_id, res.pool_endpoint, res.pool_svc,
                  res.pool_length, res.pool_token, res.pool_active > 0))


@partial(jax.jit, static_argnames=("eos", "max_len", "block_i", "fold"))
def _complete(pool: PoolState, nxt, ep_load, rx_bytes, ep_inflight_ewma,
              ep_tput_ewma, *, eos: int, max_len: int, block_i: int,
              fold: str) -> CompleteOut:
    res = _cp.complete(pool.req_id, pool.endpoint, pool.svc, pool.length,
                       pool.token, pool.active, nxt, ep_load, rx_bytes,
                       ep_inflight_ewma, ep_tput_ewma,
                       eos=eos, max_len=max_len, block_i=block_i, fold=fold)
    return CompleteOut(
        PoolState(res.req_id, res.endpoint, res.svc, res.length, res.token,
                  res.active > 0),
        res.done > 0, res.ep_load, res.rx_bytes, res.done_cnt,
        res.inflight_ewma, res.tput_ewma)


def _ewma_defaults(ep_load, ep_inflight_ewma, ep_tput_ewma):
    E = ep_load.shape[0]
    if ep_inflight_ewma is None:
        ep_inflight_ewma = jnp.zeros((E,), jnp.float32)
    if ep_tput_ewma is None:
        ep_tput_ewma = jnp.zeros((E,), jnp.float32)
    return ep_inflight_ewma, ep_tput_ewma


def complete(pool: PoolState, nxt, ep_load, rx_bytes, ep_inflight_ewma=None,
             ep_tput_ewma=None, *, eos: int, max_len: int,
             block_i: int | None = None,
             fold: str | None = None) -> CompleteOut:
    """Fused completion: done detect → load release → rx metrics → free →
    health EWMA update (None EWMAs → cold-start zeros)."""
    block_i, fold = tune.plan_complete(pool.req_id.shape, block_i=block_i,
                                       fold=fold)
    ep_inflight_ewma, ep_tput_ewma = _ewma_defaults(
        ep_load, ep_inflight_ewma, ep_tput_ewma)
    res = _complete(pool, nxt, ep_load, rx_bytes, ep_inflight_ewma,
                    ep_tput_ewma, eos=eos, max_len=max_len,
                    block_i=block_i, fold=fold)
    if sanitize_enabled():
        guard("complete", dict(load_before=ep_load, load_after=res.ep_load,
                               done_cnt=res.done_cnt, done=res.done,
                               active_after=res.pool.active,
                               req_id_after=res.pool.req_id))
    return res


def complete_sharded(pool: PoolState, nxt, ep_load, rx_bytes,
                     ep_inflight_ewma=None, ep_tput_ewma=None, *, mesh,
                     axis: str = "shard", eos: int, max_len: int,
                     block_i: int | None = None,
                     fold: str | None = None) -> CompleteOut:
    """``complete`` sharded over mesh axis ``axis``: the pool splits
    ``(I/M,)``, the (E,)/(S,) tables replicate, and the per-shard integer
    folds (load releases, rx bytes, completion counts) are psum-reconciled
    before ONE shared ``health_update`` epilogue on the global counts — so
    the EWMAs are bit-exact vs single-shard ``complete`` on the whole pool
    (``kernels/shard_admit.py``)."""
    M = mesh.shape[axis]
    I, C = pool.req_id.shape
    block_i, fold = tune.plan_complete((max(I // max(M, 1), 1), C),
                                       block_i=block_i, fold=fold)
    ep_inflight_ewma, ep_tput_ewma = _ewma_defaults(
        ep_load, ep_inflight_ewma, ep_tput_ewma)
    res = _sa.complete_sharded(
        pool.req_id, pool.endpoint, pool.svc, pool.length, pool.token,
        pool.active, nxt, ep_load, rx_bytes, ep_inflight_ewma, ep_tput_ewma,
        mesh=mesh, axis=axis, eos=eos, max_len=max_len, block_i=block_i,
        fold=fold)
    return CompleteOut(
        PoolState(res.req_id, res.endpoint, res.svc, res.length, res.token,
                  res.active > 0),
        res.done > 0, res.ep_load, res.rx_bytes, res.done_cnt,
        res.inflight_ewma, res.tput_ewma)


@partial(jax.jit, static_argnames=("n_dest", "block_n"))
def relay_slots(idx, n_dest: int, *, block_n: int = 1024):
    return _rd.relay_slots(idx, n_dest, block_n=block_n)
