"""Backend-aware execution defaults shared by every Pallas kernel.

Off-TPU (CPU CI, local runs) the kernels execute through the Pallas
interpreter — bit-accurate against the BlockSpec pipeline; on a real TPU
backend they lower to Mosaic.  Callers pass ``interpret=None`` to get the
auto-selected mode, or force a bool explicitly (tests, debugging).
"""

from __future__ import annotations

import jax


def default_interpret() -> bool:
    """True when the default backend cannot compile Mosaic kernels."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """None → backend auto-selection; a bool is passed through untouched."""
    return default_interpret() if interpret is None else bool(interpret)
