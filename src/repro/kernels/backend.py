"""Backend-aware execution defaults shared by every Pallas kernel.

Off-TPU (CPU CI, local runs) the kernels execute through the Pallas
interpreter — bit-accurate against the BlockSpec pipeline; on a real TPU
backend they lower to Mosaic.  Callers pass ``interpret=None`` to get the
auto-selected mode, or force a bool explicitly (tests, debugging).

The same split selects the *aggregation strategy* of the datapath kernels
(DESIGN.md §5): the dense one-hot folds are the Mosaic-lowerable form
(iota/compare/cumsum — on TPU the reductions feed the MXU), while the
scatter/sort segment folds are the form XLA:CPU executes in linear time.
``resolve_fold`` picks per backend; the block-size autotuner
(``kernels/tune.py``) can override both the fold and the tile shapes.
"""

from __future__ import annotations

import jax

FOLDS = ("onehot", "segment")


def backend_kind() -> str:
    """The cache/tuning key: 'tpu' | 'gpu' | 'cpu' (anything else verbatim)."""
    return jax.default_backend()


def default_interpret() -> bool:
    """True when the default backend cannot compile Mosaic kernels."""
    return backend_kind() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """None → backend auto-selection; a bool is passed through untouched."""
    return default_interpret() if interpret is None else bool(interpret)


def default_fold() -> str:
    """Mosaic needs the one-hot form; everything else runs the interpreter,
    where the scatter/sort segment folds are linear-time."""
    return "onehot" if backend_kind() == "tpu" else "segment"


def resolve_fold(fold: str | None) -> str:
    """None → backend auto-selection; an explicit strategy passes through."""
    if fold is None:
        return default_fold()
    if fold not in FOLDS:
        raise ValueError(f"unknown fold strategy {fold!r}; one of {FOLDS}")
    return fold
