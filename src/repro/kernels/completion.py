"""Connection-completion datapath as one fused Pallas kernel (paper §4.1's
close path: the response-side eBPF program that tears the connection down
without a host round-trip).

``complete`` fuses the whole post-decode completion chain of
``Engine.step`` over the (I, C) connection pool:

  * done detection      — an active slot finishes on EOS or on hitting the
                          length budget (``new_len >= max_len - 1``);
  * load release        — each finished slot decrements its endpoint's
                          outstanding-request counter (``policies.release``);
  * rx traffic metrics  — every active slot adds its per-token response
                          bytes to its service's rx counter;
  * slot free           — finished slots clear req_id/endpoint, zero their
                          length, and drop out of the active set.

Grid: (I / BI,) sequential over instance-lane tiles.  The endpoint-load
decrements and per-service rx bytes accumulate in VMEM scratch across the
grid and are folded into the (E,) / (S,) outputs on the last step — the same
running-counter carry as the admit kernel (``kernels/route_match.py``).
The per-tile aggregation goes through the shared segment-fold seam
(``route_match._seg_sum``, DESIGN.md §5): ``fold="segment"`` scatter-adds
into the scratch counters in O(tile) — the CPU-interpreter default —
while ``fold="onehot"`` keeps the dense Mosaic-lowerable dispatch matrix.

The kernel also closes the health-observation loop (DESIGN.md §8): every
step it folds the per-endpoint completion count (the same segment fold as
the load release) and carries two f32 EWMA accumulators exactly like
``ep_load`` — ``ep_inflight_ewma`` (requests in flight at the step, i.e.
ticks-in-flight mass) and ``ep_tput_ewma`` (completions per step).  Their
ratio is the per-endpoint latency estimate under Little's law; the
``HealthPolicy`` daemon (core/health.py) reads it, the kernel never
decides.  The EWMA epilogue is the shared ``health_update`` helper so the
single-shard kernel, the psum-reconciled sharded path, the numpy sidecar
parity, and the ref oracle are bit-exact by construction.

Sequential semantics are pinned by ``kernels.ref.complete_ref`` (bit-exact,
property-tested in tests/test_kernels.py under both folds).
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_fold, resolve_interpret
from repro.kernels.route_match import _seg_sum, _table_spec

RX_BYTES_PER_TOKEN = 2     # response payload attributed per decoded token

# EWMA smoothing for the health accumulators.  In-flight reacts faster than
# throughput so occupancy build-up on a degraded endpoint shows before its
# completion rate has fully decayed.
ALPHA_INFLIGHT = 0.25
ALPHA_TPUT = 0.125


class CompleteResult(NamedTuple):
    """Everything ``Engine.step`` needs from one fused completion launch."""

    req_id: jax.Array     # (I, C) i32, -1 on freed slots
    endpoint: jax.Array   # (I, C) i32, -1 on freed slots
    svc: jax.Array        # (I, C) i32 (unchanged; stale slots keep svc)
    length: jax.Array     # (I, C) i32, 0 on freed slots
    token: jax.Array      # (I, C) i32 last emitted token
    active: jax.Array     # (I, C) i32 0/1
    done: jax.Array       # (I, C) i32 0/1 finished this step
    ep_load: jax.Array    # (E,) i32 counters after release
    rx_bytes: jax.Array   # (S,) i32 per-service rx metric after this step
    done_cnt: jax.Array   # (E,) i32 completions this step (raw fold output)
    inflight_ewma: jax.Array  # (E,) f32 updated in-flight EWMA
    tput_ewma: jax.Array  # (E,) f32 updated completions-per-step EWMA


def health_update(inflight_ewma, tput_ewma, ep_load, done_cnt, *,
                  alpha_inflight: float = ALPHA_INFLIGHT,
                  alpha_tput: float = ALPHA_TPUT):
    """One EWMA step over the integer health observations.

    ``ep_load`` is the occupancy *before* this step's releases (requests in
    flight during the step) and ``done_cnt`` the per-endpoint completions.
    Single source of truth for the f32 epilogue: the fused kernel, the
    sharded psum path, the sidecar baselines and the ref oracle all call
    this on identical integer inputs, so the EWMAs are bit-exact across
    folds and shard counts.
    """
    occ = ep_load.astype(jnp.float32)
    cnt = done_cnt.astype(jnp.float32)
    inflight = inflight_ewma + jnp.float32(alpha_inflight) * (occ - inflight_ewma)
    tput = tput_ewma + jnp.float32(alpha_tput) * (cnt - tput_ewma)
    return inflight.astype(jnp.float32), tput.astype(jnp.float32)


def _complete_kernel(preq_ref, pep_ref, psvc_ref, plen_ref, ptok_ref,
                     pact_ref, nxt_ref, load0_ref, rx0_ref, ewl0_ref,
                     ewt0_ref, oreq_ref, oep_ref, osvc_ref, olen_ref,
                     otok_ref, oact_ref, done_ref, loadout_ref, rxout_ref,
                     cntout_ref, ewlout_ref, ewtout_ref,
                     dec_s, rx_s, *, eos: int, max_len: int, fold: str,
                     alpha_inflight: float, alpha_tput: float):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dec_s[...] = jnp.zeros_like(dec_s)
        rx_s[...] = jnp.zeros_like(rx_s)

    E = load0_ref.shape[0]
    S = rx0_ref.shape[0]
    BI, C = preq_ref.shape
    N = BI * C

    act = pact_ref[...] > 0
    nxt = nxt_ref[...]
    new_len = jnp.where(act, plen_ref[...] + 1, plen_ref[...])
    done = act & ((nxt == eos) | (new_len >= max_len - 1))

    # ---- slot free ----------------------------------------------------- #
    oreq_ref[...] = jnp.where(done, -1, preq_ref[...])
    oep_ref[...] = jnp.where(done, -1, pep_ref[...])
    osvc_ref[...] = psvc_ref[...]
    olen_ref[...] = jnp.where(done, 0, new_len)
    otok_ref[...] = jnp.where(act, nxt, ptok_ref[...])
    oact_ref[...] = (act & ~done).astype(jnp.int32)
    done_ref[...] = done.astype(jnp.int32)

    # ---- load release (tiled segment fold over endpoints) -------------- #
    epf = pep_ref[...].reshape(N)
    rel = (done & (pep_ref[...] >= 0) & (pep_ref[...] < E)).reshape(N)
    one = jnp.ones((N,), jnp.int32)
    dec_s[...] = _seg_sum(dec_s[...], jnp.where(rel, jnp.clip(epf, 0, E - 1),
                                                E), one, fold=fold)

    # ---- rx traffic metrics (per active slot, svc >= S drops) ---------- #
    svcf = jnp.maximum(psvc_ref[...], 0).reshape(N)
    actf = act.reshape(N)
    rx_s[...] = _seg_sum(rx_s[...], jnp.where(actf, jnp.minimum(svcf, S), S),
                         RX_BYTES_PER_TOKEN * one, fold=fold)

    @pl.when(i == pl.num_programs(0) - 1)
    def _emit():
        loadout_ref[...] = load0_ref[...] - dec_s[...]
        rxout_ref[...] = rx0_ref[...] + rx_s[...]
        cntout_ref[...] = dec_s[...]
        ewl, ewt = health_update(ewl0_ref[...], ewt0_ref[...],
                                 load0_ref[...], dec_s[...],
                                 alpha_inflight=alpha_inflight,
                                 alpha_tput=alpha_tput)
        ewlout_ref[...] = ewl
        ewtout_ref[...] = ewt


def complete(pool_req_id, pool_endpoint, pool_svc, pool_length, pool_token,
             pool_active, nxt, ep_load, rx_bytes, ep_inflight_ewma=None,
             ep_tput_ewma=None, *, eos: int, max_len: int,
             block_i: int = 8, fold: str | None = None,
             alpha_inflight: float = ALPHA_INFLIGHT,
             alpha_tput: float = ALPHA_TPUT,
             interpret: bool | None = None) -> CompleteResult:
    """Fused completion over the pool after one decode step.

    pool_*: (I, C) connection state (active may be bool or i32); nxt: (I, C)
    i32 tokens emitted this step; ep_load: (E,) i32; rx_bytes: (S,) i32;
    ep_inflight_ewma / ep_tput_ewma: (E,) f32 carried health accumulators
    (None → zeros, i.e. a cold start).
    ``eos`` / ``max_len`` are compile-time constants (engine attributes).
    """
    I, C = pool_req_id.shape
    E = ep_load.shape[0]
    S = rx_bytes.shape[0]
    if ep_inflight_ewma is None:
        ep_inflight_ewma = jnp.zeros((E,), jnp.float32)
    if ep_tput_ewma is None:
        ep_tput_ewma = jnp.zeros((E,), jnp.float32)
    block_i = max(1, math.gcd(I, block_i))     # tiles must cover I exactly
    grid = (I // block_i,)
    lane = pl.BlockSpec((block_i, C), lambda i: (i, 0))
    pool = [pool_req_id.astype(jnp.int32), pool_endpoint.astype(jnp.int32),
            pool_svc.astype(jnp.int32), pool_length.astype(jnp.int32),
            pool_token.astype(jnp.int32), pool_active.astype(jnp.int32)]
    o = pl.pallas_call(
        functools.partial(_complete_kernel, eos=eos, max_len=max_len,
                          fold=resolve_fold(fold),
                          alpha_inflight=alpha_inflight,
                          alpha_tput=alpha_tput),
        grid=grid,
        in_specs=[lane] * 7 + [_table_spec((E,)), _table_spec((S,)),
                               _table_spec((E,)), _table_spec((E,))],
        out_specs=[lane] * 7 + [_table_spec((E,)), _table_spec((S,)),
                                _table_spec((E,)), _table_spec((E,)),
                                _table_spec((E,))],
        out_shape=[jax.ShapeDtypeStruct((I, C), jnp.int32)] * 7
                  + [jax.ShapeDtypeStruct((E,), jnp.int32),
                     jax.ShapeDtypeStruct((S,), jnp.int32),
                     jax.ShapeDtypeStruct((E,), jnp.int32),
                     jax.ShapeDtypeStruct((E,), jnp.float32),
                     jax.ShapeDtypeStruct((E,), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((E,), jnp.int32),
                        pltpu.VMEM((S,), jnp.int32)],
        interpret=resolve_interpret(interpret),
    )(*pool, nxt.astype(jnp.int32), ep_load, rx_bytes,
      ep_inflight_ewma.astype(jnp.float32), ep_tput_ewma.astype(jnp.float32))
    return CompleteResult(*o)
