"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose against
these across shape/dtype sweeps)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None):
    """q: (B,S,H,hd); k/v: (B,S,K,hd) — naive full-matrix GQA attention."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    qg = q.reshape(B, S, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths,
                         scale: float | None = None):
    """q: (B,H,hd); caches (B,S,K,hd); attend to kpos <= lengths[b]."""
    B, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    qg = q.reshape(B, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, :] <= lengths[:, None]          # (B,S)
    s = jnp.where(mask[:, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def ssd_scan_ref(xdt, a_log, Bm, Cm):
    """Sequential (non-chunked) SSD recurrence — the ground truth.

    xdt:(B,S,nh,hd)=dt⊙x; a_log:(B,S,nh)=dt·A; Bm/Cm:(B,S,nh,N).
    """
    B, S, nh, hd = xdt.shape
    N = Bm.shape[-1]

    def step(h, inp):
        x_t, a_t, b_t, c_t = inp
        h = jnp.exp(a_t)[..., None, None] * h + jnp.einsum(
            "bhp,bhn->bhpn", x_t, b_t)
        y = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y

    h0 = jnp.zeros((B, nh, hd, N), jnp.float32)
    xs = (xdt.transpose(1, 0, 2, 3).astype(jnp.float32),
          a_log.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2, 3).astype(jnp.float32),
          Cm.transpose(1, 0, 2, 3).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(xdt.dtype)


def route_match_ref(svc, features, state):
    """First-match routing + full-scan least-request (cf. core.router)."""
    from repro.core import router
    cluster = router.match_cluster(state, svc, features)
    cl = jnp.maximum(cluster, 0)
    start = state.cluster_ep_start[cl]
    count = state.cluster_ep_count[cl]
    W = 64
    win = jnp.arange(W, dtype=jnp.int32)
    idx = jnp.clip(start[:, None] + win[None, :], 0,
                   state.ep_load.shape[0] - 1)
    ok = win[None, :] < count[:, None]
    load = jnp.where(ok, state.ep_load[idx], 2**30)
    best = jnp.argmin(load, axis=1)
    ep = jnp.take_along_axis(idx, best[:, None], 1)[:, 0]
    ep = jnp.where((cluster >= 0) & (count > 0), ep, -1)
    return cluster, ep


def relay_slots_ref(idx, n_dest: int):
    from repro.core import relay
    return relay.positions_sort(idx, n_dest)


def admit_ref(req_id, svc, features, msg_bytes, state, free_mask, rnd,
              gumbel):
    """Sequential per-request reference for the fused admit kernel.

    Processes the batch in arrival order with *live* counters: every
    routable request advances its cluster's rr cursor and bumps its chosen
    endpoint's load immediately (the next request sees it); requests that
    find no free pool slot are held and release their counter at the end of
    the batch.  Bit-exact contract with ``route_match.admit``.
    """
    import numpy as np

    from repro.core import policy_defs
    from repro.core.routing_table import (MAX_EPS_PER_CLUSTER,
                                          MAX_RULES_PER_SVC, WILDCARD)
    from repro.kernels.route_match import AdmitResult

    rid = np.asarray(req_id, np.int64)
    feats = np.asarray(features, np.int64)
    mb = np.asarray(msg_bytes, np.int64)
    rndv = np.asarray(rnd, np.int64)
    rs = np.asarray(state.svc_rule_start, np.int64)
    rc = np.asarray(state.svc_rule_count, np.int64)
    rf = np.asarray(state.rule_field, np.int64)
    rv = np.asarray(state.rule_value, np.int64)
    rcl = np.asarray(state.rule_cluster, np.int64)
    cs = np.asarray(state.cluster_ep_start, np.int64)
    cc = np.asarray(state.cluster_ep_count, np.int64)
    cp = np.asarray(state.cluster_policy, np.int64)
    einst = np.asarray(state.ep_instance, np.int64)
    drained = np.asarray(state.ep_drained, np.int64)
    free = np.asarray(free_mask).astype(bool)
    R = rid.shape[0]
    S, MR, E = rs.shape[0], rf.shape[0], einst.shape[0]
    I = free.shape[0]
    WE = MAX_EPS_PER_CLUSTER
    sv_raw = np.asarray(svc, np.int64)
    sv = np.clip(sv_raw, 0, S - 1)

    # weighted offsets are state-independent: use the kernel's exact float
    # expression (via jnp) so f32 rounding and argmax tie-breaks agree
    cl0 = np.zeros((R,), np.int64)
    for r in range(R):
        if rid[r] < 0:
            continue
        start, count = rs[sv[r]], rc[sv[r]]
        for t in range(MAX_RULES_PER_SVC):
            if t >= count:
                continue
            ix = min(max(start + t, 0), MR - 1)
            if rv[ix] == WILDCARD or rv[ix] == feats[r, rf[ix]]:
                cl0[r] = rcl[ix] + 1        # +1: 0 stays "no match"
                break
    clm = np.maximum(cl0 - 1, 0)
    win = jnp.arange(WE, dtype=jnp.int32)
    eidx_all = jnp.clip(jnp.asarray(cs[clm], jnp.int32)[:, None]
                        + win[None, :], 0, E - 1)
    eok_all = ((win[None, :] < jnp.asarray(cc[clm], jnp.int32)[:, None])
               & (state.ep_drained[eidx_all] == 0))   # eligibility mask
    w = jnp.where(eok_all, state.ep_weight[eidx_all], 0.0)
    wt_off = np.asarray(jnp.argmax(
        jnp.where(eok_all, jnp.log(w + 1e-9) + jnp.asarray(gumbel),
                  -jnp.inf), axis=1), np.int64)

    loads = np.asarray(state.ep_load, np.int64).copy()
    cur = np.asarray(state.rr_cursor, np.int64).copy()
    # the oracle ctx handed to every policy's sequential hook (the same
    # registry entry the kernel lowers — core/policy_defs.py); affinity
    # hooks mutate affk/affe in place, request by request
    octx = policy_defs.OracleCtx(
        loads=loads, cur=cur, cs=cs, cc=cc, E=E,
        drained=drained,
        rnd=rndv,
        fkey=np.asarray(policy_defs.flow_hash(jnp.asarray(features)),
                        np.int64),
        wt_off=None,                    # filled below (needs the window)
        mg=np.asarray(state.maglev_table, np.int64),
        T=state.maglev_table.shape[1],
        affk=np.asarray(state.aff_key, np.int64).copy(),
        affe=np.asarray(state.aff_ep, np.int64).copy(),
        A=state.aff_key.shape[0])
    octx.wt_off = wt_off
    icnt = np.zeros((I,), np.int64)
    cluster = np.full((R,), -1, np.int64)
    ep_out = np.full((R,), -1, np.int64)
    inst_out = np.full((R,), -1, np.int64)
    slot_out = np.full((R,), -1, np.int64)
    ok_out = np.zeros((R,), np.int64)
    sreq = np.zeros((S,), np.int64)
    stx = np.zeros((S,), np.int64)
    no_route = held_n = 0
    held_eps: list = []

    for r in range(R):
        if rid[r] < 0:
            continue
        if cl0[r] == 0:
            no_route += 1
            continue
        c = cl0[r] - 1
        cluster[r] = c
        # eligible = in the window AND not draining; a cluster with no
        # eligible endpoint (empty, or fully draining) is unroutable
        elig = [min(max(cs[c] + j, 0), E - 1) for j in range(min(cc[c], WE))]
        elig = [e for e in elig if drained[e] == 0]
        if not elig:
            continue
        pol = int(cp[c])
        pdef = policy_defs.BY_ENUM.get(pol,
                                       policy_defs.BY_ENUM[0])  # unknown→rr
        ep = pdef.oracle_pick(octx, r, c, elig)
        cur[c] += 1          # raw count; reduced modulo at batch end
        loads[ep] += 1
        ep_out[r] = ep
        inst = einst[ep]
        inst_out[r] = inst
        ic = min(max(inst, 0), I - 1)
        rank = icnt[ic]
        icnt[ic] += 1
        free_slots = np.flatnonzero(free[ic])
        if rank < free_slots.shape[0]:
            ok_out[r] = 1
            slot_out[r] = free_slots[rank]
            if sv_raw[r] < S:                   # metrics drop svc >= S
                sreq[sv[r]] += 1
                stx[sv[r]] += mb[r]
        else:
            held_n += 1
            held_eps.append(ep)
    for e in held_eps:                      # batch-end release of held
        loads[e] -= 1
    cur = cur % np.maximum(cc, 1)           # kernel reduces every cursor

    i32 = lambda a: np.asarray(a, np.int32)
    return AdmitResult(i32(cluster), i32(ep_out), i32(inst_out),
                       i32(slot_out), i32(ok_out), i32(loads), i32(cur),
                       i32(sreq), i32(stx), np.int32(no_route),
                       np.int32(held_n), i32(octx.affk), i32(octx.affe))


def admit_commit_ref(req_id, svc, features, msg_bytes, token, state,
                     pool_req_id, pool_endpoint, pool_svc, pool_length,
                     pool_token, pool_active, rnd, gumbel):
    """Sequential reference for ``route_match.admit_commit``: ``admit_ref``
    grown with the pool writeback — each admitted request (arrival order)
    writes req_id/endpoint/svc/length=0/token/active=1 at its
    (instance, slot).  Bit-exact contract with the fused kernel."""
    import numpy as np

    from repro.kernels.route_match import AdmitCommitResult

    free = ~np.asarray(pool_active).astype(bool)
    base = admit_ref(req_id, svc, features, msg_bytes, state, free, rnd,
                     gumbel)
    preq = np.asarray(pool_req_id, np.int32).copy()
    pep = np.asarray(pool_endpoint, np.int32).copy()
    psvc = np.asarray(pool_svc, np.int32).copy()
    plen = np.asarray(pool_length, np.int32).copy()
    ptok = np.asarray(pool_token, np.int32).copy()
    pact = np.asarray(pool_active).astype(np.int32).copy()
    rid = np.asarray(req_id, np.int32)
    sv = np.asarray(svc, np.int32)
    tok = np.asarray(token, np.int32)
    for r in range(rid.shape[0]):
        if not base.ok[r]:
            continue
        i, s = int(base.instance[r]), int(base.slot[r])
        preq[i, s] = rid[r]
        pep[i, s] = base.endpoint[r]
        psvc[i, s] = sv[r]
        plen[i, s] = 0
        ptok[i, s] = tok[r]
        pact[i, s] = 1
    return AdmitCommitResult(*base, preq, pep, psvc, plen, ptok, pact)


def admit_sharded_ref(req_id, svc, features, msg_bytes, token, state,
                      pool_req_id, pool_endpoint, pool_svc, pool_length,
                      pool_token, pool_active, rnd, gumbel):
    """Oracle for the mesh-sharded admission (``ops.admit_commit_sharded``).

    Per-request inputs arrive stacked per shard — ``(M, R_loc)`` (features
    ``(M, R_loc, F)``, gumbel ``(M, R_loc, WE)``) — and the deterministic
    merge rule is **shard-major order**: the sharded datapath must behave
    exactly as if one host had ingested shard 0's rows, then shard 1's, and
    so on.  Under that rule every field is pinned bit-exactly by
    ``admit_commit_ref`` on the concatenation:

      * order-insensitive state — ``ep_load`` (rr/water-fill/random/weighted
        multisets depend only on counts + per-request draws), per-service
        metrics, the ``no_route``/``held`` counts and the pool occupancy
        multiset — is identical under ANY serialization of the shards;
      * order-sensitive outputs — which (instance, slot) each request lands
        in, and WHICH requests are held when a pool fills — are resolved by
        the shard-major rule (global per-instance arrival rank = preceding
        shards' counts + local rank).

    Returns ``AdmitCommitResult`` with per-request fields back in
    ``(M, R_loc)`` shard layout.
    """
    M, R_loc = req_id.shape
    flat = lambda a: a.reshape(M * R_loc, *a.shape[2:])
    base = admit_commit_ref(flat(req_id), flat(svc), flat(features),
                            flat(msg_bytes), flat(token), state,
                            pool_req_id, pool_endpoint, pool_svc,
                            pool_length, pool_token, pool_active,
                            flat(rnd), flat(gumbel))
    from repro.kernels.route_match import AdmitCommitResult
    unflat = lambda a: a.reshape(M, R_loc)
    return AdmitCommitResult(
        unflat(base.cluster), unflat(base.endpoint), unflat(base.instance),
        unflat(base.slot), unflat(base.ok), *base[5:])


def complete_ref(pool_req_id, pool_endpoint, pool_svc, pool_length,
                 pool_token, pool_active, nxt, ep_load, rx_bytes,
                 ep_inflight_ewma=None, ep_tput_ewma=None, *,
                 eos: int, max_len: int):
    """Sequential per-slot reference for the fused completion kernel
    (``kernels.completion.complete``): done detect (EOS / length budget) →
    endpoint load release → per-service rx metrics → slot free → health
    EWMA update (via the shared ``health_update`` epilogue on the integer
    completion counts, so the oracle is bit-exact with the kernel)."""
    import numpy as np

    from repro.kernels.completion import (RX_BYTES_PER_TOKEN, CompleteResult,
                                          health_update)

    preq = np.asarray(pool_req_id, np.int32).copy()
    pep = np.asarray(pool_endpoint, np.int32).copy()
    psvc = np.asarray(pool_svc, np.int32).copy()
    plen = np.asarray(pool_length, np.int32).copy()
    ptok = np.asarray(pool_token, np.int32).copy()
    pact = np.asarray(pool_active).astype(bool).copy()
    nx = np.asarray(nxt, np.int32)
    loads = np.asarray(ep_load, np.int32).copy()
    loads0 = loads.copy()                       # occupancy before releases
    rx = np.asarray(rx_bytes, np.int32).copy()
    I, C = preq.shape
    E, S = loads.shape[0], rx.shape[0]
    ewl = (np.zeros((E,), np.float32) if ep_inflight_ewma is None
           else np.asarray(ep_inflight_ewma, np.float32).copy())
    ewt = (np.zeros((E,), np.float32) if ep_tput_ewma is None
           else np.asarray(ep_tput_ewma, np.float32).copy())
    done = np.zeros((I, C), np.int32)
    cnt = np.zeros((E,), np.int32)
    for i in range(I):
        for c in range(C):
            if not pact[i, c]:
                continue
            sv = max(int(psvc[i, c]), 0)
            if sv < S:                          # mode="drop" semantics
                rx[sv] += RX_BYTES_PER_TOKEN
            plen[i, c] += 1
            ptok[i, c] = nx[i, c]
            if nx[i, c] == eos or plen[i, c] >= max_len - 1:
                done[i, c] = 1
                if 0 <= pep[i, c] < E:
                    loads[pep[i, c]] -= 1
                    cnt[pep[i, c]] += 1
                preq[i, c] = -1
                pep[i, c] = -1
                plen[i, c] = 0
                pact[i, c] = False
    new_ewl, new_ewt = health_update(jnp.asarray(ewl), jnp.asarray(ewt),
                                     jnp.asarray(loads0), jnp.asarray(cnt))
    return CompleteResult(preq, pep, psvc, plen, ptok,
                          pact.astype(np.int32), done, loads, rx, cnt,
                          np.asarray(new_ewl), np.asarray(new_ewt))
