"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose against
these across shape/dtype sweeps)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None):
    """q: (B,S,H,hd); k/v: (B,S,K,hd) — naive full-matrix GQA attention."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    qg = q.reshape(B, S, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, lengths,
                         scale: float | None = None):
    """q: (B,H,hd); caches (B,S,K,hd); attend to kpos <= lengths[b]."""
    B, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = scale if scale is not None else 1.0 / jnp.sqrt(hd)
    qg = q.reshape(B, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(S)[None, :] <= lengths[:, None]          # (B,S)
    s = jnp.where(mask[:, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w, v_cache.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


def ssd_scan_ref(xdt, a_log, Bm, Cm):
    """Sequential (non-chunked) SSD recurrence — the ground truth.

    xdt:(B,S,nh,hd)=dt⊙x; a_log:(B,S,nh)=dt·A; Bm/Cm:(B,S,nh,N).
    """
    B, S, nh, hd = xdt.shape
    N = Bm.shape[-1]

    def step(h, inp):
        x_t, a_t, b_t, c_t = inp
        h = jnp.exp(a_t)[..., None, None] * h + jnp.einsum(
            "bhp,bhn->bhpn", x_t, b_t)
        y = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y

    h0 = jnp.zeros((B, nh, hd, N), jnp.float32)
    xs = (xdt.transpose(1, 0, 2, 3).astype(jnp.float32),
          a_log.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2, 3).astype(jnp.float32),
          Cm.transpose(1, 0, 2, 3).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(xdt.dtype)


def route_match_ref(svc, features, state):
    """First-match routing + full-scan least-request (cf. core.router)."""
    from repro.core import router
    cluster = router.match_cluster(state, svc, features)
    cl = jnp.maximum(cluster, 0)
    start = state.cluster_ep_start[cl]
    count = state.cluster_ep_count[cl]
    W = 64
    win = jnp.arange(W, dtype=jnp.int32)
    idx = jnp.clip(start[:, None] + win[None, :], 0,
                   state.ep_load.shape[0] - 1)
    ok = win[None, :] < count[:, None]
    load = jnp.where(ok, state.ep_load[idx], 2**30)
    best = jnp.argmin(load, axis=1)
    ep = jnp.take_along_axis(idx, best[:, None], 1)[:, 0]
    ep = jnp.where((cluster >= 0) & (count > 0), ep, -1)
    return cluster, ep


def relay_slots_ref(idx, n_dest: int):
    from repro.core import relay
    return relay.positions_sort(idx, n_dest)
