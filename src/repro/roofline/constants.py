"""Target-hardware constants (TPU v5e), per the assignment brief."""

PEAK_FLOPS_BF16 = 197e12      # per chip, bf16
HBM_BW = 819e9                # bytes/s per chip
ICI_BW_PER_LINK = 50e9        # bytes/s per link (~50 GB/s)
HBM_BYTES = 16 * 2**30        # 16 GiB per chip

BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
         "f16": 2, "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
         "u64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}
