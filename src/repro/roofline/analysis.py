"""Roofline analysis from the compiled dry-run artifact (deliverable g).

Three terms per (arch × shape × mesh) cell, all in seconds-per-step:

  compute    = HLO_dot_FLOPs_per_device / PEAK_FLOPS_BF16
  memory     = analytic_HBM_bytes_per_device / HBM_BW
  collective = HLO_collective_wire_bytes_per_device / ICI_BW_PER_LINK

Why parsed + analytic instead of raw ``cost_analysis()``: XLA's CPU cost
analysis counts a ``while`` body ONCE (verified in this container — a
12-step scan reports ~1/12 of the true FLOPs), and every layer stack here is
a ``lax.scan``.  So we (a) parse the optimized HLO per computation, (b) build
the call graph (entry → while bodies → fusions), (c) multiply each
computation's dots/collectives by its loop trip count (= the known scan
length), giving exact whole-program numbers from the real compiled module.
``cost_analysis()`` raw values are still recorded for reference.

MODEL_FLOPS (6·N·T dense / 6·N_active·T MoE + attention) provides the
useful-work yardstick; MODEL_FLOPS / HLO_FLOPs exposes remat & padding waste.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from typing import Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.roofline.constants import (BYTES, HBM_BW, ICI_BW_PER_LINK,
                                      PEAK_FLOPS_BF16)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s+(%[\w.\-]+) = (\(.*?\)|\S+) ([\w\-]+)\((.*)$")
# computation headers sit at column 0: "%name (params...) -> type {" —
# params may contain /*index=N*/ comments, so don't exclude '='
_COMP_RE = re.compile(r"^(ENTRY )?(%[\w.\-]+) \(.*\{\s*$")
_CALL_ATTR_RE = re.compile(
    r"(?:to_apply|body|condition|calls)=(%[\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]*)\}")


def _tensor_bytes(type_str: str) -> int:
    """bytes of 'bf16[2,3]{1,0}' or a tuple '(f32[2], s32[])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def parse_hlo(text: str, trip_hint: int) -> dict:
    """Walk the optimized HLO; return dot FLOPs + collective bytes, loop-
    scaled.  All numbers are PER DEVICE (the module is the per-device SPMD
    program)."""
    # ---- split into computations ---------------------------------------- #
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)

    # ---- per computation: ops, result shapes, edges ---------------------- #
    result_type: dict[str, str] = {}
    ops: dict[str, list[tuple[str, str, str, str]]] = defaultdict(list)
    edges: dict[str, list[tuple[str, bool]]] = defaultdict(list)
    for cname, lines in comps.items():
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            name, rtype, opname, rest = m.groups()
            result_type[name] = rtype
            ops[cname].append((name, rtype, opname, rest))
            trip = 1
            if opname == "while":
                tm = _TRIP_RE.search(line)
                # per-while trip count from backend_config; fall back to the
                # layer-scan hint when XLA didn't record one
                trip = int(tm.group(1)) if tm else trip_hint
            for callee in _CALL_ATTR_RE.findall(line):
                edges[cname].append((callee, trip))
            bm = _BRANCH_RE.search(line)
            if bm:
                for callee in bm.group(1).split(","):
                    edges[cname].append((callee.strip(), 1))

    # ---- multipliers via BFS (while bodies × trip_hint) ------------------ #
    mult: dict[str, float] = {entry: 1.0} if entry else {}
    frontier = [entry] if entry else []
    while frontier:
        c = frontier.pop()
        for callee, trip in edges.get(c, ()):
            m_new = mult[c] * trip
            if mult.get(callee, 0) < m_new:
                mult[callee] = m_new
                frontier.append(callee)

    # ---- accumulate ------------------------------------------------------ #
    dot_flops = 0.0
    coll = {k: {"bytes": 0.0, "count": 0.0} for k in COLLECTIVES}
    n_while = 0
    for cname, cops in ops.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for name, rtype, opname, rest in cops:
            if opname == "while":
                n_while += 1
            if opname == "dot":
                out = _shape_dims(rtype)
                operands = re.findall(r"(%[\w.\-]+)", rest)
                lhs_dims = _shape_dims(result_type.get(
                    operands[0], "")) if operands else []
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
                contract = 1
                if cm and lhs_dims:
                    for d in cm.group(1).split(","):
                        if d:
                            contract *= lhs_dims[int(d)]
                dot_flops += m * 2.0 * math.prod(out or [0]) * contract
            elif opname in COLLECTIVES:
                b = _tensor_bytes(rtype)
                gm = _GROUPS_RE.search(rest)
                g = len(gm.group(1).split(",")) if gm and gm.group(1) else 2
                if opname == "all-gather":
                    wire = b * (g - 1) / g
                elif opname == "all-reduce":
                    wire = 2.0 * b * (g - 1) / g
                elif opname == "reduce-scatter":
                    wire = b * (g - 1)          # result is the shard
                elif opname == "all-to-all":
                    wire = b * (g - 1) / g
                else:                            # collective-permute
                    wire = b
                coll[opname]["bytes"] += m * wire
                coll[opname]["count"] += m
    return {"dot_flops": dot_flops, "collectives": coll, "n_while": n_while,
            "collective_bytes": sum(c["bytes"] for c in coll.values())}


# --------------------------------------------------------------------------- #
# Analytic useful-work + memory-traffic models
# --------------------------------------------------------------------------- #


def attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.is_hybrid:
        return cfg.n_layers // cfg.attn_period
    return cfg.n_layers


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·T (train) / 2·N·T (inference) + attention score/value FLOPs."""
    B, S = shape.global_batch, shape.seq_len
    N = cfg.active_param_count()
    La = attn_layers(cfg)
    H, hd = max(cfg.n_heads, 1), max(cfg.head_dim, 1)
    if cfg.mla is not None:
        hd = cfg.mla.qk_head_dim
    if shape.kind == "train":
        T = B * S
        attn = La * 2.0 * B * S * S * H * hd          # causal fwd (÷2) ×QK,AV
        if cfg.is_encdec:
            F = cfg.enc_frames
            attn += cfg.n_enc_layers * 4.0 * B * F * F * H * hd
            attn += La * 4.0 * B * S * F * H * hd     # cross
        return 6.0 * N * T + 3.0 * attn               # bwd ≈ 2× fwd
    if shape.kind == "prefill":
        T = B * S
        return 2.0 * N * T + La * 2.0 * B * S * S * H * hd
    # decode: one token, full-cache attention reads
    if cfg.mla is not None:
        r = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        attn = La * 2.0 * B * S * cfg.n_heads * (r + cfg.mla.kv_lora_rank)
    else:
        attn = La * 4.0 * B * S * H * hd
    ssm = 0.0
    if cfg.ssm is not None:
        s = cfg.ssm
        nh = s.n_heads(cfg.d_model)
        n_ssm = (cfg.n_layers - La) if cfg.is_hybrid else cfg.n_layers
        ssm = n_ssm * 6.0 * B * nh * s.head_dim * s.d_state
    return 2.0 * N * B + attn + ssm


def analytic_memory_bytes(cfg: ModelConfig, shape: ShapeConfig,
                          n_chips: int, moment_bytes: int = 4,
                          param_shards: Optional[int] = None) -> float:
    """Per-device HBM traffic per step (documented approximation):

      train   : params 2R+1W (fwd+bwd use, update write) + grads 1W+1R +
                moments 2R+2W + remat boundary activations (2W+2R)
      prefill : params 1R + boundary activations + cache 1W
      decode  : params 1R + cache 1R (+ small writes)
    """
    P = cfg.param_count()
    pb = 2 * P / (param_shards or n_chips)      # bf16 local param bytes
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, cfg.n_layers
    if shape.kind == "train":
        act = 2 * B * S * D * L / n_chips       # bf16 boundary residuals
        mom = 2 * moment_bytes * P / n_chips
        return 3 * pb + 2 * pb + 2 * mom + 4 * act
    if shape.kind == "prefill":
        act = 2 * B * S * D * L / n_chips
        cache = cache_bytes(cfg, shape) / n_chips
        return pb + 2 * act + cache
    cache = cache_bytes(cfg, shape) / n_chips
    return pb + cache


def cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    La = attn_layers(cfg)
    if cfg.mla is not None:
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    else:
        per_tok = 2 * cfg.n_kv_heads * cfg.head_dim
    kv = 2.0 * La * B * S * per_tok             # bf16
    ssm = 0.0
    if cfg.ssm is not None:
        s = cfg.ssm
        nh = s.n_heads(cfg.d_model)
        n_ssm = (cfg.n_layers - La) if cfg.is_hybrid else cfg.n_layers
        ssm = 4.0 * n_ssm * B * nh * s.head_dim * s.d_state
    if cfg.is_encdec:
        kv += 2.0 * La * B * cfg.enc_frames * per_tok * 2
    return kv + ssm


# --------------------------------------------------------------------------- #
# Entry point used by dryrun.py
# --------------------------------------------------------------------------- #


def trip_hint(cfg: ModelConfig) -> int:
    from repro.models.model import n_scan_blocks
    return n_scan_blocks(cfg)


def analyze_compiled(cfg: ModelConfig, shape: ShapeConfig, ms, compiled,
                     multi_pod: bool) -> dict:
    n_chips = math.prod(ms.mesh.shape.values())
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    parsed = parse_hlo(compiled.as_text(), trip_hint(cfg))

    flops_dev = parsed["dot_flops"]
    coll_dev = parsed["collective_bytes"]
    param_shards = (ms.mesh.shape[ms.tp]
                    if getattr(ms, "params_tp_only", False) else None)
    mem_dev = analytic_memory_bytes(cfg, shape, n_chips,
                                    param_shards=param_shards)
    mf = model_flops(cfg, shape)

    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = mem_dev / HBM_BW
    coll_s = coll_dev / ICI_BW_PER_LINK
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful_frac = (mf / n_chips / PEAK_FLOPS_BF16) / bound if bound else 0.0

    per_dev_bytes = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes)
    return {
        "n_chips": n_chips,
        "memory_analysis": {
            "argument_GiB": round(mem.argument_size_in_bytes / 2**30, 3),
            "output_GiB": round(mem.output_size_in_bytes / 2**30, 3),
            "temp_GiB": round(mem.temp_size_in_bytes / 2**30, 3),
            "total_GiB": round(per_dev_bytes / 2**30, 3),
            "fits_16GiB": bool(per_dev_bytes < 16 * 2**30),
        },
        "cost_analysis_raw": {
            "flops": ca.get("flops"), "bytes": ca.get("bytes accessed")},
        "hlo": {
            "dot_flops_per_device": flops_dev,
            "collective_bytes_per_device": coll_dev,
            "collectives": {k: v for k, v in parsed["collectives"].items()
                            if v["count"]},
            "n_while": parsed["n_while"],
            "trip_hint": trip_hint(cfg),
        },
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": coll_s,
            "dominant": dominant,
            "step_lower_bound_s": bound,
            "model_flops": mf,
            "model_flops_per_device": mf / n_chips,
            "useful_flops_ratio": (mf / n_chips) / flops_dev if flops_dev
            else None,
            "roofline_fraction": useful_frac,
            "analytic_hbm_bytes_per_device": mem_dev,
        },
    }
