"""Repo-wide AST lints — the hazards the repo keeps fixing by hand.

Where :mod:`repro.analysis.verifier` proves properties of *traced* jaxprs,
this pass reads the *source*: hazards that precede tracing (a scatter
written without an explicit OOB ``mode``, wall-clock or unseeded host
randomness inside a traced datapath module, a policy enum compared as a
bare integer literal, a ``PolicyDef`` registered without all four lowering
hooks) and the repo-structure question no trace can answer — which seed
modules are dead weight and whether the datapath has started importing
them.

Scopes
------
* **traced datapath** (``TRACED_DATAPATH``): ``repro.kernels`` +
  ``repro.core`` — code that ends up inside jit/pallas programs.  The
  scatter-mode, nondeterminism and enum-literal lints run here.
  ``kernels/tune.py`` is exempt from the wall-clock lint: it is the
  autotuner, whose whole job is timing.
* **import graph**: every module under ``src/repro``.  Seed modules under
  ``repro.models`` / ``repro.optim`` / ``repro.data`` /
  ``repro.sharding`` / ``repro.configs`` (plus the train-side launch and
  runtime legs) are *expected* to be unreachable from the serving
  datapath; the report marks them dead rather than deleting them, and CI
  fails only if a datapath module *newly imports* one
  (``datapath-imports-dead`` finding).

Findings reuse :class:`repro.analysis.verifier.Finding` so the CLI and the
mutation tests treat both passes uniformly.
"""

from __future__ import annotations

import ast
import os

from repro.analysis.verifier import Finding

SRC_ROOT = os.path.join(os.path.dirname(__file__), "..", "..")

#: modules whose code runs inside traced programs — strictest lints
TRACED_DATAPATH = ("repro.kernels", "repro.core")

#: the serving datapath the import-graph reachability starts from
DATAPATH_ROOTS = (
    "repro.kernels", "repro.core", "repro.runtime.serve_loop",
    "repro.runtime.transport", "repro.runtime.elastic",
    "repro.workload", "repro.launch.serve", "repro.launch.mesh",
    "repro.analysis",
)

#: seed packages/modules that MAY be dead — reported, never deleted; a
#: datapath import of a dead one is the CI-failing event
SEED_LEGACY = (
    "repro.models", "repro.optim", "repro.data", "repro.sharding",
    "repro.configs", "repro.roofline", "repro.launch.train",
    "repro.launch.dryrun", "repro.runtime.train_loop",
    "repro.runtime.checkpoint",
)

#: wall-clock exemptions inside the traced datapath (measurement code)
CLOCK_EXEMPT = ("repro.kernels.tune",)

#: seeded constructors — deterministic host PRNG is fine, module-level
#: draws are not
SEEDED_RNG_CTORS = {"RandomState", "default_rng", "Generator",
                    "SeedSequence", "PRNGKey", "key"}

#: names whose comparison against a bare int literal bypasses policy_defs
ENUM_NAMES = {"policy", "cluster_policy", "enum"}

#: .at[...] update methods that scatter
_SCATTER_METHODS = {"set", "add", "mul", "min", "max", "apply", "subtract",
                    "divide", "power"}


def _module_name(path: str) -> str:
    rel = os.path.relpath(path, SRC_ROOT).replace(os.sep, "/")
    mod = rel[:-3].replace("/", ".")
    return mod[:-9] if mod.endswith(".__init__") else mod


def _iter_modules():
    root = os.path.join(SRC_ROOT, "repro")
    for dirpath, _dirs, files in os.walk(root):
        for f in sorted(files):
            if f.endswith(".py"):
                path = os.path.join(dirpath, f)
                yield _module_name(path), path


def _in(mod: str, prefixes) -> bool:
    return any(mod == p or mod.startswith(p + ".") for p in prefixes)


def _static_index(node: ast.expr) -> bool:
    """True if a subscript index is fully static (ints / slices of ints /
    ellipsis / None) — such scatters cannot go OOB and need no mode."""
    if isinstance(node, ast.Tuple):
        return all(_static_index(e) for e in node.elts)
    if isinstance(node, ast.Constant):
        return isinstance(node.val if hasattr(node, "val") else node.value,
                          (int, type(None), type(...)))
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        return _static_index(node.operand)
    if isinstance(node, ast.Slice):
        return all(s is None or _static_index(s)
                   for s in (node.lower, node.upper, node.step))
    return False


class _ModuleLinter(ast.NodeVisitor):
    def __init__(self, mod: str, findings: list):
        self.mod = mod
        self.findings = findings
        self.traced = _in(mod, TRACED_DATAPATH)

    def flag(self, code, node, detail):
        self.findings.append(Finding(
            code, f"{self.mod}:{getattr(node, 'lineno', '?')}", detail))

    # ---- scatter mode ---------------------------------------------------- #

    def _check_scatter(self, call: ast.Call):
        f = call.func
        if not (isinstance(f, ast.Attribute) and f.attr in _SCATTER_METHODS):
            return
        sub = f.value
        if not (isinstance(sub, ast.Subscript)
                and isinstance(sub.value, ast.Attribute)
                and sub.value.attr == "at"):
            return
        if _static_index(sub.slice):
            return
        if any(kw.arg == "mode" for kw in call.keywords):
            return
        self.flag("scatter-missing-mode", call,
                  f".at[...].{f.attr}() with a computed index relies on "
                  "the backend's implicit OOB behavior — spell the mode "
                  "(mode=\"drop\" for sentinel-steered folds)")

    # ---- nondeterminism -------------------------------------------------- #

    def _check_nondet(self, call: ast.Call):
        f = call.func
        if not isinstance(f, ast.Attribute):
            return
        base = f.value
        if isinstance(base, ast.Attribute) and isinstance(base.value,
                                                          ast.Name):
            root, mid = base.value.id, base.attr
            if root in ("np", "numpy") and mid == "random" \
                    and f.attr not in SEEDED_RNG_CTORS:
                self.flag("nondet-in-datapath", call,
                          f"module-level np.random.{f.attr}() draws from "
                          "hidden global state — pass a seeded Generator/"
                          "RandomState in")
        elif isinstance(base, ast.Name):
            if base.id == "time" and self.mod not in CLOCK_EXEMPT:
                self.flag("nondet-in-datapath", call,
                          f"wall-clock time.{f.attr}() inside a traced "
                          "datapath module — clocks belong to the serving "
                          "loop, not the compiled step")
            if base.id == "random" and f.attr not in ("Random",
                                                      "SystemRandom"):
                self.flag("nondet-in-datapath", call,
                          f"stdlib random.{f.attr}() draws from hidden "
                          "global state — use a seeded instance")

    # ---- enum literals --------------------------------------------------- #

    def _check_enum_literal(self, node: ast.Compare):
        sides = [node.left] + list(node.comparators)
        names = [s for s in sides
                 if (isinstance(s, ast.Name) and s.id in ENUM_NAMES)
                 or (isinstance(s, ast.Attribute) and s.attr in ENUM_NAMES)]
        lits = [s for s in sides if isinstance(s, ast.Constant)
                and isinstance(s.value, int)
                and not isinstance(s.value, bool)]
        if names and lits and not any(
                isinstance(op, (ast.Lt, ast.LtE, ast.Gt, ast.GtE))
                for op in node.ops):
            self.flag("enum-literal-bypass", node,
                      "policy enum compared against a bare integer "
                      "literal — route through policy_defs (POLICY_* / "
                      "PolicyDef.enum) so renumbering cannot silently "
                      "reroute traffic")

    # ---- PolicyDef registration ------------------------------------------ #

    def _check_policy_def(self, call: ast.Call):
        f = call.func
        name = f.id if isinstance(f, ast.Name) else \
            f.attr if isinstance(f, ast.Attribute) else None
        if name != "PolicyDef":
            return
        hooks = ("kernel_offset", "oracle_pick", "staged_offset",
                 "host_pick")
        kw = {k.arg for k in call.keywords}
        # dataclass field order: 5 metadata fields then the four hooks
        covered = max(len(call.args) - 5, 0) + len(kw & set(hooks))
        if covered < len(hooks) and not any(k.arg is None
                                            for k in call.keywords):
            self.flag("policy-missing-hook", call,
                      f"PolicyDef registration covers only {covered}/4 "
                      "lowering hooks (kernel_offset, oracle_pick, "
                      "staged_offset, host_pick)")

    def visit_Call(self, node: ast.Call):
        if self.traced:
            self._check_scatter(node)
            self._check_nondet(node)
        self._check_policy_def(node)
        self.generic_visit(node)

    def visit_Compare(self, node: ast.Compare):
        if self.traced and self.mod != "repro.core.policy_defs":
            self._check_enum_literal(node)
        self.generic_visit(node)


def lint_sources() -> list[Finding]:
    """Run every AST lint over ``src/repro``."""
    findings: list[Finding] = []
    for mod, path in _iter_modules():
        with open(path, encoding="utf-8") as fh:
            tree = ast.parse(fh.read(), filename=path)
        _ModuleLinter(mod, findings).visit(tree)
    return findings


# --------------------------------------------------------------------------- #
# Import graph: dead seed modules + datapath containment
# --------------------------------------------------------------------------- #


def _imports_of(path: str, mod: str) -> set[str]:
    with open(path, encoding="utf-8") as fh:
        tree = ast.parse(fh.read(), filename=path)
    pkg = mod.rsplit(".", 1)[0] if "." in mod else mod
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            out.update(a.name for a in node.names)
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            if node.level:                      # relative import
                parts = pkg.split(".")
                parts = parts[:len(parts) - (node.level - 1)]
                base = ".".join(parts + ([base] if base else []))
            out.add(base)
            out.update(f"{base}.{a.name}" for a in node.names)
    return {m for m in out if m.startswith("repro")}


def import_graph() -> dict[str, set[str]]:
    """``module -> set(imported repro modules)`` over ``src/repro``."""
    mods = dict(_iter_modules())
    graph = {}
    for mod, path in mods.items():
        deps = set()
        for imp in _imports_of(path, mod):
            # resolve "from pkg import name" where name is an attr
            while imp and imp not in mods:
                imp = imp.rsplit(".", 1)[0] if "." in imp else ""
            if imp and imp != mod:
                deps.add(imp)
        graph[mod] = deps
    return graph


def _reachable(graph, roots):
    seen, stack = set(), [r for r in graph if _in(r, roots)]
    while stack:
        m = stack.pop()
        if m in seen:
            continue
        seen.add(m)
        stack.extend(graph.get(m, ()))
    return seen


def import_report() -> tuple[dict, list[Finding]]:
    """The dead-module report and its CI-failing subset.

    Returns ``(report, findings)``: the report maps every module to its
    status (``datapath`` / ``dead-seed`` / ``other``), findings carry only
    ``datapath-imports-dead`` — a *new* import edge from live datapath
    code into a module the report marks dead.  Dead modules themselves
    are informational: the seed keeps its scaffolding until a PR needs
    the space.
    """
    graph = import_graph()
    live = _reachable(graph, DATAPATH_ROOTS)
    report, findings = {"modules": {}, "dead": [], "datapath": []}, []
    for mod in sorted(graph):
        legacy = _in(mod, SEED_LEGACY)
        if mod in live and not legacy:
            status = "datapath"
            report["datapath"].append(mod)
        elif legacy:
            status = "dead-seed" if mod not in live else "legacy-imported"
            if mod not in live:
                report["dead"].append(mod)
        else:
            status = "other"
        report["modules"][mod] = {
            "status": status, "imports": sorted(graph[mod])}
    dead = set(report["dead"])
    for mod in sorted(live):
        if _in(mod, SEED_LEGACY):
            continue
        hits = sorted(graph.get(mod, set()) & dead)
        for h in hits:
            findings.append(Finding(
                "datapath-imports-dead", mod,
                f"datapath module imports dead seed module {h!r} — either "
                "revive it intentionally (move it out of the legacy list) "
                "or drop the import"))
    return report, findings


def lint_all() -> tuple[dict, list[Finding]]:
    """AST lints + import containment.  Returns (report, findings)."""
    report, graph_findings = import_report()
    return report, lint_sources() + graph_findings
