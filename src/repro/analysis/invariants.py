"""Declarative invariants — conservation laws, table-value bounds, and row
schemas, written once and compiled three ways (DESIGN.md §12).

The registry below is the single source of truth for what "well-formed"
means across the stack:

  * **Plan wire checks** — ``core/control.py::unpack_plan`` validates every
    payload against :data:`FIELD_BOUNDS` and :data:`PLAN_LAWS` before
    anything is applied, exactly as the eBPF side sanitizes map updates
    before the verifier-trusted datapath may read them.
  * **Checkify sanitizer** — ``XLB_SANITIZE=1`` compiles the traced laws
    with :mod:`jax.experimental.checkify` and runs them after every kernel
    wrapper call (``kernels/ops.py``) and host laws after every
    ServeLoop/ChainRunner tick.  Errors fail loud (``err.throw()``).
  * **Row schemas** — the BENCH_TREND.jsonl ``scenario``/``chaos`` row
    schemas (hoisted from ``workload/slo.py``, which re-exports them) share
    the same field-spec engine as the plan wire format.

The split with :mod:`repro.analysis.verifier` mirrors the paper's split
between the eBPF verifier and runtime map sanitization: the verifier
*assumes* the value bounds declared here when proving kernel gathers in
bounds; this module *enforces* them on every plan that can reach the live
tables.  Neither is sound without the other.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable

import numpy as np

from repro.core.policy_defs import BIG, POLICY_NAMES
from repro.core.routing_table import (MAX_CLUSTERS, MAX_ENDPOINTS,
                                      MAX_EPS_PER_CLUSTER, MAX_RULES,
                                      MAX_RULES_PER_SVC, N_FEATURES, WILDCARD)

INT32_MAX = 2**31 - 1


# --------------------------------------------------------------------------- #
# Table-value bounds — what every int32 routing-table cell may hold.
#
# These are the verifier's entry facts: a gather whose index derives from a
# table read is provable only because the table's values are bounded here,
# and the plan validator rejects any wire payload that would break a bound.
# --------------------------------------------------------------------------- #

FIELD_BOUNDS: dict[str, tuple[int, int]] = {
    "svc_rule_start": (0, MAX_RULES - 1),
    "svc_rule_count": (0, MAX_RULES_PER_SVC),
    "rule_field": (0, N_FEATURES - 1),
    "rule_value": (WILDCARD, INT32_MAX),
    "rule_cluster": (-1, MAX_CLUSTERS - 1),
    "cluster_ep_start": (0, MAX_ENDPOINTS - 1),
    "cluster_ep_count": (0, MAX_EPS_PER_CLUSTER),
    "cluster_policy": (0, len(POLICY_NAMES) - 1),
    "ep_instance": (-1, INT32_MAX),
    "ep_drained": (0, 1),
    # maglev rows hold WINDOW OFFSETS (-1 = empty), not absolute slots
    "maglev_table": (-1, MAX_EPS_PER_CLUSTER - 1),
    "ep_src": (-1, MAX_ENDPOINTS - 1),
    "ep_dst": (-1, MAX_ENDPOINTS - 1),
    # mutable datapath state (bounds assumed by the verifier, maintained by
    # the kernels themselves; BIG is the water-fill sentinel ceiling)
    "ep_load": (0, BIG),
    "rr_cursor": (0, INT32_MAX),
    "aff_key": (-1, INT32_MAX),
    "aff_ep": (-1, MAX_ENDPOINTS - 1),
}


# --------------------------------------------------------------------------- #
# Plan wire laws — cross-field invariants of a packed RefreshPlan.
# Each law returns a list of violation strings (empty = holds).
# --------------------------------------------------------------------------- #


def _law_field_bounds(a: dict) -> list[str]:
    errs = []
    for k, (lo, hi) in FIELD_BOUNDS.items():
        if k not in a:
            continue
        v = np.asarray(a[k])
        if not np.issubdtype(v.dtype, np.integer):
            continue
        if v.size and (int(v.min()) < lo or int(v.max()) > hi):
            errs.append(f"field {k!r} out of bounds [{lo}, {hi}]: "
                        f"min={int(v.min())}, max={int(v.max())}")
    return errs


def _law_windows(a: dict) -> list[str]:
    """Rule/endpoint windows stay inside their tables and occupied cluster
    windows are pairwise disjoint — the wire-level face of the free-list
    'slots disjoint from occupied' law."""
    errs = []
    ss, sc = np.asarray(a["svc_rule_start"]), np.asarray(a["svc_rule_count"])
    if np.any((sc > 0) & (ss + sc > MAX_RULES)):
        errs.append("service rule window exceeds MAX_RULES")
    cs = np.asarray(a["cluster_ep_start"])
    cc = np.asarray(a["cluster_ep_count"])
    if np.any((cc > 0) & (cs + cc > MAX_ENDPOINTS)):
        errs.append("cluster endpoint window exceeds MAX_ENDPOINTS")
    occupied = np.zeros((MAX_ENDPOINTS,), np.int32)
    for c in np.nonzero(cc > 0)[0]:
        occupied[cs[c]:cs[c] + cc[c]] += 1
    if int(occupied.max(initial=0)) > 1:
        errs.append("cluster endpoint windows overlap "
                    f"(slot {int(np.argmax(occupied))} owned twice)")
    return errs


def _law_permutation(a: dict) -> list[str]:
    """ep_src/ep_dst are mutually consistent partial permutations: a load
    migrated INTO new slot n from old slot e must be the same association
    the old→new map records, or apply_plan double-counts in-flight load."""
    errs = []
    src, dst = np.asarray(a["ep_src"]), np.asarray(a["ep_dst"])
    live = np.nonzero(src >= 0)[0]
    if live.size and np.any(dst[src[live]] != live):
        errs.append("ep_src/ep_dst disagree (dst[src[n]] != n)")
    kept = np.nonzero(dst >= 0)[0]
    if kept.size:
        if np.any(src[dst[kept]] != kept):
            errs.append("ep_dst/ep_src disagree (src[dst[e]] != e)")
        vals = dst[kept]
        if np.unique(vals).size != vals.size:
            errs.append("ep_dst maps two old slots to one new slot")
    return errs


def _law_version(a: dict) -> list[str]:
    """Version strictly monotone per incarnation: a versioned plan must
    advance past the config it was diffed against (-1 = unversioned)."""
    base, version = int(a["base_version"]), int(a["version"])
    if version == 0 or (version > 0 and base >= version):
        return [f"base_version={base}, version={version}"]
    return []


PLAN_LAWS: tuple[tuple[str, Callable[[dict], list[str]]], ...] = (
    ("field-bounds", _law_field_bounds),
    ("window-disjoint", _law_windows),
    ("slot-permutation", _law_permutation),
    ("version-monotone", _law_version),
)


def check_plan_wire(arrays: dict) -> list[str]:
    """All plan-law violations of an unpacked wire dict (shape/dtype checks
    are ``unpack_plan``'s job; this is the semantic layer on top)."""
    errs = []
    for name, law in PLAN_LAWS:
        errs += [f"[{name}] {e}" for e in law(arrays)]
    return errs


# --------------------------------------------------------------------------- #
# Conservation laws — the traced (checkify) and host (python) registries.
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Law:
    """One conservation law: ``check(ctx) -> bool scalar`` over the ctx keys
    in ``requires``.  ``traced`` laws run under jit/checkify on device
    arrays; host laws run on plain python/numpy values."""

    name: str
    scope: str               # admit | complete | loop | chain
    doc: str
    requires: tuple[str, ...]
    check: Callable[[dict], Any]
    traced: bool = True


def _l_admit_load(c):
    import jax.numpy as jnp
    return (jnp.sum(c["load_after"]) - jnp.sum(c["load_before"])
            == jnp.sum(c["ok"]))


def _l_load_nonneg(c):
    import jax.numpy as jnp
    return jnp.min(c["load_after"]) >= 0


def _l_admit_held(c):
    import jax.numpy as jnp
    return c["held"] == jnp.sum((c["endpoint"] >= 0) & (c["ok"] == 0))


def _l_admit_pool(c):
    import jax.numpy as jnp
    I, C = c["pool_req_id"].shape
    ii = jnp.clip(c["instance"], 0, I - 1)
    ss = jnp.clip(c["slot"], 0, C - 1)
    ok = c["ok"] > 0
    stored = c["pool_req_id"][ii, ss]
    act = c["pool_active"][ii, ss]
    return jnp.all(jnp.where(ok, (stored == c["req_id"]) & act, True))


def _l_complete_release(c):
    import jax.numpy as jnp
    return (jnp.sum(c["load_before"]) - jnp.sum(c["load_after"])
            == jnp.sum(c["done_cnt"]))


def _l_complete_free(c):
    import jax.numpy as jnp
    done = c["done"]
    freed = jnp.where(done, ~c["active_after"], True)
    cleared = jnp.where(done, c["req_id_after"] == -1, True)
    return jnp.all(freed) & jnp.all(cleared)


def _l_loop_queue(c):
    return (c["submitted"]
            == c["done"] + c["dropped"] + c["queued"] + c["inflight"])


def _l_chain_position(c):
    return all(0 <= p < c["depth"] for p in c["positions"])


def _l_chain_disjoint(c):
    return not (set(c["positions_ids"]) & set(c["done_ids"]))


LAWS: tuple[Law, ...] = (
    Law("load-delta-conservation", "admit",
        "sum of ep_load deltas == admitted count (admits - releases)",
        ("load_before", "load_after", "ok"), _l_admit_load),
    Law("load-nonnegative", "admit",
        "outstanding-request counters never go negative",
        ("load_after",), _l_load_nonneg),
    Law("held-accounting", "admit",
        "held == routable requests that did not land a slot",
        ("held", "endpoint", "ok"), _l_admit_held),
    Law("admit-commit-visible", "admit",
        "every admitted (instance, slot) holds the request in the pool",
        ("pool_req_id", "pool_active", "instance", "slot", "ok", "req_id"),
        _l_admit_pool),
    Law("release-conservation", "complete",
        "sum of ep_load releases == completions counted",
        ("load_before", "load_after", "done_cnt"), _l_complete_release),
    Law("load-nonnegative", "complete",
        "outstanding-request counters never go negative",
        ("load_after",), _l_load_nonneg),
    Law("done-frees-slot", "complete",
        "a completed slot is inactive with req_id == -1",
        ("done", "active_after", "req_id_after"), _l_complete_free),
    Law("queue-conservation", "loop",
        "submitted == done + dropped + queued + inflight",
        ("submitted", "done", "dropped", "queued", "inflight"),
        _l_loop_queue, traced=False),
    Law("position-in-range", "chain",
        "every in-chain request sits at a real hop",
        ("positions", "depth"), _l_chain_position, traced=False),
    Law("done-disjoint", "chain",
        "a finished request is no longer positioned in the chain",
        ("positions_ids", "done_ids"), _l_chain_disjoint, traced=False),
)


def laws(scope: str) -> list[Law]:
    return [l for l in LAWS if l.scope == scope]


# --------------------------------------------------------------------------- #
# The XLB_SANITIZE=1 checkify sanitizer.
# --------------------------------------------------------------------------- #


def sanitize_enabled() -> bool:
    return os.environ.get("XLB_SANITIZE", "0") not in ("", "0")


_GUARDS: dict[tuple, Any] = {}


def _checked(scope: str, keys: tuple[str, ...]):
    """Build (and cache) the checkified runner for one (scope, ctx-keys)
    combination — one jit specialization per kernel-wrapper call shape."""
    import jax
    from jax.experimental import checkify

    active = [l for l in laws(scope)
              if l.traced and set(l.requires) <= set(keys)]

    def run(ctx):
        for law in active:
            checkify.check(law.check(ctx),
                           f"XLB_SANITIZE[{scope}/{law.name}]: {law.doc}")

    return checkify.checkify(jax.jit(run), errors=checkify.user_checks)


def emit_checks(scope: str, ctx: dict) -> None:
    """Emit ``checkify.check`` calls for the traced laws of ``scope`` into
    the *current* trace.  The enclosing program must be functionalized with
    ``checkify.checkify`` (the sanitized ``make_jitted`` wrapper does this)
    or staging will fail loudly — which is the right failure mode: a check
    that silently vanished would be worse."""
    from jax.experimental import checkify
    for law in laws(scope):
        if law.traced and set(law.requires) <= set(ctx):
            checkify.check(law.check(ctx),
                           f"XLB_SANITIZE[{scope}/{law.name}]: {law.doc}")


def guard(scope: str, ctx: dict) -> None:
    """Run every traced law of ``scope`` whose ctx keys are present; raise
    ``checkify.JaxRuntimeError`` on the first violated law.  Callers gate on
    :func:`sanitize_enabled` — this is the opt-in sanitizer, not a hot-path
    check.

    Under an enclosing trace (the kernel wrapper was called inside an
    engine's jitted ``serve_step``) the laws are emitted as in-graph checks
    instead — ``err.throw()`` cannot run mid-trace — and discharged by the
    checkify wrapper the engine's sanitized ``make_jitted`` adds."""
    import jax
    import jax.numpy as jnp
    ctx = {k: jnp.asarray(v) for k, v in ctx.items()}
    if any(isinstance(v, jax.core.Tracer) for v in ctx.values()):
        emit_checks(scope, ctx)
        return
    key = (scope, tuple(sorted(ctx)))
    if key not in _GUARDS:
        _GUARDS[key] = _checked(scope, key[1])
    err, _ = _GUARDS[key](ctx)
    err.throw()


def assert_host(scope: str, ctx: dict) -> None:
    """Run the host-side (non-traced) laws of ``scope``; raise
    AssertionError naming the violated law."""
    for law in laws(scope):
        if law.traced or not set(law.requires) <= set(ctx):
            continue
        if not law.check(ctx):
            raise AssertionError(
                f"XLB_SANITIZE[{scope}/{law.name}]: {law.doc} — ctx="
                + repr({k: ctx[k] for k in law.requires
                        if not isinstance(ctx[k], (list, set, dict))}))


# --------------------------------------------------------------------------- #
# Trend-row schemas (BENCH_TREND.jsonl) — the same field-spec engine as the
# plan wire format, declaratively per bench kind.  workload/slo.py
# re-exports the public validate_* names for compatibility.
# --------------------------------------------------------------------------- #

SCENARIO_ROW_REQUIRED = {
    "bench": str, "scenario": str, "mode": str, "depth": int, "seed": int,
    "arrivals": str, "n_requests": int, "completed": int, "dropped": int,
    "ticks": int, "p50_ticks": float, "p99_ticks": float,
    "p999_ticks": float,
}
SCENARIO_ROW_OPTIONAL = {
    "service": str, "scale": float, "ops": int, "txns": int,
    "held_first": int, "rate": float, "shards": int,
    "mean_ticks": float, "per_hop_p99_ticks": list,
    "health_txns": int, "end_weights": list,
}
CHAOS_ROW_REQUIRED = {
    "bench": str, "scenario": str, "mode": str, "seed": int,
    "n_requests": int, "completed": int, "dropped": int, "ticks": int,
    "flush_ticks": int, "versions": int, "consumers": int,
    "resyncs": int, "crashes": int, "converged": bool,
    "healthy_p99_ticks": float, "chaos_p99_ticks": float,
    "recovered_p99_ticks": float, "recovery_ratio": float,
    "msgs_sent": int, "msgs_dropped": int, "msgs_duped": int,
    "msgs_delivered": int,
}
CHAOS_ROW_OPTIONAL = {
    "msgs_partitioned": int, "stale": int, "held": int, "rejected": int,
    "plan_sends": int, "snap_sends": int, "ops": int, "txns": int,
    "rate": float, "baseline_p99_ticks": float,
}


def type_errs(row: dict, required: dict, optional: dict) -> list[str]:
    """Field-presence + type errors for one row schema.  ``bool`` fields
    accept only bool; ``float`` fields accept int-or-float (never bool)."""
    def ok(v, t):
        if t is bool:
            return isinstance(v, bool)
        if isinstance(v, bool):
            return False
        if t is float:
            return isinstance(v, (int, float))
        return isinstance(v, t)

    errs = []
    for k, t in required.items():
        if k not in row:
            errs.append(f"missing field {k!r}")
        elif not ok(row[k], t):
            errs.append(f"field {k!r} wants {t.__name__}, got "
                        f"{type(row[k]).__name__}")
    allowed = set(required) | set(optional) | {"ts", "commit"}
    for k in row:
        if k not in allowed:
            errs.append(f"unknown field {k!r}")
        elif k in optional and not ok(row[k], optional[k]):
            errs.append(f"field {k!r} wants {optional[k].__name__}, got "
                        f"{type(row[k]).__name__}")
    return errs


def _scenario_laws(row: dict) -> list[str]:
    errs = []
    if row["completed"] + row["dropped"] > row["n_requests"]:
        errs.append("completed + dropped exceeds n_requests")
    ps = [row["p50_ticks"], row["p99_ticks"], row["p999_ticks"]]
    fin = [p for p in ps if not np.isnan(p)]
    if fin != sorted(fin):
        errs.append("percentiles not monotone (p50 <= p99 <= p999)")
    return errs


def _chaos_laws(row: dict) -> list[str]:
    errs = []
    if row["completed"] + row["dropped"] > row["n_requests"]:
        errs.append("completed + dropped exceeds n_requests")
    for k in ("versions", "consumers", "resyncs", "crashes", "msgs_sent",
              "msgs_dropped", "msgs_duped", "msgs_delivered"):
        if row[k] < 0:
            errs.append(f"field {k!r} negative")
    if row["msgs_delivered"] > row["msgs_sent"] + row["msgs_duped"]:
        errs.append("delivered exceeds sent + duplicated")
    if not np.isnan(row["recovery_ratio"]) and row["recovery_ratio"] < 0:
        errs.append("recovery_ratio negative")
    return errs


@dataclasses.dataclass(frozen=True)
class RowSchema:
    """Declarative trend-row schema: field specs + cross-field laws."""

    bench: str
    required: dict
    optional: dict
    cross: Callable[[dict], list[str]]

    def errors(self, row: dict) -> list[str]:
        errs = type_errs(row, self.required, self.optional)
        if not errs:
            if row["bench"] != self.bench:
                errs.append(f'bench must be "{self.bench}", got '
                            f'{row["bench"]!r}')
            else:
                errs += self.cross(row)
        return errs


ROW_SCHEMAS: dict[str, RowSchema] = {
    "scenario": RowSchema("scenario", SCENARIO_ROW_REQUIRED,
                          SCENARIO_ROW_OPTIONAL, _scenario_laws),
    "chaos": RowSchema("chaos", CHAOS_ROW_REQUIRED, CHAOS_ROW_OPTIONAL,
                       _chaos_laws),
}


def validate_row(row: dict, kind: str) -> None:
    """Raise ValueError on any schema violation of a ``kind`` trend row."""
    errs = ROW_SCHEMAS[kind].errors(row)
    if errs:
        raise ValueError(f"invalid {kind} row: " + "; ".join(errs))
