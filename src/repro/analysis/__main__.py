"""``python -m repro.analysis`` — the full static verification gate.

Sections (any finding fails the process with exit code 1):

  1. ``registry``  — every PolicyDef carries all four lowering hooks, a
     valid shard-merge rule, and a unique enum.
  2. ``kernels``   — jaxpr interval analysis over every registered Pallas
     kernel × fold on the tune.py representative shapes (plus the staged
     ``policies.select`` chain and the sharded admit relay).
  3. ``lint``      — repo-wide AST lints + import-graph containment.
  4. ``plans``     — one ControlPlane transaction of every named op kind;
     each journaled wire plan must round-trip ``unpack_plan`` (which now
     enforces the declarative plan laws) with zero law violations.
  5. ``lowerings`` — runtime smoke of the two numpy lowerings the jaxpr
     pass cannot see (``ref.admit_ref`` oracle, sidecar ``HostRouter``):
     one batch per registered policy, outputs bounds-checked.

``--fast`` skips the kernel sweep (the slow section) for edit loops;
``--report`` additionally prints the import-graph dead-module report.
"""

from __future__ import annotations

import argparse
import sys

import numpy as np


def _plan_ops_findings():
    """Exercise every named ControlPlane op; validate every wire plan."""
    from repro.analysis.invariants import check_plan_wire
    from repro.analysis.verifier import Finding
    from repro.core import control
    from repro.core.routing_table import (POLICY_MAGLEV, POLICY_RR, Rule)

    findings = []
    cp = control.ControlPlane()
    cp.add_cluster("gold", endpoints=[0, 1, 2])
    cp.add_cluster("canary", policy=POLICY_MAGLEV, endpoints=[3, 4])
    cp.add_service("checkout", rules=[Rule(0, "fast", "gold"),
                                      Rule(0, None, "canary")])
    cp.add_endpoint("gold", 5)
    cp.set_weight("gold", 5, 2.5)
    cp.set_policy("gold", POLICY_RR)
    cp.upsert_rule("checkout", 1, "beta", "canary")
    cp.drain_endpoint("gold", 5)
    cp.reap()                                    # no consumers: removes it
    cp.remove_endpoint("canary", 4)
    cp.remove_rule("checkout", 1, "beta")
    cp.remove_service("checkout")
    cp.remove_cluster("canary")
    cp.remove_cluster("gold")
    for i, wire in enumerate(cp.journal):
        for err in check_plan_wire(wire):
            findings.append(Finding("plan-law-violation",
                                    f"plan[{i}]", err))
        try:
            control.unpack_plan(wire)
        except ValueError as e:
            findings.append(Finding("plan-unpack-rejected",
                                    f"plan[{i}]", str(e)))
    if not cp.journal:
        findings.append(Finding("plan-sweep-empty", "plans",
                                "ControlPlane op sweep produced no plans"))
    return findings


def _lowering_smoke_findings():
    """Run the oracle (ref) and sidecar (host) lowerings — plain numpy
    loops the jaxpr pass never sees — once per registered policy."""
    import jax
    import jax.numpy as jnp

    from repro.analysis.verifier import Finding, _sweep_state, SWEEP_I
    from repro.core import policy_defs
    from repro.core.routing_table import (MAX_EPS_PER_CLUSTER, N_FEATURES,
                                          fnv1a)
    from repro.core.sidecar import HostRouter
    from repro.kernels import ref

    findings = []
    state = _sweep_state()
    E = state.ep_load.shape[0]
    R = 8 * len(policy_defs.REGISTRY)
    key = jax.random.PRNGKey(7)
    kr, kw = jax.random.split(key)
    # feature 0 == the policy enum routes to that policy's cluster
    svc = jnp.arange(R, dtype=jnp.int32) % len(policy_defs.REGISTRY)
    feats = jnp.zeros((R, N_FEATURES), jnp.int32).at[:, 0].set(
        jnp.asarray([fnv1a(str(int(s))) for s in svc], jnp.int32))
    rid = jnp.arange(R, dtype=jnp.int32)
    rnd = jax.random.randint(kr, (R,), 0, 1 << 30, dtype=jnp.int32)
    gum = jax.random.gumbel(kw, (R, MAX_EPS_PER_CLUSTER), jnp.float32)
    free = jnp.ones((SWEEP_I, 4), jnp.int32)
    res = ref.admit_ref(rid, svc, feats, jnp.ones((R,), jnp.int32),
                        state, free, rnd, gum)
    ep = np.asarray(res.endpoint)
    if ep.min(initial=0) < -1 or ep.max(initial=0) >= E:
        findings.append(Finding(
            "oracle-endpoint-oob", "ref.admit_ref",
            f"oracle endpoint outside [-1, {E - 1}]: "
            f"[{ep.min()}, {ep.max()}]"))
    if not (np.asarray(res.cluster) >= 0).any():
        findings.append(Finding(
            "oracle-no-route", "ref.admit_ref",
            "policy-per-cluster sweep batch routed nothing"))

    hr = HostRouter(state, seed=3)
    routed = 0
    for r in range(R):
        c = hr.match(int(svc[r]), np.asarray(feats[r]))
        if c < 0:
            continue
        e, inst = hr.select(c, np.asarray(feats[r]))
        if e >= 0:
            routed += 1
            if not 0 <= e < E:
                findings.append(Finding(
                    "host-endpoint-oob", "sidecar.HostRouter",
                    f"host lowering picked endpoint {e} outside "
                    f"[0, {E - 1}]"))
            hr.release(e)
    if routed == 0:
        findings.append(Finding(
            "host-no-route", "sidecar.HostRouter",
            "host lowering routed nothing in the per-policy sweep"))
    if np.asarray(hr.t.ep_load).any():
        findings.append(Finding(
            "host-load-leak", "sidecar.HostRouter",
            "ep_load nonzero after releasing every pick "
            "(admits != releases)"))
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--fast", action="store_true",
                    help="skip the (slow) kernel jaxpr sweep")
    ap.add_argument("--report", action="store_true",
                    help="print the import-graph dead-module report")
    args = ap.parse_args(argv)

    from repro.analysis import lint as _lint
    from repro.analysis import verifier as _ver

    sections: list[tuple[str, list]] = []
    sections.append(("registry", _ver.check_registry()))
    if not args.fast:
        sections.append(("kernels", _ver.verify_kernels()))
    report, lint_findings = _lint.lint_all()
    sections.append(("lint", lint_findings))
    sections.append(("plans", _plan_ops_findings()))
    sections.append(("lowerings", _lowering_smoke_findings()))

    total = 0
    for name, findings in sections:
        status = "ok" if not findings else f"{len(findings)} finding(s)"
        print(f"[{name:>9}] {status}")
        for f in findings:
            print(f"    {f}")
        total += len(findings)
    print(f"[   import] {len(report['datapath'])} datapath modules, "
          f"{len(report['dead'])} dead seed modules (report-only)")
    if args.report:
        for mod in report["dead"]:
            print(f"    dead: {mod}")
    if total:
        print(f"FAILED: {total} finding(s)")
        return 1
    print("verified: all sections clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
