"""Jaxpr-level static safety checker — the eBPF verifier analogue
(DESIGN.md §12).

The paper's datapath is trusted in-kernel only because the eBPF verifier
statically proves every map access in bounds before the program may load.
This module plays that role for the reproduction: each registered Pallas
kernel is traced via ``jax.make_jaxpr`` (never executed) and the jaxpr is
walked with an interval abstract domain — every variable carries a sound
``[lo, hi]`` over its possible values — to prove:

  * every ``gather`` / ``scatter`` whose mode is ``PROMISE_IN_BOUNDS`` (the
    form plain ``x[i]`` lowers to) has index operands whose interval fits
    the indexed window — i.e. the index derives from a ``clip`` / ``%`` /
    ``iota`` / ``argmax``-style bounded source, not a raw table read;
  * every dynamic index into a Pallas ``Ref`` (``get``/``swap``/
    ``addupdate`` NDIndexers) is likewise proven, since compiled Mosaic
    refs have **no** OOB clamping at all;
  * no primitive produces a 64-bit value (float64/int64 promotion breaks
    the int32 table contract and the TPU lowering) and no nondeterministic
    RNG primitive appears in a datapath trace.

Entry assumptions come from :data:`repro.analysis.invariants.FIELD_BOUNDS`:
the verifier *assumes* exactly the table-value bounds the plan validator
*enforces* on every wire payload (``core/control.py::unpack_plan``) —
mirroring the split between the eBPF verifier and the map-update
sanitization in the paper.  Neither side is sound alone.

Scatters with ``FILL_OR_DROP``/``CLIP`` modes (``.at[].set(mode="drop")``
and friends) are safe by construction and need no proof; the companion AST
lint (:mod:`repro.analysis.lint`) separately enforces that computed
scatters *spell* an explicit OOB mode.

``verify_kernels()`` sweeps admit / admit_commit / complete /
route_match / the sharded admit relay under both folds on representative
shapes from ``kernels/tune.py``; because the admit kernel folds every
``PolicyDef.kernel_offset`` through one ``jnp.select`` (and the staged
chain every ``staged_offset``), a newly registered policy is swept
automatically with no verifier change.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.analysis.invariants import FIELD_BOUNDS

NEG = float("-inf")
POS = float("inf")

#: Primitives that WRITE through a Pallas ref — a ref touched by one of
#: these anywhere in a kernel gets TOP at entry (its content is no longer
#: the operand the wrapper passed in).
WRITE_PRIMS = ("swap", "addupdate", "masked_swap")

#: Nondeterministic / stateful RNG primitives.  Seeded ``jax.random``
#: (threefry bit math) is deterministic and allowed; these are not.
RNG_PRIMS = ("rng_uniform", "rng_bit_generator")

_64BIT = ("float64", "int64", "uint64", "complex128")


# --------------------------------------------------------------------------- #
# The interval domain.
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class Interval:
    """Sound value bounds; ``None`` = unbounded on that side."""

    lo: float | None = None
    hi: float | None = None

    def __repr__(self):
        f = lambda v, s: s if v is None else f"{v:g}"
        return f"[{f(self.lo, '-inf')}, {f(self.hi, 'inf')}]"


TOP = Interval()


def _lo(iv):
    return NEG if iv.lo is None else iv.lo


def _hi(iv):
    return POS if iv.hi is None else iv.hi


def _mk(lo, hi):
    return Interval(None if lo == NEG else lo, None if hi == POS else hi)


def _hull(*ivs):
    ivs = [i for i in ivs if i is not None]
    if not ivs:
        return TOP
    return _mk(min(_lo(i) for i in ivs), max(_hi(i) for i in ivs))


def _meet(a, b):
    lo, hi = max(_lo(a), _lo(b)), min(_hi(a), _hi(b))
    return _mk(lo, hi) if lo <= hi else _mk(lo, lo)


def _shift(iv, k):
    return _mk(_lo(iv) + k, _hi(iv) + k)


def _pmul(x, y):
    """inf-safe product for bound candidates (0 * inf = 0)."""
    if x == 0 or y == 0:
        return 0
    return x * y


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verifier diagnostic.  ``code`` is the stable machine-matchable
    name (what the mutation tests assert on); ``where`` locates the trace
    (kernel × fold × primitive)."""

    code: str
    where: str
    detail: str

    def __str__(self):
        return f"[{self.code}] {self.where}: {self.detail}"


def _is_literal(atom):
    return hasattr(atom, "val")


def _const_interval(val):
    a = np.asarray(val)
    if a.size == 0 or not (np.issubdtype(a.dtype, np.integer)
                           or np.issubdtype(a.dtype, np.floating)
                           or a.dtype == np.bool_):
        return TOP
    lo, hi = a.min(), a.max()
    if not (np.isfinite(lo) and np.isfinite(hi)):
        return TOP
    if np.issubdtype(a.dtype, np.integer) or a.dtype == np.bool_:
        return Interval(int(lo), int(hi))
    return Interval(float(lo), float(hi))


def _aval_dtype(aval):
    # AbstractMemoryRef wraps the array aval; plain ShapedArray has .dtype
    inner = getattr(aval, "inner_aval", aval)
    return getattr(inner, "dtype", None)


def _aval_shape(aval):
    inner = getattr(aval, "inner_aval", aval)
    return tuple(getattr(inner, "shape", ()))


def _dtype_default(aval):
    """The widest interval a value of this dtype can hold — the fallback
    for unhandled primitives (never ``TOP`` for bools/unsigned, which is
    what makes mask-hash chains like ``flow_hash`` provable)."""
    dt = _aval_dtype(aval)
    if dt is None:
        return TOP
    try:
        dt = np.dtype(dt)
    except TypeError:                 # extended dtypes (PRNG keys, …)
        return TOP
    if dt == np.bool_:
        return Interval(0, 1)
    if dt.kind == "u":
        return Interval(0, int(2 ** (8 * dt.itemsize)) - 1)
    if dt.kind == "i":
        n = 8 * dt.itemsize
        return Interval(-int(2 ** (n - 1)), int(2 ** (n - 1)) - 1)
    return TOP


def _sub_jaxprs(obj):
    """Yield every Jaxpr found in a params value (handles ClosedJaxpr,
    bare Jaxpr, and tuples/lists of either)."""
    vals = obj if isinstance(obj, (tuple, list)) else [obj]
    for v in vals:
        if hasattr(v, "jaxpr") and hasattr(v, "consts"):     # ClosedJaxpr
            yield v.jaxpr, list(v.consts)
        elif hasattr(v, "eqns") and hasattr(v, "invars"):    # Jaxpr
            yield v, None


def _invar_maps(eqn):
    """(sub_jaxpr, consts, {sub_invar_pos: outer_atom_pos}) for each
    sub-jaxpr of an eqn — the best-effort alignment the written-ref
    analysis and the recursive walk both use."""
    name = eqn.primitive.name
    out = []
    if name == "cond":
        for sub, consts in _sub_jaxprs(eqn.params.get("branches", ())):
            out.append((sub, consts,
                        {i: i + 1 for i in range(len(sub.invars))}))
    elif name == "while":
        cn = eqn.params.get("cond_nconsts", 0)
        body, bconsts = next(_sub_jaxprs(eqn.params["body_jaxpr"]))
        out.append((body, bconsts,
                    {i: cn + i for i in range(len(body.invars))}))
        cond, cconsts = next(_sub_jaxprs(eqn.params["cond_jaxpr"]))
        out.append((cond, cconsts, {i: i for i in range(cn)}))
    elif name == "pallas_call":
        gm = eqn.params.get("grid_mapping")
        ni = getattr(gm, "num_index_operands", 0)
        n_in = getattr(gm, "num_inputs", 0)
        sub, consts = next(_sub_jaxprs(eqn.params["jaxpr"]))
        mapping = {j: j for j in range(min(ni + n_in, len(eqn.invars)))}
        out.append((sub, consts, mapping))
    else:
        for key in ("jaxpr", "call_jaxpr"):
            if key in eqn.params:
                for sub, consts in _sub_jaxprs(eqn.params[key]):
                    n = min(len(sub.invars), len(eqn.invars))
                    out.append((sub, consts, {i: i for i in range(n)}))
                break
    return out


def _written_positions(jaxpr, memo):
    """Invar positions of ``jaxpr`` whose refs are written (directly or via
    a sub-jaxpr).  Sound: unmapped sub-jaxpr ref invars taint every outer
    ref operand of the eqn."""
    key = id(jaxpr)
    if key in memo:
        return memo[key]
    memo[key] = set()               # cycles cannot occur; terminate anyway
    written = set()                 # Vars of this jaxpr that are written
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in WRITE_PRIMS:
            if not _is_literal(eqn.invars[0]):
                written.add(eqn.invars[0])
            continue
        subs = _invar_maps(eqn)
        if not subs and any(_sub_jaxprs(v) and False for v in ()):
            pass
        for sub, _consts, mapping in subs:
            for pos in _written_positions(sub, memo):
                outer_pos = mapping.get(pos)
                if outer_pos is not None and outer_pos < len(eqn.invars):
                    atom = eqn.invars[outer_pos]
                    if not _is_literal(atom):
                        written.add(atom)
    pos = {i for i, v in enumerate(jaxpr.invars) if v in written}
    memo[key] = pos
    return pos


# --------------------------------------------------------------------------- #
# The analyzer.
# --------------------------------------------------------------------------- #

_PASSTHROUGH = {
    "broadcast_in_dim", "reshape", "squeeze", "expand_dims", "transpose",
    "rev", "slice", "copy", "convert_element_type", "stop_gradient",
    "reduce_max", "reduce_min", "cummax", "cummin", "reduce_or",
    "reduce_and", "all_gather", "all_to_all", "ppermute", "pbroadcast",
    "reduce_precision", "sharding_constraint", "device_put", "real",
}

_BOOL_OUT = {
    "lt", "le", "gt", "ge", "eq", "ne", "le_to", "lt_to", "not", "is_finite",
    "reduce_xor",
}


class _Analyzer:
    def __init__(self, findings: list, where: str):
        self.findings = findings
        self.where = where
        self._written_memo: dict[int, set] = {}

    # ---- bookkeeping ---------------------------------------------------- #

    def flag(self, code, detail, prim=""):
        where = f"{self.where}/{prim}" if prim else self.where
        self.findings.append(Finding(code, where, detail))

    def read(self, env, atom):
        if _is_literal(atom):
            return _const_interval(atom.val)
        return env.get(atom, _dtype_default(atom.aval))

    def set(self, env, var, iv):
        env[var] = _meet(iv, _dtype_default(var.aval))

    def parts(self, env, atom):
        """Per-piece intervals if ``atom`` is (a shape-op away from) a
        ``concatenate`` — lets a stacked (i, j) index pair check each dim
        against its own bound."""
        seen = 0
        while not _is_literal(atom) and seen < 8:
            eqn = self._defs.get(atom)
            if eqn is None:
                return None
            name = eqn.primitive.name
            if name == "concatenate":
                return [self.read(env, v) for v in eqn.invars]
            if name in ("broadcast_in_dim", "reshape", "copy",
                        "convert_element_type"):
                atom = eqn.invars[0]
                seen += 1
                continue
            return None
        return None

    # ---- dtype / determinism sweeps ------------------------------------- #

    def _check_eqn_hygiene(self, eqn):
        name = eqn.primitive.name
        if name in RNG_PRIMS:
            self.flag("rng-in-datapath",
                      f"nondeterministic RNG primitive {name!r} in a "
                      "datapath trace (draw host randomness outside and "
                      "pass it in)", name)
        for v in eqn.outvars:
            dt = _aval_dtype(v.aval)
            try:
                dt = np.dtype(dt) if dt is not None else None
            except TypeError:         # extended dtypes (PRNG keys, …)
                dt = None
            if dt is not None and str(dt) in _64BIT:
                self.flag("x64-promotion",
                          f"primitive {name!r} produces {dt} "
                          "(64-bit values break the int32 table contract "
                          "and the Mosaic lowering)", name)

    # ---- gather / scatter proofs ---------------------------------------- #

    def _gather_allowed(self, eqn):
        """Per-mapped-dim max start index: shape[d] - slice_sizes[d]."""
        dnums = eqn.params["dimension_numbers"]
        op_shape = _aval_shape(eqn.invars[0].aval)
        ss = eqn.params.get("slice_sizes")
        out = []
        for d in dnums.start_index_map:
            size = ss[d] if ss is not None else 1
            out.append(op_shape[d] - size)
        return out

    def _prove_indices(self, env, eqn, idx_atom, allowed):
        """True iff the index operand's interval(s) fit ``allowed`` (one
        bound per mapped dim).  Uses per-piece concatenate intervals when
        the index vector was stacked from several index arrays."""
        pieces = self.parts(env, idx_atom) if not _is_literal(idx_atom) \
            else None
        if pieces is not None and len(pieces) == len(allowed):
            return all(_lo(p) >= 0 and _hi(p) <= a
                       for p, a in zip(pieces, allowed)), pieces
        iv = self.read(env, idx_atom)
        ok = _lo(iv) >= 0 and _hi(iv) <= min(allowed)
        return ok, [iv]

    def _check_gather(self, env, eqn):
        mode = str(eqn.params.get("mode"))
        operand, indices = eqn.invars[0], eqn.invars[1]
        allowed = self._gather_allowed(eqn)
        proven, ivs = self._prove_indices(env, eqn, indices, allowed)
        if "PROMISE_IN_BOUNDS" in mode and not proven:
            bounded = all(i.lo is not None and i.hi is not None for i in ivs)
            code = "oob-gather-bound" if bounded else "unclamped-gather-index"
            self.flag(code,
                      f"gather index interval {ivs} not within "
                      f"[0, {allowed}] of operand "
                      f"{_aval_shape(operand.aval)} — clamp/mod/mask the "
                      "index or use an explicit OOB mode", "gather")
        out = self.read(env, operand)
        fv = eqn.params.get("fill_value")
        if "FILL" in mode and fv is not None and not proven:
            out = _hull(out, _const_interval(fv))
        self.set(env, eqn.outvars[0], out)

    def _check_scatter(self, env, eqn):
        mode = str(eqn.params.get("mode"))
        operand, indices, updates = eqn.invars[:3]
        if "PROMISE_IN_BOUNDS" in mode:
            dnums = eqn.params["dimension_numbers"]
            op_shape = _aval_shape(operand.aval)
            allowed = [op_shape[d] - 1
                       for d in dnums.scatter_dims_to_operand_dims]
            proven, ivs = self._prove_indices(env, eqn, indices, allowed)
            if not proven:
                bounded = all(i.lo is not None and i.hi is not None
                              for i in ivs)
                code = ("oob-scatter-bound" if bounded
                        else "unclamped-scatter-index")
                self.flag(code,
                          f"scatter index interval {ivs} not within "
                          f"[0, {allowed}] of operand {op_shape} — "
                          "PROMISE_IN_BOUNDS scatters corrupt neighbouring "
                          "table slots on overflow", "scatter")
        op_iv, up_iv = self.read(env, operand), self.read(env, updates)
        if eqn.primitive.name == "scatter":
            self.set(env, eqn.outvars[0], _hull(op_iv, up_iv))
        elif _lo(op_iv) >= 0 and _lo(up_iv) >= 0:
            self.set(env, eqn.outvars[0], Interval(0, None))
        else:
            self.set(env, eqn.outvars[0], TOP)

    def _check_ref_index(self, env, eqn):
        """Prove every dynamic NDIndexer index of a get/swap/addupdate —
        compiled Pallas refs have no OOB semantics at all."""
        tree = eqn.params.get("tree")
        if tree is None:
            return
        import jax.tree_util as jtu
        n = tree.num_leaves
        idx_atoms = list(eqn.invars[len(eqn.invars) - n:]) if n else []
        try:
            indexers = jtu.tree_unflatten(tree, idx_atoms)
        except Exception:
            return
        for indexer in (indexers if isinstance(indexers, (tuple, list))
                        else [indexers]):
            dims = getattr(indexer, "shape", None)
            idx = getattr(indexer, "indices", None)
            if dims is None or idx is None:
                continue
            for d, entry in zip(dims, idx):
                if hasattr(entry, "start") and hasattr(entry, "size"):
                    start, size = entry.start, entry.size
                    if isinstance(start, (int, np.integer)):
                        if start < 0 or start + size > d:
                            self.flag("oob-ref-slice",
                                      f"static ref slice [{start}:"
                                      f"{start + size}] exceeds dim {d}",
                                      eqn.primitive.name)
                    else:
                        iv = self.read(env, start)
                        if not (_lo(iv) >= 0 and _hi(iv) <= d - size):
                            self.flag("unclamped-ref-index",
                                      f"dynamic ref slice start {iv} not "
                                      f"within [0, {d - size}]",
                                      eqn.primitive.name)
                elif isinstance(entry, (int, np.integer)):
                    if not 0 <= int(entry) < d:
                        self.flag("oob-ref-slice",
                                  f"static ref index {int(entry)} outside "
                                  f"dim {d}", eqn.primitive.name)
                elif hasattr(entry, "aval") or _is_literal(entry):
                    iv = self.read(env, entry)
                    if not (_lo(iv) >= 0 and _hi(iv) <= d - 1):
                        self.flag("unclamped-ref-index",
                                  f"dynamic ref index interval {iv} not "
                                  f"within [0, {d - 1}] (refs have no OOB "
                                  "clamping once compiled)",
                                  eqn.primitive.name)

    # ---- the wrap-normalize pattern (negative-index adjustment) ---------- #

    def _wrap_interval(self, env, eqn):
        """jnp indexing emits ``select_n(x < 0, x, x + dim)`` before every
        gather/scatter; recognize it exactly so ``x ∈ [0, d-1]`` stays
        provable through the normalization."""
        if len(eqn.invars) != 3:
            return None
        pred, case0, case1 = eqn.invars
        if _is_literal(pred) or _is_literal(case0):
            return None
        pd = self._defs.get(pred)
        if pd is None or pd.primitive.name != "lt":
            return None
        if pd.invars[0] is not case0 or not _is_literal(pd.invars[1]):
            return None
        if np.asarray(pd.invars[1].val).max(initial=0) != 0 \
                or np.asarray(pd.invars[1].val).min(initial=0) != 0:
            return None
        if _is_literal(case1):
            return None
        cd = self._defs.get(case1)
        if cd is None or cd.primitive.name != "add":
            return None
        k = None
        if cd.invars[0] is case0 and _is_literal(cd.invars[1]):
            k = np.asarray(cd.invars[1].val)
        elif cd.invars[1] is case0 and _is_literal(cd.invars[0]):
            k = np.asarray(cd.invars[0].val)
        if k is None or k.size == 0 or k.min() != k.max() or k.min() <= 0:
            return None
        k = int(k.min())
        x = self.read(env, case0)
        if x.lo is None:
            return None
        if x.lo >= 0:
            return x
        if x.hi is not None and x.hi < 0:
            return _shift(x, k)
        return _mk(min(0, _lo(x) + k), max(_hi(x), k - 1))

    # ---- intrinsics for trusted jnp-library pjits ------------------------ #

    def _pjit_intrinsic(self, env, eqn):
        name = eqn.params.get("name", "")
        if name in ("remainder", "mod"):
            b = self.read(env, eqn.invars[1])
            if _lo(b) >= 1 and b.hi is not None:   # python-sign remainder
                return Interval(0, b.hi - 1)
            return None
        if name == "floor_divide":
            a, b = (self.read(env, v) for v in eqn.invars[:2])
            if _lo(b) >= 1:
                cands = []
                for x in (_lo(a), _hi(a)):
                    for y in (_lo(b), _hi(b)):
                        if x in (NEG, POS) or y == POS:
                            cands.append(x if x in (NEG, POS)
                                         else (0 if x >= 0 else -1))
                        else:
                            cands.append(math.floor(x / y))
                return _mk(min(cands), max(cands))
            return None
        if name in ("searchsorted", "_searchsorted"):
            n = max((_aval_shape(v.aval)[-1] for v in eqn.invars
                     if _aval_shape(v.aval)), default=None)
            if n is not None:
                return Interval(0, n)              # trusted jnp internal
            return TOP
        return None

    # ---- the walk -------------------------------------------------------- #

    def walk(self, jaxpr, env):
        self._defs = getattr(self, "_defs", {})
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                if not _is_literal(v):
                    self._defs[v] = eqn
            self._eqn(jaxpr, env, eqn)

    def _default_outs(self, env, eqn):
        for v in eqn.outvars:
            self.set(env, v, TOP)

    def _recurse(self, env, eqn, carry_positions=()):
        """Walk every sub-jaxpr of ``eqn`` with mapped entry intervals;
        returns hulled out intervals per sub (for cond)."""
        hulls = None
        for sub, consts, mapping in _invar_maps(eqn):
            sub_env = dict(env)
            for pos, v in enumerate(sub.invars):
                outer_pos = mapping.get(pos)
                if pos in carry_positions or outer_pos is None \
                        or outer_pos >= len(eqn.invars):
                    self.set(sub_env, v, TOP)
                else:
                    self.set(sub_env, v,
                             self.read(env, eqn.invars[outer_pos]))
            written = _written_positions(sub, self._written_memo)
            for pos in written:
                self.set(sub_env, sub.invars[pos], TOP)
            if consts is not None:
                for v, c in zip(sub.constvars, consts):
                    self.set(sub_env, v, _const_interval(c))
            else:
                for v in sub.constvars:
                    self.set(sub_env, v, self.read(env, v))
            self.walk(sub, sub_env)
            outs = [self.read(sub_env, v) for v in sub.outvars]
            if hulls is None:
                hulls = outs
            else:
                hulls = [_hull(a, b) for a, b in zip(hulls, outs)]
        return hulls

    def _eqn(self, jaxpr, env, eqn):
        self._check_eqn_hygiene(eqn)
        name = eqn.primitive.name
        rd = lambda i: self.read(env, eqn.invars[i])

        if name == "gather":
            self._check_gather(env, eqn)
            return
        if name.startswith("scatter"):
            self._check_scatter(env, eqn)
            return
        if name in ("get", "swap"):
            self._check_ref_index(env, eqn)
            self.set(env, eqn.outvars[0], rd(0))
            return
        if name == "addupdate":
            self._check_ref_index(env, eqn)
            return
        if name == "dynamic_slice":
            self.set(env, eqn.outvars[0], rd(0))     # XLA clamps starts
            return
        if name == "dynamic_update_slice":
            self.set(env, eqn.outvars[0], _hull(rd(0), rd(1)))
            return

        if name == "pjit":
            iv = self._pjit_intrinsic(env, eqn)
            if iv is not None:
                for v in eqn.outvars:
                    self.set(env, v, iv)
                return
            outs = self._recurse(env, eqn)
            if outs is not None:
                for v, o in zip(eqn.outvars, outs):
                    self.set(env, v, o)
            else:
                self._default_outs(env, eqn)
            return
        if name == "cond":
            outs = self._recurse(env, eqn)
            if outs is not None:
                for v, o in zip(eqn.outvars, outs):
                    self.set(env, v, o)
            else:
                self._default_outs(env, eqn)
            return
        if name == "scan":
            nc = eqn.params.get("num_consts", 0)
            ncar = eqn.params.get("num_carry", 0)
            self._recurse(env, eqn,
                          carry_positions=set(range(nc, nc + ncar)))
            self._default_outs(env, eqn)
            return
        if name == "while":
            ncar = len(eqn.outvars)
            bn = eqn.params.get("body_nconsts", 0)
            self._recurse(env, eqn,
                          carry_positions=set(range(bn, bn + ncar)))
            self._default_outs(env, eqn)
            return
        if name == "pallas_call":
            self._recurse(env, eqn)
            self._default_outs(env, eqn)
            return
        if name in ("shard_map", "remat", "remat2", "checkpoint",
                    "custom_jvp_call", "custom_vjp_call", "closed_call",
                    "core_call", "custom_vjp_call_jaxpr"):
            outs = self._recurse(env, eqn)
            if outs is not None and name == "shard_map":
                for v, o in zip(eqn.outvars, outs):
                    self.set(env, v, o)
            else:
                self._default_outs(env, eqn)
            return

        # ---- scalar/elementwise transfer functions ----------------------- #
        if name == "add":
            a, b = rd(0), rd(1)
            self.set(env, eqn.outvars[0], _mk(_lo(a) + _lo(b),
                                              _hi(a) + _hi(b)))
        elif name == "sub":
            a, b = rd(0), rd(1)
            self.set(env, eqn.outvars[0], _mk(_lo(a) - _hi(b),
                                              _hi(a) - _lo(b)))
        elif name == "mul":
            a, b = rd(0), rd(1)
            cands = [_pmul(x, y) for x in (_lo(a), _hi(a))
                     for y in (_lo(b), _hi(b))]
            self.set(env, eqn.outvars[0], _mk(min(cands), max(cands)))
        elif name == "neg":
            a = rd(0)
            self.set(env, eqn.outvars[0], _mk(-_hi(a), -_lo(a)))
        elif name == "max":
            a, b = rd(0), rd(1)
            self.set(env, eqn.outvars[0], _mk(max(_lo(a), _lo(b)),
                                              max(_hi(a), _hi(b))))
        elif name == "min":
            a, b = rd(0), rd(1)
            self.set(env, eqn.outvars[0], _mk(min(_lo(a), _lo(b)),
                                              min(_hi(a), _hi(b))))
        elif name == "clamp":
            lo_iv, _x, hi_iv = rd(0), rd(1), rd(2)
            self.set(env, eqn.outvars[0], _mk(_lo(lo_iv), _hi(hi_iv)))
        elif name == "rem":                      # lax.rem: sign of dividend
            a, b = rd(0), rd(1)
            if _lo(a) >= 0 and _lo(b) >= 1 and b.hi is not None:
                self.set(env, eqn.outvars[0], Interval(0, b.hi - 1))
            elif _lo(b) >= 1 and b.hi is not None:
                self.set(env, eqn.outvars[0],
                         Interval(-(b.hi - 1), b.hi - 1))
            else:
                self._default_outs(env, eqn)
        elif name == "div":                      # lax.div: trunc toward 0
            a, b = rd(0), rd(1)
            if _lo(b) >= 1:
                self.set(env, eqn.outvars[0],
                         _mk(min(0, _lo(a)), max(0, _hi(a))))
            else:
                self._default_outs(env, eqn)
        elif name == "sign":
            self.set(env, eqn.outvars[0], Interval(-1, 1))
        elif name == "select_n":
            wrap = self._wrap_interval(env, eqn)
            if wrap is not None:
                self.set(env, eqn.outvars[0], wrap)
            else:
                self.set(env, eqn.outvars[0],
                         _hull(*[self.read(env, v)
                                 for v in eqn.invars[1:]]))
        elif name == "concatenate":
            ivs = [self.read(env, v) for v in eqn.invars]
            self.set(env, eqn.outvars[0], _hull(*ivs))
        elif name == "iota":
            dim = eqn.params.get("dimension", 0)
            shape = eqn.params.get("shape") or _aval_shape(
                eqn.outvars[0].aval)
            self.set(env, eqn.outvars[0],
                     Interval(0, max(shape[dim] - 1, 0)))
        elif name in ("argmax", "argmin"):
            axes = eqn.params.get("axes", (0,))
            n = _aval_shape(eqn.invars[0].aval)[axes[0]]
            self.set(env, eqn.outvars[0], Interval(0, max(n - 1, 0)))
        elif name == "reduce_sum":
            a = rd(0)
            n = max(1, int(np.prod(_aval_shape(eqn.invars[0].aval))
                           // max(1, int(np.prod(
                               _aval_shape(eqn.outvars[0].aval))))))
            self.set(env, eqn.outvars[0],
                     _mk(min(_pmul(_lo(a), n), _lo(a)),
                         max(_pmul(_hi(a), n), _hi(a))))
        elif name == "cumsum":
            a = rd(0)
            n = _aval_shape(eqn.invars[0].aval)[eqn.params.get("axis", 0)]
            self.set(env, eqn.outvars[0],
                     _mk(min(_pmul(_lo(a), n), _lo(a)),
                         max(_pmul(_hi(a), n), _hi(a))))
        elif name == "sort":
            for v, o in zip(eqn.outvars, eqn.invars):
                self.set(env, v, self.read(env, o))
        elif name == "and":
            a, b = rd(0), rd(1)
            his = [_hi(x) for x in (a, b) if _lo(x) >= 0]
            if his:
                self.set(env, eqn.outvars[0], _mk(0, min(his)))
            else:
                self._default_outs(env, eqn)
        elif name in ("or", "xor"):
            a, b = rd(0), rd(1)
            if _lo(a) >= 0 and _lo(b) >= 0 and a.hi is not None \
                    and b.hi is not None:
                bits = max(int(a.hi).bit_length(), int(b.hi).bit_length())
                self.set(env, eqn.outvars[0], Interval(0, (1 << bits) - 1))
            else:
                self._default_outs(env, eqn)
        elif name in ("program_id", "axis_index", "num_programs"):
            self.set(env, eqn.outvars[0], Interval(0, None))
        elif name.startswith("psum"):
            for v, o in zip(eqn.outvars, eqn.invars):
                iv = self.read(env, o)
                self.set(env, v,
                         Interval(0, None) if _lo(iv) >= 0 else TOP)
        elif name in _PASSTHROUGH:
            for v, o in zip(eqn.outvars, eqn.invars[:len(eqn.outvars)]):
                self.set(env, v, self.read(env, o))
        elif name in _BOOL_OUT:
            self._default_outs(env, eqn)         # dtype default = [0, 1]
        else:
            # unknown primitive: recurse into any sub-jaxpr (so nothing
            # hides a gather from the pass), outputs at dtype default
            self._recurse(env, eqn)
            self._default_outs(env, eqn)


# --------------------------------------------------------------------------- #
# Entry points.
# --------------------------------------------------------------------------- #


def _flat_bounds(args, bounds):
    """Flatten per-argument bounds to the traced fn's flat invar order.
    Each bound is an Interval (broadcast over the arg's leaves), None
    (TOP), or a pytree of Intervals congruent with the arg."""
    import jax
    flat = []
    for a, b in zip(args, bounds):
        n = len(jax.tree_util.tree_leaves(a))
        if b is None:
            flat += [TOP] * n
        elif isinstance(b, Interval):
            flat += [b] * n
        else:
            leaves = jax.tree_util.tree_leaves(
                b, is_leaf=lambda x: isinstance(x, Interval))
            if len(leaves) != n:
                raise ValueError(
                    f"bounds pytree has {len(leaves)} leaves for an "
                    f"argument with {n}")
            flat += [x if isinstance(x, Interval) else TOP for x in leaves]
    return flat


def verify_fn(fn, args, bounds=None, *, name: str) -> list[Finding]:
    """Trace ``fn(*args)`` and statically verify the jaxpr.  ``bounds``
    gives entry intervals per positional argument (see
    :func:`_flat_bounds`); omitted arguments are unbounded.  Returns all
    findings (empty = verified)."""
    import jax
    closed = jax.make_jaxpr(fn)(*args)
    findings: list[Finding] = []
    an = _Analyzer(findings, name)
    env: dict = {}
    if bounds is None:
        bounds = [None] * len(args)
    flat = _flat_bounds(args, bounds)
    if len(flat) != len(closed.jaxpr.invars):
        raise ValueError(f"{name}: {len(flat)} bound leaves for "
                         f"{len(closed.jaxpr.invars)} traced inputs")
    for v, iv in zip(closed.jaxpr.invars, flat):
        an.set(env, v, iv)
    for v, c in zip(closed.jaxpr.constvars, closed.consts):
        an.set(env, v, _const_interval(c))
    an.walk(closed.jaxpr, env)
    return findings


def routing_bounds():
    """A ``RoutingState`` of entry intervals built from
    :data:`FIELD_BOUNDS` — the verifier's table assumptions, identical to
    what the plan validator enforces on every wire payload."""
    from repro.core.routing_table import RoutingState

    def iv(field, default=TOP):
        b = FIELD_BOUNDS.get(field)
        return Interval(*b) if b else default

    return RoutingState(
        svc_rule_start=iv("svc_rule_start"),
        svc_rule_count=iv("svc_rule_count"),
        rule_field=iv("rule_field"),
        rule_value=iv("rule_value"),
        rule_cluster=iv("rule_cluster"),
        cluster_ep_start=iv("cluster_ep_start"),
        cluster_ep_count=iv("cluster_ep_count"),
        cluster_policy=iv("cluster_policy"),
        ep_instance=iv("ep_instance"),
        ep_weight=Interval(0, None),
        ep_drained=iv("ep_drained"),
        maglev_table=iv("maglev_table"),
        ep_load=iv("ep_load"),
        ep_inflight_ewma=Interval(0, None),
        ep_tput_ewma=Interval(0, None),
        rr_cursor=iv("rr_cursor"),
        aff_key=iv("aff_key"),
        aff_ep=iv("aff_ep"),
        version=Interval(0, None),
    )


# --------------------------------------------------------------------------- #
# The kernel sweep — representative shapes from kernels/tune.py, every
# registered policy in the table, both folds.
# --------------------------------------------------------------------------- #

SWEEP_R, SWEEP_I, SWEEP_C = 64, 18, 4
FOLDS = ("segment", "onehot")


def _sweep_state():
    """One 3-lane cluster per registered policy (rule: feature 0 == enum
    routes to it), so every ``kernel_offset`` lowering is live in the
    trace and a newly registered policy is swept automatically."""
    from repro.core import policy_defs
    from repro.core.routing_table import Cluster, Rule, ServiceConfig, \
        build_state

    services, clusters = [], []
    for p in policy_defs.REGISTRY:
        eps = [(3 * p.enum + j) % SWEEP_I for j in range(3)]
        clusters.append(Cluster(f"c_{p.name}", endpoints=eps, policy=p.enum))
        services.append(ServiceConfig(
            f"s_{p.name}", rules=[Rule(0, str(p.enum), f"c_{p.name}")]))
    state, names = build_state(services, clusters)
    return state


def _admit_args(commit: bool):
    import jax.numpy as jnp
    from repro.core.routing_table import MAX_EPS_PER_CLUSTER, N_FEATURES

    R, I, C = SWEEP_R, SWEEP_I, SWEEP_C
    state = _sweep_state()
    rid = jnp.arange(R, dtype=jnp.int32)
    z = jnp.zeros((R,), jnp.int32)
    feats = jnp.zeros((R, N_FEATURES), jnp.int32)
    gum = jnp.zeros((R, MAX_EPS_PER_CLUSTER), jnp.float32)
    head = [rid, z, feats, z]
    head_b = [None, None, None, Interval(0, None)]
    if commit:
        pool = [jnp.full((I, C), -1, jnp.int32),
                jnp.full((I, C), -1, jnp.int32),
                jnp.zeros((I, C), jnp.int32), jnp.zeros((I, C), jnp.int32),
                jnp.zeros((I, C), jnp.int32), jnp.zeros((I, C), jnp.int32)]
        args = head + [z, state] + pool + [z, gum]
        bounds = head_b + [None, routing_bounds()] + [None] * 6 \
            + [Interval(0, None), None]
    else:
        free = jnp.ones((I, C), jnp.int32)
        args = head + [state, free] + [z, gum]
        bounds = head_b + [routing_bounds(), Interval(0, 1),
                           Interval(0, None), None]
    return args, bounds


def _complete_args():
    import jax.numpy as jnp
    from repro.core.routing_table import MAX_ENDPOINTS, MAX_SERVICES

    I, C = SWEEP_I, SWEEP_C
    pool = [jnp.full((I, C), -1, jnp.int32), jnp.full((I, C), -1, jnp.int32),
            jnp.zeros((I, C), jnp.int32), jnp.zeros((I, C), jnp.int32),
            jnp.zeros((I, C), jnp.int32), jnp.ones((I, C), jnp.int32)]
    nxt = jnp.zeros((I, C), jnp.int32)
    load = jnp.zeros((MAX_ENDPOINTS,), jnp.int32)
    rx = jnp.zeros((MAX_SERVICES,), jnp.int32)
    ewl = jnp.zeros((MAX_ENDPOINTS,), jnp.float32)
    ewt = jnp.zeros((MAX_ENDPOINTS,), jnp.float32)
    args = pool + [nxt, load, rx, ewl, ewt]
    bounds = [None] * 7 + [Interval(0, None), Interval(0, None),
                           Interval(0, None), Interval(0, None)]
    return args, bounds


def verify_kernels(folds=FOLDS) -> list[Finding]:
    """Statically verify every registered datapath kernel × fold on the
    representative sweep shapes.  Empty list = all proven."""
    import functools

    import jax
    import numpy as np_
    from jax.sharding import Mesh

    from repro.kernels import completion as _cp
    from repro.kernels import route_match as _rm
    from repro.kernels import shard_admit as _sa
    from repro.core.routing_table import N_FEATURES

    findings: list[Finding] = []
    for fold in folds:
        args, bounds = _admit_args(commit=False)
        findings += verify_fn(
            functools.partial(_rm.admit, block_r=SWEEP_R, fold=fold,
                              interpret=True),
            args, bounds, name=f"admit[{fold}]")
        args, bounds = _admit_args(commit=True)
        findings += verify_fn(
            functools.partial(_rm.admit_commit, block_r=SWEEP_R, fold=fold,
                              interpret=True),
            args, bounds, name=f"admit_commit[{fold}]")
        args, bounds = _complete_args()
        findings += verify_fn(
            functools.partial(_cp.complete, eos=1, max_len=16, block_i=2,
                              fold=fold, interpret=True),
            args, bounds, name=f"complete[{fold}]")

    # route_match building block (least-request scan only)
    import jax.numpy as jnp
    state = _sweep_state()
    svc = jnp.zeros((SWEEP_R,), jnp.int32)
    feats = jnp.zeros((SWEEP_R, N_FEATURES), jnp.int32)
    findings += verify_fn(
        functools.partial(_rm.route_match, block_r=SWEEP_R, interpret=True),
        (svc, feats, state), (None, None, routing_bounds()),
        name="route_match")

    # staged policy chain (every staged_offset lowering in one trace)
    from repro.core import policies as _pol
    cluster = jnp.zeros((SWEEP_R,), jnp.int32)
    key = jax.random.PRNGKey(0)
    findings += verify_fn(
        lambda st, cl, k, f: _pol.select(st, cl, k, f),
        (state, cluster, key, feats),
        (routing_bounds(), None, None, None), name="policies.select")

    # sharded admit relay on a 1-device mesh (collectives + relay hop)
    mesh = Mesh(np_.asarray(jax.devices()[:1]), ("shard",))
    args, bounds = _admit_args(commit=True)
    (rid, z, feats2, mb, tok, st), pool = args[:6], args[6:12]
    rnd, gum = args[12], args[13]
    findings += verify_fn(
        functools.partial(_sa.admit_commit_sharded, mesh=mesh,
                          block_r=SWEEP_R, fold="segment", interpret=True),
        (rid, z, feats2, mb, tok, st, *pool, rnd, gum),
        (None, None, None, Interval(0, None), Interval(0, None),
         routing_bounds(), *([None] * 6), Interval(0, None), None),
        name="admit_commit_sharded[segment]")
    return findings


# --------------------------------------------------------------------------- #
# PolicyDef registry checks — the four-lowering contract.
# --------------------------------------------------------------------------- #

REQUIRED_HOOKS = ("kernel_offset", "oracle_pick", "staged_offset",
                  "host_pick")
VALID_MERGES = ("cursor", "waterfill", "none")


def check_registry() -> list[Finding]:
    """Every registered policy carries all four lowering hooks, a valid
    shard-merge rule, and a unique enum."""
    from repro.core import policy_defs

    findings: list[Finding] = []
    seen: dict[int, str] = {}
    for p in policy_defs.REGISTRY:
        where = f"registry/{p.name}"
        for hook in REQUIRED_HOOKS:
            fn = getattr(p, hook, None)
            if not callable(fn):
                findings.append(Finding(
                    "policy-missing-hook", where,
                    f"policy {p.name!r} lacks a callable {hook!r} — all "
                    "four datapath lowerings must be registered"))
        merge = getattr(p, "shard_merge", None)
        if merge not in VALID_MERGES:
            findings.append(Finding(
                "policy-bad-merge", where,
                f"shard_merge {merge!r} not one of {VALID_MERGES} — the "
                "sharded reconciliation cannot carry this policy's state"))
        if p.enum in seen:
            findings.append(Finding(
                "policy-dup-enum", where,
                f"enum {p.enum} already registered by {seen[p.enum]!r}"))
        seen.setdefault(p.enum, p.name)
    return findings


def verify_all() -> list[Finding]:
    """The full static pass: registry contract + every kernel × fold."""
    return check_registry() + verify_kernels()
