"""Static verification subsystem — the reproduction's eBPF-verifier analogue
(DESIGN.md §12).

  * ``verifier``   — jaxpr-level interval analysis proving every gather /
    scatter / dynamic_slice in the datapath kernels stays inside its table
    window, plus dtype / determinism sweeps and the PolicyDef four-lowering
    sweep.
  * ``invariants`` — ONE declarative registry of conservation laws and
    field-value bounds, compiled three ways: static checks on plan wire
    dicts (``core/control.py::unpack_plan``), a ``jax.experimental.checkify``
    sanitizer (``XLB_SANITIZE=1``) hooked into the kernel wrappers and the
    serving loops, and the BENCH_TREND.jsonl row schemas.
  * ``lint``       — repo-wide AST lints (computed scatters without an OOB
    mode, bare nondeterminism in datapath modules, policy-enum literals)
    and the import-graph dead-module report.

Run it all: ``python -m repro.analysis`` (also wired into
``benchmarks/run.py --check``).  Submodules are imported explicitly —
``from repro.analysis import invariants`` — so that core/ and workload/
can depend on the lightweight invariant engine without pulling the kernel
tracer into their import graph.
"""
