"""arctic-480b — 128-expert top-2 MoE with a parallel dense residual MLP.
[hf:Snowflake/snowflake-arctic-base; hf]
"""

from repro.configs.base import ModelConfig, MoEConfig, register

ARCTIC_480B = register(
    ModelConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,
        vocab=32000,
        head_dim=128,
        ffn_act="swiglu",
        moe=MoEConfig(
            n_experts=128,
            top_k=2,
            d_ff_expert=4864,
            moe_every=1,
            dense_residual=True,   # dense MLP in parallel with the MoE output
        ),
        source="hf:Snowflake/snowflake-arctic-base; hf",
    )
)
