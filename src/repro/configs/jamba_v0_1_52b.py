"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave with MoE.
[arXiv:2403.19887; hf]

Each 8-layer period has 1 attention mixer (position 4) and 7 mamba mixers;
every second layer's FFN is a 16-expert top-2 MoE.  Jamba's mamba blocks use
d_state=16.  Runs long_500k via SSM state + KV-sequence-sharded attention on
the 4 attention layers.
"""

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, register

JAMBA_V0_1_52B = register(
    ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=65536,
        head_dim=128,
        ffn_act="swiglu",
        moe=MoEConfig(
            n_experts=16,
            top_k=2,
            d_ff_expert=14336,
            moe_every=2,
            moe_offset=1,        # odd layers are MoE
        ),
        ssm=SSMConfig(d_state=16, expand=2, head_dim=64, n_groups=1, conv_width=4),
        attn_period=8,
        attn_pos=4,
        source="arXiv:2403.19887; hf",
    )
)
