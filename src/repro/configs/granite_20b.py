"""granite-20b — dense llama-arch code model, MQA (GQA kv=1). [arXiv:2405.04324; hf]"""

from repro.configs.base import ModelConfig, register

GRANITE_20B = register(
    ModelConfig(
        name="granite-20b",
        family="dense",
        n_layers=52,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,            # MQA
        d_ff=24576,
        vocab=49152,
        head_dim=128,
        ffn_act="swiglu",
        source="arXiv:2405.04324; hf",
    )
)
