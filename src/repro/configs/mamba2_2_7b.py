"""mamba2-2.7b — attention-free SSD (state-space duality). [arXiv:2405.21060; unverified]

d_inner = 2*2560 = 5120, head_dim=64 -> 80 SSD heads, d_state=128.
Decode state is O(1) in sequence length, so this arch runs long_500k.
"""

from repro.configs.base import ModelConfig, SSMConfig, register

MAMBA2_2_7B = register(
    ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        n_layers=64,
        d_model=2560,
        n_heads=0,               # attention-free
        n_kv_heads=0,
        d_ff=0,                  # no FFN: mamba blocks only (per released model)
        vocab=50280,
        head_dim=0,
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, n_groups=1, conv_width=4),
        source="arXiv:2405.21060; unverified",
    )
)
