"""deepseek-v2-236b — MoE with MLA. [arXiv:2405.04434; hf]

MLA kv_lora=512 (+64 rope dims cached), 128 heads.  MoE: 2 shared + 160 routed
experts, top-6, expert d_ff=1536; layer 0 keeps a dense FFN (d_ff=12288, per
the released model).  The XLB expert relay (core.relay) is the dispatch path.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, register

DEEPSEEK_V2_236B = register(
    ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,           # MLA: latent cache, logical kv = n_heads
        d_ff=12288,               # dense FFN used on first_dense layers
        vocab=102400,
        head_dim=128,
        ffn_act="swiglu",
        moe=MoEConfig(
            n_experts=160,
            top_k=6,
            n_shared_experts=2,
            d_ff_expert=1536,
            moe_every=1,
            first_dense=1,
        ),
        mla=MLAConfig(
            q_lora_rank=1536,
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        source="arXiv:2405.04434; hf",
    )
)
