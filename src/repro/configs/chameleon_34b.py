"""chameleon-34b — early-fusion VLM. [arXiv:2405.09818; unverified]

Early fusion = VQ image tokens share the text token stream; the VQ tokenizer
frontend is a STUB (tokens arrive pre-quantized inside the 65536 vocab), so the
backbone is a dense decoder-only transformer.
"""

from repro.configs.base import ModelConfig, register

CHAMELEON_34B = register(
    ModelConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab=65536,
        head_dim=128,
        ffn_act="swiglu",
        source="arXiv:2405.09818; unverified",
    )
)
