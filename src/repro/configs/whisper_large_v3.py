"""whisper-large-v3 — enc-dec audio backbone. [arXiv:2212.04356; unverified]

The conv frontend is a STUB: ``input_specs()`` provides precomputed
(enc_frames, d_model) frame embeddings.  32 encoder + 32 decoder layers,
GELU FFN, full (non-causal) encoder attention, causal decoder self-attention
plus cross-attention to the encoder output.
"""

from repro.configs.base import ModelConfig, register

WHISPER_LARGE_V3 = register(
    ModelConfig(
        name="whisper-large-v3",
        family="audio",
        n_layers=32,              # decoder depth
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,
        d_ff=5120,
        vocab=51866,
        head_dim=64,
        ffn_act="gelu",
        is_encdec=True,
        n_enc_layers=32,
        enc_frames=1500,
        source="arXiv:2212.04356; unverified",
    )
)
