"""The paper's own evaluation topologies (§6).

These are not LM architectures but service graphs: a "service" is a model
instance fleet behind the XLB router.  The micro-benchmark config mirrors the
paper's setup (one client service, one server service with 2 instances, a
single URL-prefix routing rule) and the application configs mirror bookinfo
(Fig. 12a) and Bank of Anthos (Fig. 12b).  Benchmarks use a tiny dense LM as
the per-service "application" so end-to-end request latency is measurable on
CPU.
"""

from dataclasses import dataclass, field

from repro.configs.base import ModelConfig, register

# Tiny per-service application model (shared by all services in a graph).
XLB_SERVICE_MODEL = register(
    ModelConfig(
        name="xlb-service-model",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab=512,
        head_dim=32,
        ffn_act="swiglu",
        source="paper §6 microbenchmark",
    )
)


@dataclass(frozen=True)
class ServiceGraph:
    """A microservice topology: services, instance counts, and call edges."""

    name: str
    services: tuple[str, ...]
    instances: dict[str, int] = field(default_factory=dict)
    # edges: (caller, callee); the entry service is services[0]
    edges: tuple[tuple[str, str], ...] = ()

    def chain(self) -> list[str]:
        """Topological call order starting at the entry service."""
        order, seen = [], set()

        def visit(s: str) -> None:
            if s in seen:
                return
            seen.add(s)
            order.append(s)
            for a, b in self.edges:
                if a == s:
                    visit(b)

        visit(self.services[0])
        return order


MICROBENCH = ServiceGraph(
    name="microbench",
    services=("client", "server"),
    instances={"client": 1, "server": 2},
    edges=(("client", "server"),),
)


def chain_graph(length: int, instances_per_service: int = 2) -> ServiceGraph:
    """Paper Fig. 8: a linear chain of `length` services."""
    names = tuple(f"svc{i}" for i in range(length + 1))
    return ServiceGraph(
        name=f"chain{length}",
        services=names,
        instances={n: (1 if i == 0 else instances_per_service) for i, n in enumerate(names)},
        edges=tuple((names[i], names[i + 1]) for i in range(length)),
    )


BOOKINFO = ServiceGraph(
    name="bookinfo",
    services=("client", "productpage", "details", "reviews", "ratings"),
    instances={"client": 1, "productpage": 50, "details": 5, "reviews": 5, "ratings": 5},
    edges=(
        ("client", "productpage"),
        ("productpage", "details"),
        ("productpage", "reviews"),
        ("reviews", "ratings"),
    ),
)

BANK_OF_ANTHOS = ServiceGraph(
    name="bank-of-anthos",
    services=(
        "client", "frontend", "userservice", "contacts",
        "ledgerwriter", "balancereader", "transactionhistory",
    ),
    instances={
        "client": 1, "frontend": 30, "userservice": 50, "contacts": 5,
        "ledgerwriter": 5, "balancereader": 5, "transactionhistory": 5,
    },
    edges=(
        ("client", "frontend"),
        ("frontend", "userservice"),
        ("frontend", "contacts"),
        ("frontend", "ledgerwriter"),
        ("ledgerwriter", "balancereader"),
        ("frontend", "transactionhistory"),
    ),
)
