"""Architecture configs (assigned pool + the paper's own topologies)."""

from repro.configs.base import (
    SHAPES,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    get_config,
    list_configs,
    shape_applicable,
    smoke_config,
)

# importing each module registers its config
from repro.configs.granite_20b import GRANITE_20B
from repro.configs.internlm2_20b import INTERNLM2_20B
from repro.configs.yi_34b import YI_34B
from repro.configs.minitron_4b import MINITRON_4B
from repro.configs.deepseek_v2_236b import DEEPSEEK_V2_236B
from repro.configs.arctic_480b import ARCTIC_480B
from repro.configs.whisper_large_v3 import WHISPER_LARGE_V3
from repro.configs.chameleon_34b import CHAMELEON_34B
from repro.configs.mamba2_2_7b import MAMBA2_2_7B
from repro.configs.jamba_v0_1_52b import JAMBA_V0_1_52B
from repro.configs.xlb_microbench import (
    BANK_OF_ANTHOS,
    BOOKINFO,
    MICROBENCH,
    XLB_SERVICE_MODEL,
    ServiceGraph,
    chain_graph,
)

ASSIGNED_ARCHS = [
    "granite-20b",
    "internlm2-20b",
    "yi-34b",
    "minitron-4b",
    "deepseek-v2-236b",
    "arctic-480b",
    "whisper-large-v3",
    "chameleon-34b",
    "mamba2-2.7b",
    "jamba-v0.1-52b",
]

__all__ = [
    "SHAPES",
    "ASSIGNED_ARCHS",
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "SSMConfig",
    "ShapeConfig",
    "ServiceGraph",
    "get_config",
    "list_configs",
    "shape_applicable",
    "smoke_config",
    "chain_graph",
]
