"""Configuration system.

Every assigned architecture is expressed as a frozen ``ModelConfig``; input
shapes are ``ShapeConfig``.  Configs are pure data — no jax imports here so the
control plane (and tests) can import them without touching device state.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional

# --------------------------------------------------------------------------- #
# Model configuration
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1          # MoE replaces the FFN on layers where (i % moe_every == moe_offset)
    moe_offset: int = 0
    first_dense: int = 0        # first N layers use a dense FFN (deepseek-v2)
    dense_residual: bool = False  # arctic: dense MLP in parallel with the MoE
    capacity_factor: float = 1.25
    router_dtype: str = "float32"

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2)."""

    q_lora_rank: int = 0          # 0 = no q compression
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""

    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256            # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | audio | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # FFN activation: "swiglu" (llama-family) or "gelu" (whisper)
    ffn_act: str = "swiglu"
    # sub-configs
    moe: MoEConfig = MoEConfig()
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (jamba): one attention layer per `attn_period` layers, at `attn_pos`;
    # remaining mixers are mamba.
    attn_period: int = 0
    attn_pos: int = 4
    # encoder-decoder (whisper): n_layers is the decoder depth.
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_frames: int = 1500       # precomputed conv-frontend frames (stub)
    # misc
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # citation tag from the assignment table
    source: str = ""

    # ----------------------------------------------------------------- #
    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dim shards
        evenly over a 16-way model axis (whisper's 51866 is the offender)."""
        return -(-self.vocab // 256) * 256

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Archs that can run 500k-token decode (SSM state / hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def is_hybrid(self) -> bool:
        return self.attn_period > 0

    def param_count(self) -> int:
        """Approximate total parameter count N (embeddings included)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        H, K, hd = self.n_heads, self.n_kv_heads, self.head_dim
        total = V * D * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                qin = m.q_lora_rank or D
                p = 0
                if m.q_lora_rank:
                    p += D * m.q_lora_rank
                p += qin * H * m.qk_head_dim                      # q up
                p += D * (m.kv_lora_rank + m.qk_rope_head_dim)    # kv down
                p += m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
                p += H * m.v_head_dim * D                         # out
                return p
            return D * H * hd + 2 * D * K * hd + H * hd * D

        def dense_ffn(dff: int) -> int:
            mult = 3 if self.ffn_act == "swiglu" else 2
            return mult * D * dff

        def moe_ffn() -> int:
            m = self.moe
            p = D * m.n_experts                                    # router
            p += m.n_experts * dense_ffn(m.d_ff_expert) // 1
            if m.n_shared_experts:
                p += dense_ffn(m.n_shared_experts * m.d_ff_expert)
            if m.dense_residual:
                p += dense_ffn(F)
            return p

        def mamba_params() -> int:
            s = self.ssm
            di = s.d_inner(D)
            nh = s.n_heads(D)
            conv_dim = di + 2 * s.n_groups * s.d_state
            p = D * (2 * di + 2 * s.n_groups * s.d_state + nh)    # in_proj
            p += conv_dim * s.conv_width + conv_dim               # conv
            p += nh * 2                                           # A_log, D
            p += di                                               # dt_bias via nh? folded
            p += di * D                                           # out_proj
            return p

        if self.family == "ssm":
            total += L * (mamba_params() + D)
            return total

        n_moe = 0
        if self.moe.enabled:
            n_moe = sum(
                1
                for i in range(L)
                if i >= self.moe.first_dense
                and i % self.moe.moe_every == self.moe.moe_offset
            )
        n_dense_ffn = L - n_moe

        if self.is_hybrid:
            n_attn = L // self.attn_period
            n_mamba = L - n_attn
            total += n_attn * attn_params() + n_mamba * mamba_params()
        else:
            dec_attn = attn_params() * (2 if self.is_encdec else 1)  # self+cross
            total += L * dec_attn
            if self.is_encdec:
                total += self.n_enc_layers * (attn_params() + dense_ffn(F) + 2 * D)

        total += n_moe * moe_ffn() + n_dense_ffn * dense_ffn(F)
        total += L * 2 * D + D                                    # norms
        return total

    def active_param_count(self) -> int:
        """Parameters active per token (MoE: only routed top-k experts)."""
        if not self.moe.enabled:
            return self.param_count()
        m = self.moe
        mult = 3 if self.ffn_act == "swiglu" else 2
        per_expert = mult * self.d_model * m.d_ff_expert
        n_moe = sum(
            1
            for i in range(self.n_layers)
            if i >= m.first_dense and i % m.moe_every == m.moe_offset
        )
        inactive = n_moe * (m.n_experts - m.top_k) * per_expert
        return self.param_count() - inactive


# --------------------------------------------------------------------------- #
# Input shapes
# --------------------------------------------------------------------------- #


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a given (arch, shape) cell is runnable. Returns (ok, reason)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 524k-token decode is quadratic; skipped per assignment"
    return True, ""


# --------------------------------------------------------------------------- #
# Reduced (smoke-test) configs
# --------------------------------------------------------------------------- #


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink a full config to something a CPU can forward in <1s.

    Keeps the *family structure* (MoE/MLA/SSM/hybrid wiring) but with tiny dims.
    """
    kw: dict = dict(
        n_layers=max(2, cfg.attn_period or 2),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        d_ff=128,
        vocab=256,
        head_dim=16,
        name=cfg.name + "-smoke",
    )
    if cfg.moe.enabled:
        kw["moe"] = replace(
            cfg.moe,
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=32,
            first_dense=min(cfg.moe.first_dense, 1),
            # drop-free so decode == full-forward equivalence tests hold
            # (capacity drops are data-dependent and differ between a 1-token
            # decode batch and the full prefill batch)
            capacity_factor=8.0,
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            q_lora_rank=32, kv_lora_rank=32, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16,
        )
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, d_state=16, head_dim=16, chunk=32)
    if cfg.is_encdec:
        kw["n_enc_layers"] = 2
        kw["enc_frames"] = 16
    if cfg.attn_period:
        kw["n_layers"] = cfg.attn_period  # one full period
    return replace(cfg, **kw)


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # populate registry lazily
    from repro import configs as _c  # noqa: F401  (imports all arch modules)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from repro import configs as _c  # noqa: F401

    return sorted(_REGISTRY)
