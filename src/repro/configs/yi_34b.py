"""yi-34b — dense llama-arch GQA kv=8. [arXiv:2403.04652; hf]

Note: 56 heads is not divisible by the 16-way model axis; GSPMD shards unevenly
(pads to 64) — the waste shows up in the §Roofline useful-FLOPs ratio.
"""

from repro.configs.base import ModelConfig, register

YI_34B = register(
    ModelConfig(
        name="yi-34b",
        family="dense",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab=64000,
        head_dim=128,
        ffn_act="swiglu",
        rope_theta=5_000_000.0,
        source="arXiv:2403.04652; hf",
    )
)
