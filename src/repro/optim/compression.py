"""Int8 error-feedback gradient compression for the slow (cross-pod) hop.

Distributed-optimization trick for the 2-pod mesh: gradients are all-reduced
in two stages — full precision inside a pod (fast ICI), int8 with error
feedback across pods (slow DCI link) — cutting cross-pod collective bytes 4×.
The error-feedback residual keeps the compression unbiased over steps
(1-bit Adam / EF-SGD lineage).

``compress_pytree``/``decompress_pytree`` are pure and autodiff-free; the
train loop threads the residual state explicitly.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any      # pytree like grads, fp32


def init(grads_like) -> EFState:
    return EFState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize(g: jax.Array, res: jax.Array):
    """Per-tensor symmetric int8 with error feedback."""
    x = g.astype(jnp.float32) + res
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, x - deq


def compress_pytree(grads, ef: EFState):
    """→ (int8 pytree, scales pytree, new EFState).  Collective payload is the
    int8 tree + one fp32 scale per tensor (4 bytes amortised)."""
    out = jax.tree.map(quantize, grads, ef.residual)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    r = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return q, s, EFState(r)


def decompress_pytree(q, s):
    return jax.tree.map(lambda qi, si: qi.astype(jnp.float32) * si, q, s)


def cross_pod_allreduce(grads, ef: EFState, axis: str = "pod"):
    """psum over the pod axis with int8 payload (call inside shard_map)."""
    q, s, ef = compress_pytree(grads, ef)
    # int8 psum: sum of quantised values stays exact in int32
    q32 = jax.tree.map(lambda x: jax.lax.psum(x.astype(jnp.int32), axis), q)
    s = jax.tree.map(lambda x: jax.lax.pmax(x, axis), s)
    from repro.compat import axis_size
    n = axis_size(axis)
    deq = jax.tree.map(lambda qi, si: qi.astype(jnp.float32) * si / n, q32, s)
    return deq, ef
