"""AdamW in pure JAX, FSDP-friendly (moments inherit parameter shardings).

Built for the scale this framework targets:
  * bf16 params with fp32 moments (fp32 master copies are redundant when the
    update is computed in fp32 and cast on write — recorded in DESIGN.md)
  * global-norm clipping
  * optional int8 error-feedback gradient compression applied on the slow
    (cross-pod) data axis before the all-reduce (optim/compression.py)
  * least-request router-bias update for MoE (the XLB LB policy as an
    optimizer-side state; aux-loss-free balancing, DeepSeek-V3-style)
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init(params) -> AdamWState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros32, params),
                      v=jax.tree.map(zeros32, params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply(params, grads, state: AdamWState, cfg: AdamWConfig,
          lr_scale: jax.Array | float = 1.0):
    """One AdamW step.  Returns (new_params, new_state, stats)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step, new_m, new_v), \
        {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------------------------------- #
# Least-request router bias (XLB LB policy → MoE expert balancing)
# --------------------------------------------------------------------------- #


def update_router_bias(bias: jax.Array, load: jax.Array,
                       rate: float = 1e-3) -> jax.Array:
    """Aux-loss-free balancing: bias experts inversely to their recent load.

    ``load``: (E,) tokens routed this step.  The sign-rule update nudges
    selection away from hot experts — the least-request policy expressed as a
    slowly-varying bias instead of a per-request counter scan.
    """
    err = load.astype(jnp.float32) - load.mean()
    return bias - rate * jnp.sign(err)
