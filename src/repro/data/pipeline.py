"""Deterministic, resumable, step-indexed data pipeline.

Design requirements at 1000-node scale (DESIGN.md §5):
  * **Step-indexed determinism** — batch(step) is a pure function of
    (seed, step), so a job restarted from checkpoint step N regenerates byte-
    identical batches with zero pipeline state to persist, and any host can
    produce any shard (elastic re-sharding is index arithmetic).
  * **Host sharding** — each host materialises only its slice of the global
    batch (``host_slice``).
  * **Prefetch** — a bounded background thread keeps ``depth`` batches ready.

The generator is a synthetic LM stream (hashed-counter tokens with a Zipf-ish
skew so MoE routing/load-balancing sees realistic imbalance), plus a
fixed-vocab "document boundary" structure for the label mask.  Swapping in a
real tokenised corpus only replaces ``_tokens_for_index``.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    enc_frames: int = 0       # >0 → also emit encoder frame embeddings
    d_model: int = 0
    zipf_a: float = 1.3


class Pipeline:
    """Deterministic synthetic stream; ``batch_at(step)`` is pure."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts

    # ------------------------------------------------------------------ #
    def _tokens_for_index(self, idx: np.ndarray) -> np.ndarray:
        """(B,) sample indices → (B, S+1) token rows, pure & vectorised."""
        cfg = self.cfg
        S = cfg.seq_len + 1
        # counter-based RNG: philox via numpy Generator seeded per row
        rows = []
        for i in idx:
            rng = np.random.Generator(np.random.Philox(key=cfg.seed,
                                                       counter=int(i)))
            u = rng.random(S)
            # Zipf-ish skew over the vocab for realistic router imbalance
            toks = (cfg.vocab * u ** cfg.zipf_a).astype(np.int32)
            rows.append(np.clip(toks, 0, cfg.vocab - 1))
        return np.stack(rows)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        base = step * cfg.global_batch + self.host_id * self.local_batch
        idx = np.arange(base, base + self.local_batch, dtype=np.int64)
        toks = self._tokens_for_index(idx)
        batch = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
        }
        if cfg.enc_frames:
            rng = np.random.Generator(np.random.Philox(key=cfg.seed + 1,
                                                       counter=step))
            batch["enc_frames"] = rng.standard_normal(
                (self.local_batch, cfg.enc_frames, cfg.d_model),
                dtype=np.float32)
        return batch

    # ------------------------------------------------------------------ #
    def iterate(self, start_step: int = 0, prefetch: int = 2
                ) -> Iterator[dict]:
        """Prefetching iterator resumable at any step."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            s = start_step
            while not stop.is_set():
                q.put((s, self.batch_at(s)))
                s += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                _, b = q.get()
                yield b
        finally:
            stop.set()
