"""Request map — stream-id rewriting and response re-ordering (paper §4.1).

When several p-socks multiplex onto one i-sock, XLB allocates *internal*
request identifiers and maps them back to the original ids on the response
path.  Here: requests admitted into instance pools get an internal id =
(instance, slot); the original request id is stored per slot, and responses
are returned to request order with one inverse gather.

This module is the *staged baseline* implementation: the engine's fused
path commits pool state inside the admit kernel
(kernels/route_match.py::admit_commit) and never calls scatter_to_pool;
the sidecar baselines and bench_admit still drive allocate_slots/
scatter_to_pool as the pre-fusion comparison chain.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import relay


class SlotAssignment(NamedTuple):
    instance: jax.Array     # (R,) int32 target instance (-1 unroutable)
    slot: jax.Array         # (R,) int32 slot within instance (-1 held)
    ok: jax.Array           # (R,) bool admitted


def allocate_slots(instance: jax.Array, free_mask: jax.Array
                   ) -> SlotAssignment:
    """Assign each request a free slot on its chosen instance.

    instance: (R,) int32 (may be -1); free_mask: (I, C) bool — True = free.
    Stable: requests keep arrival order within an instance (HTTP/1.1 in-order
    semantics); requests that exceed the free-slot count are held (ok=False),
    the paper's bounded hold queue.
    """
    I, C = free_mask.shape
    routable = instance >= 0
    inst = jnp.where(routable, instance, 0)
    # rank of each request within its instance (counting-sort, cf. relay)
    rank, _ = relay.positions_sort(jnp.where(routable, inst, I), I + 1)
    # free slots, free-first stable order per instance
    order = jnp.argsort(~free_mask, axis=1, stable=True)    # (I,C) free first
    n_free = free_mask.sum(axis=1)                          # (I,)
    ok = routable & (rank < n_free[inst])
    slot = jnp.where(ok, order[inst, jnp.minimum(rank, C - 1)], -1)
    return SlotAssignment(jnp.where(routable, instance, -1), slot, ok)


def scatter_to_pool(pool_val: jax.Array, assign: SlotAssignment,
                    values: jax.Array) -> jax.Array:
    """Write per-request values into (I, C, ...) pool arrays at (inst, slot).

    Un-admitted rows are steered to an out-of-bounds index and dropped, so
    they can never collide with a real slot write.
    """
    I = pool_val.shape[0]
    i = jnp.where(assign.ok, assign.instance, I)     # OOB when not admitted
    s = jnp.where(assign.ok, assign.slot, 0)
    return pool_val.at[i, s].set(values, mode="drop")


def gather_responses(pool_val: jax.Array, assign: SlotAssignment,
                     fill=0) -> jax.Array:
    """Inverse map: read back per-request values from the pool (response
    re-ordering; un-admitted requests get ``fill``)."""
    i = jnp.where(assign.ok, assign.instance, 0)
    s = jnp.where(assign.ok, assign.slot, 0)
    out = pool_val[i, s]
    return jnp.where(assign.ok.reshape((-1,) + (1,) * (out.ndim - 1)),
                     out, fill)
