"""Closed-loop endpoint health — the circuit-breaker daemon (DESIGN.md §8).

The datapath observes for free: every completion tick the fused kernel
carries two per-endpoint EWMAs in ``RoutingState`` — ``ep_inflight_ewma``
(requests in flight during the step) and ``ep_tput_ewma`` (completions per
step).  Their ratio is the endpoint's latency estimate in ticks under
Little's law (L = λW ⇒ W = L/λ), which stays meaningful under every fault
mode: a slow endpoint's occupancy builds while its completion rate decays,
and a fully *stalled* endpoint — which never produces a completion sample —
still diverges because the denominator drains to zero.

``HealthPolicy`` is the decision half of the loop.  The kernel never
decides: ejection is config authorship (weights, drained bits), which is
ControlPlane's monopoly — the datapath only reads config, so a decision
made in-kernel would either race the control plane's transactions or need
its own write path into the tables.  Instead the daemon runs a per-endpoint
circuit breaker each control epoch and commits every resulting action in
ONE ControlPlane transaction (one plan, one version bump — the datapath
never sees partial state):

  CLOSED ──(latency > k_eject × fleet median, ``trip_after`` consecutive
            epochs, worst-first, capped by the max-ejection-fraction
            guard)──▶ OPEN   (drain reason="health": weight 0 + drained
                              bit up; never reaped, immune to set_weight)
  OPEN   ──(``cooldown`` epochs)──▶ HALF_OPEN  (undrain at a small probe
                              weight: a weight-limited trickle re-tastes
                              the endpoint)
  HALF_OPEN ──(healthy for ``recover_after`` epochs)──▶ CLOSED  (weight
                              restored, breaker reset)
            ──(still sick, or no recovery within ``probe_patience``
               epochs)──▶ OPEN  (re-ejected, cooldown restarts)

Outlier detection is *relative* — each endpoint is judged against the
leave-one-out median of its cluster peers — so a uniformly slow fleet has
no outlier and nothing is ejected: overload is the load balancer's problem,
not the breaker's.  Together with the max-ejection-fraction guard
(``min(floor(frac·n), n-1)`` open breakers at most) the policy can never
drain a whole cluster: the least-bad endpoints always keep serving, so a
degraded fleet degrades instead of returning NO_ROUTE.
"""

from __future__ import annotations

import dataclasses

import numpy as np

CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"

# An endpoint with both EWMAs below these floors has seen no meaningful
# traffic — it is not judged (no data is not evidence of health or sickness).
MIN_INFLIGHT = 0.05
MIN_TPUT = 0.02
# Completion-rate floor for the latency ratio: caps the estimate for a
# stalled endpoint (tput → 0) at inflight / TPUT_FLOOR instead of inf.
TPUT_FLOOR = 1.0 / 64.0


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Breaker thresholds, all in control epochs / multiples of the fleet
    median latency."""

    k_eject: float = 3.0        # trip when latency > k_eject × peer median
    k_recover: float = 2.0      # healthy when latency ≤ k_recover × median
    trip_after: int = 2         # consecutive sick epochs before ejection
    cooldown: int = 3           # OPEN epochs before the half-open probe
    recover_after: int = 2      # healthy probe epochs before closing
    probe_patience: int = 8     # half-open epochs without recovery → re-open
    max_eject_frac: float = 0.5  # ejection budget as a fraction of the fleet
    probe_weight: float = 0.1   # trickle weight during the half-open probe
    min_probe_tput: float = 0.05  # a probe must actually complete requests
    #                               at this EWMA rate to count as healthy
    # Graded-weight mode (WEIGHTED clusters only): continuously scale each
    # serving endpoint's weight by peer-median/latency instead of waiting
    # for the breaker's binary verdict — the paper's weighted-LB analogue
    # of gradual backend demotion.
    graded_weights: bool = False
    graded_floor: float = 0.25  # weight floor: demoted, never starved
    #                             (full removal stays the breaker's job)
    graded_alpha: float = 0.5   # EWMA smoothing toward the target weight
    graded_deadband: float = 0.05  # skip commits within this of the live
    #                                weight — the no-flap band


@dataclasses.dataclass
class _Breaker:
    state: str = CLOSED
    sick: int = 0               # consecutive sick epochs while CLOSED
    healthy: int = 0            # consecutive healthy epochs while HALF_OPEN
    open_epochs: int = 0
    probe_epochs: int = 0
    saved_weight: float = 1.0   # weight to restore when the breaker closes


def latency_estimate(inflight_ewma, tput_ewma) -> np.ndarray:
    """Per-endpoint latency estimate in ticks (Little's law W = L/λ), 0.0
    where the endpoint has seen no meaningful traffic."""
    infl = np.asarray(inflight_ewma, np.float32)
    tput = np.asarray(tput_ewma, np.float32)
    lat = infl / np.maximum(tput, TPUT_FLOOR)
    has_data = (infl >= MIN_INFLIGHT) | (tput >= MIN_TPUT)
    return np.where(has_data, lat, 0.0).astype(np.float32)


class HealthPolicy:
    """Per-cluster circuit breakers over the datapath's health EWMAs.

    ``epoch(routing)`` is the daemon tick: read the EWMAs out of a live
    RoutingState, run every breaker, and commit all resulting actions in
    one ControlPlane transaction.  Returns the action list (empty = no
    transaction, no version bump)."""

    def __init__(self, cp, cfg: HealthConfig | None = None,
                 clusters: list[str] | None = None):
        self.cp = cp
        self.cfg = cfg or HealthConfig()
        self.clusters = clusters            # None = every cluster
        self.breakers: dict[tuple[str, int], _Breaker] = {}
        self._gw: dict[tuple[str, int], float] = {}  # graded smoothed weights
        self.epochs = 0
        self.commits = 0
        self.events: list[tuple] = []       # (epoch, action...) audit trail

    # ------------------------------------------------------------------ #
    def _bk(self, cluster: str, instance: int) -> _Breaker:
        return self.breakers.setdefault((cluster, instance), _Breaker())

    def state_of(self, cluster: str, instance: int) -> str:
        bk = self.breakers.get((cluster, instance))
        return bk.state if bk is not None else CLOSED

    def ejected(self) -> list[tuple[str, int]]:
        return [k for k, b in self.breakers.items() if b.state == OPEN]

    # ------------------------------------------------------------------ #
    def _peer_median(self, cluster: str, members, lat, exclude: int) -> float:
        """Leave-one-out median latency of the cluster's serving peers —
        robust for small fleets (with a plain median a 2-endpoint cluster
        could never flag its sick half).  OPEN (ejected) peers don't vote
        unless nobody else has data."""
        vals = [float(lat[s]) for s, i in members
                if i != exclude and lat[s] > 0.0
                and self.state_of(cluster, i) != OPEN]
        if not vals:
            vals = [float(lat[s]) for s, i in members
                    if i != exclude and lat[s] > 0.0]
        return float(np.median(vals)) if vals else 0.0

    def _epoch_cluster(self, name: str, lat: np.ndarray) -> list[tuple]:
        cfg = self.cfg
        members = self.cp.cluster_members(name)
        if not members:
            return []
        alive = {inst for _, inst in members}
        for key in [k for k in self.breakers
                    if k[0] == name and k[1] not in alive]:
            del self.breakers[key]          # endpoint left the cluster

        acts: list[tuple] = []
        candidates: list[tuple] = []
        for slot, inst in members:
            bk = self._bk(name, inst)
            l = float(lat[slot])
            med = self._peer_median(name, members, lat, inst)
            has_data = l > 0.0 and med > 0.0
            sick = has_data and l > cfg.k_eject * med
            healthy = has_data and l <= cfg.k_recover * med
            if bk.state == CLOSED:
                bk.sick = bk.sick + 1 if sick else 0
                if bk.sick >= cfg.trip_after:
                    candidates.append((l, slot, inst, bk))
            elif bk.state == OPEN:
                bk.open_epochs += 1
                if bk.open_epochs >= cfg.cooldown:
                    bk.state = HALF_OPEN
                    bk.probe_epochs = 0
                    bk.healthy = 0
                    acts.append(("probe", name, inst, cfg.probe_weight))
            else:                           # HALF_OPEN: judge the probe
                bk.probe_epochs += 1
                tput = self._tput[slot]
                if healthy and tput >= cfg.min_probe_tput:
                    bk.healthy += 1
                    if bk.healthy >= cfg.recover_after:
                        bk.state = CLOSED
                        bk.sick = 0
                        acts.append(("close", name, inst, bk.saved_weight))
                else:
                    bk.healthy = 0
                    if sick or bk.probe_epochs >= cfg.probe_patience:
                        bk.state = OPEN      # re-ejected; cooldown restarts
                        bk.open_epochs = 0
                        acts.append(("eject", name, inst))

        # max-ejection-fraction guard: never more than floor(frac·n) open
        # breakers, and never the last serving endpoint — the least-bad
        # endpoints keep taking traffic instead of the cluster going
        # NO_ROUTE.  Worst (highest latency) candidates go first; the rest
        # stay CLOSED with their sick streak saturated for the next epoch.
        n = len(members)
        committed = sum(1 for _, i in members
                        if self.state_of(name, i) in (OPEN, HALF_OPEN))
        budget = min(int(cfg.max_eject_frac * n), n - 1) - committed
        for l, slot, inst, bk in sorted(candidates, key=lambda x: -x[0]):
            if budget <= 0:
                break
            bk.state = OPEN
            bk.open_epochs = 0
            bk.saved_weight = float(self.cp.endpoint_weight(name, inst))
            acts.append(("eject", name, inst))
            budget -= 1
        return acts

    def _graded_cluster(self, name: str, lat: np.ndarray) -> list[tuple]:
        """Graded-weight mode: nudge each serving endpoint's weight toward
        ``clip(peer_median / latency, graded_floor, 1.0)`` — a
        slow-but-not-sick endpoint sheds load *continuously* instead of
        waiting for the breaker's binary verdict.  WEIGHTED clusters only
        (the other policies never read ``ep_weight``).  The smoothed weight
        is EWMA'd (``graded_alpha``) and only committed when it moved past
        ``graded_deadband`` from the live weight, so a steady fleet
        converges and then stops producing transactions (no-flap).
        Endpoints that are not CLOSED, are draining, or have no data keep
        their weight — graded mode never fights the breaker."""
        from repro.core.routing_table import POLICY_WEIGHTED
        if self.cp.cluster_policy(name) != POLICY_WEIGHTED:
            return []
        cfg = self.cfg
        members = self.cp.cluster_members(name)
        acts: list[tuple] = []
        for slot, inst in members:
            if self.state_of(name, inst) != CLOSED \
                    or self.cp.drain_reason(name, inst) is not None:
                continue
            l = float(lat[slot])
            med = self._peer_median(name, members, lat, inst)
            if l <= 0.0 or med <= 0.0:
                continue                    # no data: leave the weight alone
            target = float(np.clip(med / l, cfg.graded_floor, 1.0))
            prev = self._gw.get(
                (name, inst), float(self.cp.endpoint_weight(name, inst)))
            w = (1.0 - cfg.graded_alpha) * prev + cfg.graded_alpha * target
            self._gw[(name, inst)] = w
            if abs(w - float(self.cp.endpoint_weight(name, inst))) \
                    > cfg.graded_deadband:
                acts.append(("weight", name, inst, w))
        return acts

    # ------------------------------------------------------------------ #
    def epoch(self, routing) -> list[tuple]:
        """One daemon tick: read EWMAs → run breakers → one transaction."""
        self.epochs += 1
        self.cp.advance_epoch()             # the liveness-lease clock
        lat = latency_estimate(routing.ep_inflight_ewma,
                               routing.ep_tput_ewma)
        self._tput = np.asarray(routing.ep_tput_ewma, np.float32)
        names = self.clusters if self.clusters is not None \
            else self.cp.cluster_names()
        actions: list[tuple] = []
        for name in names:
            actions += self._epoch_cluster(name, lat)
            if self.cfg.graded_weights:
                actions += self._graded_cluster(name, lat)
        if actions:
            with self.cp.transaction():
                for act in actions:
                    kind, name, inst = act[0], act[1], act[2]
                    if kind == "eject":
                        self.cp.drain_endpoint(name, inst, reason="health")
                    elif kind == "probe":
                        self.cp.undrain_endpoint(name, inst, weight=act[3])
                    elif kind == "weight":
                        self.cp.set_weight(name, inst, act[3])
                    elif kind == "close":
                        # an operator may have staged a weight while the
                        # breaker was open (set_weight doesn't un-eject);
                        # honor it over the pre-ejection saved weight.  The
                        # current weight is probe_weight unless somebody
                        # staged one mid-probe/mid-open.
                        staged = self.cp.endpoint_weight(name, inst)
                        w = act[3]
                        if staged > 0.0 and \
                                abs(staged - self.cfg.probe_weight) > 1e-6:
                            w = staged
                        self.cp.set_weight(name, inst, w)
            self.commits += 1
        self.events += [(self.epochs,) + a for a in actions]
        return actions
