"""XLB core: the paper's contribution as a composable JAX module.

  routing_table  nested eBPF-map state (map-in-map → index-linked arrays)
  control        ControlPlane: named, transactional config updates (the
                 userspace daemon — directory, slot allocator, drain/reap)
  balancer       the Balancer protocol all three engines implement, plus
                 the shared wire types (RequestBatch, PoolState)
  router         content-based rule matching (filter/route managers)
  policies       LB algorithms (rr / random / least-request / weighted)
  relay          socket relay → scatter / all-to-all payload redirection
  request_map    stream-id rewrite + response re-ordering
  delta          raw slot-index delta refresh (ControlPlane's low level)
  interpose      the in-graph serving engine (admit + step in one program)
  sidecar        Istio/Cilium-analogue baselines (host-interposed)
"""

from repro.core import relay, routing_table

__all__ = ["relay", "routing_table"]
