"""XLB core: the paper's contribution as a composable JAX module.

  routing_table  nested eBPF-map state (map-in-map → index-linked arrays)
  router         content-based rule matching (filter/route managers)
  policies       LB algorithms (rr / random / least-request / weighted)
  relay          socket relay → scatter / all-to-all payload redirection
  request_map    stream-id rewrite + response re-ordering
  delta          delta refresh (bottom-up add, top-down delete)
  interpose      the in-graph serving engine (admit + step in one program)
  sidecar        Istio/Cilium-analogue baselines (host-interposed)
"""

from repro.core import relay, routing_table

__all__ = ["relay", "routing_table"]
