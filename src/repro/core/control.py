"""ControlPlane — the paper's userspace control daemon (§4.2) as a
transactional, *named* API over the nested-map routing tables.

The Go daemon of the paper watches Envoy config, compiles it into the
C-struct maps of Figure 3(b), and retargets the kernel tables without ever
touching the datapath.  This module is that daemon: it owns everything the
datapath must never own —

  * the **name → id directory** (services, clusters) that ``build_state``
    used to return once and lose;
  * a **slot allocator** over the flat endpoint/rule arrays: every cluster
    (service) holds a contiguous *window* whose extent comes from a
    free-list; windows relocate when they outgrow their capacity and the
    vacated extent returns to the free-list for reuse;
  * **transactions**: ``with cp.transaction(): ...`` batches any number of
    named deltas — ``add_endpoint`` / ``drain_endpoint`` /
    ``remove_endpoint`` / ``set_policy`` / ``set_weight`` /
    ``upsert_rule`` / ``remove_rule`` / ``add_service`` / ``add_cluster``
    / ``remove_service`` / ``remove_cluster`` (directory ids recycle
    through free-lists, like the endpoint/rule window extents) — into
    **one** buffer swap with a **single version bump**.  Each delta's
    primitive writes follow the paper's ordering discipline (adds
    bottom-up: endpoint row before the cluster count that exposes it;
    deletes top-down: the count shrinks before the row is compacted), and
    the order is observable through ``last_commit_log``;
  * **swap-with-last hygiene**: compaction migrates the moved endpoint's
    in-flight load counter along with it and *zeroes the vacated slot*, so
    a slot reused by a later ``add_endpoint`` can never inherit a stale
    counter, and a release against the moved endpoint can never corrupt a
    new occupant (consumers remap their pool endpoint references through
    the plan's old→new map);
  * **drain before remove**: ``drain_endpoint`` zeroes the weight at once
    (no new connections) but the row survives until every attached
    consumer's live load counter for it reads zero — the reap happens on a
    later commit (or an explicit ``reap()``).

A commit compiles into a :class:`RefreshPlan` — new config arrays plus an
endpoint slot permutation — and applies it to every attached consumer with
one jit'd splice (:func:`apply_plan`) over that consumer's *live* state:
config tables swap, load counters gather through the permutation, the
datapath-owned fields (``rr_cursor``) pass through untouched, and the
version bumps once.  Same pytree shapes in and out, so the compiled
``serve_step`` never recompiles — the paper's "configuration updates do not
disturb the kernel data path".
"""

from __future__ import annotations

import collections
import contextlib
import copy
import dataclasses
import weakref
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy_defs
from repro.core.routing_table import (AFFINITY_SLOTS, MAGLEV_TABLE_SIZE,
                                      MAX_CLUSTERS, MAX_ENDPOINTS,
                                      MAX_EPS_PER_CLUSTER, MAX_RULES,
                                      MAX_RULES_PER_SVC, MAX_SERVICES,
                                      POLICY_LEAST_REQUEST, WILDCARD, Cluster,
                                      RoutingState, Rule, ServiceConfig,
                                      build_state, fnv1a)

# The tables the control plane owns.  Everything else in RoutingState
# (ep_load, ep_inflight_ewma, ep_tput_ewma, rr_cursor, aff_key, aff_ep,
# version) is datapath-owned and only ever *migrated* by a commit, never
# authored.  ``maglev_table`` is config: derived from cluster membership and
# rebuilt (incrementally, per dirty row) inside ``_commit``.
CONFIG_FIELDS = ("svc_rule_start", "svc_rule_count", "rule_field",
                 "rule_value", "rule_cluster", "cluster_ep_start",
                 "cluster_ep_count", "cluster_policy", "ep_instance",
                 "ep_weight", "ep_drained", "maglev_table")


class RefreshPlan(NamedTuple):
    """One committed transaction, ready to splice into any live state.

    The plan is the control plane's *wire format*: one commit produces one
    plan, and the same plan pytree fans out to every attached consumer —
    a local ``ServeLoop``, a mesh-sharded engine (whose replicated routing
    swaps once and is thereby visible on every shard with a single version
    bump), or a remote ingress host that receives it through
    ``pack_plan``/``unpack_plan`` (plain ndarray dict, transport-agnostic).
    """

    config: tuple            # new config arrays, CONFIG_FIELDS order
    ep_src: np.ndarray       # (E,) i32: new slot → old slot (-1 = fresh)
    ep_dst: np.ndarray       # (E,) i32: old slot → new slot (-1 = removed)
    # transport versioning (runtime/transport.py): ``base_version`` is the
    # config version this plan was diffed against; a remote consumer applies
    # the plan only when its own version matches (gap → snapshot resync),
    # and ``apply_plan`` stamps ``version`` instead of blind +1 so a
    # resync'd consumer lands on the control plane's exact version.  The
    # defaults (-1) keep in-process consumers on the legacy +1 behaviour.
    base_version: int = -1   # scalar i32; -1 = unversioned (local commit)
    version: int = -1        # scalar i32; -1 = bump live.version + 1


# Expected wire shapes/kinds for every pack_plan field — the validation
# table unpack_plan checks a payload against before anything is applied.
_WIRE_SPECS: dict = {
    "svc_rule_start": ((MAX_SERVICES,), "i"),
    "svc_rule_count": ((MAX_SERVICES,), "i"),
    "rule_field": ((MAX_RULES,), "i"),
    "rule_value": ((MAX_RULES,), "i"),
    "rule_cluster": ((MAX_RULES,), "i"),
    "cluster_ep_start": ((MAX_CLUSTERS,), "i"),
    "cluster_ep_count": ((MAX_CLUSTERS,), "i"),
    "cluster_policy": ((MAX_CLUSTERS,), "i"),
    "ep_instance": ((MAX_ENDPOINTS,), "i"),
    "ep_weight": ((MAX_ENDPOINTS,), "f"),
    "ep_drained": ((MAX_ENDPOINTS,), "i"),
    "maglev_table": ((MAX_CLUSTERS, MAGLEV_TABLE_SIZE), "i"),
    "ep_src": ((MAX_ENDPOINTS,), "i"),
    "ep_dst": ((MAX_ENDPOINTS,), "i"),
}


def pack_plan(plan: RefreshPlan) -> dict:
    """Flatten a plan into a name→ndarray dict for shipping to a consumer
    that is not in this process (a remote ingress host of the sharded
    fleet).  Inverse of :func:`unpack_plan`; round-trip is bit-exact."""
    out = {k: np.asarray(v) for k, v in zip(CONFIG_FIELDS, plan.config)}
    out["ep_src"] = np.asarray(plan.ep_src)
    out["ep_dst"] = np.asarray(plan.ep_dst)
    out["base_version"] = int(plan.base_version)
    out["version"] = int(plan.version)
    return out


def _wire_scalar(arrays: dict, key: str) -> int:
    v = arrays[key]
    ok = (isinstance(v, int) and not isinstance(v, bool)) \
        or isinstance(v, np.integer) \
        or (isinstance(v, np.ndarray) and v.ndim == 0
            and np.issubdtype(v.dtype, np.integer))
    if not ok:
        raise ValueError(f"plan payload field {key!r} must be an integer "
                         f"scalar, got {v!r}")
    iv = int(v)
    if iv < -1:
        raise ValueError(f"plan payload field {key!r} out of range: {iv}")
    return iv


def unpack_plan(arrays: dict) -> RefreshPlan:
    """Rebuild a :class:`RefreshPlan` from ``pack_plan`` output — the
    receiving host applies it with the same ``apply_refresh`` seam local
    consumers use (one splice, one version bump).

    A payload off the wire is validated *before* anything is returned —
    missing keys, wrong shapes, wrong dtype kinds, and malformed version
    fields each raise :class:`ValueError` naming the offending field, so a
    corrupted plan can never half-apply downstream.  Unknown extra keys are
    ignored (transport envelopes ride alongside the payload)."""
    if not isinstance(arrays, dict):
        raise ValueError(f"plan payload must be a dict, got "
                         f"{type(arrays).__name__}")
    missing = [k for k in (*_WIRE_SPECS, "base_version", "version")
               if k not in arrays]
    if missing:
        raise ValueError(f"plan payload missing fields: {missing}")
    vals: dict = {}
    for k, (shape, kind) in _WIRE_SPECS.items():
        try:
            a = np.asarray(arrays[k])
        except Exception as e:
            raise ValueError(f"plan payload field {k!r} is not "
                             f"array-like") from e
        if a.shape != shape:
            raise ValueError(f"plan payload field {k!r} has shape "
                             f"{a.shape}, expected {shape}")
        want = np.integer if kind == "i" else np.floating
        if not np.issubdtype(a.dtype, want):
            raise ValueError(f"plan payload field {k!r} has dtype "
                             f"{a.dtype}, expected "
                             f"{'integer' if kind == 'i' else 'floating'}")
        vals[k] = a.astype(np.int32 if kind == "i" else np.float32)
    base = _wire_scalar(arrays, "base_version")
    version = _wire_scalar(arrays, "version")
    if version == 0 or (version > 0 and base >= version):
        raise ValueError(f"plan payload has bad version fields: "
                         f"base_version={base}, version={version}")
    # semantic layer: the declarative plan laws (field bounds, disjoint
    # windows, slot-permutation consistency, version monotonicity) — the
    # same registry the XLB_SANITIZE runtime mode and the static verifier's
    # entry assumptions compile from (repro.analysis.invariants)
    from repro.analysis.invariants import check_plan_wire
    violations = check_plan_wire(
        {**vals, "base_version": base, "version": version})
    if violations:
        raise ValueError("plan payload violates invariants: "
                         + "; ".join(violations))
    return RefreshPlan(
        config=tuple(vals[k] for k in CONFIG_FIELDS),
        ep_src=vals["ep_src"], ep_dst=vals["ep_dst"],
        base_version=base, version=version)


@jax.jit
def apply_plan(live: RoutingState, plan: RefreshPlan) -> RoutingState:
    """The single buffer swap: new config in, live loads + health EWMAs
    migrated through the slot permutation (fresh slots start cold at zero),
    rr cursors untouched.  A versioned plan (transport) stamps its own
    version; an unversioned one (plan.version == -1) bumps live + 1."""
    cfg = {k: jnp.asarray(v) for k, v in zip(CONFIG_FIELDS, plan.config)}
    ver = jnp.asarray(plan.version, jnp.int32)
    new_version = jnp.where(ver >= 0, ver, live.version + 1)
    src = jnp.asarray(plan.ep_src)
    gather = jnp.maximum(src, 0)
    load = jnp.where(src >= 0, live.ep_load[gather], 0)
    ewl = jnp.where(src >= 0, live.ep_inflight_ewma[gather], 0.0)
    ewt = jnp.where(src >= 0, live.ep_tput_ewma[gather], 0.0)
    # sticky sessions follow their endpoint through the slot permutation;
    # entries whose endpoint was removed or is drained in the new config
    # invalidate here — the affinity cache can never outlive a drain.
    dst = jnp.asarray(plan.ep_dst)
    E = dst.shape[0]
    ae = live.aff_ep
    ae2 = jnp.where(ae >= 0, dst[jnp.clip(ae, 0, E - 1)], -1)
    alive = (ae2 >= 0) & (cfg["ep_drained"][jnp.clip(ae2, 0, E - 1)] == 0)
    return live._replace(ep_load=load.astype(jnp.int32),
                         ep_inflight_ewma=ewl.astype(jnp.float32),
                         ep_tput_ewma=ewt.astype(jnp.float32),
                         aff_ep=jnp.where(alive, ae2, -1).astype(jnp.int32),
                         aff_key=jnp.where(alive, live.aff_key,
                                           -1).astype(jnp.int32),
                         version=new_version.astype(jnp.int32), **cfg)


def remap_endpoints(plan: RefreshPlan, endpoint: jax.Array) -> jax.Array:
    """Rewrite endpoint slot references (e.g. ``PoolState.endpoint``) from
    old to new coordinates; references to removed endpoints become -1, so a
    later release is a no-op instead of corrupting the slot's new occupant."""
    dst = jnp.asarray(plan.ep_dst)
    e = jnp.asarray(endpoint)
    return jnp.where(e >= 0, dst[jnp.maximum(e, 0)], -1).astype(jnp.int32)


# --------------------------------------------------------------------------- #
# Free-list extents (the slot allocator)
# --------------------------------------------------------------------------- #


def _extent_alloc(extents: list[list[int]], size: int) -> int:
    """First-fit carve from a sorted [(start, size), ...] free-list."""
    if size == 0:
        return 0
    for ext in extents:
        if ext[1] >= size:
            start = ext[0]
            ext[0] += size
            ext[1] -= size
            if ext[1] == 0:
                extents.remove(ext)
            return start
    raise RuntimeError("slot space exhausted (or too fragmented)")


def _extent_free(extents: list[list[int]], start: int, size: int) -> None:
    """Return an extent and coalesce neighbours."""
    if size == 0:
        return
    extents.append([start, size])
    extents.sort()
    merged: list[list[int]] = []
    for ext in extents:
        if merged and merged[-1][0] + merged[-1][1] == ext[0]:
            merged[-1][1] += ext[1]
        else:
            merged.append(ext)
    extents[:] = merged


@dataclasses.dataclass
class _Window:
    start: int
    cap: int


@dataclasses.dataclass
class _Dir:
    id: int
    win: _Window


@dataclasses.dataclass
class _Store:
    """Everything a commit swaps atomically (host-side)."""

    cfg: dict
    services: dict
    clusters: dict
    ep_free: list
    rule_free: list
    draining: dict          # {(cluster_name, instance): reason}; reason is
    #                         "operator" (drain_endpoint default — the reaper
    #                         removes the row once load hits zero) or
    #                         "health" (circuit-breaker ejection — temporary:
    #                         never reaped, only HealthPolicy lifts it)
    # directory-id recycling: removed service/cluster ids return here and
    # are reused before the high-water counters grow the tables
    svc_id_free: list = dataclasses.field(default_factory=list)
    cluster_id_free: list = dataclasses.field(default_factory=list)
    svc_id_next: int = 0
    cluster_id_next: int = 0


class _Txn:
    def __init__(self, store: _Store):
        self.store = copy.deepcopy(store)
        self.src = np.arange(MAX_ENDPOINTS, dtype=np.int32)
        self.log: list[tuple] = []


class ControlPlane:
    """Owner of the routing config: directory + allocator + transactions."""

    def __init__(self, services: list[ServiceConfig] = (),
                 clusters: list[Cluster] = (), *, lease_epochs: int = 0,
                 journal_limit: int = 64):
        # One packing implementation: the initial build IS a build_state
        # rebuild (bit-exact by construction); the directory and free-lists
        # are recovered from its window layout.
        st, ids = build_state(list(services), list(clusters))
        cfg = {k: np.array(getattr(st, k)) for k in CONFIG_FIELDS}
        store = _Store(cfg=cfg, services={}, clusters={}, ep_free=[],
                       rule_free=[], draining={})
        ep_cursor = 0
        for c in clusters:
            ci = ids["clusters"][c.name]
            store.clusters[c.name] = _Dir(
                ci, _Window(int(cfg["cluster_ep_start"][ci]),
                            len(c.endpoints)))
            ep_cursor += len(c.endpoints)
        rule_cursor = 0
        for s in services:
            si = ids["services"][s.name]
            store.services[s.name] = _Dir(
                si, _Window(int(cfg["svc_rule_start"][si]), len(s.rules)))
            rule_cursor += len(s.rules)
        _extent_free(store.ep_free, ep_cursor, MAX_ENDPOINTS - ep_cursor)
        _extent_free(store.rule_free, rule_cursor, MAX_RULES - rule_cursor)
        store.svc_id_next = len(services)
        store.cluster_id_next = len(clusters)
        self._store = store
        self._txn: _Txn | None = None
        self._refs: list[weakref.ref] = []
        self.version = 0
        self.last_commit_log: list[tuple] = []
        self.last_plan: RefreshPlan | None = None
        # bounded plan journal: the last ``journal_limit`` commits as packed
        # (wire-format) plans, each stamped base_version/version.  The
        # transport publisher replays journal suffixes to consumers that
        # fell behind; a consumer whose ack predates the journal floor gets
        # a full snapshot resync instead (runtime/transport.py).
        self.journal: collections.deque = collections.deque(
            maxlen=max(1, int(journal_limit)))
        # liveness leases: a consumer's heartbeat records the control epoch
        # it was last seen alive at.  With lease_epochs > 0 the drain reaper
        # ignores load pinned by a consumer whose lease expired (a dead host
        # cannot deadlock drain-before-remove); 0 disables expiry.
        self.lease_epochs = lease_epochs
        self.epoch = 0
        self._leases: weakref.WeakKeyDictionary = weakref.WeakKeyDictionary()

    # ------------------------------------------------------------------ #
    # directory / snapshots
    # ------------------------------------------------------------------ #
    @property
    def ids(self) -> dict:
        """build_state-compatible name→id maps (but never lost)."""
        return {"services": {n: d.id for n, d in
                             self._store.services.items()},
                "clusters": {n: d.id for n, d in
                             self._store.clusters.items()}}

    def service_id(self, name: str) -> int:
        return self._store.services[name].id

    def cluster_id(self, name: str) -> int:
        return self._store.clusters[name].id

    def endpoint_slot(self, cluster: str, instance: int) -> int:
        """Global slot currently holding ``instance`` in ``cluster``."""
        store = self._txn.store if self._txn is not None else self._store
        return self._find_slot(store, cluster, instance)

    def snapshot(self) -> RoutingState:
        """A fresh RoutingState at the control plane's current config (zero
        load/cursors/EWMAs — the datapath owns those from here on)."""
        cfg = self._store.cfg
        return RoutingState(
            ep_load=jnp.zeros((MAX_ENDPOINTS,), jnp.int32),
            ep_inflight_ewma=jnp.zeros((MAX_ENDPOINTS,), jnp.float32),
            ep_tput_ewma=jnp.zeros((MAX_ENDPOINTS,), jnp.float32),
            rr_cursor=jnp.zeros((MAX_CLUSTERS,), jnp.int32),
            aff_key=jnp.full((AFFINITY_SLOTS,), -1, jnp.int32),
            aff_ep=jnp.full((AFFINITY_SLOTS,), -1, jnp.int32),
            version=jnp.asarray(self.version, jnp.int32),
            **{k: jnp.asarray(cfg[k]) for k in CONFIG_FIELDS})

    def packed_snapshot(self) -> dict:
        """The full current config as a wire-format dict (CONFIG_FIELDS
        arrays + the config version) — the transport's resync payload for a
        consumer whose ack fell behind the plan journal (or that crashed
        and rejoined at version -1).  The consumer side rebuilds a
        load-preserving :class:`RefreshPlan` from it by matching (cluster,
        instance) rows against its own live config
        (``runtime.transport.snapshot_plan``)."""
        out = {k: np.array(self._store.cfg[k]) for k in CONFIG_FIELDS}
        out["version"] = int(self.version)
        return out

    def cluster_names(self) -> list[str]:
        return list(self._store.clusters)

    def cluster_members(self, name: str) -> list[tuple[int, int]]:
        """[(global slot, instance), ...] currently in cluster ``name`` —
        the HealthPolicy's view of who it may judge."""
        store = self._txn.store if self._txn is not None else self._store
        d = store.clusters[name]
        n = int(store.cfg["cluster_ep_count"][d.id])
        return [(d.win.start + j,
                 int(store.cfg["ep_instance"][d.win.start + j]))
                for j in range(n)]

    def cluster_policy(self, name: str) -> int:
        """The cluster's LB policy id (core/routing_table POLICY_*)."""
        store = self._txn.store if self._txn is not None else self._store
        return int(store.cfg["cluster_policy"][store.clusters[name].id])

    def endpoint_weight(self, cluster: str, instance: int) -> float:
        store = self._txn.store if self._txn is not None else self._store
        slot = self._find_slot(store, cluster, instance)
        if slot < 0:
            raise KeyError(f"no endpoint {instance} in {cluster!r}")
        return float(store.cfg["ep_weight"][slot])

    def drain_reason(self, cluster: str, instance: int) -> str | None:
        """Pending drain reason for an endpoint, or None if not draining."""
        store = self._txn.store if self._txn is not None else self._store
        return store.draining.get((cluster, instance))

    def attach(self, consumer) -> None:
        """Register a consumer (``ServeLoop``, benchmark service, ...): its
        ``apply_refresh(plan)`` runs on every commit, and its live
        ``routing.ep_load`` gates the drain reaper.  Held by weak
        reference — an abandoned consumer drops out on its own instead of
        pinning drained endpoints alive (and paying a splice) forever.
        Attaching is an implicit heartbeat (the lease starts now)."""
        if consumer not in self._consumers():
            self._refs.append(weakref.ref(consumer))
        self.heartbeat(consumer)

    def detach(self, consumer) -> None:
        self._refs = [r for r in self._refs if r() is not consumer]

    def _consumers(self) -> list:
        live = [(r, r()) for r in self._refs]
        self._refs = [r for r, c in live if c is not None]
        return [c for _, c in live if c is not None]

    # ------------------------------------------------------------------ #
    # liveness leases
    # ------------------------------------------------------------------ #
    def heartbeat(self, consumer) -> None:
        """Record the consumer alive at the current control epoch."""
        try:
            self._leases[consumer] = self.epoch
        except TypeError:                  # non-weakref-able consumer: the
            pass                           # lease never expires for it

    def advance_epoch(self) -> int:
        """Tick the control-epoch clock (the HealthPolicy daemon's cadence;
        anything periodic may drive it)."""
        self.epoch += 1
        return self.epoch

    def lease_live(self, consumer) -> bool:
        """Public read of the liveness lease — the transport publisher
        stops shipping plans to a consumer whose lease expired and resumes
        (with a resync if needed) when its heartbeats return."""
        return self._lease_live(consumer)

    def _lease_live(self, consumer) -> bool:
        if self.lease_epochs <= 0:
            return True
        last = self._leases.get(consumer)
        if last is None:                   # never heard from: treat the
            return True                    # attach itself as the heartbeat
        return (self.epoch - last) <= self.lease_epochs

    # ------------------------------------------------------------------ #
    # transactions
    # ------------------------------------------------------------------ #
    @contextlib.contextmanager
    def transaction(self):
        """Batch named deltas into one swap with a single version bump."""
        if self._txn is not None:
            raise RuntimeError("ControlPlane transactions do not nest")
        self._txn = _Txn(self._store)
        try:
            yield self
        except BaseException:
            self._txn = None               # abort: staged writes discarded
            raise
        txn, self._txn = self._txn, None
        self._commit(txn)

    @contextlib.contextmanager
    def _auto(self):
        if self._txn is not None:
            yield self._txn
        else:
            with self.transaction():
                yield self._txn

    def reap(self) -> None:
        """Run just the drain reaper (an empty transaction)."""
        with self.transaction():
            pass

    def _commit(self, txn: _Txn) -> None:
        consumers = self._consumers()
        # drain reaper: a drained endpoint leaves once no attached consumer
        # still counts in-flight load against it.  Health ejections are
        # temporary by design — never reaped, only HealthPolicy lifts them —
        # and a consumer with an expired lease no longer votes (a dead host's
        # phantom load cannot deadlock drain-before-remove).
        leased = [c for c in consumers if self._lease_live(c)]
        for cl, inst in sorted(txn.store.draining):
            if txn.store.draining.get((cl, inst)) == "health":
                continue
            slot = self._find_slot(txn.store, cl, inst)
            if slot < 0:
                txn.store.draining.pop((cl, inst), None)
                continue
            old = int(txn.src[slot])
            load = 0 if old < 0 else max(
                (int(np.asarray(c.routing.ep_load)[old])
                 for c in leased), default=0)
            if load == 0:
                self._do_remove_endpoint(txn, cl, inst)
                txn.log.append(("reap", cl, inst))
        if not txn.log:                    # nothing happened: no bump
            return
        # Maglev rows rebuild incrementally: only clusters whose
        # (membership, drain) inputs changed this transaction.  One
        # add/drain remaps ~1/E of a row's slots; untouched clusters'
        # rows never churn, so keys hashed there keep their endpoints.
        T = txn.store.cfg["maglev_table"].shape[1]
        for c in range(MAX_CLUSTERS):
            new_in = policy_defs.maglev_row_inputs(txn.store.cfg, c)
            if new_in == policy_defs.maglev_row_inputs(self._store.cfg, c):
                continue
            n, insts, drs = new_in
            offs = [j for j in range(n) if drs[j] == 0]
            txn.store.cfg["maglev_table"][c] = policy_defs._maglev_row(
                offs, [int(insts[j]) for j in offs], T)
        dst = np.full((MAX_ENDPOINTS,), -1, np.int32)
        occupied = txn.src >= 0
        dst[txn.src[occupied]] = np.nonzero(occupied)[0]
        plan = RefreshPlan(
            config=tuple(txn.store.cfg[k].copy() for k in CONFIG_FIELDS),
            ep_src=txn.src.copy(), ep_dst=dst,
            base_version=self.version, version=self.version + 1)
        self._store = txn.store
        self.version += 1
        self.last_commit_log = list(txn.log)
        self.last_plan = plan
        self.journal.append(pack_plan(plan))
        for consumer in consumers:
            consumer.apply_refresh(plan)

    # ------------------------------------------------------------------ #
    # named deltas
    # ------------------------------------------------------------------ #
    def add_service(self, name: str, rules: list[Rule] = ()) -> int:
        with self._auto() as t:
            if name in t.store.services:
                raise ValueError(f"service {name!r} exists")
            if t.store.svc_id_free:            # recycle a removed id first
                sid = t.store.svc_id_free.pop(0)
            else:
                sid = t.store.svc_id_next
                if sid >= MAX_SERVICES:
                    raise RuntimeError("service table full")
                t.store.svc_id_next += 1
            assert len(rules) <= MAX_RULES_PER_SVC
            start = _extent_alloc(t.store.rule_free, len(rules))
            for j, r in enumerate(rules):      # bottom-up: rows first
                self._write_rule(t, start + j, r.field, r.value,
                                 r.cluster)
            t.store.cfg["svc_rule_start"][sid] = start
            t.store.cfg["svc_rule_count"][sid] = len(rules)
            t.log.append(("svc_count", sid, len(rules)))
            t.store.services[name] = _Dir(sid, _Window(start, len(rules)))
            return sid

    def add_cluster(self, name: str, policy: int = POLICY_LEAST_REQUEST,
                    endpoints: list[int] = (), weights=None) -> int:
        with self._auto() as t:
            if name in t.store.clusters:
                raise ValueError(f"cluster {name!r} exists")
            if t.store.cluster_id_free:        # recycle a removed id first
                cid = t.store.cluster_id_free.pop(0)
            else:
                cid = t.store.cluster_id_next
                if cid >= MAX_CLUSTERS:
                    raise RuntimeError("cluster table full")
                t.store.cluster_id_next += 1
            assert len(endpoints) <= MAX_EPS_PER_CLUSTER
            start = _extent_alloc(t.store.ep_free, len(endpoints))
            for j, inst in enumerate(endpoints):   # bottom-up: rows first
                w = 1.0 if weights is None else weights[j]
                self._write_ep(t, start + j, inst, w)
            t.store.cfg["cluster_ep_start"][cid] = start
            t.store.cfg["cluster_policy"][cid] = policy
            t.log.append(("cluster_window", cid, start, len(endpoints)))
            t.store.cfg["cluster_ep_count"][cid] = len(endpoints)
            t.log.append(("cluster_count", cid, len(endpoints)))
            t.store.clusters[name] = _Dir(cid, _Window(start,
                                                       len(endpoints)))
            return cid

    def add_endpoint(self, cluster: str, instance: int,
                     weight: float = 1.0) -> int:
        """Grow ``cluster`` by one endpoint; returns its global slot.

        Bottom-up: the endpoint row lands before the cluster count exposes
        it, so a mid-step datapath never reads an unwritten row."""
        with self._auto() as t:
            d = t.store.clusters[cluster]
            count = int(t.store.cfg["cluster_ep_count"][d.id])
            if count >= MAX_EPS_PER_CLUSTER:
                raise RuntimeError(f"cluster {cluster!r} at capacity")
            if count >= d.win.cap:
                self._grow_ep_window(t, cluster)
            slot = d.win.start + count
            self._write_ep(t, slot, instance, weight)
            t.store.cfg["cluster_ep_count"][d.id] += 1
            t.log.append(("cluster_count", d.id, +1))
            return slot

    def remove_endpoint(self, cluster: str, instance: int) -> None:
        """Top-down: shrink the count first, then compact the window —
        migrating the moved endpoint's load and zeroing the vacated slot."""
        with self._auto() as t:
            self._do_remove_endpoint(t, cluster, instance)

    def drain_endpoint(self, cluster: str, instance: int,
                       reason: str = "operator") -> None:
        """Graceful removal: the weight drops to zero AND the endpoint's
        ``ep_drained`` bit raises at once — the datapath-visible draining
        mask every selection path consults (the fused admit kernel, the
        staged ``policies.select``, the sidecar ``HostRouter``), so new
        traffic stops immediately under EVERY policy, not just WEIGHTED.

        ``reason="operator"`` (default): the row survives until a later
        commit finds every attached consumer's live load for it at zero,
        then the reaper removes it.  ``reason="health"``: a circuit-breaker
        ejection — temporary, never reaped, and immune to ``set_weight``
        (only ``undrain_endpoint``, i.e. the HealthPolicy, lifts it)."""
        if reason not in ("operator", "health"):
            raise ValueError(f"unknown drain reason {reason!r}")
        with self._auto() as t:
            slot = self._find_slot(t.store, cluster, instance)
            if slot < 0:
                raise KeyError(f"no endpoint {instance} in {cluster!r}")
            t.store.cfg["ep_weight"][slot] = 0.0
            t.store.cfg["ep_drained"][slot] = 1
            t.store.draining[(cluster, instance)] = reason
            t.log.append(("drain", t.store.clusters[cluster].id, instance,
                          reason))

    def undrain_endpoint(self, cluster: str, instance: int,
                         weight: float = 1.0) -> None:
        """Lift a pending drain (any reason) and restore the endpoint to
        service at ``weight`` — the HealthPolicy's half-open re-admission
        path (a small probe weight) and full recovery path (the saved
        weight)."""
        with self._auto() as t:
            slot = self._find_slot(t.store, cluster, instance)
            if slot < 0:
                raise KeyError(f"no endpoint {instance} in {cluster!r}")
            t.store.cfg["ep_weight"][slot] = weight
            t.store.cfg["ep_drained"][slot] = 0
            t.store.draining.pop((cluster, instance), None)
            t.log.append(("undrain", t.store.clusters[cluster].id, instance))

    def set_weight(self, cluster: str, instance: int,
                   weight: float) -> None:
        """Set an endpoint's weight — and cancel a pending *operator* drain
        on it (an operator re-weighting a draining endpoint is changing
        their mind; the reaper must not remove it later).  A *health* drain
        is NOT cancelled: an operator weight change must never silently
        un-eject a sick endpoint — the weight is staged for when the
        breaker closes, but the drained mask stays up."""
        with self._auto() as t:
            slot = self._find_slot(t.store, cluster, instance)
            if slot < 0:
                raise KeyError(f"no endpoint {instance} in {cluster!r}")
            t.store.cfg["ep_weight"][slot] = weight
            if t.store.draining.get((cluster, instance)) != "health":
                t.store.cfg["ep_drained"][slot] = 0  # drain cancelled
                t.store.draining.pop((cluster, instance), None)
            t.log.append(("weight", slot))

    def set_policy(self, cluster: str, policy: int) -> None:
        with self._auto() as t:
            d = t.store.clusters[cluster]
            t.store.cfg["cluster_policy"][d.id] = policy
            t.log.append(("policy", d.id))

    def remove_cluster(self, name: str) -> None:
        """Tear a whole cluster down, top-down: the endpoint count hides
        the window first, then the rows clear, then the window extent and
        the directory id return to their free-lists for reuse.  Refuses
        while any service rule still routes to the cluster (remove or
        retarget the rules first — a dangling cluster id in ``rule_cluster``
        would silently route live traffic into another cluster's window)."""
        with self._auto() as t:
            d = t.store.clusters[name]
            cfg = t.store.cfg
            for sname, sd in t.store.services.items():
                for j in range(int(cfg["svc_rule_count"][sd.id])):
                    if int(cfg["rule_cluster"][sd.win.start + j]) == d.id:
                        raise RuntimeError(
                            f"cluster {name!r} still referenced by service "
                            f"{sname!r}; remove or retarget the rule first")
            count = int(cfg["cluster_ep_count"][d.id])
            cfg["cluster_ep_count"][d.id] = 0      # top-down: hide first
            t.log.append(("cluster_count", d.id, 0))
            for j in range(count):
                self._clear_ep(t, d.win.start + j)
            cfg["cluster_ep_start"][d.id] = 0
            cfg["cluster_policy"][d.id] = 0
            _extent_free(t.store.ep_free, d.win.start, d.win.cap)
            t.store.draining = {(c, i): r for (c, i), r
                                in t.store.draining.items() if c != name}
            del t.store.clusters[name]
            t.store.cluster_id_free.append(d.id)
            t.store.cluster_id_free.sort()
            t.log.append(("cluster_remove", d.id))

    def remove_service(self, name: str) -> None:
        """Remove a service and its whole rule chain, top-down: the chain
        count zeroes first (no request can match a rule mid-teardown), the
        rows clear, then the rule-window extent and the directory id return
        to their free-lists."""
        with self._auto() as t:
            d = t.store.services[name]
            cfg = t.store.cfg
            count = int(cfg["svc_rule_count"][d.id])
            cfg["svc_rule_count"][d.id] = 0        # top-down: hide first
            t.log.append(("svc_count", d.id, 0))
            for j in range(count):
                self._clear_rule(t, d.win.start + j)
            cfg["svc_rule_start"][d.id] = 0
            _extent_free(t.store.rule_free, d.win.start, d.win.cap)
            del t.store.services[name]
            t.store.svc_id_free.append(d.id)
            t.store.svc_id_free.sort()
            t.log.append(("service_remove", d.id))

    def upsert_rule(self, service: str, field: int, value: str | None,
                    cluster: str) -> None:
        """Replace the service's rule matching (field, value) or append a
        new one (bottom-up: row before count)."""
        with self._auto() as t:
            d = t.store.services[service]
            cfg = t.store.cfg
            vhash = WILDCARD if value is None else fnv1a(value)
            count = int(cfg["svc_rule_count"][d.id])
            for j in range(count):
                s = d.win.start + j
                if (int(cfg["rule_field"][s]) == field
                        and int(cfg["rule_value"][s]) == vhash):
                    cfg["rule_cluster"][s] = t.store.clusters[cluster].id
                    t.log.append(("rule_row", s))
                    return
            if count >= MAX_RULES_PER_SVC:
                raise RuntimeError(f"service {service!r} rule chain full")
            if count >= d.win.cap:
                self._grow_rule_window(t, service)
            self._write_rule(t, d.win.start + count, field, value, cluster)
            cfg["svc_rule_count"][d.id] += 1
            t.log.append(("svc_count", d.id, +1))

    def remove_rule(self, service: str, field: int,
                    value: str | None) -> None:
        """Top-down: the chain shrinks before the row compacts."""
        with self._auto() as t:
            d = t.store.services[service]
            cfg = t.store.cfg
            vhash = WILDCARD if value is None else fnv1a(value)
            count = int(cfg["svc_rule_count"][d.id])
            for j in range(count):
                s = d.win.start + j
                if (int(cfg["rule_field"][s]) == field
                        and int(cfg["rule_value"][s]) == vhash):
                    cfg["svc_rule_count"][d.id] -= 1
                    t.log.append(("svc_count", d.id, -1))
                    last = d.win.start + count - 1
                    if s != last:
                        for k in ("rule_field", "rule_value",
                                  "rule_cluster"):
                            cfg[k][s] = cfg[k][last]
                        t.log.append(("rule_row", s))
                    self._clear_rule(t, last)
                    return
            raise KeyError(f"no rule ({field}, {value!r}) on {service!r}")

    # ------------------------------------------------------------------ #
    # staged-write primitives
    # ------------------------------------------------------------------ #
    @staticmethod
    def _find_slot(store: _Store, cluster: str, instance: int) -> int:
        d = store.clusters[cluster]
        count = int(store.cfg["cluster_ep_count"][d.id])
        for j in range(count):
            if int(store.cfg["ep_instance"][d.win.start + j]) == instance:
                return d.win.start + j
        return -1

    def _write_ep(self, t: _Txn, slot: int, instance: int,
                  weight: float) -> None:
        t.store.cfg["ep_instance"][slot] = instance
        t.store.cfg["ep_weight"][slot] = weight
        t.store.cfg["ep_drained"][slot] = 0
        t.src[slot] = -1                       # fresh row: load starts at 0
        t.log.append(("ep_row", slot, instance))

    def _clear_ep(self, t: _Txn, slot: int) -> None:
        t.store.cfg["ep_instance"][slot] = -1
        t.store.cfg["ep_weight"][slot] = 1.0
        t.store.cfg["ep_drained"][slot] = 0
        t.src[slot] = -1                       # vacated: counter zeroed
        t.log.append(("ep_clear", slot))

    def _move_ep(self, t: _Txn, dst: int, src: int) -> None:
        """Relocate one endpoint row — including its draining mask — and
        its *live load* (via the plan permutation)."""
        cfg = t.store.cfg
        cfg["ep_instance"][dst] = cfg["ep_instance"][src]
        cfg["ep_weight"][dst] = cfg["ep_weight"][src]
        cfg["ep_drained"][dst] = cfg["ep_drained"][src]
        t.src[dst] = t.src[src]
        t.log.append(("ep_row", dst, int(cfg["ep_instance"][dst])))

    def _write_rule(self, t: _Txn, slot: int, field: int,
                    value: str | None, cluster: str) -> None:
        cfg = t.store.cfg
        cfg["rule_field"][slot] = field
        cfg["rule_value"][slot] = (WILDCARD if value is None
                                   else fnv1a(value))
        cfg["rule_cluster"][slot] = t.store.clusters[cluster].id
        t.log.append(("rule_row", slot))

    def _clear_rule(self, t: _Txn, slot: int) -> None:
        cfg = t.store.cfg
        cfg["rule_field"][slot] = 0
        cfg["rule_value"][slot] = WILDCARD
        cfg["rule_cluster"][slot] = -1
        t.log.append(("rule_clear", slot))

    def _do_remove_endpoint(self, t: _Txn, cluster: str,
                            instance: int) -> None:
        slot = self._find_slot(t.store, cluster, instance)
        if slot < 0:
            raise KeyError(f"no endpoint {instance} in {cluster!r}")
        d = t.store.clusters[cluster]
        count = int(t.store.cfg["cluster_ep_count"][d.id])
        t.store.cfg["cluster_ep_count"][d.id] -= 1    # top-down: count first
        t.log.append(("cluster_count", d.id, -1))
        last = d.win.start + count - 1
        if slot != last:
            self._move_ep(t, slot, last)       # swap-with-last + load migrate
        self._clear_ep(t, last)                # vacated slot zeroed
        t.store.draining.pop((cluster, instance), None)

    def _grow_ep_window(self, t: _Txn, cluster: str) -> None:
        """Relocate a full cluster window to a larger extent (bottom-up:
        the new rows are fully written before the start pointer swings)."""
        d = t.store.clusters[cluster]
        count = int(t.store.cfg["cluster_ep_count"][d.id])
        new_cap = min(MAX_EPS_PER_CLUSTER, max(2 * d.win.cap, 2))
        new_start = _extent_alloc(t.store.ep_free, new_cap)
        for j in range(count):
            self._move_ep(t, new_start + j, d.win.start + j)
        t.store.cfg["cluster_ep_start"][d.id] = new_start
        t.log.append(("cluster_window", d.id, new_start, new_cap))
        old = d.win
        for j in range(count):
            self._clear_ep(t, old.start + j)
        _extent_free(t.store.ep_free, old.start, old.cap)
        d.win = _Window(new_start, new_cap)

    def _grow_rule_window(self, t: _Txn, service: str) -> None:
        d = t.store.services[service]
        cfg = t.store.cfg
        count = int(cfg["svc_rule_count"][d.id])
        new_cap = min(MAX_RULES_PER_SVC, max(2 * d.win.cap, 2))
        new_start = _extent_alloc(t.store.rule_free, new_cap)
        for j in range(count):
            for k in ("rule_field", "rule_value", "rule_cluster"):
                cfg[k][new_start + j] = cfg[k][d.win.start + j]
            t.log.append(("rule_row", new_start + j))
        cfg["svc_rule_start"][d.id] = new_start
        t.log.append(("svc_window", d.id, new_start, new_cap))
        old = d.win
        for j in range(count):
            self._clear_rule(t, old.start + j)
        _extent_free(t.store.rule_free, old.start, old.cap)
        d.win = _Window(new_start, new_cap)
