"""Nested-map routing state — the eBPF map-in-map hierarchy (paper §4.2).

Envoy's configuration tree (listener → filter → route → cluster → endpoint)
is flattened into capacity-bounded, fixed-shape int32/float32 arrays with
index references instead of pointers — exactly the transformation the paper
performs for the eBPF verifier, which maps 1:1 onto XLA's static-shape
constraint (DESIGN.md §2).  The whole state is a pytree of device arrays that
is passed as an *argument* to the compiled datapath, so control-plane updates
(delta refresh, core/delta.py) never trigger recompilation.

Capacity bounds mirror the paper's 10K-entry map cap.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

# Capacity bounds (the paper's FILTER_MAX_NUM / ROUTE_MAX_NUM / map capacity).
MAX_SERVICES = 64          # listeners (virtual IPs)
MAX_RULES = 256            # route rules, globally
MAX_RULES_PER_SVC = 16     # bounded rule-chain walk per request
MAX_CLUSTERS = 64          # destination clusters
MAX_ENDPOINTS = 512        # backend instances, globally
MAX_EPS_PER_CLUSTER = 64   # bounded LB scan per cluster
N_FEATURES = 8             # hashed L7 header fields per request

# LB policies (paper §4.1: round-robin, random, least request; + weighted,
# Maglev consistent-hash and session affinity).  The enum lives in ONE
# place — core/policy_defs.py, the policy-dispatch registry (DESIGN.md §9) —
# and is re-exported here so the kernels, the oracle and the staged chain
# all resolve the same constants.
from repro.core.policy_defs import (AFFINITY_SLOTS, MAGLEV_TABLE_SIZE,  # noqa: E402,F401
                                    POLICY_AFFINITY, POLICY_LEAST_REQUEST,
                                    POLICY_MAGLEV, POLICY_NAMES,
                                    POLICY_RANDOM, POLICY_RR,
                                    POLICY_WEIGHTED, build_maglev_table)

NO_ROUTE = jnp.int32(-1)
WILDCARD = -1


class RoutingState(NamedTuple):
    """All tables the in-graph datapath reads (+ the counters it writes)."""

    # --- listener / service level -------------------------------------- #
    svc_rule_start: jax.Array    # (MAX_SERVICES,) i32 → index into rule_*
    svc_rule_count: jax.Array    # (MAX_SERVICES,) i32
    # --- route rules (content match) ----------------------------------- #
    rule_field: jax.Array        # (MAX_RULES,) i32 feature column to inspect
    rule_value: jax.Array        # (MAX_RULES,) i32 expected hash; -1 wildcard
    rule_cluster: jax.Array      # (MAX_RULES,) i32 destination cluster
    # --- clusters -------------------------------------------------------#
    cluster_ep_start: jax.Array  # (MAX_CLUSTERS,) i32 → index into ep_*
    cluster_ep_count: jax.Array  # (MAX_CLUSTERS,) i32
    cluster_policy: jax.Array    # (MAX_CLUSTERS,) i32 POLICY_*
    # --- endpoints ------------------------------------------------------#
    ep_instance: jax.Array       # (MAX_ENDPOINTS,) i32 instance-lane id
    ep_weight: jax.Array         # (MAX_ENDPOINTS,) f32
    ep_drained: jax.Array        # (MAX_ENDPOINTS,) i32 1 = draining: no new
    #                              traffic under ANY policy (control-authored;
    #                              the datapath only reads it)
    maglev_table: jax.Array      # (MAX_CLUSTERS, MAGLEV_TABLE_SIZE) i32
    #                              per-cluster Maglev permutation rows of
    #                              WINDOW OFFSETS (-1 = empty); built and
    #                              incrementally rebuilt by the control
    #                              plane (core/policy_defs.py)
    # --- mutable datapath state (load-balancing states, paper §4.2) ----- #
    ep_load: jax.Array           # (MAX_ENDPOINTS,) i32 outstanding requests
    ep_inflight_ewma: jax.Array  # (MAX_ENDPOINTS,) f32 EWMA of requests in
    #                              flight (ticks-in-flight mass; the latency
    #                              numerator under Little's law — DESIGN §8)
    ep_tput_ewma: jax.Array      # (MAX_ENDPOINTS,) f32 EWMA of completions
    #                              per step (the latency denominator)
    rr_cursor: jax.Array         # (MAX_CLUSTERS,) i32 round-robin cursor
    aff_key: jax.Array           # (AFFINITY_SLOTS,) i32 session-affinity
    #                              cache: flow id per direct-mapped slot
    #                              (-1 = empty); written by the admit
    #                              kernel, invalidated by drain/remove
    #                              through the control plane's remap path
    aff_ep: jax.Array            # (AFFINITY_SLOTS,) i32 cached absolute
    #                              endpoint per slot (-1 = empty)
    version: jax.Array           # () i32, bumped by every delta refresh


class FlowMetrics(NamedTuple):
    """Per-service traffic metrics (paper §4.2 third state type).

    ``overflow`` counts **hold events, one per admission attempt** — the
    datapath has no memory of a request across batches, so a request the
    host re-queues and re-admits k times before it lands contributes k
    (bounded by the host's retry cap, 64 in ``ServeLoop``).  Distinct
    held *requests* are a host-side notion: ``ServeLoop.held_first``
    counts each re-queued request exactly once."""

    tx_bytes: jax.Array          # (MAX_SERVICES,) i32
    rx_bytes: jax.Array          # (MAX_SERVICES,) i32
    requests: jax.Array          # (MAX_SERVICES,) i32
    no_route_match: jax.Array    # () i32
    overflow: jax.Array          # () i32  hold events (per ATTEMPT — see
    #                              class docstring; not distinct requests)

    @staticmethod
    def zeros() -> "FlowMetrics":
        z = jnp.zeros((), jnp.int32)
        return FlowMetrics(jnp.zeros((MAX_SERVICES,), jnp.int32),
                           jnp.zeros((MAX_SERVICES,), jnp.int32),
                           jnp.zeros((MAX_SERVICES,), jnp.int32), z, z)


def empty_state() -> RoutingState:
    i = lambda n: jnp.zeros((n,), jnp.int32)
    return RoutingState(
        svc_rule_start=i(MAX_SERVICES), svc_rule_count=i(MAX_SERVICES),
        rule_field=i(MAX_RULES),
        rule_value=jnp.full((MAX_RULES,), WILDCARD, jnp.int32),
        rule_cluster=jnp.full((MAX_RULES,), -1, jnp.int32),
        cluster_ep_start=i(MAX_CLUSTERS), cluster_ep_count=i(MAX_CLUSTERS),
        cluster_policy=i(MAX_CLUSTERS),
        ep_instance=jnp.full((MAX_ENDPOINTS,), -1, jnp.int32),
        ep_weight=jnp.ones((MAX_ENDPOINTS,), jnp.float32),
        ep_drained=i(MAX_ENDPOINTS),
        maglev_table=jnp.full((MAX_CLUSTERS, MAGLEV_TABLE_SIZE), -1,
                              jnp.int32),
        ep_load=i(MAX_ENDPOINTS),
        ep_inflight_ewma=jnp.zeros((MAX_ENDPOINTS,), jnp.float32),
        ep_tput_ewma=jnp.zeros((MAX_ENDPOINTS,), jnp.float32),
        rr_cursor=i(MAX_CLUSTERS),
        aff_key=jnp.full((AFFINITY_SLOTS,), -1, jnp.int32),
        aff_ep=jnp.full((AFFINITY_SLOTS,), -1, jnp.int32),
        version=jnp.zeros((), jnp.int32),
    )


# --------------------------------------------------------------------------- #
# Host-side (control plane) builder — mirrors the Go daemon that converts
# protobuf Envoy config into the C structs of Figure 3(b).
# --------------------------------------------------------------------------- #


def fnv1a(s: str) -> int:
    """Stable 31-bit string hash (the host-side 'protocol parse' helper)."""
    h = 0x811C9DC5
    for ch in s.encode():
        h = ((h ^ ch) * 0x01000193) & 0xFFFFFFFF
    return int(h & 0x7FFFFFFF)


@dataclasses.dataclass
class Rule:
    field: int                   # feature column
    value: str | None            # None = wildcard
    cluster: str


@dataclasses.dataclass
class Cluster:
    name: str
    endpoints: list[int]         # instance-lane ids
    policy: int = POLICY_LEAST_REQUEST
    weights: list[float] | None = None


@dataclasses.dataclass
class ServiceConfig:
    name: str
    rules: list[Rule]


def build_state(services: list[ServiceConfig], clusters: list[Cluster],
                ) -> tuple[RoutingState, dict[str, int]]:
    """Compile a control-plane config tree into the flat tables.

    Returns (state, name→id maps for services and clusters).
    """
    assert len(services) <= MAX_SERVICES and len(clusters) <= MAX_CLUSTERS
    st = jax.tree.map(np.asarray, empty_state())
    st = RoutingState(*[np.array(a) for a in st])
    cluster_id = {c.name: i for i, c in enumerate(clusters)}
    svc_id = {s.name: i for i, s in enumerate(services)}

    ep_cursor = 0
    for ci, c in enumerate(clusters):
        n = len(c.endpoints)
        assert n <= MAX_EPS_PER_CLUSTER and ep_cursor + n <= MAX_ENDPOINTS
        st.cluster_ep_start[ci] = ep_cursor
        st.cluster_ep_count[ci] = n
        st.cluster_policy[ci] = c.policy
        st.ep_instance[ep_cursor:ep_cursor + n] = c.endpoints
        if c.weights is not None:
            st.ep_weight[ep_cursor:ep_cursor + n] = c.weights
        ep_cursor += n

    # per-cluster Maglev permutation rows (policy_defs owns the builder;
    # the control plane rebuilds only dirty rows on later transactions)
    st.maglev_table[...] = build_maglev_table(
        st.cluster_ep_start, st.cluster_ep_count, st.ep_instance,
        st.ep_drained)

    rule_cursor = 0
    for si, s in enumerate(services):
        assert len(s.rules) <= MAX_RULES_PER_SVC
        st.svc_rule_start[si] = rule_cursor
        st.svc_rule_count[si] = len(s.rules)
        for r in s.rules:
            st.rule_field[rule_cursor] = r.field
            st.rule_value[rule_cursor] = (WILDCARD if r.value is None
                                          else fnv1a(r.value))
            st.rule_cluster[rule_cursor] = cluster_id[r.cluster]
            rule_cursor += 1

    state = RoutingState(*[jnp.asarray(a) for a in st])
    return state, {"services": svc_id, "clusters": cluster_id}
