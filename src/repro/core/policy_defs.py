"""The policy-dispatch seam: every LB policy defined ONCE (DESIGN.md §9).

The four datapaths that select endpoints — the fused Pallas kernel
(``kernels/route_match.py``, both folds), the sequential numpy oracle
(``kernels/ref.py``), the staged jnp chain (``core/policies.py``) and the
host-side sidecar router (``core/sidecar.py``) — historically re-implemented
each policy four times with hand-kept agreement.  This module is the single
registry they all derive from: one :class:`PolicyDef` per policy carries

  * the enum value and CLI name (``serve.py --policy``),
  * state descriptors — which ``RoutingState`` fields the policy reads and
    writes in the datapath,
  * the per-policy shard **merge rule** consumed by the mesh-sharded
    admission (``kernels/shard_admit.py``): ``"cursor"`` (rr/random advance a
    per-cluster arrival counter → count-offset carry-in), ``"waterfill"``
    (least-request needs the closed-form load carry-in), ``"none"`` (hash /
    affinity selection is independent of carried load+cursor state — the
    embarrassingly shard-parallel case),
  * four lowering hooks: ``kernel_offset`` (one body serving BOTH the
    segment and onehot folds of the Pallas kernel), ``oracle_pick`` (the
    sequential per-request numpy reference), ``staged_offset`` (batched
    jnp) and ``host_pick`` (per-request numpy in the sidecar baselines).

Adding a policy is one ``PolicyDef`` in ``REGISTRY`` — every datapath,
including the sharded reconciliation, picks it up from here.

The hook contracts hand each hook a small namespace ("ctx") built by the
calling datapath; the fields are documented on each hook builder below.
This module deliberately imports nothing from ``repro.kernels`` (the kernels
import *it*), and not ``routing_table`` either (which re-exports the enum
from here) — it is the leaf of the dependency graph.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

# --------------------------------------------------------------------------- #
# The policy enum — THE single source of truth.  routing_table re-exports
# these; kernels/route_match.py, kernels/ref.py and core/policies.py import
# them from there (one definition site, asserted below).
# --------------------------------------------------------------------------- #
POLICY_RR = 0             # round-robin over eligible endpoints
POLICY_RANDOM = 1         # host-PRNG uniform over eligible endpoints
POLICY_LEAST_REQUEST = 2  # sequentially-consistent least outstanding
POLICY_WEIGHTED = 3       # Gumbel-max over log weights
POLICY_MAGLEV = 4         # Maglev consistent hash over the flow id
POLICY_AFFINITY = 5       # session stickiness: flow → endpoint cache,
#                           Maglev fallback on miss

#: CLI name → enum (``launch/serve.py --policy`` and benchmark knobs)
POLICY_NAMES = {
    "rr": POLICY_RR,
    "random": POLICY_RANDOM,
    "least_request": POLICY_LEAST_REQUEST,
    "weighted": POLICY_WEIGHTED,
    "maglev": POLICY_MAGLEV,
    "affinity": POLICY_AFFINITY,
}

#: Maglev permutation-table width per cluster.  Prime (every skip is
#: coprime → each endpoint's probe sequence is a full permutation) and
#: ~8× MAX_EPS_PER_CLUSTER so per-endpoint shares stay within ~±1 slot.
MAGLEV_TABLE_SIZE = 521

#: Direct-mapped session-affinity cache slots (flow_hash % slots).
AFFINITY_SLOTS = 512

#: Sentinel load for ineligible lanes — a python literal so Pallas kernels
#: can close over it (a jnp scalar would be verifier-rejected).
BIG = 2**30


# --------------------------------------------------------------------------- #
# Flow identity — one hash, every datapath.
# --------------------------------------------------------------------------- #


def flow_hash(features):
    """31-bit FNV-style flow id over the request's feature columns.

    Works on numpy AND jnp arrays (``(..., F)`` int32 → ``(...,)`` int32,
    always ≥ 0): integer math in uint32 wraps identically in both, so the
    kernel wrapper, the staged chain, the oracle and the host router all
    derive the same key from the same features.
    """
    if isinstance(features, np.ndarray):
        f = features.astype(np.uint32)
        h = np.full(f.shape[:-1], 0x811C9DC5, np.uint32)
        with np.errstate(over="ignore"):     # uint32 wraparound is the hash
            for j in range(f.shape[-1]):
                h = (h ^ f[..., j]) * np.uint32(0x01000193)
        return (h & np.uint32(0x7FFFFFFF)).astype(np.int32)
    import jax.numpy as jnp
    f = features.astype(jnp.uint32)
    h = jnp.full(f.shape[:-1], 0x811C9DC5, jnp.uint32)
    for j in range(f.shape[-1]):
        h = (h ^ f[..., j]) * jnp.uint32(0x01000193)
    return (h & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)


# --------------------------------------------------------------------------- #
# Maglev table construction (host side, numpy — the control plane's job).
# --------------------------------------------------------------------------- #


def _mix(x: int, salt: int) -> int:
    """Deterministic 32-bit scramble of an endpoint identity."""
    h = (int(x) ^ salt) & 0xFFFFFFFF
    h = (h * 0x01000193 + 0x811C9DC5) & 0xFFFFFFFF
    h ^= h >> 13
    h = (h * 0x5BD1E995) & 0xFFFFFFFF
    h ^= h >> 15
    return h


def _maglev_row(offsets: list[int], ids: list[int], T: int) -> np.ndarray:
    """One cluster's Maglev lookup row (T,) of WINDOW OFFSETS, -1 = empty.

    Canonical Maglev: endpoint k probes slots ``(offset_k + j·skip_k) % T``
    and claims the next untaken one, round-robin across endpoints, until the
    table is full — each endpoint owns ~T/E slots (max−min ≤ ~1).  Probe
    sequences are keyed on the endpoint's *identity hash* (``ids``, the
    instance-lane id), NOT its window position, so membership changes —
    swap-with-last compaction, window relocation, drain of a neighbour —
    leave surviving endpoints' claims nearly untouched (~1/E of slots remap
    per add/drain, the consistent-hash property the tests pin).
    """
    row = np.full((T,), -1, np.int32)
    if not offsets:
        return row
    E = len(offsets)
    offset = [_mix(i, 0x9E3779B9) % T for i in ids]
    skip = [_mix(i, 0x85EBCA6B) % (T - 1) + 1 for i in ids]
    ptr = [0] * E
    filled = 0
    while filled < T:
        for k in range(E):
            while True:
                c = (offset[k] + ptr[k] * skip[k]) % T
                ptr[k] += 1
                if row[c] < 0:
                    row[c] = offsets[k]
                    filled += 1
                    break
            if filled == T:
                break
    return row


def build_maglev_table(ep_start, ep_count, ep_instance, ep_drained,
                       table_size: int = MAGLEV_TABLE_SIZE) -> np.ndarray:
    """(CL, T) i32 Maglev table over every cluster's ELIGIBLE (non-drained)
    endpoints; rows of empty / fully-drained clusters stay -1 (the kernel
    then reports NO_ROUTE via the eligibility count, never a stale entry)."""
    cs = np.asarray(ep_start, np.int64)
    cc = np.asarray(ep_count, np.int64)
    inst = np.asarray(ep_instance, np.int64)
    dr = np.asarray(ep_drained, np.int64)
    CL = cs.shape[0]
    tab = np.full((CL, table_size), -1, np.int32)
    for c in range(CL):
        n = int(cc[c])
        if n <= 0:
            continue
        s = int(cs[c])
        offs = [j for j in range(n) if dr[s + j] == 0]
        ids = [int(inst[s + j]) for j in offs]
        tab[c] = _maglev_row(offs, ids, table_size)
    return tab


def maglev_row_inputs(cfg: dict, c: int) -> tuple:
    """The exact inputs one cluster's table row depends on — the control
    plane diffs this across a transaction to rebuild only dirty rows."""
    s = int(cfg["cluster_ep_start"][c])
    n = int(cfg["cluster_ep_count"][c])
    return (n, tuple(np.asarray(cfg["ep_instance"][s:s + n]).tolist()),
            tuple(np.asarray(cfg["ep_drained"][s:s + n]).tolist()))


# --------------------------------------------------------------------------- #
# Lowering hooks.  Each hook receives a ctx namespace built by its datapath:
#
# kernel ctx (route_match._admit_kernel, BOTH folds; (BR,) unless noted):
#   fold, block_r      static fold name / tile rows
#   policy, cl         per-request policy enum / clamped cluster id
#   routable, rank_c   eligibility mask / in-tile arrival rank within cluster
#   estart, count      cluster window start / raw window count
#   cnt1, cnt2         eligible-endpoint count (≥1 clamped / raw)
#   eidx, eok          (BR, WE) window endpoint indices / eligibility mask
#   rnd, fkey          host PRNG draw / flow id
#   gum                (BR, WE) Gumbel noise
#   loads, ew, ed      (E,) live loads / weights / drain mask
#   cs_vec, cc_vec     (CL,) cluster windows (for per-cluster fold tables)
#   cur_cl             per-request live rr cursor (cur_s[cl])
#   mg_tab             (CL, T) Maglev table
#   aff_key, aff_ep    (A,) affinity cache (tile-start snapshot)
#   kth(k)             window offset of the k-th eligible endpoint
#   cyc(k)             kth(k) with the segment fold's no-drain shortcut
#   seg_rank(ids, mask, n)  the fold-seam rank helper
#
# oracle ctx (ref.admit_ref; numpy, mutated in place by the loop):
#   loads, cur         (E,)/(CL,) live counters
#   cs, cc, E          cluster windows / endpoint capacity
#   drained            (E,) drain mask
#   rnd, fkey, wt_off  per-request draws / flow ids / precomputed
#                      weighted offsets
#   mg, T              (CL, T) Maglev table / its width
#   affk, affe, A      affinity cache arrays (hooks may write) / slots
#
# staged ctx (policies.select; jnp, batched):
#   state              RoutingState
#   cl, start, count   clamped cluster / window start / raw count
#   cnt1, ok, idx      eligible count (≥1) / (B, WE) masks / indices
#   rank               arrival rank within cluster
#   rnd, fkey, gum     PRNG draws / flow ids / Gumbel noise
#   kth(k)             k-th eligible offset
#
# host ctx (sidecar.HostRouter; one request at a time, numpy):
#   t                  the router's mutable numpy RoutingState copy
#   rng                the router's PRNG
#   E                  endpoint capacity
# Hooks return WINDOW OFFSETS (kernel/staged) or ABSOLUTE endpoint indices
# (oracle/host).
# --------------------------------------------------------------------------- #

import types  # noqa: E402

import jax.numpy as jnp  # noqa: E402  (hooks below are jnp-lowered)
import jax  # noqa: E402


class KernelCtx(types.SimpleNamespace):
    """The kernel-hook ctx (field contract in the comment above) — a plain
    namespace the Pallas kernel fills with traced arrays + fold helpers."""


class StagedCtx(types.SimpleNamespace):
    """The staged-hook ctx (``core/policies.py`` fills it per batch)."""


class OracleCtx(types.SimpleNamespace):
    """The oracle-hook ctx (``kernels/ref.py`` fills it with live numpy
    arrays; affinity hooks mutate ``affk``/``affe`` in place)."""


# ---- round robin ---------------------------------------------------------- #

def _rr_kernel(ctx):
    return ctx.cyc((ctx.cur_cl + ctx.rank_c) % ctx.cnt1)


def _rr_oracle(o, r, c, elig):
    return elig[o.cur[c] % len(elig)]


def _rr_staged(s):
    return s.kth((s.state.rr_cursor[s.cl] + s.rank) % s.cnt1)


def _rr_host(h, c, elig, feats):
    ep = elig[h.t.rr_cursor[c] % len(elig)]
    h.t.rr_cursor[c] += 1
    return ep


# ---- random --------------------------------------------------------------- #

def _random_kernel(ctx):
    return ctx.cyc(ctx.rnd % ctx.cnt1)


def _random_oracle(o, r, c, elig):
    return elig[o.rnd[r] % len(elig)]


def _random_staged(s):
    return s.kth(s.rnd % s.cnt1)


def _random_host(h, c, elig, feats):
    return elig[h.rng.randint(0, len(elig))]


# ---- least request -------------------------------------------------------- #

def _lr_kernel(ctx):
    """Sequential least-request without a per-request scan: the request with
    in-tile cluster rank ρ owns the ρ-th smallest ticket of the multiset
    {load_j + t : t ≥ 0} ordered by (value, j) — water-filling closed form
    of "argmin then increment".  The segment fold reads the level from
    per-cluster sorted-prefix tables (one (CL, WE) sort per tile); the
    onehot fold finds it by a static-depth binary search (Mosaic-friendly,
    no sort)."""
    eok, eidx, rank_c = ctx.eok, ctx.eidx, ctx.rank_c
    load = jnp.where(eok, ctx.loads[eidx], BIG)            # (BR, WE)

    def pick(v, n_prev):
        m = rank_c - n_prev                # rank among value-v ties
        elig = load <= v[:, None]
        ec = jnp.cumsum(elig.astype(jnp.int32), axis=1)
        return jnp.argmax(elig & (ec == (m + 1)[:, None]),
                          axis=1).astype(jnp.int32)

    if ctx.fold == "segment":
        # per-CLUSTER water-fill tables: every request of a cluster shares
        # the same tile-start load multiset, so the ticket geometry —
        # sorted eligible loads ``cls_``, inclusive prefix ``cpin``,
        # segment starts ``cS`` (tickets below level ls[k]) — is computed
        # once per cluster on (CL, WE) arrays (tiny) and each request only
        # gathers scalars from it: k* engaged endpoints where
        # cS[k*] ≤ ρ < cS[k*+1], then v = ⌈(ρ+1+Σ_{i<k*} l_i)/k*⌉ − 1.
        # BIG lanes clamp to lo+BR so they never engage (and the prefix
        # sums stay far from int32 range for sane load counters ≥ 0).
        CL = ctx.cs_vec.shape[0]
        WE = eidx.shape[1]
        E = ctx.loads.shape[0]
        cwin = jax.lax.broadcasted_iota(jnp.int32, (CL, WE), 1)
        ceidx = jnp.clip(ctx.cs_vec[:, None] + cwin, 0, E - 1)
        ceok = (cwin < ctx.cc_vec[:, None]) & (ctx.ed[ceidx] == 0)
        cload = jnp.where(ceok, ctx.loads[ceidx], BIG)
        clo = jnp.min(cload, axis=1)
        cls_ = jnp.sort(jnp.minimum(cload, clo[:, None] + ctx.block_r),
                        axis=1)
        cpin = jnp.cumsum(cls_, axis=1)                # inclusive prefix
        cS = (cwin + 1) * cls_ - cpin                  # nondecreasing
        kstar = jnp.sum((cS[ctx.cl] <= rank_c[:, None]).astype(jnp.int32),
                        axis=1)                        # ≥ 1 (cS[0] == 0)
        pk = cpin.reshape(-1)[ctx.cl * WE + kstar - 1]  # Σ engaged loads
        v = (rank_c + pk + kstar) // kstar - 1
        return pick(v, kstar * v - pk)
    # onehot: static-depth binary search for the ticket level
    lo = jnp.min(load, axis=1)
    hi = lo + rank_c
    tgt = rank_c + 1
    for _ in range(max(ctx.block_r, 2).bit_length()):
        mid = (lo + hi) // 2
        n_mid = jnp.sum(jnp.maximum(mid[:, None] - load + 1, 0), axis=1)
        ge = n_mid >= tgt
        hi = jnp.where(ge, mid, hi)
        lo = jnp.where(ge, lo, mid + 1)
    v = lo
    return pick(v, jnp.sum(jnp.maximum(v[:, None] - load, 0), axis=1))


def _lr_oracle(o, r, c, elig):
    return elig[int(np.argmin([o.loads[e] for e in elig]))]


def _lr_staged(s):
    # vectorised batch semantics: the r-th request (arrival order) of a
    # cluster takes the r-th LEAST-loaded endpoint, emulating sequential
    # per-request counters; ineligible endpoints sort behind INT_MAX
    load = jnp.where(s.ok, s.state.ep_load[s.idx],
                     jnp.iinfo(jnp.int32).max)
    by_load = jnp.argsort(load, axis=1).astype(jnp.int32)
    return jnp.take_along_axis(by_load, (s.rank % s.cnt1)[:, None], 1)[:, 0]


def _lr_host(h, c, elig, feats):
    return elig[int(np.argmin(h.t.ep_load[elig]))]


# ---- weighted ------------------------------------------------------------- #

def _wt_kernel(ctx):
    w = jnp.where(ctx.eok, ctx.ew[ctx.eidx], 0.0)
    return jnp.argmax(jnp.where(ctx.eok, jnp.log(w + 1e-9) + ctx.gum,
                                -jnp.inf), axis=1).astype(jnp.int32)


def _wt_oracle(o, r, c, elig):
    # precomputed via jnp so f32 rounding / argmax tie-breaks match the
    # kernel bit-exactly (see ref.admit_ref)
    return min(max(o.cs[c] + o.wt_off[r], 0), o.E - 1)


def _wt_staged(s):
    w = jnp.where(s.ok, s.state.ep_weight[s.idx], 0.0)
    return jnp.argmax(jnp.where(s.ok, jnp.log(w + 1e-9) + s.gum, -jnp.inf),
                      axis=1).astype(jnp.int32)


def _wt_host(h, c, elig, feats):
    w = np.maximum(h.t.ep_weight[elig], 0.0)
    tot = float(w.sum())
    if tot <= 0.0:
        return elig[h.rng.randint(0, len(elig))]
    return elig[h.rng.choice(len(elig), p=w / tot)]


# ---- maglev consistent hash ----------------------------------------------- #
# Selection rule (identical in all four datapaths): look the flow id up in
# the cluster's permutation row → a window offset.  The entry is trusted
# only if it is inside the window AND its endpoint is not drained (the
# drain mask gates BEFORE the table result is used — a mid-serve drain the
# table has not been rebuilt for can never route onto a drained endpoint);
# otherwise fall back to hash-cycling over the k-th eligible endpoint.
# A cluster with zero eligible endpoints is unroutable upstream (cnt2 == 0
# → NO_ROUTE), exactly like the other policies.

def _maglev_kernel(ctx):
    T = ctx.mg_tab.shape[1]
    t = ctx.mg_tab[ctx.cl, ctx.fkey % T]                   # window offsets
    te = jnp.clip(ctx.estart + t, 0, ctx.ed.shape[0] - 1)
    t_ok = (t >= 0) & (t < ctx.count) & (ctx.ed[te] == 0)
    return jnp.where(t_ok, t, ctx.cyc(ctx.fkey % ctx.cnt1)
                     ).astype(jnp.int32)


def _maglev_oracle(o, r, c, elig):
    key = int(o.fkey[r])
    t = int(o.mg[c, key % o.T])
    if 0 <= t < o.cc[c]:
        e = min(max(o.cs[c] + t, 0), o.E - 1)
        if o.drained[e] == 0:
            return e
    return elig[key % len(elig)]


def _maglev_staged(s):
    T = s.state.maglev_table.shape[1]
    t = s.state.maglev_table[s.cl, s.fkey % T]
    te = jnp.clip(s.start + t, 0, s.state.ep_drained.shape[0] - 1)
    t_ok = (t >= 0) & (t < s.count) & (s.state.ep_drained[te] == 0)
    return jnp.where(t_ok, t, s.kth(s.fkey % s.cnt1)).astype(jnp.int32)


# ---- session affinity ----------------------------------------------------- #
# Snapshot-pure semantics (the property that makes tile-carried, batched
# and sharded evaluation bit-identical to the sequential oracle): a HIT
# requires stored_key == flow id AND the cached endpoint inside the
# request's cluster window AND not drained; a MISS falls back to the pure
# stateless Maglev pick (a function of the flow id and static tables only);
# the cache is written only when the slot is empty or already owns this
# key — never evicting another flow.  Because the fallback is pure, a
# request that reads a stale snapshot routes identically to one that saw
# the write, and at most one distinct value is ever written per slot per
# batch (first writer in arrival order wins).

def _aff_hit(ctx):
    A = ctx.aff_key.shape[0]
    s = ctx.fkey % A
    ak = ctx.aff_key[s]
    ae = ctx.aff_ep[s]
    aec = jnp.clip(ae, 0, ctx.ed.shape[0] - 1)
    hit = ((ak == ctx.fkey) & (ae >= ctx.estart)
           & (ae < ctx.estart + ctx.count) & (ctx.ed[aec] == 0))
    return s, ak, ae, hit


def _affinity_kernel(ctx):
    _, _, ae, hit = _aff_hit(ctx)
    return jnp.where(hit, ae - ctx.estart,
                     _maglev_kernel(ctx)).astype(jnp.int32)


def affinity_kernel_update(ctx, ep):
    """Fold this tile's affinity writes into the carried cache (both folds).

    ``ep`` is the post-selection absolute endpoint per request.  First
    writer per slot (in-tile arrival order) wins — `.at[].set` gives no
    ordering guarantee under duplicate indices, so winners are picked by
    the fold-seam rank first.  Returns (new_aff_key, new_aff_ep)."""
    A = ctx.aff_key.shape[0]
    s, ak, _, hit = _aff_hit(ctx)
    want = (ctx.routable & (ctx.policy == POLICY_AFFINITY) & ~hit
            & ((ak == -1) | (ak == ctx.fkey)))
    rank_w, _ = ctx.seg_rank(jnp.where(want, s, A), want, A)
    win = want & (rank_w == 0)
    if ctx.fold == "segment":
        tgt = jnp.where(win, s, A)
        nk = ctx.aff_key.at[tgt].set(ctx.fkey, mode="drop")
        ne = ctx.aff_ep.at[tgt].set(ep, mode="drop")
        return nk, ne
    oh = (win[:, None] & (s[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (win.shape[0], A), 1))).astype(jnp.int32)
    wrote = jnp.sum(oh, axis=0) > 0
    nk = jnp.where(wrote, jnp.sum(oh * ctx.fkey[:, None], axis=0),
                   ctx.aff_key)
    ne = jnp.where(wrote, jnp.sum(oh * ep[:, None], axis=0), ctx.aff_ep)
    return nk, ne


def _affinity_oracle(o, r, c, elig):
    key = int(o.fkey[r])
    s = key % o.A
    ae = int(o.affe[s])
    if (int(o.affk[s]) == key and o.cs[c] <= ae < o.cs[c] + o.cc[c]
            and o.drained[ae] == 0):
        return ae
    ep = _maglev_oracle(o, r, c, elig)
    if o.affk[s] == -1 or o.affk[s] == key:     # first admit writes through
        o.affk[s] = key
        o.affe[s] = ep
    return ep


def _affinity_staged(s):
    A = s.state.aff_key.shape[0]
    sl = s.fkey % A
    ak = s.state.aff_key[sl]
    ae = s.state.aff_ep[sl]
    aec = jnp.clip(ae, 0, s.state.ep_drained.shape[0] - 1)
    hit = ((ak == s.fkey) & (ae >= s.start) & (ae < s.start + s.count)
           & (s.state.ep_drained[aec] == 0))
    return jnp.where(hit, ae - s.start, _maglev_staged(s)).astype(jnp.int32)


def affinity_staged_update(s, ep, routable, policy):
    """Batch-snapshot cache update for the staged chain (bit-identical to
    the sequential write rule — see the purity argument above).  Returns
    (new_aff_key, new_aff_ep)."""
    from repro.core import relay
    A = s.state.aff_key.shape[0]
    sl = s.fkey % A
    ak = s.state.aff_key[sl]
    ae = s.state.aff_ep[sl]
    aec = jnp.clip(ae, 0, s.state.ep_drained.shape[0] - 1)
    hit = ((ak == s.fkey) & (ae >= s.start) & (ae < s.start + s.count)
           & (s.state.ep_drained[aec] == 0))
    want = (routable & (policy == POLICY_AFFINITY) & ~hit
            & ((ak == -1) | (ak == s.fkey)))
    rank_w, _ = relay.positions_sort(jnp.where(want, sl, A), A + 1)
    win = want & (rank_w == 0)
    tgt = jnp.where(win, sl, A)
    nk = s.state.aff_key.at[tgt].set(s.fkey, mode="drop")
    ne = s.state.aff_ep.at[tgt].set(ep, mode="drop")
    return nk, ne


class _HostOracleView:
    """Adapt a HostRouter + one request to the oracle-ctx field contract,
    so maglev/affinity are literally the oracle hooks run per request (the
    sidecar is sequential by construction — exact sharing, zero drift)."""

    def __init__(self, h):
        t = h.t
        self.loads = t.ep_load
        self.cur = t.rr_cursor
        self.cs = t.cluster_ep_start
        self.cc = t.cluster_ep_count
        self.E = t.ep_instance.shape[0]
        self.drained = t.ep_drained
        self.mg = t.maglev_table
        self.T = t.maglev_table.shape[1]
        self.affk = t.aff_key
        self.affe = t.aff_ep
        self.A = t.aff_key.shape[0]
        self.fkey = [0]              # filled per request by the host hook


def _host_view(h, feats):
    o = _HostOracleView(h)
    o.fkey = np.array([flow_hash(np.asarray(feats, np.int32))])
    return o


def _maglev_host(h, c, elig, feats):
    return _maglev_oracle(_host_view(h, feats), 0, c, elig)


def _affinity_host(h, c, elig, feats):
    return _affinity_oracle(_host_view(h, feats), 0, c, elig)


# --------------------------------------------------------------------------- #
# The registry.
# --------------------------------------------------------------------------- #


@dataclasses.dataclass(frozen=True)
class PolicyDef:
    """One LB policy, defined once for every datapath."""

    name: str
    enum: int
    state_reads: tuple[str, ...]         # RoutingState fields consulted
    state_writes: tuple[str, ...]        # RoutingState fields mutated
    shard_merge: str                     # 'cursor' | 'waterfill' | 'none'
    kernel_offset: Callable[[Any], Any]  # Pallas, both folds → window offs
    oracle_pick: Callable                # sequential numpy → absolute ep
    staged_offset: Callable[[Any], Any]  # batched jnp → window offs
    host_pick: Callable                  # sidecar numpy → absolute ep
    gate: bool = True                    # segment fold: lax.cond-skip when
    #                                      no cluster uses this policy


REGISTRY: tuple[PolicyDef, ...] = (
    PolicyDef("rr", POLICY_RR,
              ("rr_cursor",), ("rr_cursor", "ep_load"), "cursor",
              _rr_kernel, _rr_oracle, _rr_staged, _rr_host, gate=False),
    PolicyDef("random", POLICY_RANDOM,
              (), ("ep_load",), "cursor",
              _random_kernel, _random_oracle, _random_staged, _random_host),
    PolicyDef("least_request", POLICY_LEAST_REQUEST,
              ("ep_load",), ("ep_load",), "waterfill",
              _lr_kernel, _lr_oracle, _lr_staged, _lr_host),
    PolicyDef("weighted", POLICY_WEIGHTED,
              ("ep_weight",), ("ep_load",), "none",
              _wt_kernel, _wt_oracle, _wt_staged, _wt_host),
    PolicyDef("maglev", POLICY_MAGLEV,
              ("maglev_table", "ep_drained"), ("ep_load",), "none",
              _maglev_kernel, _maglev_oracle, _maglev_staged, _maglev_host),
    PolicyDef("affinity", POLICY_AFFINITY,
              ("aff_key", "aff_ep", "maglev_table", "ep_drained"),
              ("aff_key", "aff_ep", "ep_load"), "none",
              _affinity_kernel, _affinity_oracle, _affinity_staged,
              _affinity_host),
)

BY_ENUM: dict[int, PolicyDef] = {p.enum: p for p in REGISTRY}

#: enums whose shard merge rule needs the water-fill load carry-in
WATERFILL_ENUMS: tuple[int, ...] = tuple(
    p.enum for p in REGISTRY if p.shard_merge == "waterfill")

# import-time divergence guard: the registry is dense over 0..N-1, names
# are unique and agree with POLICY_NAMES — any drift between the enum
# constants above and the registry entries fails at import, not at runtime.
assert tuple(p.enum for p in REGISTRY) == tuple(range(len(REGISTRY))), \
    "policy registry enums must be dense and ordered"
assert {p.name: p.enum for p in REGISTRY} == POLICY_NAMES, \
    "POLICY_NAMES and REGISTRY disagree"
assert (POLICY_RR, POLICY_RANDOM, POLICY_LEAST_REQUEST, POLICY_WEIGHTED,
        POLICY_MAGLEV, POLICY_AFFINITY) == tuple(range(6)), \
    "policy enum constants drifted"
