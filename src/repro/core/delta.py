"""Delta refresh — incremental control-plane updates (paper §4.2).

Adds follow *bottom-up* order (endpoints → cluster → rules → service), deletes
*top-down*, so the datapath — which may be mid-step on the previous state —
never observes a dangling index.  Because RoutingState is an argument of the
compiled step (never a traced constant), these updates are plain buffer swaps:
zero recompilation, exactly the paper's "configuration updates do not disturb
the kernel data path".

All functions are pure: they return a new RoutingState with version+1.
They are jit-compatible so the control daemon can run them on-device.

This is the *raw slot-index* layer: callers must compute global slots and
window offsets themselves, and each call bumps the version.  Application
code should go through ``core/control.py::ControlPlane`` instead — named,
transactional operations that batch any number of these deltas into one
buffer swap (and own the slot arithmetic via free-list allocators).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.routing_table import (WILDCARD, RoutingState)


def _bump(state: RoutingState) -> RoutingState:
    return state._replace(version=state.version + 1)


# --------------------------------------------------------------------------- #
# Endpoint-level (lowest level first on add)
# --------------------------------------------------------------------------- #


def add_endpoint(state: RoutingState, cluster_id: int, ep_slot: int,
                 instance: int, weight: float = 1.0) -> RoutingState:
    """Insert one endpoint at global slot ``ep_slot`` then grow the cluster.

    Bottom-up: the endpoint row is written *before* the cluster's count is
    bumped, so a concurrent reader never indexes an unwritten row.
    """
    st = state._replace(
        ep_instance=state.ep_instance.at[ep_slot].set(instance, mode="drop"),
        ep_weight=state.ep_weight.at[ep_slot].set(weight, mode="drop"),
        ep_drained=state.ep_drained.at[ep_slot].set(0, mode="drop"),
        ep_load=state.ep_load.at[ep_slot].set(0, mode="drop"),
        ep_inflight_ewma=state.ep_inflight_ewma.at[ep_slot].set(0.0,
                                                               mode="drop"),
        ep_tput_ewma=state.ep_tput_ewma.at[ep_slot].set(0.0, mode="drop"),
    )
    st = st._replace(
        cluster_ep_count=st.cluster_ep_count.at[cluster_id].add(
            1, mode="drop"))
    return _bump(st)


def remove_endpoint(state: RoutingState, cluster_id: int, ep_off: int
                    ) -> RoutingState:
    """Top-down: shrink the cluster count first, then compact the window by
    swapping the last endpoint into the vacated offset.

    The vacated ``last`` slot is zeroed: the swap migrates the moved
    endpoint's in-flight load counter with it, and a later ``add_endpoint``
    reusing the slot must start from a clean row — leaving the stale
    ``ep_instance``/``ep_load`` behind let a new occupant inherit phantom
    load (and a late release corrupt it).

    Removing from an empty cluster (a raced double-remove) is a
    version-bump no-op: the count never goes negative and the swap targets
    are steered to the drop sentinel — otherwise ``last = start - 1`` and
    an unclamped ``tgt`` would corrupt a *neighbouring cluster's* slots
    (the invariant audit finding pinned by tests/test_analysis.py)."""
    E = state.ep_instance.shape[0]
    start = state.cluster_ep_start[cluster_id]
    count = state.cluster_ep_count[cluster_id]
    has = count > 0
    st = state._replace(
        cluster_ep_count=state.cluster_ep_count.at[cluster_id].add(
            -has.astype(state.cluster_ep_count.dtype), mode="drop"))
    last = jnp.where(has, start + count - 1, E)
    tgt = jnp.where(has, start + jnp.clip(ep_off, 0, count - 1), E)
    lastc = jnp.minimum(last, E - 1)           # in-bounds gather source
    st = st._replace(
        ep_instance=st.ep_instance.at[tgt].set(st.ep_instance[lastc],
                                               mode="drop"),
        ep_weight=st.ep_weight.at[tgt].set(st.ep_weight[lastc],
                                           mode="drop"),
        ep_drained=st.ep_drained.at[tgt].set(st.ep_drained[lastc],
                                             mode="drop"),
        ep_load=st.ep_load.at[tgt].set(st.ep_load[lastc], mode="drop"),
        ep_inflight_ewma=st.ep_inflight_ewma.at[tgt].set(
            st.ep_inflight_ewma[lastc], mode="drop"),
        ep_tput_ewma=st.ep_tput_ewma.at[tgt].set(st.ep_tput_ewma[lastc],
                                                 mode="drop"),
    )
    st = st._replace(
        ep_instance=st.ep_instance.at[last].set(-1, mode="drop"),
        ep_weight=st.ep_weight.at[last].set(1.0, mode="drop"),
        ep_drained=st.ep_drained.at[last].set(0, mode="drop"),
        ep_load=st.ep_load.at[last].set(0, mode="drop"),
        ep_inflight_ewma=st.ep_inflight_ewma.at[last].set(0.0, mode="drop"),
        ep_tput_ewma=st.ep_tput_ewma.at[last].set(0.0, mode="drop"),
    )
    return _bump(st)


# --------------------------------------------------------------------------- #
# Rule-level
# --------------------------------------------------------------------------- #


def add_rule(state: RoutingState, svc_id: int, rule_slot: int, field: int,
             value_hash: int, cluster_id: int) -> RoutingState:
    """Write the rule row first (bottom), then extend the service chain."""
    st = state._replace(
        rule_field=state.rule_field.at[rule_slot].set(field, mode="drop"),
        rule_value=state.rule_value.at[rule_slot].set(value_hash, mode="drop"),
        rule_cluster=state.rule_cluster.at[rule_slot].set(cluster_id,
                                                          mode="drop"),
    )
    st = st._replace(svc_rule_count=st.svc_rule_count.at[svc_id].add(
        1, mode="drop"))
    return _bump(st)


def remove_rule(state: RoutingState, svc_id: int, rule_off: int
                ) -> RoutingState:
    """Top-down: shrink the chain, then compact (swap-with-last).  The
    vacated ``last`` row resets to the empty-state defaults so a slot later
    reused by ``add_rule`` can never briefly expose a stale match.

    Empty-chain removal is a version-bump no-op (see ``remove_endpoint``:
    same neighbouring-window corruption hazard, same drop-sentinel fix)."""
    R = state.rule_field.shape[0]
    start = state.svc_rule_start[svc_id]
    count = state.svc_rule_count[svc_id]
    has = count > 0
    st = state._replace(svc_rule_count=state.svc_rule_count.at[svc_id].add(
        -has.astype(state.svc_rule_count.dtype), mode="drop"))
    last = jnp.where(has, start + count - 1, R)
    tgt = jnp.where(has, start + jnp.clip(rule_off, 0, count - 1), R)
    lastc = jnp.minimum(last, R - 1)
    st = st._replace(
        rule_field=st.rule_field.at[tgt].set(st.rule_field[lastc],
                                             mode="drop"),
        rule_value=st.rule_value.at[tgt].set(st.rule_value[lastc],
                                             mode="drop"),
        rule_cluster=st.rule_cluster.at[tgt].set(st.rule_cluster[lastc],
                                                 mode="drop"),
    )
    st = st._replace(
        rule_field=st.rule_field.at[last].set(0, mode="drop"),
        rule_value=st.rule_value.at[last].set(WILDCARD, mode="drop"),
        rule_cluster=st.rule_cluster.at[last].set(-1, mode="drop"),
    )
    return _bump(st)


def set_policy(state: RoutingState, cluster_id: int, policy: int
               ) -> RoutingState:
    return _bump(state._replace(
        cluster_policy=state.cluster_policy.at[cluster_id].set(
            policy, mode="drop")))


def set_weight(state: RoutingState, ep_slot: int, weight: float
               ) -> RoutingState:
    return _bump(state._replace(
        ep_weight=state.ep_weight.at[ep_slot].set(weight, mode="drop")))


def set_drained(state: RoutingState, ep_slot: int, drained: bool
                ) -> RoutingState:
    """Raise/clear the datapath-visible draining bit: a drained endpoint
    receives no new traffic under ANY policy (every selection path — the
    fused admit kernel, ``policies.select``, the sidecar HostRouter —
    consults the mask)."""
    return _bump(state._replace(
        ep_drained=state.ep_drained.at[ep_slot].set(int(drained),
                                                    mode="drop")))
