"""Load-balancing policies over cluster endpoints (paper §4.1/§4.2).

All policies are vectorised over the request batch and run in-graph.  The
mutable LB state (ep_load counters, rr cursors, affinity cache) lives in
RoutingState and is functionally updated — "the eBPF map handles
synchronization internally" becomes XLA's single-program-order scatter
semantics.

This module is the registry's *staged* lowering (DESIGN.md §9): the policy
definitions — round-robin, random, least-request, weighted, Maglev
consistent hash, session affinity — live once in ``core/policy_defs.py``;
``select`` builds the batch context and dispatches over the registry's
``staged_offset`` hooks, so a new policy lands here without touching this
file."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import policy_defs, relay
from repro.core.routing_table import MAX_EPS_PER_CLUSTER, RoutingState


class Selection(NamedTuple):
    endpoint: jax.Array      # (B,) global endpoint index (-1 = unroutable)
    instance: jax.Array      # (B,) instance-lane id (-1 = unroutable)


def _window(state: RoutingState, cluster):
    """Per-request endpoint window (B, MAX_EPS_PER_CLUSTER) + validity mask."""
    start = state.cluster_ep_start[cluster]                 # (B,)
    count = state.cluster_ep_count[cluster]
    win = jnp.arange(MAX_EPS_PER_CLUSTER, dtype=jnp.int32)
    idx = jnp.clip(start[:, None] + win[None, :], 0,
                   state.ep_instance.shape[0] - 1)          # (B,W)
    ok = win[None, :] < count[:, None]
    return idx, ok, count


def select(state: RoutingState, cluster: jax.Array, key: jax.Array,
           features: jax.Array | None = None
           ) -> tuple[Selection, RoutingState]:
    """Pick one endpoint per request according to each cluster's policy and
    update the LB state (load counters, rr cursors, affinity cache).

    cluster: (B,) int32, may contain NO_ROUTE (-1) → endpoint -1.
    features: (B, F) int32 request features, hashed into the flow id the
    hash-keyed policies (maglev/affinity) select on; None → flow id 0
    (callers that never route to a hashed cluster may omit it).
    """
    B = cluster.shape[0]
    n_cl = state.cluster_ep_start.shape[0]
    # clamp both ends: -1 is the documented NO_ROUTE sentinel, but an id
    # past the table must not walk the per-cluster tables out of window
    cl = jnp.clip(cluster, 0, n_cl - 1)
    idx, ok, count = _window(state, cl)
    # drained endpoints (the ControlPlane's datapath-visible draining mask)
    # are ineligible under EVERY policy; matched-but-empty clusters — zero
    # endpoints after a delta refresh, or every endpoint draining — are
    # unroutable, since the clipped window would otherwise hand out an
    # endpoint owned by a different cluster (kernel/oracle parity:
    # _admit_kernel and admit_ref implement the same eligibility rule)
    ok = ok & (state.ep_drained[idx] == 0)
    count2 = jnp.sum(ok.astype(jnp.int32), axis=1)          # eligible eps
    cnt1 = jnp.maximum(count2, 1)
    routable = (cluster >= 0) & (count2 > 0)
    policy = state.cluster_policy[cl]                       # (B,)
    kr, kw, _ = jax.random.split(key, 3)

    # offset of the k-th *eligible* endpoint in the window (== k itself when
    # nothing is draining, so the pre-mask behavior is unchanged)
    cum = jnp.cumsum(ok.astype(jnp.int32), axis=1)

    def _kth(k):
        return jnp.argmax(ok & (cum == (k + 1)[:, None]),
                          axis=1).astype(jnp.int32)

    # stable rank of this request within its cluster this batch (the relay's
    # counting sort).  Unroutable (NO_ROUTE) requests are steered to a
    # sentinel bucket the way request_map.allocate_slots steers them to
    # instance I — ranking them at max(cluster, 0) would inflate the arrival
    # ranks of genuine cluster-0 traffic and skew rr/least-request offsets
    # away from the fused kernel and the admit_ref oracle.
    rank, _ = relay.positions_sort(jnp.where(routable, cl, n_cl), n_cl + 1)
    fkey = (jnp.zeros((B,), jnp.int32) if features is None
            else policy_defs.flow_hash(features).astype(jnp.int32))

    sctx = policy_defs.StagedCtx(
        state=state, cl=cl, start=state.cluster_ep_start[cl], count=count,
        cnt1=cnt1, ok=ok, idx=idx, rank=rank,
        rnd=jax.random.randint(kr, (B,), 0, 1 << 30), fkey=fkey,
        gum=jax.random.gumbel(kw, ok.shape), kth=_kth)
    default_off = None
    conds, offs = [], []
    for p in policy_defs.REGISTRY:
        o_p = p.staged_offset(sctx).astype(jnp.int32)
        if p.enum == policy_defs.POLICY_RR:   # unknown-policy fallback
            default_off = o_p
        else:
            conds.append(policy == p.enum)
            offs.append(o_p)
    off = jnp.select(conds, offs, default_off).astype(jnp.int32)

    ep = jnp.take_along_axis(idx, off[:, None], 1)[:, 0]
    ep = jnp.where(routable, ep, -1)
    inst = jnp.where(routable, state.ep_instance[jnp.maximum(ep, 0)], -1)

    # --- state update: load++ on chosen endpoints, cursors advance, the
    # affinity cache learns first admits (first writer per slot wins) ----- #
    new_load = state.ep_load.at[jnp.maximum(ep, 0)].add(
        routable.astype(jnp.int32), mode="drop")
    per_cluster = jax.ops.segment_sum(routable.astype(jnp.int32), cl,
                                      num_segments=state.rr_cursor.shape[0])
    new_cursor = (state.rr_cursor + per_cluster) % jnp.maximum(
        state.cluster_ep_count, 1)
    nk, ne = policy_defs.affinity_staged_update(sctx, ep, routable, policy)
    state = state._replace(ep_load=new_load, rr_cursor=new_cursor,
                           aff_key=nk, aff_ep=ne)
    return Selection(ep, inst), state


def release(state: RoutingState, endpoint: jax.Array, done: jax.Array
            ) -> RoutingState:
    """Decrement load counters for finished requests (connection close)."""
    dec = jnp.where(done & (endpoint >= 0), -1, 0).astype(jnp.int32)
    return state._replace(
        ep_load=state.ep_load.at[jnp.maximum(endpoint, 0)].add(dec,
                                                               mode="drop"))
