"""Load-balancing policies over cluster endpoints (paper §4.1/§4.2).

All policies are vectorised over the request batch and run in-graph.  The
mutable LB state (ep_load counters, rr cursors) lives in RoutingState and is
functionally updated — "the eBPF map handles synchronization internally"
becomes XLA's single-program-order scatter semantics.

Policies: round-robin, random, least-request (paper) + weighted (Envoy).
``least_request`` uses Envoy's power-of-two-choices variant: O(1) per request
instead of a full scan, then falls back to a full argmin for small clusters.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import relay
from repro.core.routing_table import (MAX_EPS_PER_CLUSTER, POLICY_LEAST_REQUEST,
                                      POLICY_RANDOM, POLICY_RR, POLICY_WEIGHTED,
                                      RoutingState)


class Selection(NamedTuple):
    endpoint: jax.Array      # (B,) global endpoint index (-1 = unroutable)
    instance: jax.Array      # (B,) instance-lane id (-1 = unroutable)


def _window(state: RoutingState, cluster):
    """Per-request endpoint window (B, MAX_EPS_PER_CLUSTER) + validity mask."""
    start = state.cluster_ep_start[cluster]                 # (B,)
    count = state.cluster_ep_count[cluster]
    win = jnp.arange(MAX_EPS_PER_CLUSTER, dtype=jnp.int32)
    idx = jnp.clip(start[:, None] + win[None, :], 0,
                   state.ep_instance.shape[0] - 1)          # (B,W)
    ok = win[None, :] < count[:, None]
    return idx, ok, count


def select(state: RoutingState, cluster: jax.Array, key: jax.Array
           ) -> tuple[Selection, RoutingState]:
    """Pick one endpoint per request according to each cluster's policy and
    update the LB state (load counters + rr cursors).

    cluster: (B,) int32, may contain NO_ROUTE (-1) → endpoint -1.
    """
    B = cluster.shape[0]
    cl = jnp.maximum(cluster, 0)
    idx, ok, count = _window(state, cl)
    # drained endpoints (the ControlPlane's datapath-visible draining mask)
    # are ineligible under EVERY policy; matched-but-empty clusters — zero
    # endpoints after a delta refresh, or every endpoint draining — are
    # unroutable, since the clipped window would otherwise hand out an
    # endpoint owned by a different cluster (kernel/oracle parity:
    # _admit_kernel and admit_ref implement the same eligibility rule)
    ok = ok & (state.ep_drained[idx] == 0)
    count2 = jnp.sum(ok.astype(jnp.int32), axis=1)          # eligible eps
    cnt1 = jnp.maximum(count2, 1)
    routable = (cluster >= 0) & (count2 > 0)
    policy = state.cluster_policy[cl]                       # (B,)
    kr, kw, kp = jax.random.split(key, 3)

    # offset of the k-th *eligible* endpoint in the window (== k itself when
    # nothing is draining, so the pre-mask behavior is unchanged)
    cum = jnp.cumsum(ok.astype(jnp.int32), axis=1)

    def _kth(k):
        return jnp.argmax(ok & (cum == (k + 1)[:, None]),
                          axis=1).astype(jnp.int32)

    # --- round robin: cursor + stable rank of this request within its
    # cluster this batch (the relay's counting sort gives the rank).
    # Unroutable (NO_ROUTE) requests are steered to a sentinel bucket the
    # way request_map.allocate_slots steers them to instance I — ranking
    # them at max(cluster, 0) would inflate the arrival ranks of genuine
    # cluster-0 traffic and skew rr/least-request offsets away from the
    # fused kernel and the admit_ref oracle ------------------------------- #
    n_cl = state.cluster_ep_start.shape[0]
    rank, _ = relay.positions_sort(jnp.where(routable, cl, n_cl), n_cl + 1)
    rr_off = _kth((state.rr_cursor[cl] + rank) % cnt1)

    # --- random ----------------------------------------------------------- #
    rnd_off = _kth(jax.random.randint(kr, (B,), 0, 1 << 30) % cnt1)

    # --- least request -------------------------------------------------- #
    # vectorised batch semantics: the r-th request (arrival order) of a
    # cluster takes the r-th LEAST-loaded endpoint, emulating the paper's
    # sequential per-request counters (a naive batch argmin would send the
    # whole batch to one endpoint before any counter updates); ineligible
    # endpoints sort to the back behind the INT_MAX sentinel
    load = jnp.where(ok, state.ep_load[idx], jnp.iinfo(jnp.int32).max)
    by_load = jnp.argsort(load, axis=1).astype(jnp.int32)     # (B,W)
    lr_off = jnp.take_along_axis(
        by_load, (rank % cnt1)[:, None], 1)[:, 0]

    # --- weighted: Gumbel-max over log-weights ----------------------------- #
    w = jnp.where(ok, state.ep_weight[idx], 0.0)
    g = jax.random.gumbel(kw, w.shape)
    wt_off = jnp.argmax(jnp.where(ok, jnp.log(w + 1e-9) + g, -jnp.inf),
                        axis=1).astype(jnp.int32)

    off = jnp.select(
        [policy == POLICY_RR, policy == POLICY_RANDOM,
         policy == POLICY_LEAST_REQUEST, policy == POLICY_WEIGHTED],
        [rr_off, rnd_off, lr_off, wt_off], rr_off).astype(jnp.int32)

    ep = jnp.take_along_axis(idx, off[:, None], 1)[:, 0]
    ep = jnp.where(routable, ep, -1)
    inst = jnp.where(routable, state.ep_instance[jnp.maximum(ep, 0)], -1)

    # --- state update: load++ on chosen endpoints, cursors advance -------- #
    new_load = state.ep_load.at[jnp.maximum(ep, 0)].add(
        routable.astype(jnp.int32), mode="drop")
    per_cluster = jax.ops.segment_sum(routable.astype(jnp.int32), cl,
                                      num_segments=state.rr_cursor.shape[0])
    new_cursor = (state.rr_cursor + per_cluster) % jnp.maximum(
        state.cluster_ep_count, 1)
    state = state._replace(ep_load=new_load, rr_cursor=new_cursor)
    return Selection(ep, inst), state


def release(state: RoutingState, endpoint: jax.Array, done: jax.Array
            ) -> RoutingState:
    """Decrement load counters for finished requests (connection close)."""
    dec = jnp.where(done & (endpoint >= 0), -1, 0).astype(jnp.int32)
    return state._replace(
        ep_load=state.ep_load.at[jnp.maximum(endpoint, 0)].add(dec,
                                                               mode="drop"))
