"""Content-based routing — the eBPF filter/route managers (paper Fig. 4).

The paper walks a bounded rule chain per request inside the kernel; here the
walk is a vectorised gather over the flat rule tables for a whole request
batch at once.  The bounded loop (ROUTE_MAX_NUM) becomes a masked window of
``MAX_RULES_PER_SVC`` — the same verifier-friendly static bound.

Byte-level protocol parsing stays on the host ingress (the paper's helper
functions): requests arrive with an int32 feature vector of hashed L7 fields.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.routing_table import (MAX_RULES_PER_SVC, NO_ROUTE, WILDCARD,
                                      RoutingState)


def match_cluster(state: RoutingState, svc: jax.Array, features: jax.Array
                  ) -> jax.Array:
    """Resolve destination cluster per request.

    svc: (B,) int32 service (virtual-IP) id; features: (B, N_FEATURES) int32.
    Returns (B,) int32 cluster id, NO_ROUTE where no rule matched.

    Matches rules sequentially (the paper: "the last matched rule resolves the
    destination" is implemented as first-match over a priority-ordered chain —
    the control plane emits rules most-specific-first).
    """
    B = svc.shape[0]
    start = state.svc_rule_start[svc]                       # (B,)
    count = state.svc_rule_count[svc]                       # (B,)
    win = jnp.arange(MAX_RULES_PER_SVC, dtype=jnp.int32)    # (W,)
    idx = jnp.clip(start[:, None] + win[None, :], 0,
                   state.rule_field.shape[0] - 1)           # (B,W)
    in_range = win[None, :] < count[:, None]                # (B,W)
    fields = state.rule_field[idx]                          # (B,W)
    expect = state.rule_value[idx]                          # (B,W)
    actual = jnp.take_along_axis(features, fields, axis=1)  # (B,W)
    hit = in_range & ((expect == WILDCARD) | (expect == actual))
    any_hit = hit.any(axis=1)
    first = jnp.argmax(hit, axis=1)                         # (B,)
    cluster = state.rule_cluster[idx[jnp.arange(B), first]]
    return jnp.where(any_hit, cluster, NO_ROUTE)
