"""Sidecar baselines — the architectures XLB replaces (paper Fig. 1 a/b).

Both baselines implement the exact :class:`repro.core.balancer.Balancer`
protocol the XLB engine implements (init_state / admit / step / make_jitted
over I×C instance pools) but place the LB where Istio/Cilium place the
proxy:

  * ``IstioEngine``  — a *per-instance proxy*: every instance lane is its own
    compiled program with its own cache; the host router inspects every
    response, re-routes, and re-launches per-instance programs each step.
    Overheads reproduced: per-hop host↔device copies (syscalls / kernel stack
    traversals), per-instance dispatch (cross-process scheduling), duplicate
    routing work (duplicate protocol processing).
  * ``CiliumEngine`` — a *global proxy*: one compiled program for all lanes
    (sockmap-style shortcut) but routing/admission still runs on the host, so
    each step still pays one host round-trip and the python LB.

The XLB engine (core/interpose.py) removes all of the above by compiling
admission + decode into a single on-device program.  Because all three
implement one protocol, ``ServeLoop`` / ``launch/serve.py --engine`` /
``benchmarks`` drive them with zero per-engine glue, and a ControlPlane
transaction reaches the host router through the same ``apply_refresh`` seam
(the pre-refresh private numpy copy that silently diverged is gone: the
router's tables are refreshed in place, loads migrated, pool references
remapped).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import control, policy_defs
from repro.core.balancer import PoolState, RequestBatch
from repro.core.routing_table import (MAX_SERVICES, FlowMetrics,
                                      RoutingState)
from repro.kernels.completion import RX_BYTES_PER_TOKEN, health_update
from repro.models import model as M
from repro.models.transformer import DEFAULT_CTX


class HostRouter:
    """The user-space LB logic of the proxy (numpy, per-request python).

    Holds the proxy's routing tables as host numpy arrays; ``refresh``
    adopts a new control-plane snapshot (the caller migrates mutable state
    through the plan before handing it over)."""

    def __init__(self, routing: RoutingState, seed: int = 0):
        self.t = jax.tree.map(lambda a: np.array(a, copy=True), routing)
        self.rng = np.random.RandomState(seed)

    def refresh(self, routing: RoutingState) -> None:
        self.t = jax.tree.map(lambda a: np.array(a, copy=True), routing)

    def match(self, svc: int, features: np.ndarray) -> int:
        t = self.t
        start, count = int(t.svc_rule_start[svc]), int(t.svc_rule_count[svc])
        for r in range(start, start + count):
            exp = int(t.rule_value[r])
            if exp == -1 or exp == int(features[int(t.rule_field[r])]):
                return int(t.rule_cluster[r])
        return -1

    def select(self, cluster: int,
               features: np.ndarray | None = None) -> tuple[int, int]:
        t = self.t
        start, count = (int(t.cluster_ep_start[cluster]),
                        int(t.cluster_ep_count[cluster]))
        # the ControlPlane's draining mask gates selection under every
        # policy (same eligibility rule as the fused kernel / staged path);
        # a cluster whose endpoints are all draining is unroutable.  The
        # no-drain steady state takes a vectorized fast path — the same
        # shortcut the kernel's segment fold takes via lax.cond — instead
        # of a per-slot python filter on every pick.
        if count == 0:
            return -1, -1
        window = t.ep_drained[start:start + count]
        if window.any():
            elig = [start + j for j in range(count) if not window[j]]
            if not elig:
                return -1, -1
        else:
            elig = list(range(start, start + count))
        # registry dispatch (DESIGN.md §9): the host lowering of whichever
        # policy the cluster runs; hash-keyed policies (maglev/affinity)
        # select on the request features' flow id
        pol = int(t.cluster_policy[cluster])
        pdef = policy_defs.BY_ENUM.get(pol, policy_defs.BY_ENUM[0])
        feats = (np.zeros((1,), np.int32) if features is None
                 else np.asarray(features, np.int32))
        ep = int(pdef.host_pick(self, cluster, elig, feats))
        t.ep_load[ep] += 1
        return ep, int(t.ep_instance[ep])

    def release(self, ep: int) -> None:
        if ep >= 0:
            self.t.ep_load[ep] -= 1


class SidecarState(NamedTuple):
    """Host-resident engine state: same shape contract as ``EngineState``,
    numpy residency (every field the host proxy touches stays on the host —
    that *is* the baseline's overhead)."""

    router: HostRouter
    pool: PoolState          # numpy arrays, mutated in place
    caches: Any              # list of per-instance caches (istio) | one
    metrics: FlowMetrics     # numpy arrays, mutated in place


def _np_pool(I: int, C: int) -> PoolState:
    return PoolState(
        req_id=np.full((I, C), -1, np.int32),
        endpoint=np.full((I, C), -1, np.int32),
        svc=np.zeros((I, C), np.int32),
        length=np.zeros((I, C), np.int32),
        token=np.zeros((I, C), np.int32),
        active=np.zeros((I, C), bool),
    )


def _np_metrics() -> FlowMetrics:
    return FlowMetrics(
        tx_bytes=np.zeros((MAX_SERVICES,), np.int64),
        rx_bytes=np.zeros((MAX_SERVICES,), np.int64),
        requests=np.zeros((MAX_SERVICES,), np.int64),
        no_route_match=np.zeros((), np.int64),
        overflow=np.zeros((), np.int64),
    )


@dataclasses.dataclass
class SidecarEngine:
    """Host-interposed serving engine (mode: 'istio' | 'cilium')."""

    cfg: ModelConfig
    n_instances: int
    slots: int
    max_len: int
    mode: str = "istio"
    eos: int = 1
    ctx: Any = DEFAULT_CTX

    def __post_init__(self):
        cfg, ctx = self.cfg, self.ctx

        @jax.jit
        def decode(params, tokens, lengths, cache):
            logits, cache = M.decode_step(cfg, params, tokens, lengths, cache,
                                          ctx=ctx)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        self._decode = decode

    # ------------------------------------------------------------------ #
    def init_state(self, routing: RoutingState, dtype=None) -> SidecarState:
        I, C = self.n_instances, self.slots
        dtype = dtype or jnp.float32
        if self.mode == "istio":
            # one cache + one compiled program PER instance (per-svc proxy)
            caches = [M.init_cache(self.cfg, C, self.max_len, dtype)
                      for _ in range(I)]
        else:
            caches = M.init_cache(self.cfg, I * C, self.max_len, dtype)
        return SidecarState(HostRouter(routing), _np_pool(I, C), caches,
                            _np_metrics())

    # ------------------------------------------------------------------ #
    def admit(self, state: SidecarState, reqs: RequestBatch) -> SidecarState:
        """Host-side routing + slot allocation (per-request python)."""
        router, pool, m = state.router, state.pool, state.metrics
        req_id = np.asarray(reqs.req_id)
        svc = np.asarray(reqs.svc)
        feats = np.asarray(reqs.features)
        tok = np.asarray(reqs.token)
        nbytes = np.asarray(reqs.msg_bytes)
        for r in range(len(req_id)):
            if req_id[r] < 0:
                continue
            cluster = router.match(int(svc[r]), feats[r])
            if cluster < 0:
                m.no_route_match[...] += 1
                continue
            ep, inst = router.select(cluster, feats[r])
            if inst < 0:
                continue
            free = np.where(~pool.active[inst])[0]
            if len(free) == 0:                   # held (pool exhausted)
                router.release(ep)
                m.overflow[...] += 1
                continue
            s = int(free[0])
            pool.req_id[inst, s] = req_id[r]
            pool.endpoint[inst, s] = ep
            pool.svc[inst, s] = svc[r]
            pool.length[inst, s] = 0
            pool.token[inst, s] = tok[r]
            pool.active[inst, s] = True
            if svc[r] < MAX_SERVICES:
                m.requests[svc[r]] += 1
                m.tx_bytes[svc[r]] += nbytes[r]
        return state

    # ------------------------------------------------------------------ #
    def step(self, params, state: SidecarState) -> tuple[SidecarState, dict]:
        """One decode step for all lanes, host-mediated."""
        I, C = self.n_instances, self.slots
        router, pool, m = state.router, state.pool, state.metrics
        caches = state.caches
        if self.mode == "istio":
            nxt = np.zeros((I, C), np.int32)
            for i in range(I):                   # per-instance program launch
                toks = jnp.asarray(pool.token[i][:, None], jnp.int32)
                lens = jnp.asarray(pool.length[i], jnp.int32)
                out, caches[i] = self._decode(params, toks, lens, caches[i])
                nxt[i] = np.asarray(out)         # proxy reads every response
        else:
            toks = jnp.asarray(pool.token.reshape(-1, 1), jnp.int32)
            lens = jnp.asarray(pool.length.reshape(-1), jnp.int32)
            out, caches = self._decode(params, toks, lens, caches)
            nxt = np.asarray(out).reshape(I, C)  # one global proxy round-trip
        state = state._replace(caches=caches)

        # vectorised host bookkeeping (numpy): keeps the baseline honest — the
        # architectural cost we measure is the per-request python ROUTING and
        # (for istio) per-instance program launches, not sloppy loops.
        pre_req = pool.req_id.copy()             # ids serviced this tick
        act = pool.active.copy()
        pool.length[act] += 1
        pool.token[act] = nxt[act]
        np.add.at(m.rx_bytes, np.maximum(pool.svc[act], 0),
                  RX_BYTES_PER_TOKEN)
        done = act & ((nxt == self.eos) | (pool.length >= self.max_len - 1))
        # health EWMAs: same shared epilogue as the fused kernel, on the
        # same integer observations (occupancy before release, completions
        # per endpoint) — host-resident parity for the closed loop
        E = router.t.ep_load.shape[0]
        occ0 = router.t.ep_load.astype(np.int32).copy()
        cnt = np.zeros((E,), np.int32)
        eps = pool.endpoint[done]
        np.add.at(cnt, eps[(eps >= 0) & (eps < E)], 1)
        ewl, ewt = health_update(jnp.asarray(router.t.ep_inflight_ewma),
                                 jnp.asarray(router.t.ep_tput_ewma),
                                 jnp.asarray(occ0), jnp.asarray(cnt))
        router.t.ep_inflight_ewma[...] = np.asarray(ewl)
        router.t.ep_tput_ewma[...] = np.asarray(ewt)
        for ep in pool.endpoint[done]:           # release load counters
            router.release(int(ep))
        pool.active[done] = False
        pool.req_id[done] = -1
        pool.endpoint[done] = -1
        pool.length[done] = 0
        out = {"emitted": nxt, "done": done, "req_id": pre_req,
               "active": int(act.sum() - done.sum())}
        return state, out

    # ------------------------------------------------------------------ #
    def make_jitted(self, donate: bool = True):
        """Protocol parity with ``Engine.make_jitted``: the returned
        ``serve_step`` has the same signature, but only the decode inside is
        compiled — admission stays a host round-trip, which is the point."""

        def serve_step(params, state: SidecarState, reqs: RequestBatch):
            if np.any(np.asarray(reqs.req_id) >= 0):
                state = self.admit(state, reqs)
            return self.step(params, state)

        return serve_step

    # ------------------------------------------------------------------ #
    # control-plane seam (Balancer protocol)
    # ------------------------------------------------------------------ #
    def get_routing(self, state: SidecarState) -> RoutingState:
        return state.router.t

    def apply_refresh(self, state: SidecarState,
                      plan: control.RefreshPlan) -> SidecarState:
        """Adopt a committed transaction: same plan splice as the in-graph
        engine (config swap + load migration), then remap the host pool's
        endpoint references in place."""
        state.router.refresh(control.apply_plan(state.router.t, plan))
        pe = state.pool.endpoint
        pe[...] = np.asarray(control.remap_endpoints(plan, pe))
        return state


@dataclasses.dataclass
class IstioEngine(SidecarEngine):
    """Per-instance sidecar proxy (paper Fig. 1a)."""

    mode: str = "istio"


@dataclasses.dataclass
class CiliumEngine(SidecarEngine):
    """Shared global proxy (paper Fig. 1b)."""

    mode: str = "cilium"
