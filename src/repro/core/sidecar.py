"""Sidecar baselines — the architectures XLB replaces (paper Fig. 1 a/b).

Both baselines implement the exact Engine contract (admit + step over I×C
instance pools) but place the LB where Istio/Cilium place the proxy:

  * ``IstioEngine``  — a *per-instance proxy*: every instance lane is its own
    compiled program with its own cache; the host router inspects every
    response, re-routes, and re-launches per-instance programs each step.
    Overheads reproduced: per-hop host↔device copies (syscalls / kernel stack
    traversals), per-instance dispatch (cross-process scheduling), duplicate
    routing work (duplicate protocol processing).
  * ``CiliumEngine`` — a *global proxy*: one compiled program for all lanes
    (sockmap-style shortcut) but routing/admission still runs on the host, so
    each step still pays one host round-trip and the python LB.

The XLB engine (core/interpose.py) removes all of the above by compiling
admission + decode into a single on-device program.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.interpose import RequestBatch
from repro.core.routing_table import (POLICY_LEAST_REQUEST, POLICY_RANDOM,
                                      POLICY_RR, POLICY_WEIGHTED, RoutingState)
from repro.models import model as M
from repro.models.transformer import DEFAULT_CTX


class HostRouter:
    """The user-space LB logic of the proxy (numpy, per-request python)."""

    def __init__(self, routing: RoutingState):
        self.t = jax.tree.map(lambda a: np.array(a, copy=True), routing)
        self.rng = np.random.RandomState(0)

    def match(self, svc: int, features: np.ndarray) -> int:
        t = self.t
        start, count = int(t.svc_rule_start[svc]), int(t.svc_rule_count[svc])
        for r in range(start, start + count):
            exp = int(t.rule_value[r])
            if exp == -1 or exp == int(features[int(t.rule_field[r])]):
                return int(t.rule_cluster[r])
        return -1

    def select(self, cluster: int) -> tuple[int, int]:
        t = self.t
        start, count = (int(t.cluster_ep_start[cluster]),
                        int(t.cluster_ep_count[cluster]))
        if count == 0:
            return -1, -1
        pol = int(t.cluster_policy[cluster])
        if pol == POLICY_RR:
            off = int(t.rr_cursor[cluster]) % count
            t.rr_cursor[cluster] += 1
        elif pol == POLICY_RANDOM:
            off = int(self.rng.randint(count))
        elif pol == POLICY_WEIGHTED:
            w = t.ep_weight[start:start + count]
            s = float(w.sum())
            # all-zero weights fall back to uniform (mirrors the kernel's
            # log(w + 1e-9) guard) instead of NaN-crashing np.random.choice
            off = int(self.rng.choice(count, p=w / s if s > 0 else None))
        else:                                   # least request
            off = int(np.argmin(t.ep_load[start:start + count]))
        ep = start + off
        t.ep_load[ep] += 1
        return ep, int(t.ep_instance[ep])

    def release(self, ep: int) -> None:
        if ep >= 0:
            self.t.ep_load[ep] -= 1


@dataclasses.dataclass
class SidecarEngine:
    """Host-interposed serving engine (mode: 'istio' | 'cilium')."""

    cfg: ModelConfig
    n_instances: int
    slots: int
    max_len: int
    routing: RoutingState
    mode: str = "istio"
    eos: int = 1
    ctx: Any = DEFAULT_CTX

    def __post_init__(self):
        I, C = self.n_instances, self.slots
        self.router = HostRouter(self.routing)
        self.pool_req = np.full((I, C), -1, np.int64)
        self.pool_ep = np.full((I, C), -1, np.int64)
        self.pool_len = np.zeros((I, C), np.int64)
        self.pool_tok = np.zeros((I, C), np.int64)
        self.pool_active = np.zeros((I, C), bool)
        dtype = jnp.float32
        if self.mode == "istio":
            # one cache + one compiled program PER instance (per-service proxy)
            self.caches = [M.init_cache(self.cfg, C, self.max_len, dtype)
                           for _ in range(I)]
        else:
            self.caches = M.init_cache(self.cfg, I * C, self.max_len, dtype)
        cfg, ctx = self.cfg, self.ctx

        @jax.jit
        def decode(params, tokens, lengths, cache):
            logits, cache = M.decode_step(cfg, params, tokens, lengths, cache,
                                          ctx=ctx)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        self._decode = decode

    # ------------------------------------------------------------------ #
    def admit(self, reqs: RequestBatch) -> int:
        """Host-side routing + slot allocation. Returns #admitted."""
        req_id = np.asarray(reqs.req_id)
        svc = np.asarray(reqs.svc)
        feats = np.asarray(reqs.features)
        tok = np.asarray(reqs.token)
        admitted = 0
        for r in range(len(req_id)):
            if req_id[r] < 0:
                continue
            cluster = self.router.match(int(svc[r]), feats[r])
            if cluster < 0:
                continue
            ep, inst = self.router.select(cluster)
            if inst < 0:
                continue
            free = np.where(~self.pool_active[inst])[0]
            if len(free) == 0:                   # held (pool exhausted)
                self.router.release(ep)
                continue
            s = int(free[0])
            self.pool_req[inst, s] = req_id[r]
            self.pool_ep[inst, s] = ep
            self.pool_len[inst, s] = 0
            self.pool_tok[inst, s] = tok[r]
            self.pool_active[inst, s] = True
            admitted += 1
        return admitted

    # ------------------------------------------------------------------ #
    def step(self, params) -> dict:
        """One decode step for all lanes, host-mediated."""
        I, C = self.n_instances, self.slots
        if self.mode == "istio":
            nxt = np.zeros((I, C), np.int64)
            for i in range(I):                   # per-instance program launch
                toks = jnp.asarray(self.pool_tok[i][:, None], jnp.int32)
                lens = jnp.asarray(self.pool_len[i], jnp.int32)
                out, self.caches[i] = self._decode(params, toks, lens,
                                                   self.caches[i])
                nxt[i] = np.asarray(out)         # proxy reads every response
        else:
            toks = jnp.asarray(self.pool_tok.reshape(-1, 1), jnp.int32)
            lens = jnp.asarray(self.pool_len.reshape(-1), jnp.int32)
            out, self.caches = self._decode(params, toks, lens, self.caches)
            nxt = np.asarray(out).reshape(I, C)  # one global proxy round-trip

        # vectorised host bookkeeping (numpy): keeps the baseline honest — the
        # architectural cost we measure is the per-request python ROUTING and
        # (for istio) per-instance program launches, not sloppy loops.
        act = self.pool_active
        self.pool_len[act] += 1
        self.pool_tok[act] = nxt[act]
        done = act & ((nxt == self.eos) | (self.pool_len >= self.max_len - 1))
        for ep in self.pool_ep[done]:            # release load counters
            self.router.release(int(ep))
        self.pool_active[done] = False
        self.pool_req[done] = -1
        return {"done": int(done.sum()), "active": int(act.sum() - done.sum())}
