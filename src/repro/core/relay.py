"""XLB socket relay — in-graph payload redirection between "sockets".

Paper mapping (§4.1): a p-sock relays a message straight into the TX queue of
the chosen i-sock; responses come back i-sock.RX → p-sock.RX.  On a TPU mesh
the analogous primitive is *capacity-bounded counting-sort dispatch*: payload
rows move to their destination's buffer slot in one scatter (single-device) or
one all-to-all hop over the ICI (expert/instance parallel) — never through the
host.

Three interchangeable dispatch methods (tests cross-check them):
  * ``sort``    — counting-sort positions + scatter; O(N log N) compare, O(N·D)
                  data movement.  Default.
  * ``cumsum``  — one-hot cumsum positions (GShard-style rank); O(N·E) but
                  matmul-friendly; the Pallas ``relay_dispatch`` kernel tiles
                  this form.
  * ``einsum``  — full dense one-hot dispatch/combine einsum (GShard).  The
                  oracle: simplest semantics, highest FLOPs.

The ``a2a`` path (``sharded_relay``) wraps dispatch in ``shard_map`` so the
relay hop is an explicit ``all_to_all`` over a named mesh axis — the
collective schedule the roofline analysis attributes to the technique.

Overflow (connection-pool exhaustion, paper's held requests) is counted and
surfaced in metrics as ``overflow_frac``; overflowing rows are dropped by the
dispatch and restored by the residual connection of the caller (MoE) or held
by the serving engine (router).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RelayMeta(NamedTuple):
    """Bookkeeping produced by dispatch, consumed by combine."""

    idx: jax.Array        # (N,) int32 destination id per payload row
    slot: jax.Array       # (N,) int32 slot within the destination pool
    ok: jax.Array         # (N,) bool  row fit inside capacity (per-SOURCE
    #                       quota under sharded_apply — see its docstring)
    load: jax.Array       # (E,) int32 rows destined per backend, pre-drop
    #                       (GLOBAL — psum'd over the axis — when produced
    #                       by sharded_apply; local rows otherwise)
    overflow_frac: jax.Array  # () fraction of rows dropped


# --------------------------------------------------------------------------- #
# Slot assignment ("which position in the destination's connection pool")
# --------------------------------------------------------------------------- #


def positions_sort(idx: jax.Array, n_dest: int) -> tuple[jax.Array, jax.Array]:
    """Counting-sort rank: stable position of each row within its destination.

    Returns (slot (N,), load (E,)).
    """
    N = idx.shape[0]
    order = jnp.argsort(idx, stable=True)                     # (N,)
    sorted_idx = idx[order]
    load = jnp.bincount(idx, length=n_dest)                   # (E,)
    starts = jnp.cumsum(load) - load                          # (E,)
    # shard_admit steers dropped rows to the sentinel destination
    # ``n_dest``: their rank is never consumed, but the gather must still
    # stay inside ``starts`` — OOB reads are undefined once compiled
    pos_sorted = jnp.arange(N, dtype=jnp.int32) \
        - starts[jnp.minimum(sorted_idx, n_dest - 1)]
    slot = jnp.zeros((N,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32), mode="drop")
    return slot, load.astype(jnp.int32)


def positions_cumsum(idx: jax.Array, n_dest: int) -> tuple[jax.Array, jax.Array]:
    """One-hot cumsum rank (GShard form).  O(N·E) memory."""
    oh = jax.nn.one_hot(idx, n_dest, dtype=jnp.int32)         # (N,E)
    ranks = jnp.cumsum(oh, axis=0) - oh                       # rank before self
    slot = jnp.sum(ranks * oh, axis=-1).astype(jnp.int32)
    load = jnp.sum(oh, axis=0).astype(jnp.int32)
    return slot, load


_POSITIONS = {"sort": positions_sort, "cumsum": positions_cumsum}


# --------------------------------------------------------------------------- #
# Dispatch / combine (single-shard)
# --------------------------------------------------------------------------- #


def relay_dispatch(x: jax.Array, idx: jax.Array, n_dest: int, capacity: int,
                   method: str = "sort") -> tuple[jax.Array, RelayMeta]:
    """Scatter payload rows x:(N,D) into per-destination pools (E,C,D).

    Rows beyond ``capacity`` land in a dump slot and are dropped (ok=False).
    """
    N, D = x.shape
    slot, load = _POSITIONS[method](idx, n_dest)
    ok = slot < capacity
    write_slot = jnp.where(ok, slot, capacity)                # dump row = C
    buf = jnp.zeros((n_dest, capacity + 1, D), x.dtype)
    buf = buf.at[idx, write_slot].set(x, mode="drop")
    overflow = 1.0 - jnp.mean(ok.astype(jnp.float32))
    return buf[:, :capacity], RelayMeta(idx, slot, ok, load, overflow)


def relay_combine(buf: jax.Array, meta: RelayMeta, weights: jax.Array | None = None
                  ) -> jax.Array:
    """Gather rows back from pools (E,C,D) to payload order (N,D).

    ``weights``: optional (N,) scale (MoE gate weight / response weighting).
    Dropped rows come back as zeros (caller's residual covers them).
    """
    safe_slot = jnp.minimum(meta.slot, buf.shape[1] - 1)
    rows = buf[meta.idx, safe_slot]                           # (N,D)
    rows = jnp.where(meta.ok[:, None], rows, 0)
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    return rows


# --------------------------------------------------------------------------- #
# Dense-einsum oracle (GShard): slowest, simplest
# --------------------------------------------------------------------------- #


def relay_dispatch_einsum(x, idx, n_dest: int, capacity: int):
    N, D = x.shape
    slot, load = positions_cumsum(idx, n_dest)
    ok = slot < capacity
    e_oh = jax.nn.one_hot(idx, n_dest, dtype=x.dtype)          # (N,E)
    c_oh = jax.nn.one_hot(jnp.minimum(slot, capacity - 1), capacity,
                          dtype=x.dtype)                       # (N,C)
    d_onehot = (e_oh[:, :, None] * c_oh[:, None, :]
                * ok[:, None, None].astype(x.dtype))           # (N,E,C)
    buf = jnp.einsum("nec,nd->ecd", d_onehot, x)
    overflow = 1.0 - jnp.mean(ok.astype(jnp.float32))
    return buf, RelayMeta(idx, slot, ok, load, overflow), d_onehot


def relay_combine_einsum(buf, d_onehot, weights=None):
    out = jnp.einsum("nec,ecd->nd", d_onehot.astype(buf.dtype), buf)
    if weights is not None:
        out = out * weights[:, None].astype(out.dtype)
    return out


# --------------------------------------------------------------------------- #
# Expert/instance-parallel relay: explicit all-to-all over a mesh axis
# --------------------------------------------------------------------------- #


def sharded_apply(x, idx, weights, n_dest: int, capacity: int, axis: str,
                  backend_fn, backend_params):
    """shard_map body: relay local rows over ``axis`` to backend owners,
    apply ``backend_fn(params_local, pool)`` on each owner, relay back.

    Must run inside ``shard_map`` with mesh axis ``axis`` of size M;
    ``n_dest % M == 0``; backend b lives on shard b // (n_dest // M).
    x: (N_loc, D) local rows; idx: (N_loc,) global destination ids.
    Returns (out (N_loc,D), meta).

    Meta semantics across the shards (pinned by the 4-shard round-trip test
    in tests/test_shard_admit.py): ``ok``/``slot``/``overflow_frac`` are
    **per-source** — each source shard owns ``capacity`` slots at every
    destination, so a row is dropped against its own shard's quota (a
    destination absorbs up to ``M * capacity`` rows in total) and
    ``overflow_frac`` is the axis-mean of the per-source drop fractions;
    ``load`` is the **global pre-drop** row count per destination
    (psum'd over ``axis``), matching the single-shard dispatch on the
    concatenated rows.
    """
    from repro.compat import axis_size
    M = axis_size(axis)
    E_loc = n_dest // M
    # local dispatch into per-destination pools with per-source capacity
    buf, meta = relay_dispatch(x, idx, n_dest, capacity)       # (E, C, D)
    # relay hop: all_to_all moves each destination pool to its owner shard.
    # (M, E_loc, C, D) --a2a--> (M, E_loc, C, D) where leading axis becomes
    # the source-shard axis on the receiving side.
    buf = buf.reshape(M, E_loc, capacity, -1)
    buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0, tiled=False)
    # owner now holds (M, E_loc, C, D): pools from every source shard
    pool = buf.transpose(1, 0, 2, 3).reshape(E_loc, M * capacity, -1)
    out_pool = backend_fn(backend_params, pool)                # (E_loc, M*C, D')
    # reverse relay
    out_pool = out_pool.reshape(E_loc, M, capacity, -1).transpose(1, 0, 2, 3)
    out_pool = jax.lax.all_to_all(out_pool, axis, split_axis=0, concat_axis=0,
                                  tiled=False)
    out_buf = out_pool.reshape(n_dest, capacity, -1)
    meta = meta._replace(
        load=jax.lax.psum(meta.load, axis),
        overflow_frac=jax.lax.pmean(meta.overflow_frac, axis))
    return relay_combine(out_buf, meta, weights), meta
