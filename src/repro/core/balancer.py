"""The unified datapath seam: one ``Balancer`` protocol, three architectures.

The paper's comparison (Fig. 1) pits three placements of the L7 balancer —
per-instance sidecar proxy (Istio), shared global proxy (Cilium), and the
in-kernel interposition of XLB — against one another over the *same*
service contract.  This module pins that contract as a structural protocol
so every driver (``ServeLoop``, ``launch/serve.py``, ``benchmarks``) is
written once against the seam and never against an engine:

  * ``init_state(routing)``     → opaque engine state (pools, caches, ...)
  * ``admit(state, reqs)``      → state with the batch routed + committed
  * ``step(params, state)``     → (state, out) one decode + completion tick
  * ``make_jitted()``           → fused ``serve_step(params, state, reqs)``
  * ``get_routing(state)``      → the live ``RoutingState`` the engine reads
  * ``apply_refresh(state, plan)`` → state after a control-plane transaction
                                  (config swap + endpoint-reference remap)

``step``/``serve_step`` return an ``out`` dict with the same keys for every
engine: ``emitted``/``done``/``req_id`` as (I, C) arrays over the connection
pool and an ``active`` count — the host driver never branches on the mode.

The shared wire types live here too: ``RequestBatch`` (host-ingress output)
and ``PoolState`` (per-(instance, slot) connection state).  They are plain
NamedTuples, so the XLB engine holds device arrays in them while the sidecar
baselines hold host numpy arrays — same shape contract, different residency,
exactly the architectural difference the paper measures.
"""

from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
from typing import NamedTuple


class RequestBatch(NamedTuple):
    """Host-ingress output: fixed-size admission batch (pad with req_id=-1)."""

    req_id: jax.Array     # (R,) int32, -1 = padding
    svc: jax.Array        # (R,) int32 virtual-IP/service id
    features: jax.Array   # (R, N_FEATURES) int32 hashed L7 fields
    token: jax.Array      # (R,) int32 first prompt token
    msg_bytes: jax.Array  # (R,) int32 payload size (traffic metrics)


class PoolState(NamedTuple):
    """Per-(instance, slot) live-connection state."""

    req_id: jax.Array      # (I, C) int32, -1 = free
    endpoint: jax.Array    # (I, C) int32 (for load release)
    svc: jax.Array         # (I, C) int32
    length: jax.Array      # (I, C) int32
    token: jax.Array       # (I, C) int32 last emitted/fed token
    active: jax.Array      # (I, C) bool

    @staticmethod
    def init(I: int, C: int) -> "PoolState":
        return PoolState(
            req_id=jnp.full((I, C), -1, jnp.int32),
            endpoint=jnp.full((I, C), -1, jnp.int32),
            svc=jnp.zeros((I, C), jnp.int32),
            length=jnp.zeros((I, C), jnp.int32),
            token=jnp.zeros((I, C), jnp.int32),
            active=jnp.zeros((I, C), bool),
        )


@runtime_checkable
class Balancer(Protocol):
    """Structural type every serving engine implements (XLB/Istio/Cilium)."""

    def init_state(self, routing, dtype=None) -> Any:
        """Build the engine state for one fleet around a routing snapshot."""
        ...

    def admit(self, state, reqs: RequestBatch) -> Any:
        """Route + balance + commit one admission batch into the pools."""
        ...

    def step(self, params, state) -> tuple[Any, dict]:
        """One decode step for every lane + completion handling."""
        ...

    def make_jitted(self, donate: bool = True):
        """Fused ``serve_step(params, state, reqs) -> (state, out)``."""
        ...

    def get_routing(self, state):
        """The live RoutingState this engine's datapath reads."""
        ...

    def apply_refresh(self, state, plan) -> Any:
        """Apply a ControlPlane ``RefreshPlan``: swap the config tables,
        migrate load counters, and remap pool endpoint references."""
        ...


ENGINE_KINDS = ("xlb", "istio", "cilium")


def make_balancer(kind: str, cfg, n_instances: int, slots: int,
                  max_len: int, **kw) -> Balancer:
    """Factory over the three architectures — the only place a driver ever
    names an engine class."""
    if kind == "xlb":
        from repro.core.interpose import Engine
        return Engine(cfg, n_instances, slots, max_len, **kw)
    if kind == "istio":
        from repro.core.sidecar import IstioEngine
        return IstioEngine(cfg, n_instances, slots, max_len, **kw)
    if kind == "cilium":
        from repro.core.sidecar import CiliumEngine
        return CiliumEngine(cfg, n_instances, slots, max_len, **kw)
    raise ValueError(f"unknown engine kind {kind!r}; "
                     f"choose from {ENGINE_KINDS}")
