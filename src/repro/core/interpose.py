"""In-graph interposition — the XLB serving engine (paper §3/§4).

The engine owns ``I`` instance lanes × ``C`` decode slots (the pre-established
i-sock pools).  Both engine operations are compiled *into* the model program —
the LB is a logical extension of the application:

  * ``admit``  — connection establishment + load balancing: content match →
    policy select → slot allocation → pool commit, all inside one Pallas
    kernel (kernels/route_match.py::admit_commit).  No host round-trip:
    the paper's "client TCP connection is bypassed".
  * ``step``   — one decode step for every active slot across all lanes in a
    single batched program, then completion handling (done detect, load
    release, rx metrics, slot free) as one fused Pallas kernel
    (kernels/completion.py::complete).

``Engine`` implements the :class:`repro.core.balancer.Balancer` protocol —
the same contract the sidecar baselines in core/sidecar.py implement with
host-mediated routing + per-instance programs (the overhead classes of paper
Table 2).  Control-plane transactions (core/control.py) reach a running
engine through ``apply_refresh``: config tables swap, loads migrate, pool
endpoint references remap — all without recompiling ``serve_step``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import control
from repro.core.balancer import PoolState, RequestBatch  # noqa: F401 (re-export:
# RequestBatch/PoolState moved to core.balancer; importers keep working)
from repro.core.routing_table import (MAX_EPS_PER_CLUSTER, FlowMetrics,
                                      RoutingState)
from repro.kernels import ops
from repro.models import model as M
from repro.models.transformer import DEFAULT_CTX


class EngineState(NamedTuple):
    routing: RoutingState
    pool: PoolState
    cache: Any             # model KV/SSM cache, batch dim = I*C
    metrics: FlowMetrics
    key: jax.Array


@dataclasses.dataclass(frozen=True)
class Engine:
    """XLB in-graph serving engine for one service fleet."""

    cfg: ModelConfig
    n_instances: int
    slots: int
    max_len: int
    eos: int = 1
    ctx: Any = DEFAULT_CTX
    # kernel tuning overrides; None = the autotuned plan (kernels/tune.py)
    block_r: int | None = None
    block_i: int | None = None
    fold: str | None = None
    # mesh-sharded admission (DESIGN.md §7): with shards > 1 the admit
    # batch splits (R/M,) and the pool (I/M,) over ``shard_axis`` of
    # ``shard_mesh``, the fused kernel runs per shard, and one collective
    # pass reconciles — bit-exact vs the single-shard path on the same
    # batch.  Requires n_instances % shards == 0 and a mesh with >= shards
    # devices (launch/mesh.py::make_shard_mesh).
    shards: int = 1
    shard_mesh: Any = None
    shard_axis: str = "shard"

    def __post_init__(self):
        if self.shards > 1:
            if self.shard_mesh is None:
                raise ValueError("shards > 1 needs a shard_mesh "
                                 "(launch/mesh.py::make_shard_mesh)")
            mesh_m = self.shard_mesh.shape[self.shard_axis]
            if mesh_m != self.shards:
                raise ValueError(
                    f"shards={self.shards} but shard_mesh axis "
                    f"{self.shard_axis!r} is {mesh_m}-way — the datapath "
                    "would silently shard at the mesh width")
            if self.n_instances % self.shards:
                raise ValueError(f"n_instances ({self.n_instances}) must "
                                 f"divide over {self.shards} shards")

    # ------------------------------------------------------------------ #
    def init_state(self, routing: RoutingState, dtype=None) -> EngineState:
        return EngineState(
            routing=routing,
            pool=PoolState.init(self.n_instances, self.slots),
            cache=M.init_cache(self.cfg, self.n_instances * self.slots,
                               self.max_len, dtype),
            metrics=FlowMetrics.zeros(),
            key=jax.random.PRNGKey(0),
        )

    # ------------------------------------------------------------------ #
    # admit: routing + balancing + slot allocation + pool commit — one
    # fused Pallas kernel (route → balance → slot-allocate → pool write →
    # metrics), the paper's single in-kernel tail-call chain ending in the
    # sockmap update.  The staged jnp chain lives on in core/router.py +
    # core/policies.py + core/request_map.py (the sidecar baselines and
    # the bench_admit comparison drive it from there).
    # ------------------------------------------------------------------ #
    def admit(self, state: EngineState, reqs: RequestBatch) -> EngineState:
        rstate, metrics = state.routing, state.metrics
        key, sub = jax.random.split(state.key)
        kr, kw, _ = jax.random.split(sub, 3)
        R = reqs.req_id.shape[0]
        # host PRNG draws feed the kernel so random/weighted stay on the
        # engine's key stream (and match the admit_ref oracle bit-exactly)
        rnd = jax.random.randint(kr, (R,), 0, 1 << 30, dtype=jnp.int32)
        gumbel = jax.random.gumbel(kw, (R, MAX_EPS_PER_CLUSTER), jnp.float32)

        if self.shards > 1:
            res = ops.admit_commit_sharded(
                reqs, rstate, state.pool, rnd, gumbel, mesh=self.shard_mesh,
                axis=self.shard_axis, block_r=self.block_r, fold=self.fold)
        else:
            res = ops.admit_commit(reqs, rstate, state.pool, rnd, gumbel,
                                   block_r=self.block_r, fold=self.fold)
        # the committed pool, load counters, rr cursors, affinity cache,
        # held release and flow metrics all come fused out of the kernel
        rstate = rstate._replace(ep_load=res.ep_load, rr_cursor=res.rr_cursor,
                                 aff_key=res.aff_key, aff_ep=res.aff_ep)
        metrics = metrics._replace(
            requests=metrics.requests + res.svc_requests,
            tx_bytes=metrics.tx_bytes + res.svc_tx_bytes,
            no_route_match=metrics.no_route_match + res.no_route,
            # per-ATTEMPT hold events: a request the host re-queues and
            # re-admits counts once per attempt (FlowMetrics docstring);
            # distinct held requests live on the host (ServeLoop.held_first)
            overflow=metrics.overflow + res.held,
        )
        return EngineState(rstate, res.pool, state.cache, metrics, key)

    # ------------------------------------------------------------------ #
    # step: one batched decode over all lanes; completion handling (done
    # detect → load release → rx metrics → slot free) runs as one fused
    # Pallas kernel over the (I, C) pool — the paper's in-kernel close
    # path.  The staged jnp chain it replaced is kept as the baseline in
    # benchmarks/run.py::bench_step.
    # ------------------------------------------------------------------ #
    def step(self, params, state: EngineState) -> tuple[EngineState, dict]:
        pool, cache = state.pool, state.cache
        I, C = pool.req_id.shape
        B = I * C
        tokens = pool.token.reshape(B, 1)
        lengths = pool.length.reshape(B)
        logits, cache = M.decode_step(self.cfg, params, tokens, lengths,
                                      cache, ctx=self.ctx)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32).reshape(I, C)

        if self.shards > 1:
            res = ops.complete_sharded(
                pool, nxt, state.routing.ep_load, state.metrics.rx_bytes,
                state.routing.ep_inflight_ewma, state.routing.ep_tput_ewma,
                mesh=self.shard_mesh, axis=self.shard_axis,
                eos=self.eos, max_len=self.max_len,
                block_i=self.block_i, fold=self.fold)
        else:
            res = ops.complete(pool, nxt, state.routing.ep_load,
                               state.metrics.rx_bytes,
                               state.routing.ep_inflight_ewma,
                               state.routing.ep_tput_ewma,
                               eos=self.eos, max_len=self.max_len,
                               block_i=self.block_i, fold=self.fold)
        rstate = state.routing._replace(ep_load=res.ep_load,
                                        ep_inflight_ewma=res.ep_inflight_ewma,
                                        ep_tput_ewma=res.ep_tput_ewma)
        metrics = state.metrics._replace(rx_bytes=res.rx_bytes)
        out = {"emitted": nxt, "done": res.done,
               "req_id": state.pool.req_id,     # ids that produced this tick
               "active": res.pool.active.sum()}
        return EngineState(rstate, res.pool, cache, metrics, state.key), out

    # ------------------------------------------------------------------ #
    def make_jitted(self, donate: bool = True):
        """One fused program: admit + decode step (the XLB datapath).

        Admission is gated by ``lax.cond`` on "any arrivals", so steady-state
        decode ticks skip the routing/allocation work entirely (the paper's
        connect-path eBPF hook only fires on connect)."""

        @partial(jax.jit, donate_argnums=(1,) if donate else ())
        def serve_step(params, state: EngineState, reqs: RequestBatch):
            state = jax.lax.cond(jnp.any(reqs.req_id >= 0),
                                 lambda s: self.admit(s, reqs),
                                 lambda s: s, state)
            return self.step(params, state)

        from repro.analysis.invariants import sanitize_enabled
        if not sanitize_enabled():
            return serve_step

        # XLB_SANITIZE=1: the kernel wrappers emit conservation-law checks
        # into the trace (analysis/invariants.py::guard); functionalize them
        # here — the host boundary — and fail the tick loudly on violation.
        from jax.experimental import checkify
        ck = jax.jit(checkify.checkify(serve_step,
                                       errors=checkify.user_checks))

        def sanitized_step(params, state, reqs):
            err, res = ck(params, state, reqs)
            err.throw()
            return res

        sanitized_step._cache_size = ck._cache_size   # recompile probes
        return sanitized_step

    # ------------------------------------------------------------------ #
    # control-plane seam (Balancer protocol)
    # ------------------------------------------------------------------ #
    def get_routing(self, state: EngineState) -> RoutingState:
        return state.routing

    def apply_refresh(self, state: EngineState,
                      plan: control.RefreshPlan) -> EngineState:
        """Splice a committed transaction into the live state: one buffer
        swap for the tables (load counters migrate through the slot
        permutation) and a remap of the pool's endpoint references, so
        completions of in-flight connections release the counter of the
        endpoint's *new* slot — never a new occupant of its old one."""
        routing = control.apply_plan(state.routing, plan)
        pool = state.pool._replace(
            endpoint=control.remap_endpoints(plan, state.pool.endpoint))
        return state._replace(routing=routing, pool=pool)
