"""In-graph interposition — the XLB serving engine (paper §3/§4).

The engine owns ``I`` instance lanes × ``C`` decode slots (the pre-established
i-sock pools).  Both engine operations are compiled *into* the model program —
the LB is a logical extension of the application:

  * ``admit``  — connection establishment + load balancing: content match →
    policy select → slot allocation → pool commit, all inside one Pallas
    kernel (kernels/route_match.py::admit_commit).  No host round-trip:
    the paper's "client TCP connection is bypassed".
  * ``step``   — one decode step for every active slot across all lanes in a
    single batched program, then completion handling (done detect, load
    release, rx metrics, slot free) as one fused Pallas kernel
    (kernels/completion.py::complete).

The sidecar baselines in core/sidecar.py implement the same contract with
host-mediated routing + per-instance programs, reproducing the overhead
classes of paper Table 2.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.routing_table import (MAX_EPS_PER_CLUSTER, FlowMetrics,
                                      RoutingState)
from repro.kernels import ops
from repro.models import model as M
from repro.models.transformer import DEFAULT_CTX


class RequestBatch(NamedTuple):
    """Host-ingress output: fixed-size admission batch (pad with req_id=-1)."""

    req_id: jax.Array     # (R,) int32, -1 = padding
    svc: jax.Array        # (R,) int32 virtual-IP/service id
    features: jax.Array   # (R, N_FEATURES) int32 hashed L7 fields
    token: jax.Array      # (R,) int32 first prompt token
    msg_bytes: jax.Array  # (R,) int32 payload size (traffic metrics)


class PoolState(NamedTuple):
    """Per-(instance, slot) live-connection state."""

    req_id: jax.Array      # (I, C) int32, -1 = free
    endpoint: jax.Array    # (I, C) int32 (for load release)
    svc: jax.Array         # (I, C) int32
    length: jax.Array      # (I, C) int32
    token: jax.Array       # (I, C) int32 last emitted/fed token
    active: jax.Array      # (I, C) bool

    @staticmethod
    def init(I: int, C: int) -> "PoolState":
        return PoolState(
            req_id=jnp.full((I, C), -1, jnp.int32),
            endpoint=jnp.full((I, C), -1, jnp.int32),
            svc=jnp.zeros((I, C), jnp.int32),
            length=jnp.zeros((I, C), jnp.int32),
            token=jnp.zeros((I, C), jnp.int32),
            active=jnp.zeros((I, C), bool),
        )


class EngineState(NamedTuple):
    routing: RoutingState
    pool: PoolState
    cache: Any             # model KV/SSM cache, batch dim = I*C
    metrics: FlowMetrics
    key: jax.Array


@dataclasses.dataclass(frozen=True)
class Engine:
    """XLB in-graph serving engine for one service fleet."""

    cfg: ModelConfig
    n_instances: int
    slots: int
    max_len: int
    eos: int = 1
    ctx: Any = DEFAULT_CTX

    # ------------------------------------------------------------------ #
    def init_state(self, routing: RoutingState, dtype=None) -> EngineState:
        return EngineState(
            routing=routing,
            pool=PoolState.init(self.n_instances, self.slots),
            cache=M.init_cache(self.cfg, self.n_instances * self.slots,
                               self.max_len, dtype),
            metrics=FlowMetrics.zeros(),
            key=jax.random.PRNGKey(0),
        )

    # ------------------------------------------------------------------ #
    # admit: routing + balancing + slot allocation + pool commit — one
    # fused Pallas kernel (route → balance → slot-allocate → pool write →
    # metrics), the paper's single in-kernel tail-call chain ending in the
    # sockmap update.  The staged jnp chain lives on in core/router.py +
    # core/policies.py + core/request_map.py (the sidecar baselines and
    # the bench_admit comparison drive it from there).
    # ------------------------------------------------------------------ #
    def admit(self, state: EngineState, reqs: RequestBatch) -> EngineState:
        rstate, pool, metrics = state.routing, state.pool, state.metrics
        key, sub = jax.random.split(state.key)
        kr, kw, _ = jax.random.split(sub, 3)
        R = reqs.req_id.shape[0]
        # host PRNG draws feed the kernel so random/weighted stay on the
        # engine's key stream (and match the admit_ref oracle bit-exactly)
        rnd = jax.random.randint(kr, (R,), 0, 1 << 30, dtype=jnp.int32)
        gumbel = jax.random.gumbel(kw, (R, MAX_EPS_PER_CLUSTER), jnp.float32)

        res = ops.admit_commit(
            reqs.req_id, reqs.svc, reqs.features, reqs.msg_bytes, reqs.token,
            rstate, pool.req_id, pool.endpoint, pool.svc, pool.length,
            pool.token, pool.active, rnd, gumbel)
        # the six PoolState fields come committed straight out of the
        # kernel — no scatter_to_pool post-pass on the fused path
        pool = PoolState(res.pool_req_id, res.pool_endpoint, res.pool_svc,
                         res.pool_length, res.pool_token,
                         res.pool_active > 0)
        # load counters, rr cursors, held release and flow metrics all come
        # fused out of the kernel as well
        rstate = rstate._replace(ep_load=res.ep_load, rr_cursor=res.rr_cursor)
        metrics = metrics._replace(
            requests=metrics.requests + res.svc_requests,
            tx_bytes=metrics.tx_bytes + res.svc_tx_bytes,
            no_route_match=metrics.no_route_match + res.no_route,
            overflow=metrics.overflow + res.held,
        )
        return EngineState(rstate, pool, state.cache, metrics, key)

    # ------------------------------------------------------------------ #
    # step: one batched decode over all lanes; completion handling (done
    # detect → load release → rx metrics → slot free) runs as one fused
    # Pallas kernel over the (I, C) pool — the paper's in-kernel close
    # path.  The staged jnp chain it replaced is kept as the baseline in
    # benchmarks/run.py::bench_step.
    # ------------------------------------------------------------------ #
    def step(self, params, state: EngineState) -> tuple[EngineState, dict]:
        pool, cache = state.pool, state.cache
        I, C = pool.req_id.shape
        B = I * C
        tokens = pool.token.reshape(B, 1)
        lengths = pool.length.reshape(B)
        logits, cache = M.decode_step(self.cfg, params, tokens, lengths,
                                      cache, ctx=self.ctx)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32).reshape(I, C)

        res = ops.complete(pool.req_id, pool.endpoint, pool.svc, pool.length,
                           pool.token, pool.active, nxt,
                           state.routing.ep_load, state.metrics.rx_bytes,
                           eos=self.eos, max_len=self.max_len)
        rstate = state.routing._replace(ep_load=res.ep_load)
        metrics = state.metrics._replace(rx_bytes=res.rx_bytes)
        pool = PoolState(res.req_id, res.endpoint, res.svc, res.length,
                         res.token, res.active > 0)
        out = {"emitted": nxt, "done": res.done > 0,
               "req_id": state.pool.req_id,     # ids that produced this tick
               "active": pool.active.sum()}
        return EngineState(rstate, pool, cache, metrics, state.key), out

    # ------------------------------------------------------------------ #
    def make_jitted(self, donate: bool = True):
        """One fused program: admit + decode step (the XLB datapath).

        Admission is gated by ``lax.cond`` on "any arrivals", so steady-state
        decode ticks skip the routing/allocation work entirely (the paper's
        connect-path eBPF hook only fires on connect)."""

        @partial(jax.jit, donate_argnums=(1,) if donate else ())
        def serve_step(params, state: EngineState, reqs: RequestBatch):
            state = jax.lax.cond(jnp.any(reqs.req_id >= 0),
                                 lambda s: self.admit(s, reqs),
                                 lambda s: s, state)
            return self.step(params, state)

        return serve_step
