"""Per-tensor sharding rules (DP / FSDP / TP / EP / SP) for every arch.

jax 0.8 rejects *uneven* explicit shardings on jit inputs/outputs, so every
rule is divisibility-checked per tensor (``fit_spec``): a dim takes the first
candidate axis (or axis tuple) that divides it; otherwise it stays
replicated.  This is what lets yi-34b (56 heads) or granite (kv=1) share one
rule set with the evenly-shaped archs: the flat weight layouts always divide,
the awkward dims fall back, and GSPMD pads internally where it chooses to.

Baseline layout (recorded as the paper-faithful starting point in
EXPERIMENTS.md §Perf; hillclimbs change these rules):

  params      matrix (…, A, B):  A → fsdp(dp axes), B → tp("model")
              out-projections (…, tp→dp) flipped (Megatron row-parallel)
              MoE expert stacks: E → tp (expert parallel, relay a2a owner)
              embed (V, D): V → dp, D → tp;  head (D, V): D → dp, V → tp
  activations residual (B,S,D): B → dp, S → tp (Megatron-style sequence
              parallelism at block boundaries); decode (B,1,D): B → dp
  cache       (n,B,S,K,hd): B → dp, S → tp (KV-sequence sharding; decode
              softmax reductions become small all-reduces — flash-decoding)
  ssm state   (n,B,nh,hd,N): B → dp, nh → tp
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def axis_size(mesh: Mesh, cand) -> int:
    axes = cand if isinstance(cand, tuple) else (cand,)
    return math.prod(mesh.shape[a] for a in axes)


def fit_spec(mesh: Mesh, shape: Sequence[int], prefs: Sequence[Sequence],
             ) -> P:
    """Per-dim: first candidate axis(-tuple) that divides the dim and is not
    already used; else replicated."""
    used: set = set()
    out = []
    for dim, cands in zip(shape, prefs):
        chosen = None
        for cand in cands:
            if cand is None:
                break
            axes = cand if isinstance(cand, tuple) else (cand,)
            if any(a in used for a in axes):
                continue
            sz = axis_size(mesh, cand)
            if sz > 1 and dim % sz == 0:
                # unwrap 1-tuples: P("data") and P(("data",)) shard the same
                # but old PartitionSpec compares them unequal
                chosen = axes[0] if len(axes) == 1 else cand
                used.update(axes)
                break
        out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """A mesh + the role assignment of its axes.

    ``params_tp_only``: serving layout — parameters live only on the model
    axis and are REPLICATED across dp (each dp slice is an XLB instance
    lane holding a full TP copy).  Kills the per-token FSDP weight
    all-gather that dominates decode; only viable when params/tp fit HBM.
    """

    mesh: Mesh
    params_tp_only: bool = False

    @property
    def dp(self) -> tuple:
        """Data-parallel axes — everything that isn't the model axis."""
        return tuple(a for a in self.mesh.axis_names if a != "model")

    @property
    def param_dp(self) -> tuple:
        return () if self.params_tp_only else self.dp

    @property
    def tp(self) -> str:
        return "model"

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # ------------------------------------------------------------------ #
    # Parameters
    # ------------------------------------------------------------------ #
    def param_spec(self, path: str, shape: Sequence[int]) -> P:
        dp, tp = self.param_dp, self.tp
        r = len(shape)
        none = [()] * r

        def tail(rules):                      # apply rules to trailing dims
            prefs = list(none)
            for off, cands in rules.items():
                prefs[off] = cands
            return fit_spec(self.mesh, shape, prefs)

        if re.search(r"moe/(w_in|w_gate)$", path):
            return tail({r - 3: (tp,), r - 2: (dp,)})
        if re.search(r"moe/w_out$", path):
            return tail({r - 3: (tp,), r - 1: (dp,)})
        if re.search(r"moe/router$", path):
            return tail({r - 2: (dp,)})
        if path.endswith("embed"):
            return tail({r - 2: (dp,), r - 1: (tp,)})
        if path.endswith("head"):
            return tail({r - 2: (dp,), r - 1: (tp,)})
        if re.search(r"(wo|w_out|w_uk|w_uv)$", path) and r >= 2:
            # row-parallel: contraction dim → tp, output dim → dp(fsdp)
            return tail({r - 2: (tp,), r - 1: (dp,)})
        if path.endswith("conv_w"):
            return tail({r - 1: (tp,)})
        if re.search(r"(A_log|dt_bias|/D|norm)", path) or r <= 1 + (
                0 if "blocks" not in path else 1):
            # scalars / per-head vectors / norm scales: replicate
            return P()
        if r >= 2:
            # column-parallel default: input dim → fsdp, output dim → tp
            return tail({r - 2: (dp,), r - 1: (tp,)})
        return P()

    def params_shardings(self, params) -> Any:
        flat = jax.tree_util.tree_flatten_with_path(params)[0]

        def spec_of(kp, leaf):
            path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in kp)
            return self.named(self.param_spec(path, leaf.shape))

        return jax.tree_util.tree_map_with_path(
            lambda kp, leaf: spec_of(kp, leaf), params)

    # ------------------------------------------------------------------ #
    # Activations / batch / cache
    # ------------------------------------------------------------------ #
    def constrain(self, x, kind: str):
        dp, tp = self.dp, self.tp
        if not isinstance(x, jax.Array) and not hasattr(x, "shape"):
            return x
        shape = x.shape
        if kind == "resid":                    # (B,S,D)
            if shape[1] == 1:                  # decode token
                spec = fit_spec(self.mesh, shape, [(dp,), (), (tp,)])
            else:
                spec = fit_spec(self.mesh, shape, [(dp,), (tp,), ()])
        elif kind == "logits":                 # (B,S,V) / (B,V)
            if len(shape) == 3:
                spec = fit_spec(self.mesh, shape, [(dp,), (), (tp,)])
            else:
                spec = fit_spec(self.mesh, shape, [(dp,), (tp,)])
        elif kind == "heads" and len(shape) == 5:      # q (B,S,K,G,hd)
            # layout must agree with the "scores" rule or every chunk pays a
            # reshard: head-shard only when the score slab can shard K or G;
            # otherwise keep q SEQUENCE-sharded (matching the CQ-sharded
            # score slab AND the resid layout).
            ts = axis_size(self.mesh, tp)
            if shape[2] % ts == 0 or shape[3] % ts == 0:
                spec = fit_spec(self.mesh, shape,
                                [(dp,), (), (tp,), (tp,), ()])
            else:
                spec = fit_spec(self.mesh, shape, [(dp,), (tp,), (), (), ()])
        elif kind == "kv_full" and len(shape) == 4:    # K/V: batch-only
            spec = fit_spec(self.mesh, shape, [(dp,), (), (), ()])
        elif kind == "attn_in" and len(shape) == 3:    # x before q/k/v proj
            # gather the sequence at the NARROWEST tensor (width D), so the
            # S-shard → head-shard transition never touches the widened
            # q/k projections (deepseek: 24576-wide q_cat vs 5120-wide x)
            spec = fit_spec(self.mesh, shape, [(dp,), (), ()])
        elif kind == "heads4" and len(shape) == 4:     # (B,S,H,d): H→tp
            spec = fit_spec(self.mesh, shape, [(dp,), (), (tp,), ()])
        elif kind == "scores4" and len(shape) == 4:    # (B,H,CQ,Skv)
            spec = fit_spec(self.mesh, shape, [(dp,), (tp,), (), ()])
        elif kind == "scores" and len(shape) == 5:     # (B,K,G,CQ,Skv)
            # head-shard the score slab; CQ picks up tp when heads can't
            # (yi-34b: 56 heads), keeping the fp32 slab under control
            spec = fit_spec(self.mesh, shape,
                            [(dp,), (tp,), (tp,), (tp,), ()])
        else:
            return x
        return jax.lax.with_sharding_constraint(x, self.named(spec))

    def batch_spec(self, name: str, shape: Sequence[int]) -> P:
        # tokens/labels (B,S): B→dp; enc_frames (B,F,D): B→dp
        return fit_spec(self.mesh, shape,
                        [(self.dp,)] + [()] * (len(shape) - 1))

    # Cache specs are built *structurally* (mirroring model.init_cache) since
    # NamedTuple flattening loses field names.  Each leaf kind has an explicit
    # (B-dim offset, seq/head-dim offset) rule; dims that don't divide fall
    # back via fit_spec (long_500k's batch=1 → the sequence dim picks up the
    # whole (dp+tp) mesh instead: full sequence-parallel decode).
    def _kv_spec(self, shape) -> P:            # (..., B, S, K, hd)
        dp, tp = self.dp, self.tp
        r = len(shape)
        prefs = [()] * r
        b_off = max(r - 4, 0)
        prefs[b_off] = (dp,)
        prefs[b_off + 1] = (tp, dp + (tp,), dp)
        return fit_spec(self.mesh, shape, prefs)

    def _mla_spec(self, shape) -> P:           # (..., B, S, r) latent cache
        dp, tp = self.dp, self.tp
        r = len(shape)
        prefs = [()] * r
        prefs[r - 3] = (dp,)
        prefs[r - 2] = (tp, dp + (tp,), dp)
        return fit_spec(self.mesh, shape, prefs)

    def _ssm_spec(self, shape) -> P:           # (..., B, nh, hd, N)
        dp, tp = self.dp, self.tp
        r = len(shape)
        prefs = [()] * r
        prefs[r - 4] = (dp,)
        prefs[r - 3] = (tp,)
        return fit_spec(self.mesh, shape, prefs)

    def _conv_spec(self, shape) -> P:          # (..., B, C, W-1)
        dp, tp = self.dp, self.tp
        r = len(shape)
        prefs = [()] * r
        prefs[r - 3] = (dp,)
        prefs[r - 2] = (tp,)
        return fit_spec(self.mesh, shape, prefs)

    def cache_pspecs(self, cfg, cache) -> Any:
        """PartitionSpec pytree matching model.init_cache(cfg, ...) output."""
        from repro.models.ssm import SSMState  # local import, no cycle

        def attn_cache_spec(c):
            if "ckv" in c:                     # MLA latent
                return {"ckv": self._mla_spec(c["ckv"].shape),
                        "krope": self._mla_spec(c["krope"].shape)}
            return {k: self._kv_spec(c[k].shape) for k in ("k", "v")}

        if cfg.family == "ssm":
            return SSMState(ssm=self._ssm_spec(cache.ssm.shape),
                            conv=self._conv_spec(cache.conv.shape))
        if cfg.is_hybrid:
            return {
                "attn": attn_cache_spec(cache["attn"]),
                "ssm": SSMState(ssm=self._ssm_spec(cache["ssm"].ssm.shape),
                                conv=self._conv_spec(cache["ssm"].conv.shape)),
            }
        out = {"blocks": {}}
        blocks = cache["blocks"]
        out["blocks"] = {"self": attn_cache_spec(blocks["self"])}
        for extra in ("cross_k", "cross_v"):
            if extra in blocks:
                out["blocks"][extra] = self._kv_spec(blocks[extra].shape)
        if "first" in cache:
            out["first"] = [{"self": attn_cache_spec(c["self"])}
                            for c in cache["first"]]
        return out

    def cache_shardings(self, cfg, cache) -> Any:
        return jax.tree.map(self.named, self.cache_pspecs(cfg, cache),
                            is_leaf=lambda x: isinstance(x, P))

    def batch_shardings(self, batch) -> Any:
        return jax.tree_util.tree_map_with_path(
            lambda kp, leaf: self.named(self.batch_spec(str(kp), leaf.shape)),
            batch)
