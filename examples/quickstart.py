"""Quickstart: the XLB in-graph L7 load balancer in ~60 lines.

Builds a canary-routing config (the paper's §5.1 example: one virtual IP,
v2-cookie users go to the canary pool) through the ControlPlane, compiles
the serving engine, pushes requests through it, then commits a *delta
refresh* transaction (grow the stable pool + shift a weight) with zero
recompilation and a single version bump.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.core.balancer import make_balancer
from repro.core.control import ControlPlane
from repro.core.routing_table import (Cluster, POLICY_LEAST_REQUEST,
                                      POLICY_RR, Rule, ServiceConfig)
from repro.models import model as M
from repro.runtime.serve_loop import Request, ServeLoop

# 1. the application: a tiny LM standing in for a microservice fleet
cfg = smoke_config(get_config("xlb-service-model"))
params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

# 2. control plane: Envoy-style config → nested-map RoutingState, owned by
# a ControlPlane (names, slot allocation, transactions — the Go daemon)
cp = ControlPlane(
    services=[ServiceConfig("frontend", rules=[
        Rule(field=2, value="v2", cluster="canary"),      # version header
        Rule(field=2, value=None, cluster="stable"),      # wildcard
    ])],
    clusters=[
        Cluster("canary", endpoints=[0], policy=POLICY_RR),
        Cluster("stable", endpoints=[1, 2, 3],
                policy=POLICY_LEAST_REQUEST),
    ])

# 3. data plane: 4 instance lanes × 4 slots, admission+decode in ONE program
engine = make_balancer("xlb", cfg, n_instances=4, slots=4, max_len=12)
loop = ServeLoop(engine, params, cp)       # attaches the loop to cp

for i in range(8):
    loop.submit(Request(req_id=i, service=0,
                        headers={"path": "/checkout",
                                 "version": "v2" if i % 4 == 0 else "v1"},
                        prompt_token=3 + i))
rep = loop.drain()
print(f"completed {len(rep.done)} requests "
      f"(queued={rep.queued} inflight={rep.inflight})")
for r in sorted(rep.done, key=lambda r: r.req_id)[:4]:
    print(f"  req {r.req_id} ({r.headers['version']}): tokens={r.tokens}")

m = loop.state.metrics
print("traffic metrics: requests =", int(m.requests.sum()),
      " no_route =", int(m.no_route_match), " overflow =", int(m.overflow))

# 4. delta refresh: one transaction grows the stable pool and re-weights the
# canary while the datapath keeps serving — same pytree shapes, so the
# compiled step is reused (no recompilation), and the whole batch lands with
# a single version bump.
with cp.transaction():
    cp.add_endpoint("stable", instance=3)
    cp.set_weight("canary", instance=0, weight=2.0)
loop.submit(Request(req_id=100, service=0, headers={"version": "v1"},
                    prompt_token=9))
rep = loop.drain()
print(f"after delta refresh: completed {len(rep.done)} total, "
      f"routing version = {int(loop.routing.version)} "
      f"(control plane commit #{cp.version})")
