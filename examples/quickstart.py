"""Quickstart: the XLB in-graph L7 load balancer in ~60 lines.

Builds a canary-routing config (the paper's §5.1 example: one virtual IP,
v2-cookie users go to the canary pool), compiles the serving engine, pushes
requests through it, then performs a *delta refresh* (add an endpoint) with
zero recompilation.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core import delta, interpose
from repro.core.routing_table import (Cluster, POLICY_LEAST_REQUEST,
                                      POLICY_RR, Rule, ServiceConfig,
                                      build_state)
from repro.models import model as M
from repro.runtime.serve_loop import Request, ServeLoop

# 1. the application: a tiny LM standing in for a microservice fleet
cfg = smoke_config(get_config("xlb-service-model"))
params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)

# 2. control plane: Envoy-style config → nested-map RoutingState
routing, ids = build_state(
    services=[ServiceConfig("frontend", rules=[
        Rule(field=2, value="v2", cluster="canary"),      # version header
        Rule(field=2, value=None, cluster="stable"),      # wildcard
    ])],
    clusters=[
        Cluster("canary", endpoints=[0], policy=POLICY_RR),
        Cluster("stable", endpoints=[1, 2, 3],
                policy=POLICY_LEAST_REQUEST),
    ])

# 3. data plane: 4 instance lanes × 4 slots, admission+decode in ONE program
engine = interpose.Engine(cfg, n_instances=4, slots=4, max_len=12)
loop = ServeLoop(engine, params, routing)

for i in range(8):
    loop.submit(Request(req_id=i, service=0,
                        headers={"path": "/checkout",
                                 "version": "v2" if i % 4 == 0 else "v1"},
                        prompt_token=3 + i))
done = loop.drain()
print(f"completed {len(done)} requests")
for r in sorted(done, key=lambda r: r.req_id)[:4]:
    print(f"  req {r.req_id} ({r.headers['version']}): tokens={r.tokens}")

m = loop.state.metrics
print("traffic metrics: requests =", int(m.requests.sum()),
      " no_route =", int(m.no_route_match), " overflow =", int(m.overflow))

# 4. delta refresh: grow the stable pool while the datapath keeps serving —
# same pytree shapes, so the compiled step is reused (no recompilation).
st2 = delta.add_endpoint(loop.state.routing, ids["clusters"]["stable"],
                         ep_slot=4, instance=3)
loop.state = loop.state._replace(routing=st2)
loop.submit(Request(req_id=100, service=0, headers={"version": "v1"},
                    prompt_token=9))
done = loop.drain()
print(f"after delta refresh: completed {len(done)} total, "
      f"routing version = {int(st2.version)}")
