"""Serve a microservice application graph (bookinfo) behind XLB.

One engine per service; requests fan out along the call graph.  All three
architectures run through the same Balancer protocol + ControlPlane-built
routing (benchmarks/common.py) — the comparison below is the paper's
Fig. 11 in miniature with zero per-engine glue.

Run:  PYTHONPATH=src python examples/serve_cluster.py
"""

import time

from benchmarks import common
from repro.configs import BOOKINFO

print(f"topology: {BOOKINFO.name}: " +
      " -> ".join(BOOKINFO.chain()))

for mode in ("istio", "cilium", "xlb"):
    r = common.run_graph(mode, BOOKINFO, n_requests=8, tokens_per_req=2)
    print(f"{mode:7s}: {r['completed']} done  "
          f"{r['req_per_s']:8.1f} req/s  avg {r['avg_ms']:7.2f} ms")
