"""End-to-end training driver: ~100M-parameter MoE for a few hundred steps.

Exercises the full substrate on CPU: deterministic pipeline → fwd/bwd with
the XLB expert relay (token→expert load balancing with least-request router
bias) → AdamW → async checkpoints → restart-on-failure.

Run:  PYTHONPATH=src python examples/train_moe.py [--steps 300]
"""

import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig, MoEConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.optim import adamw
from repro.runtime import train_loop

# ~100M-param MoE in the deepseek-v2 family shape (shared + routed experts)
CFG = ModelConfig(
    name="deepseek-mini-100m", family="moe",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=8192, head_dim=64, ffn_act="swiglu",
    moe=MoEConfig(n_experts=16, top_k=2, n_shared_experts=1,
                  d_ff_expert=512, first_dense=1),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train-moe")
    args = ap.parse_args()

    print(f"model: {CFG.name}  params≈{CFG.param_count()/1e6:.1f}M "
          f"(active {CFG.active_param_count()/1e6:.1f}M)")
    pipe = Pipeline(DataConfig(vocab=CFG.vocab, seq_len=args.seq,
                               global_batch=args.batch))
    tcfg = train_loop.TrainConfig(
        steps=args.steps, ckpt_every=100, ckpt_dir=args.ckpt_dir,
        opt=adamw.AdamWConfig(lr=1e-3), warmup=30, log_every=20)
    out = train_loop.run(CFG, pipe, tcfg)
    losses = [h["loss"] for h in out["history"]]
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps; restarts={out['restarts']}")


if __name__ == "__main__":
    main()
