"""Generate EXPERIMENTS.md from the dry-run JSONs + benchmark CSV +
hillclimb log.  Run:  python experiments/make_report.py
"""

import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["granite-20b", "internlm2-20b", "yi-34b", "minitron-4b",
              "deepseek-v2-236b", "arctic-480b", "whisper-large-v3",
              "chameleon-34b", "mamba2-2.7b", "jamba-v0.1-52b"]


def load(mesh):
    out = {}
    for f in glob.glob(os.path.join(HERE, "dryrun", f"*__{mesh}.json")):
        r = json.load(open(f))
        out[(r.get("arch") or os.path.basename(f).split("__")[0],
             r.get("shape") or os.path.basename(f).split("__")[1])] = r
    return out


def fmt_s(x):
    if x is None:
        return "—"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def dryrun_section(single, multi):
    lines = ["## §Dry-run — lower+compile for every (arch × shape × mesh)",
             "",
             "Both meshes: single-pod `(data=16, model=16)` = 256 chips and "
             "multi-pod `(pod=2, data=16, model=16)` = 512 chips. "
             "`.lower().compile()` succeeds for **every** cell below "
             "(ShapeDtypeStruct AOT — no allocation). Memory columns are "
             "per-device from `compiled.memory_analysis()`; collective "
             "schedule parsed from the compiled SPMD module.",
             "",
             "| arch | shape | mesh | per-dev GiB (arg+out+tmp) | HLO "
             "collectives (count) | wire GB/dev/step | compile s |",
             "|---|---|---|---|---|---|---|"]
    for (arch, shape) in [(a, s) for a in ARCH_ORDER for s in SHAPE_ORDER]:
        for mesh, table in (("16x16", single), ("2x16x16", multi)):
            r = table.get((arch, shape))
            if r is None:
                continue
            if "skipped" in r:
                if mesh == "16x16":
                    lines.append(f"| {arch} | {shape} | both | — | skipped: "
                                 f"{r['skipped'][:60]}… | — | — |")
                continue
            m = r["memory_analysis"]
            colls = r["hlo"]["collectives"]
            cstr = " ".join(f"{k.replace('collective-','c-')}"
                            f"×{int(v['count'])}" for k, v in colls.items())
            lines.append(
                f"| {arch} | {shape} | {mesh} | {m['total_GiB']:.1f} | "
                f"{cstr or 'none'} | "
                f"{r['hlo']['collective_bytes_per_device']/1e9:.1f} | "
                f"{r['compile_s']:.0f} |")
    lines += [
        "",
        "**CPU-backend artifacts (affect the absolute numbers, not the "
        "structure):** XLA:CPU upcasts bf16 dot operands to f32 *before* "
        "GSPMD-inserted collectives, so weight all-gathers and partial-sum "
        "all-reduces appear at 4 B/elt where a TPU build moves 2 B/elt — "
        "collective bytes and the f32 temp copies in `memory_analysis` are "
        "conservative (≈2× worst case). XLA:CPU also lacks the TPU "
        "all-reduce→reduce-scatter rewrite, so Megatron-style row-parallel "
        "sums are counted at AR cost (2×(g−1)/g) instead of RS.",
    ]
    return "\n".join(lines)


def roofline_section(single):
    lines = ["## §Roofline — single-pod (16×16, 256 × TPU v5e)",
             "",
             "Terms per step, per device: compute = parsed HLO dot-FLOPs / "
             "197 TF/s; memory = analytic HBM traffic / 819 GB/s; collective "
             "= parsed wire bytes / 50 GB/s. Parsed values come from the "
             "compiled SPMD module with per-`while` `known_trip_count` "
             "scaling (XLA's own `cost_analysis` counts loop bodies once — "
             "verified here — so raw values are recorded but not used). "
             "MODEL_FLOPS = 6·N·T (train) / 2·N·T+attn (serve), N = active "
             "params.",
             "",
             "| arch | shape | compute | memory | collective | dominant | "
             "MODEL_FLOPS | useful ratio | roofline frac | what moves the "
             "bottleneck |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    fixes = {
        ("collective", "train"): "explicit bf16 FSDP gather + RS inside "
        "shard_map; overlap weight gathers with compute",
        ("collective", "prefill"): "same + keep KV gather per layer (not per "
        "chunk)",
        ("collective", "decode"): "serve params pure-TP (replicate over dp): "
        "kills the per-token weight all-gather",
        ("memory", "decode"): "already at the HBM floor: params+cache read "
        "per token; batch more lanes",
        ("memory", "train"): "fuse optimizer reads; bf16 moments",
        ("compute", "train"): "at the MXU roof; raise MFU via remat policy",
    }
    for (arch, shape) in [(a, s) for a in ARCH_ORDER for s in SHAPE_ORDER]:
        r = single.get((arch, shape))
        if r is None or "skipped" in r:
            continue
        rf = r["roofline"]
        kind = "train" if shape == "train_4k" else (
            "prefill" if "prefill" in shape else "decode")
        fix = fixes.get((rf["dominant"], kind), "")
        ur = rf["useful_flops_ratio"]
        lines.append(
            f"| {arch} | {shape} | {fmt_s(rf['compute_s'])} | "
            f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
            f"**{rf['dominant']}** | {rf['model_flops']:.2e} | "
            f"{ur:.2f} | {rf['roofline_fraction']:.4f} | {fix} |")
    lines += [
        "",
        "`useful ratio` = MODEL_FLOPS/device ÷ parsed HLO FLOPs/device "
        "(<1 ⇒ remat/padding/dispatch overhead; ≈0.65 on trains is the "
        "remat recompute +1 fwd). `roofline frac` = (MODEL_FLOPS/device ÷ "
        "peak) ÷ max(term) — the score this report optimises in §Perf.",
        "",
        "long_500k is skipped for the 8 pure-full-attention archs "
        "(quadratic at 524k; per assignment) and runs for mamba2-2.7b and "
        "jamba-v0.1-52b via SSM state + sequence-sharded KV.",
    ]
    return "\n".join(lines)


def perf_section():
    log_path = os.path.join(HERE, "perf_log.json")
    if not os.path.exists(log_path):
        return "## §Perf\n\n(hillclimb log pending)"
    log = json.load(open(log_path))
    lines = ["## §Perf — hypothesis → change → measure → validate",
             "",
             log.get("preamble", ""), ""]
    for cell in log["cells"]:
        lines += [f"### {cell['name']}", "", cell.get("why", ""), "",
                  "| # | hypothesis | change | before (dom term) | after | "
                  "Δ | verdict |", "|---|---|---|---|---|---|---|"]
        for i, it in enumerate(cell["iterations"]):
            lines.append(
                f"| {i+1} | {it['hypothesis']} | {it['change']} | "
                f"{it['before']} | {it['after']} | {it['delta']} | "
                f"{it['verdict']} |")
        lines += ["", cell.get("summary", ""), ""]
    lines += [log.get("closing", ""), ""]

    # variant cells measured on disk: paper-faithful baseline vs optimized
    var_files = sorted(glob.glob(os.path.join(HERE, "dryrun",
                                              "*__16x16__*.json")))
    if var_files:
        lines += ["### Baseline vs optimized cells (both recorded, per the "
                  "reproduce-then-optimize contract)", "",
                  "| cell | variant | compute | memory | collective | "
                  "dominant | roofline frac | per-dev GiB |",
                  "|---|---|---|---|---|---|---|---|"]
        for f in var_files:
            r = json.load(open(f))
            if "roofline" not in r:
                continue
            base = os.path.join(HERE, "dryrun",
                                f"{r['arch']}__{r['shape']}__16x16.json")
            for tag, rr in (("baseline", json.load(open(base))), (
                    r.get("variant", "opt"), r)):
                rf = rr["roofline"]
                lines.append(
                    f"| {r['arch']} × {r['shape']} | {tag} | "
                    f"{fmt_s(rf['compute_s'])} | {fmt_s(rf['memory_s'])} | "
                    f"{fmt_s(rf['collective_s'])} | {rf['dominant']} | "
                    f"{rf['roofline_fraction']:.4f} | "
                    f"{rr['memory_analysis']['total_GiB']:.1f} |")
    return "\n".join(lines)


def paper_claims_section():
    csv_path = os.path.join(ROOT, "bench_output.txt")
    if not os.path.exists(csv_path):
        return ("## §Paper-claims\n\n(run `PYTHONPATH=src python -m "
                "benchmarks.run | tee bench_output.txt` first)")
    rows = [l.strip() for l in open(csv_path) if "," in l and
            not l.startswith("bench,")]
    lines = ["## §Paper-claims — Table 1/2 and Figs 5–12 analogues (CPU)",
             "",
             "Measured on this container (1 CPU core; tiny per-service "
             "model). `istio` = per-instance proxy programs + host routing; "
             "`cilium` = one global program + host routing; `xlb` = one "
             "fused in-graph program. NOTE the CPU backend makes host↔device "
             "copies ≈free (no PCIe/kernel crossing), so xlb-vs-cilium gaps "
             "here are a conservative floor; xlb-vs-istio shows the "
             "per-instance dispatch cost the paper attributes to per-service "
             "sidecars. At long chain lengths (fig8 len≥6) XLB's fused "
             "program pays a fixed per-launch dispatch cost per hop that "
             "python host routing undercuts on this 1-core container — on a "
             "real accelerator the launch is amortised by device compute and "
             "the host router pays PCIe/kernel crossings instead.",
             "", "```csv"]
    lines += rows
    lines += ["```"]
    return "\n".join(lines)


def scenario_slo_section():
    """SLO tail tables from the workload subsystem's scenario rows in
    BENCH_TREND.jsonl (bench == "scenario"; benchmarks/run.py chain).
    Latencies are deterministic engine ticks — identical seed, identical
    table.  Only the latest row per (scenario, mode, depth, seed) is
    shown; the JSONL keeps the full history."""
    path = os.path.join(ROOT, "BENCH_TREND.jsonl")
    if not os.path.exists(path):
        return ("## §Scenario SLOs\n\n(run `PYTHONPATH=src python -m "
                "benchmarks.run chain` first)")
    rows = {}
    for line in open(path):
        line = line.strip()
        if not line:
            continue
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        if r.get("bench") == "scenario":
            rows[(r["scenario"], r["mode"], r["depth"], r["seed"])] = r
    if not rows:
        return ("## §Scenario SLOs\n\n(no scenario rows yet — run "
                "`PYTHONPATH=src python -m benchmarks.run chain`)")
    import sys
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.workload.slo import format_slo_table
    ordered = [rows[k] for k in sorted(rows)]
    lines = ["## §Scenario SLOs — workload subsystem (DESIGN.md §10)",
             "",
             "Per-request end-to-end latency through a depth-D service "
             "chain, in deterministic engine ticks (admit at hop 0 → "
             "completion at hop D-1; `eos=-1` makes completion purely "
             "length-driven). `chain` is the plain seeded Poisson stream "
             "the chain gate compares engines on; `chain_liveops` replays "
             "a mid-run canary shift + elastic scale-down/up against the "
             "xlb chain. Rows come from BENCH_TREND.jsonl "
             "(schema-validated at append time).",
             "",
             format_slo_table(ordered)]
    return "\n".join(lines)


def degraded_trajectory_section():
    """Eject/recover trajectory of the closed health loop plus the graded
    heterogeneous-fleet leg's per-epoch weight timeline, from
    BENCH_degraded.json (benchmarks/run.py degraded)."""
    path = os.path.join(ROOT, "BENCH_degraded.json")
    if not os.path.exists(path):
        return ("## §Degraded trajectory\n\n(run `PYTHONPATH=src python -m "
                "benchmarks.run degraded` first)")
    rec = json.load(open(path))
    if "classic" not in rec:                 # pre-transport flat record
        rec = {"classic": rec}
    c = rec["classic"]
    sick = c["n_instances"] - 1
    lines = ["## §Degraded trajectory — closed health loop (DESIGN.md §8)",
             "",
             f"Instance {sick} runs {c['factor']}× slow over ticks "
             f"[{c['fault_start']}, {c['fault_end']}); the breaker ejected "
             f"at tick {c['eject_tick']}, re-admitted at tick "
             f"{c['uneject_tick']}, with {c['daemon_txns']} daemon and "
             f"{c['operator_txns']} operator transactions. p99 ticks: "
             f"healthy {c['healthy_p99_ticks']:.1f} → degraded "
             f"{c['degraded_p99_ticks']:.1f} → recovered "
             f"{c['recovered_p99_ticks']:.1f} (ratio "
             f"{c['recovery_ratio']:.2f})."]
    tl = c.get("timeline") or []
    if tl:
        seq, prev = [], None
        for e in tl:
            st = e["state"][sick]
            if st != prev:
                seq.append(f"t{e['tick']}:{st}")
                prev = st
        lines += ["", "Breaker trajectory of the sick instance (per health "
                  "epoch): " + " → ".join(seq)]
    g = rec.get("graded")
    if g:
        n = g["n_instances"]
        lines += [
            "",
            f"**Graded leg** (WEIGHTED cluster, heterogeneous fleet: "
            f"instance 1 permanently 2× slow, instance {n - 1} "
            f"{g['factor']}× slow over [{g['fault_start']}, "
            f"{g['fault_end']})): {g['daemon_txns']} weight commits, no "
            f"ejection (min sick weight "
            f"{g['min_sick_weight']}, end weight "
            f"{g['end_weight']:.2f}). Per-epoch graded weights:",
            "",
            "| tick | " + " | ".join(f"w[{i}]" for i in range(n)) + " |",
            "|---|" + "---|" * n]
        gtl = g.get("timeline") or []
        shown = gtl[::4] + ([gtl[-1]] if gtl and gtl[-1] not in gtl[::4]
                            else [])
        for e in shown:
            ws = " | ".join("—" if w is None else f"{w:.2f}"
                            for w in e["weights"])
            lines.append(f"| {e['tick']} | {ws} |")
    return "\n".join(lines)


def chaos_section():
    """Transport-chaos record: convergence verdict, channel damage,
    resync accounting and the SLO-recovery comparison vs the fault-free
    baseline leg, from BENCH_chaos.json (benchmarks/run.py chaos)."""
    path = os.path.join(ROOT, "BENCH_chaos.json")
    if not os.path.exists(path):
        return ("## §Chaos transport\n\n(run `PYTHONPATH=src python -m "
                "benchmarks.run chaos` first)")
    rec = json.load(open(path))
    row = rec["chaos"]["row"]
    base = rec["baseline"]["row"]
    rep = rec["chaos"]["report"]
    lines = ["## §Chaos transport — versioned resync under a lossy control "
             "channel (DESIGN.md §11)",
             "",
             f"{row['versions']} config versions shipped to "
             f"{row['consumers']} consumers over a channel that dropped "
             f"{row['msgs_dropped']}, duplicated {row['msgs_duped']} and "
             f"partitioned {row['msgs_partitioned']} of {row['msgs_sent']} "
             f"messages; one consumer crash-restarted mid-canary "
             f"({row['crashes']} crash → {row['resyncs']} snapshot "
             f"resync). Publisher: {row['plan_sends']} journal plan sends, "
             f"{row['snap_sends']} snapshots. Converged: "
             f"**{row['converged']}** ({len(rep['issues'])} invariant "
             f"issues); all rows replay bit-identically under seed "
             f"{row['seed']}.",
             "",
             "| leg | p99 healthy | p99 chaos window | p99 recovered | "
             "converged | resyncs | crashes |",
             "|---|---|---|---|---|---|---|"]
    for tag, r in (("chaos", row), ("fault-free baseline", base)):
        lines.append(
            f"| {tag} | {r['healthy_p99_ticks']:.1f} | "
            f"{r['chaos_p99_ticks']:.1f} | {r['recovered_p99_ticks']:.1f} "
            f"| {r['converged']} | {r['resyncs']} | {r['crashes']} |")
    lines += ["",
              "| consumer | alive | version | resyncs | stale no-ops | "
              "rejected |",
              "|---|---|---|---|---|---|"]
    for e in rep["consumers"]:
        lines.append(f"| {e['node']} | {e['alive']} | {e['version']} | "
                     f"{e['resyncs']} | {e['stale']} | {e['rejected']} |")
    return "\n".join(lines)


def main():
    single, multi = load("16x16"), load("2x16x16")
    ok_s = sum(1 for r in single.values() if "roofline" in r)
    ok_m = sum(1 for r in multi.values() if "roofline" in r)
    head = [
        "# EXPERIMENTS",
        "",
        f"Dry-run matrix: 10 archs × 4 shapes × 2 meshes — "
        f"**{ok_s}/32 single-pod and {ok_m}/32 multi-pod cells compile** "
        "(8 cells per mesh are assignment-mandated long_500k skips for "
        "pure-attention archs). Generated by `experiments/make_report.py` "
        "from `experiments/dryrun/*.json`.",
        "",
    ]
    body = [dryrun_section(single, multi), "", roofline_section(single), "",
            perf_section(), "", paper_claims_section(), "",
            scenario_slo_section(), "", degraded_trajectory_section(), "",
            chaos_section()]
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write("\n".join(head + body) + "\n")
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
