"""Autotuner contract (kernels/tune.py): env pins beat the sweep, explicit
arguments beat everything, XLB_AUTOTUNE=0 never times a candidate, and a
swept choice is cached (one sweep per (kernel, backend, shape))."""

import math

import pytest

from repro.kernels import backend, tune


@pytest.fixture(autouse=True)
def _fresh_cache():
    tune.clear_cache()
    yield
    tune.clear_cache()


def _forbid_timing(monkeypatch):
    def boom(*a, **k):
        raise AssertionError("autotuner timed a candidate under a pin")
    monkeypatch.setattr(tune, "_time_best", boom)


def test_env_override_is_deterministic(monkeypatch):
    """The CI pin: with XLB_BLOCK_R/XLB_BLOCK_I/XLB_FOLD set, every plan is
    the pinned value, no candidate is ever timed, and repeated calls (even
    across cache clears) return the same plan."""
    monkeypatch.setenv(tune.ENV_AUTOTUNE, "1")
    monkeypatch.setenv(tune.ENV_BLOCK_R, "64")
    monkeypatch.setenv(tune.ENV_BLOCK_I, "2")
    monkeypatch.setenv(tune.ENV_FOLD, "onehot")
    _forbid_timing(monkeypatch)
    plans = set()
    for _ in range(3):
        tune.clear_cache()
        plans.add(tune.plan_admit(4096, (8, 64)))
        plans.add(tune.plan_admit(4096, (8, 64), commit=True))
        plans.add(tune.plan_complete((16, 256)))
    assert plans == {(64, "onehot"), (2, "onehot")}


def test_autotune_off_uses_static_defaults(monkeypatch):
    monkeypatch.setenv(tune.ENV_AUTOTUNE, "0")
    monkeypatch.delenv(tune.ENV_BLOCK_R, raising=False)
    monkeypatch.delenv(tune.ENV_BLOCK_I, raising=False)
    monkeypatch.delenv(tune.ENV_FOLD, raising=False)
    _forbid_timing(monkeypatch)
    br, fold = tune.plan_admit(4096, (8, 64))
    assert br == tune.DEFAULT_BLOCK_R
    assert fold == backend.default_fold()
    bi, _ = tune.plan_complete((16, 256))
    assert bi == math.gcd(16, tune.DEFAULT_BLOCK_I)
    # small batches clamp the default tile to the batch
    assert tune.plan_admit(32, (8, 64))[0] == 32


def test_explicit_args_outrank_env(monkeypatch):
    monkeypatch.setenv(tune.ENV_BLOCK_R, "64")
    monkeypatch.setenv(tune.ENV_FOLD, "onehot")
    _forbid_timing(monkeypatch)
    assert tune.plan_admit(4096, (8, 64), block_r=512,
                           fold="segment") == (512, "segment")
    assert tune.plan_complete((16, 256), block_i=4,
                              fold="segment") == (4, "segment")


def test_sweep_picks_fastest_and_caches(monkeypatch):
    """With autotune on and no pins: the sweep times each candidate once,
    picks the argmin, and the second identical call is a pure cache hit."""
    monkeypatch.setenv(tune.ENV_AUTOTUNE, "1")
    monkeypatch.delenv(tune.ENV_BLOCK_R, raising=False)
    calls = []

    def fake_time(fn, *a, **k):
        # deterministic fake timer: candidate identity is recoverable from
        # the sweep log, so just rank by insertion order — last wins
        calls.append(fn)
        return float(len(calls) % 7 == 3) + 1.0 / len(calls)

    monkeypatch.setattr(tune, "_time_best", fake_time)
    br1, fold1 = tune.plan_admit(1024, (4, 16))
    n_after_first = len(calls)
    assert n_after_first == len(tune._admit_candidates(1024)) > 1
    assert br1 in tune._admit_candidates(1024)
    br2, fold2 = tune.plan_admit(1024, (4, 16))
    assert (br1, fold1) == (br2, fold2)
    assert len(calls) == n_after_first          # cache hit: no re-timing
    # a different shape sweeps separately
    tune.plan_admit(256, (4, 16))
    assert len(calls) > n_after_first


def test_complete_candidates_divide_pool():
    for I in (1, 2, 6, 8, 16, 24):
        for b in tune._complete_candidates(I):
            assert I % b == 0 and b >= 1


def test_fold_validation():
    with pytest.raises(ValueError):
        backend.resolve_fold("bogus")
    assert backend.resolve_fold(None) in backend.FOLDS
