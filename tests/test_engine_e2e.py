"""End-to-end serving-engine tests: the XLB in-graph engine and the two
sidecar baselines must emit bit-identical token streams per request (greedy
decode is per-sequence independent of which instance/slot serves it)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import interpose, sidecar
from repro.core.routing_table import (Cluster, POLICY_RR, Rule, ServiceConfig,
                                      build_state)
from repro.models import model as M

I, C, MAXLEN, NREQ = 2, 3, 24, 4


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(get_config("xlb-service-model"))
    params = M.init_params(cfg, jax.random.PRNGKey(7), dtype=jnp.float32)
    services = [ServiceConfig("svc", rules=[Rule(0, None, "pool")])]
    clusters = [Cluster("pool", endpoints=list(range(I)), policy=POLICY_RR)]
    routing, _ = build_state(services, clusters)
    return cfg, params, routing


def _reqs(cfg, n=NREQ, pad_to=8):
    rid = np.full((pad_to,), -1, np.int32)
    rid[:n] = np.arange(n)
    tok = np.zeros((pad_to,), np.int32)
    tok[:n] = 3 + np.arange(n) % (cfg.vocab - 3)
    return interpose.RequestBatch(
        req_id=jnp.asarray(rid), svc=jnp.zeros((pad_to,), jnp.int32),
        features=jnp.zeros((pad_to, 8), jnp.int32), token=jnp.asarray(tok),
        msg_bytes=jnp.full((pad_to,), 100, jnp.int32))


def _drain_xlb(cfg, params, routing, steps=12):
    eng = interpose.Engine(cfg, I, C, MAXLEN)
    state = eng.init_state(routing, dtype=jnp.float32)
    serve = eng.make_jitted(donate=False)
    reqs = _reqs(cfg)
    streams = {}
    for t in range(steps):
        state, out = serve(params, state, reqs)
        reqs = _reqs(cfg, n=0)                     # only admit on step 0
        emitted = np.asarray(out["emitted"])
        pool_req = np.asarray(state.pool.req_id)
        done = np.asarray(out["done"])
        act = np.asarray(state.pool.active)
        for i in range(I):
            for s in range(C):
                r = pool_req[i, s]
                if r >= 0 and act[i, s]:
                    streams.setdefault(int(r), []).append(int(emitted[i, s]))
                elif done[i, s]:
                    pass
    return streams, state


def _drain_sidecar(cfg, params, routing, mode, steps=12):
    eng = sidecar.SidecarEngine(cfg, I, C, MAXLEN, routing, mode=mode)
    eng.admit(_reqs(cfg))
    streams = {}
    for t in range(steps):
        before_req = eng.pool_req.copy()
        before_act = eng.pool_active.copy()
        eng.step(params)
        for i in range(I):
            for s in range(C):
                if before_act[i, s]:
                    streams.setdefault(int(before_req[i, s]), []).append(
                        int(eng.pool_tok[i, s]))
    return streams


def test_xlb_emits_all_requests(setup):
    cfg, params, routing = setup
    streams, state = _drain_xlb(cfg, params, routing)
    assert set(streams) == set(range(NREQ))
    assert int(state.metrics.requests.sum()) == NREQ
    assert int(state.metrics.no_route_match) == 0


def test_xlb_matches_sidecars_tokenwise(setup):
    cfg, params, routing = setup
    xlb, _ = _drain_xlb(cfg, params, routing, steps=10)
    istio = _drain_sidecar(cfg, params, routing, "istio", steps=10)
    cilium = _drain_sidecar(cfg, params, routing, "cilium", steps=10)
    for r in range(NREQ):
        n = min(len(xlb[r]), len(istio[r]), len(cilium[r]))
        assert n >= 3
        assert xlb[r][:n] == istio[r][:n] == cilium[r][:n], (
            f"req {r}: xlb={xlb[r][:n]} istio={istio[r][:n]} "
            f"cilium={cilium[r][:n]}")


def test_slot_reuse_after_completion(setup):
    """Pool slots freed by EOS/length completion get reused by new arrivals."""
    cfg, params, routing = setup
    eng = interpose.Engine(cfg, I, C, max_len=6)   # force quick completion
    state = eng.init_state(routing, dtype=jnp.float32)
    serve = eng.make_jitted(donate=False)
    state, _ = serve(params, state, _reqs(cfg, n=6))   # fill all 6 slots
    assert int(state.pool.active.sum()) == 6
    for _ in range(8):
        state, out = serve(params, state, _reqs(cfg, n=0))
    assert int(state.pool.active.sum()) == 0           # all completed
    state, _ = serve(params, state, _reqs(cfg, n=3))
    assert int(state.pool.active.sum()) == 3           # slots reused
