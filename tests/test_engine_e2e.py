"""End-to-end serving-engine tests: the XLB in-graph engine and the two
sidecar baselines must emit bit-identical token streams per request (greedy
decode is per-sequence independent of which instance/slot serves it).

All three engines are driven by ONE generic loop through the Balancer
protocol — the test itself is the proof that no per-engine glue remains.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.balancer import ENGINE_KINDS, Balancer, RequestBatch, \
    make_balancer
from repro.core.routing_table import (Cluster, POLICY_RR, Rule, ServiceConfig,
                                      build_state)
from repro.models import model as M

I, C, MAXLEN, NREQ = 2, 3, 24, 4


@pytest.fixture(scope="module")
def setup():
    from repro.configs import get_config, smoke_config
    cfg = smoke_config(get_config("xlb-service-model"))
    params = M.init_params(cfg, jax.random.PRNGKey(7), dtype=jnp.float32)
    services = [ServiceConfig("svc", rules=[Rule(0, None, "pool")])]
    clusters = [Cluster("pool", endpoints=list(range(I)), policy=POLICY_RR)]
    routing, _ = build_state(services, clusters)
    return cfg, params, routing


def _reqs(cfg, n=NREQ, pad_to=8):
    rid = np.full((pad_to,), -1, np.int32)
    rid[:n] = np.arange(n)
    tok = np.zeros((pad_to,), np.int32)
    tok[:n] = 3 + np.arange(n) % (cfg.vocab - 3)
    return RequestBatch(
        req_id=jnp.asarray(rid), svc=jnp.zeros((pad_to,), jnp.int32),
        features=jnp.zeros((pad_to, 8), jnp.int32), token=jnp.asarray(tok),
        msg_bytes=jnp.full((pad_to,), 100, jnp.int32))


def _drain(cfg, params, routing, mode, steps=12):
    """One driver for every engine: admit on step 0, then pure decode —
    identical bookkeeping against the protocol's uniform state/out shapes."""
    eng = make_balancer(mode, cfg, I, C, MAXLEN)
    assert isinstance(eng, Balancer)
    state = eng.init_state(routing, dtype=jnp.float32)
    serve = eng.make_jitted(donate=False)
    reqs = _reqs(cfg)
    streams = {}
    for t in range(steps):
        state, out = serve(params, state, reqs)
        reqs = _reqs(cfg, n=0)                     # only admit on step 0
        emitted = np.asarray(out["emitted"])
        pool_req = np.asarray(state.pool.req_id)
        act = np.asarray(state.pool.active)
        for i in range(I):
            for s in range(C):
                r = pool_req[i, s]
                if r >= 0 and act[i, s]:
                    streams.setdefault(int(r), []).append(int(emitted[i, s]))
    return streams, state


def test_xlb_emits_all_requests(setup):
    cfg, params, routing = setup
    streams, state = _drain(cfg, params, routing, "xlb")
    assert set(streams) == set(range(NREQ))
    assert int(state.metrics.requests.sum()) == NREQ
    assert int(state.metrics.no_route_match) == 0


def test_sidecars_emit_all_requests(setup):
    """The protocol contract (out keys, pool/metrics state shapes) holds for
    the host-interposed engines too."""
    cfg, params, routing = setup
    for mode in ("istio", "cilium"):
        streams, state = _drain(cfg, params, routing, mode, steps=10)
        assert set(streams) == set(range(NREQ)), mode
        assert int(state.metrics.requests.sum()) == NREQ
        assert int(state.metrics.no_route_match) == 0
        assert int(state.metrics.rx_bytes.sum()) > 0


def test_xlb_matches_sidecars_tokenwise(setup):
    cfg, params, routing = setup
    xlb, _ = _drain(cfg, params, routing, "xlb", steps=10)
    istio, _ = _drain(cfg, params, routing, "istio", steps=10)
    cilium, _ = _drain(cfg, params, routing, "cilium", steps=10)
    for r in range(NREQ):
        n = min(len(xlb[r]), len(istio[r]), len(cilium[r]))
        assert n >= 3
        assert xlb[r][:n] == istio[r][:n] == cilium[r][:n], (
            f"req {r}: xlb={xlb[r][:n]} istio={istio[r][:n]} "
            f"cilium={cilium[r][:n]}")


def test_every_engine_kind_constructs(setup):
    """make_balancer covers exactly the advertised kinds and each satisfies
    the runtime-checkable protocol."""
    cfg, params, routing = setup
    for kind in ENGINE_KINDS:
        eng = make_balancer(kind, cfg, I, C, MAXLEN)
        assert isinstance(eng, Balancer), kind
    with pytest.raises(ValueError):
        make_balancer("envoy", cfg, I, C, MAXLEN)


def test_slot_reuse_after_completion(setup):
    """Pool slots freed by EOS/length completion get reused by new arrivals."""
    cfg, params, routing = setup
    eng = make_balancer("xlb", cfg, I, C, max_len=6)  # force quick completion
    state = eng.init_state(routing, dtype=jnp.float32)
    serve = eng.make_jitted(donate=False)
    state, _ = serve(params, state, _reqs(cfg, n=6))   # fill all 6 slots
    assert int(state.pool.active.sum()) == 6
    for _ in range(8):
        state, out = serve(params, state, _reqs(cfg, n=0))
    assert int(state.pool.active.sum()) == 0           # all completed
    state, _ = serve(params, state, _reqs(cfg, n=3))
    assert int(state.pool.active.sum()) == 3           # slots reused
