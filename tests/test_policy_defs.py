"""The policy-dispatch seam (core/policy_defs.py, DESIGN.md §9).

Pins the registry's single-source-of-truth contract (kernel / oracle /
staged / host enums can never diverge), the flow-hash parity between the
numpy and jnp lowerings, and the consistent-hash properties of the Maglev
table under live ControlPlane transactions: bounded key remap on add /
drain / remove, slot-ownership uniformity, affinity-cache invalidation on
drain, and sticky-session survival across a window relocation."""

import jax.numpy as jnp
import numpy as np

from repro.core import policy_defs
from repro.core.control import ControlPlane, apply_plan
from repro.core.routing_table import (AFFINITY_SLOTS, MAGLEV_TABLE_SIZE,
                                      Cluster, Rule, ServiceConfig)


def _cp(n_eps: int = 8, policy: int = policy_defs.POLICY_MAGLEV):
    return ControlPlane(
        [ServiceConfig("svc", rules=[Rule(0, None, "pool")])],
        [Cluster("pool", endpoints=list(range(n_eps)), policy=policy)])


class Consumer:
    def __init__(self, cp: ControlPlane):
        self.routing = cp.snapshot()
        cp.attach(self)

    def apply_refresh(self, plan):
        self.routing = apply_plan(self.routing, plan)


# --------------------------------------------------------------------------- #
# registry single-source-of-truth
# --------------------------------------------------------------------------- #


def test_enum_single_source_across_datapaths():
    """Every datapath's policy constants ARE the registry's — importing
    route_match / ref / policies / routing_table can never yield a
    diverged enum (the import-time asserts in policy_defs back this up)."""
    from repro.core import routing_table
    from repro.kernels import route_match  # noqa: F401 (kernel imports live)

    for name, enum in policy_defs.POLICY_NAMES.items():
        const = getattr(policy_defs, f"POLICY_{name.upper()}")
        assert const == enum
        assert getattr(routing_table, f"POLICY_{name.upper()}") == enum
        assert policy_defs.BY_ENUM[enum].name == name
    # dense, ordered, and every entry carries all four lowering hooks and a
    # shard merge rule
    enums = [p.enum for p in policy_defs.REGISTRY]
    assert enums == list(range(len(policy_defs.REGISTRY)))
    for p in policy_defs.REGISTRY:
        assert callable(p.kernel_offset) and callable(p.oracle_pick)
        assert callable(p.staged_offset) and callable(p.host_pick)
        assert p.shard_merge in ("cursor", "waterfill", "none")
    assert policy_defs.WATERFILL_ENUMS == tuple(
        p.enum for p in policy_defs.REGISTRY if p.shard_merge == "waterfill")


def test_flow_hash_numpy_jnp_parity():
    feats = (np.arange(64, dtype=np.int64).reshape(8, 8)
             * 2654435761 % 997).astype(np.int32)
    h_np = policy_defs.flow_hash(feats)
    h_jnp = np.asarray(policy_defs.flow_hash(jnp.asarray(feats)))
    np.testing.assert_array_equal(np.asarray(h_np), h_jnp)
    assert (h_np >= 0).all()                   # masked to non-negative i32
    # 1-D (single request, the sidecar host path) agrees with the batch
    one = policy_defs.flow_hash(feats[3])
    assert int(one) == int(h_np[3])


# --------------------------------------------------------------------------- #
# Maglev consistent-hash properties (live ControlPlane transactions)
# --------------------------------------------------------------------------- #


def _row(cp, cid=0):
    return np.asarray(cp.snapshot().maglev_table[cid]).copy()


def test_maglev_slot_ownership_uniform():
    """Canonical Maglev balance: every eligible endpoint owns T/E slots
    within 5% of ideal (the paper-grade uniformity bound)."""
    cp = _cp(n_eps=8)
    row = _row(cp)
    assert (row >= 0).all() and (row < 8).all()
    counts = np.bincount(row, minlength=8)
    ideal = MAGLEV_TABLE_SIZE / 8
    assert counts.max() <= ideal * 1.05 and counts.min() >= ideal * 0.95


def test_maglev_empty_and_fully_drained_rows_stay_empty():
    cp = _cp(n_eps=3)
    assert (_row(cp, cid=1) == -1).all()       # no such cluster
    for i in range(3):                         # health drain: never reaped,
        cp.drain_endpoint("pool", i, reason="health")   # rows stay present
    assert (_row(cp) == -1).all()              # fully drained: NO_ROUTE row


def test_maglev_bounded_remap_across_txn_sequence():
    """The consistent-hash acceptance bound: across a sequence of
    add / drain / undrain / remove transactions, each step remaps at most
    ~2/E of the keys (slots) that stay assigned — endpoints untouched by
    the delta keep their claims."""
    cp = _cp(n_eps=8)
    prev = _row(cp)

    def step(fn, e_after):
        nonlocal prev
        fn()
        cur = _row(cp)
        both = (prev >= 0) & (cur >= 0)
        moved = (prev != cur) & both
        frac = moved.sum() / max(both.sum(), 1)
        assert frac <= 2.0 / e_after, (
            f"remapped {frac:.3f} of keys, bound {2.0 / e_after:.3f}")
        prev = cur

    step(lambda: cp.add_endpoint("pool", instance=100), 9)
    step(lambda: cp.drain_endpoint("pool", 3, reason="health"), 8)
    step(lambda: cp.undrain_endpoint("pool", 3), 9)
    step(lambda: cp.remove_endpoint("pool", 5), 8)
    step(lambda: cp.add_endpoint("pool", instance=101), 9)


def test_maglev_unrelated_cluster_rows_never_churn():
    """A transaction against one cluster must not rebuild (or even touch)
    another cluster's row — the incremental per-row diff in _commit."""
    cp = ControlPlane(
        [ServiceConfig("svc", rules=[Rule(0, None, "a")])],
        [Cluster("a", endpoints=[0, 1, 2],
                 policy=policy_defs.POLICY_MAGLEV),
         Cluster("b", endpoints=[3, 4, 5],
                 policy=policy_defs.POLICY_MAGLEV)])
    b0 = _row(cp, cid=cp.cluster_id("b"))
    cp.add_endpoint("a", instance=9)
    cp.drain_endpoint("a", 1)
    np.testing.assert_array_equal(_row(cp, cid=cp.cluster_id("b")), b0)


def test_maglev_survives_window_relocation():
    """Window relocation (grow past capacity) moves every endpoint's slot
    but not its window offset or identity — the row's claims survive except
    the ~1/E the new endpoint takes."""
    cp = ControlPlane(
        [ServiceConfig("svc", rules=[Rule(0, None, "a")])],
        [Cluster("a", endpoints=[0, 1], policy=policy_defs.POLICY_MAGLEV),
         Cluster("b", endpoints=[2, 3], policy=policy_defs.POLICY_MAGLEV)])
    prev = _row(cp)
    start0 = int(cp.snapshot().cluster_ep_start[0])
    with cp.transaction():                     # full (cap 2): relocates
        cp.add_endpoint("a", instance=9)
    assert int(cp.snapshot().cluster_ep_start[0]) != start0
    cur = _row(cp)
    moved = (prev != cur) & (prev >= 0) & (cur >= 0)
    assert moved.sum() / MAGLEV_TABLE_SIZE <= 2.0 / 3.0 + 0.05
    # surviving endpoints keep ≥ their fair share minus the newcomer's cut
    assert (cur == 0).sum() > 0 and (cur == 1).sum() > 0


# --------------------------------------------------------------------------- #
# affinity cache across control-plane transactions
# --------------------------------------------------------------------------- #


def _seed_affinity(c: Consumer, entries):
    ak = np.full((AFFINITY_SLOTS,), -1, np.int32)
    ae = np.full((AFFINITY_SLOTS,), -1, np.int32)
    for key, ep in entries:
        ak[key % AFFINITY_SLOTS] = key
        ae[key % AFFINITY_SLOTS] = ep
    c.routing = c.routing._replace(aff_key=jnp.asarray(ak),
                                   aff_ep=jnp.asarray(ae))


def test_affinity_cache_invalidated_on_drain():
    cp = _cp(n_eps=4, policy=policy_defs.POLICY_AFFINITY)
    c = Consumer(cp)
    _seed_affinity(c, [(7, 1), (8, 2)])        # two sticky sessions
    cp.drain_endpoint("pool", 1)               # ep slot 1 drains
    ak = np.asarray(c.routing.aff_key)
    ae = np.asarray(c.routing.aff_ep)
    assert ak[7] == -1 and ae[7] == -1         # drained session evicted
    assert ak[8] == 8 and ae[8] == 2           # unrelated session survives


def test_affinity_cache_invalidated_on_remove():
    cp = _cp(n_eps=4, policy=policy_defs.POLICY_AFFINITY)
    c = Consumer(cp)
    _seed_affinity(c, [(5, 3)])
    cp.remove_endpoint("pool", 3)
    assert int(c.routing.aff_key[5]) == -1
    assert int(c.routing.aff_ep[5]) == -1


def test_affinity_cache_survives_window_relocation():
    """A relocation/compaction that MOVES the endpoint must carry the
    sticky session to the new slot, not evict it (remap via ep_dst)."""
    cp = ControlPlane(
        [ServiceConfig("svc", rules=[Rule(0, None, "a")])],
        [Cluster("a", endpoints=[0, 1],
                 policy=policy_defs.POLICY_AFFINITY),
         Cluster("b", endpoints=[2, 3],
                 policy=policy_defs.POLICY_AFFINITY)])
    c = Consumer(cp)
    slot0 = cp.endpoint_slot("a", 1)
    _seed_affinity(c, [(9, slot0)])
    with cp.transaction():                     # full: window relocates
        cp.add_endpoint("a", instance=7)
    new_slot = cp.endpoint_slot("a", 1)
    assert new_slot != slot0
    assert int(c.routing.aff_key[9]) == 9      # session survived ...
    assert int(c.routing.aff_ep[9]) == new_slot   # ... at the new slot


def test_maglev_oracle_selection_tracks_table():
    """End-to-end key→endpoint selection through the oracle hook: every key
    lands on a live endpoint, and re-selection after a drain never lands on
    the drained one while remapping only the drained endpoint's keys."""
    cp = _cp(n_eps=4)
    st = cp.snapshot()

    def pick_all(st):
        o = policy_defs.OracleCtx(
            cs=np.asarray(st.cluster_ep_start, np.int64),
            cc=np.asarray(st.cluster_ep_count, np.int64),
            E=int(st.ep_load.shape[0]),
            drained=np.asarray(st.ep_drained, np.int64),
            mg=np.asarray(st.maglev_table, np.int64),
            T=int(st.maglev_table.shape[1]),
            fkey=np.arange(500, dtype=np.int64) * 2654435761 % (1 << 31))
        elig = [j for j in range(4) if o.drained[j] == 0]
        return np.array([policy_defs._maglev_oracle(o, r, 0, elig)
                         for r in range(500)])
    before = pick_all(st)
    assert set(np.unique(before)) <= {0, 1, 2, 3}
    cp.drain_endpoint("pool", 2, reason="health")   # drained, not reaped
    after = pick_all(cp.snapshot())
    assert 2 not in set(np.unique(after))      # drained: zero traffic
    stay = before != 2
    assert (before[stay] == after[stay]).mean() >= 1.0 - 2.0 / 4.0
