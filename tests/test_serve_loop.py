"""Host serving-driver tests: drop accounting, ragged and empty admission
batches end-to-end through ServeLoop.tick (the lax.cond skip path), and
pool/metrics invariants across a full drain."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import interpose
from repro.core.routing_table import (Cluster, POLICY_RR, Rule, ServiceConfig,
                                      build_state)
from repro.models import model as M
from repro.runtime.serve_loop import Request, ServeLoop

I, C = 2, 3


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(get_config("xlb-service-model"))
    params = M.init_params(cfg, jax.random.PRNGKey(7), dtype=jnp.float32)
    return cfg, params


def _routing(require_match: bool):
    """require_match=True: the only rule matches field0 == hash('v2'), so a
    request without that header is NO_ROUTE forever."""
    services = [ServiceConfig("svc", rules=[
        Rule(0, "v2" if require_match else None, "pool")])]
    clusters = [Cluster("pool", endpoints=list(range(I)), policy=POLICY_RR)]
    routing, _ = build_state(services, clusters)
    return routing


def _loop(cfg, params, *, require_match=False, admit_batch=8, max_len=5):
    eng = interpose.Engine(cfg, I, C, max_len)
    return ServeLoop(eng, params, _routing(require_match),
                     admit_batch=admit_batch)


def _req(rid, headers=None):
    return Request(req_id=rid, service=0, headers=headers or {},
                   prompt_token=3 + rid % 7)


def test_drain_accounts_for_dropped_requests(setup):
    """Requests that exhaust their 64 retries land on ``dropped`` — after a
    drain, submitted == done + dropped + queued + inflight."""
    cfg, params = setup
    loop = _loop(cfg, params, require_match=True)
    routable = [_req(r, {"path": "v2"}) for r in range(3)]
    unroutable = [_req(100 + r) for r in range(2)]     # no matching header
    for r in routable + unroutable:
        loop.submit(r)
    loop.drain(max_ticks=200)
    n_sub = len(routable) + len(unroutable)
    assert n_sub == (len(loop.done) + len(loop.dropped) + len(loop.queue)
                     + len(loop.inflight))
    assert {r.req_id for r in loop.done} == {0, 1, 2}
    assert {r.req_id for r in loop.dropped} == {100, 101}
    assert all(r.retries == 64 for r in loop.dropped)
    assert all(r.t_done > 0 for r in loop.dropped)     # latency accounting
    assert int(np.asarray(loop.state.metrics.no_route_match)) > 0


def test_ragged_admission_batch_invariants(setup):
    """admit_batch larger than the queue: padding rows must admit nothing,
    touch no counters, and the real rows must all land in pool slots."""
    cfg, params = setup
    loop = _loop(cfg, params, admit_batch=8)
    for r in range(3):                                 # 3 real + 5 padding
        loop.submit(_req(r))
    loop.tick()
    st = loop.state
    assert int(np.asarray(st.pool.active).sum()) == 3
    assert int(np.asarray(st.metrics.requests).sum()) == 3
    assert int(np.asarray(st.metrics.no_route_match)) == 0
    assert int(np.asarray(st.metrics.overflow)) == 0
    # load counters track exactly the live connections
    assert int(np.asarray(st.routing.ep_load).sum()) == 3
    pool_ids = set(np.asarray(st.pool.req_id)[np.asarray(st.pool.active)])
    assert pool_ids == {0, 1, 2}


def test_empty_admission_batch_skips_admit(setup):
    """An all-padding batch takes make_jitted's lax.cond skip path: decode
    continues, admission state (metrics, cursors, key-driven counters) is
    untouched."""
    cfg, params = setup
    loop = _loop(cfg, params, max_len=16)              # no completions yet
    for r in range(2):
        loop.submit(_req(r))
    loop.tick()
    st1 = loop.state
    req1 = int(np.asarray(st1.metrics.requests).sum())
    cur1 = np.asarray(st1.routing.rr_cursor).copy()
    loop.tick()                                        # queue empty now
    st2 = loop.state
    assert int(np.asarray(st2.metrics.requests).sum()) == req1 == 2
    np.testing.assert_array_equal(np.asarray(st2.routing.rr_cursor), cur1)
    assert int(np.asarray(st2.pool.active).sum()) == 2
    # decode still advanced every active lane
    act = np.asarray(st2.pool.active)
    assert (np.asarray(st2.pool.length)[act]
            > np.asarray(st1.pool.length)[act]).all()


def test_drain_releases_all_load(setup):
    """After a clean drain every connection closed: pools empty, endpoint
    load counters fully released, rx bytes strictly positive."""
    cfg, params = setup
    loop = _loop(cfg, params, max_len=4)
    for r in range(5):                                 # 5 reqs through 6 slots
        loop.submit(_req(r))
    done = loop.drain(max_ticks=100)
    assert len(done) == 5 and not loop.dropped
    st = loop.state
    assert int(np.asarray(st.pool.active).sum()) == 0
    np.testing.assert_array_equal(np.asarray(st.routing.ep_load),
                                  np.zeros_like(np.asarray(
                                      st.routing.ep_load)))
    assert int(np.asarray(st.metrics.rx_bytes).sum()) > 0
