"""Host serving-driver tests: drop accounting, ragged and empty admission
batches end-to-end through ServeLoop.tick (the lax.cond skip path),
pool/metrics invariants across a full drain, the drain report, and the
control-plane seam (zero-recompilation refresh, three-engine visibility)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import interpose
from repro.core.balancer import ENGINE_KINDS, make_balancer
from repro.core.control import ControlPlane
from repro.core.routing_table import (Cluster, POLICY_LEAST_REQUEST,
                                      POLICY_RANDOM, POLICY_RR, Rule,
                                      ServiceConfig, build_state)
from repro.models import model as M
from repro.runtime.serve_loop import Request, ServeLoop

I, C = 2, 3


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config(get_config("xlb-service-model"))
    params = M.init_params(cfg, jax.random.PRNGKey(7), dtype=jnp.float32)
    return cfg, params


def _routing(require_match: bool):
    """require_match=True: the only rule matches field0 == hash('v2'), so a
    request without that header is NO_ROUTE forever."""
    services = [ServiceConfig("svc", rules=[
        Rule(0, "v2" if require_match else None, "pool")])]
    clusters = [Cluster("pool", endpoints=list(range(I)), policy=POLICY_RR)]
    routing, _ = build_state(services, clusters)
    return routing


def _loop(cfg, params, *, require_match=False, admit_batch=8, max_len=5,
          **kw):
    eng = interpose.Engine(cfg, I, C, max_len)
    return ServeLoop(eng, params, _routing(require_match),
                     admit_batch=admit_batch, **kw)


def _req(rid, headers=None):
    return Request(req_id=rid, service=0, headers=headers or {},
                   prompt_token=3 + rid % 7)


def test_drain_accounts_for_dropped_requests(setup):
    """Requests that exhaust ``max_retries`` land on ``dropped`` — after a
    drain, submitted == done + dropped + queued + inflight, where queued
    includes the backoff waiting set."""
    cfg, params = setup
    loop = _loop(cfg, params, require_match=True,
                 max_retries=6, backoff_cap=4)
    routable = [_req(r, {"path": "v2"}) for r in range(3)]
    unroutable = [_req(100 + r) for r in range(2)]     # no matching header
    for r in routable + unroutable:
        loop.submit(r)
    loop.drain(max_ticks=200)
    n_sub = len(routable) + len(unroutable)
    assert n_sub == (len(loop.done) + len(loop.dropped) + loop.n_queued
                     + len(loop.inflight))
    assert {r.req_id for r in loop.done} == {0, 1, 2}
    assert {r.req_id for r in loop.dropped} == {100, 101}
    assert all(r.retries == loop.max_retries for r in loop.dropped)
    assert all(r.t_done > 0 for r in loop.dropped)     # latency accounting
    assert int(np.asarray(loop.state.metrics.no_route_match)) > 0


def test_ragged_admission_batch_invariants(setup):
    """admit_batch larger than the queue: padding rows must admit nothing,
    touch no counters, and the real rows must all land in pool slots."""
    cfg, params = setup
    loop = _loop(cfg, params, admit_batch=8)
    for r in range(3):                                 # 3 real + 5 padding
        loop.submit(_req(r))
    loop.tick()
    st = loop.state
    assert int(np.asarray(st.pool.active).sum()) == 3
    assert int(np.asarray(st.metrics.requests).sum()) == 3
    assert int(np.asarray(st.metrics.no_route_match)) == 0
    assert int(np.asarray(st.metrics.overflow)) == 0
    # load counters track exactly the live connections
    assert int(np.asarray(st.routing.ep_load).sum()) == 3
    pool_ids = set(np.asarray(st.pool.req_id)[np.asarray(st.pool.active)])
    assert pool_ids == {0, 1, 2}


def test_empty_admission_batch_skips_admit(setup):
    """An all-padding batch takes make_jitted's lax.cond skip path: decode
    continues, admission state (metrics, cursors, key-driven counters) is
    untouched."""
    cfg, params = setup
    loop = _loop(cfg, params, max_len=16)              # no completions yet
    for r in range(2):
        loop.submit(_req(r))
    loop.tick()
    st1 = loop.state
    req1 = int(np.asarray(st1.metrics.requests).sum())
    cur1 = np.asarray(st1.routing.rr_cursor).copy()
    loop.tick()                                        # queue empty now
    st2 = loop.state
    assert int(np.asarray(st2.metrics.requests).sum()) == req1 == 2
    np.testing.assert_array_equal(np.asarray(st2.routing.rr_cursor), cur1)
    assert int(np.asarray(st2.pool.active).sum()) == 2
    # decode still advanced every active lane
    act = np.asarray(st2.pool.active)
    assert (np.asarray(st2.pool.length)[act]
            > np.asarray(st1.pool.length)[act]).all()


def test_drain_releases_all_load(setup):
    """After a clean drain every connection closed: pools empty, endpoint
    load counters fully released, rx bytes strictly positive."""
    cfg, params = setup
    loop = _loop(cfg, params, max_len=4)
    for r in range(5):                                 # 5 reqs through 6 slots
        loop.submit(_req(r))
    rep = loop.drain(max_ticks=100)
    assert len(rep.done) == 5 and not rep.dropped
    assert rep.queued == 0 and rep.inflight == 0
    st = loop.state
    assert int(np.asarray(st.pool.active).sum()) == 0
    np.testing.assert_array_equal(np.asarray(st.routing.ep_load),
                                  np.zeros_like(np.asarray(
                                      st.routing.ep_load)))
    assert int(np.asarray(st.metrics.rx_bytes).sum()) > 0


def _cp_pool(policy=POLICY_RR):
    return ControlPlane(
        [ServiceConfig("svc", rules=[Rule(0, None, "pool")])],
        [Cluster("pool", endpoints=list(range(I)), policy=policy)])


def test_drain_reports_stranded_work(setup):
    """drain() must say what it left behind (queued/inflight), not just
    return the completions."""
    cfg, params = setup
    loop = _loop(cfg, params, require_match=True)
    for r in range(2):
        loop.submit(_req(r, {"path": "v2"}))
    loop.submit(_req(50))                      # unroutable: no v2 header
    rep = loop.drain(max_ticks=30)    # far from max_retries: still queued
    assert {r.req_id for r in rep.done} == {0, 1}
    assert rep.queued == 1 and rep.inflight == 0
    assert rep.queued == loop.n_queued           # ready queue + backoff set
    assert not rep.dropped


def test_delta_refresh_zero_recompilation(setup):
    """The paper's no-disturbance property, pinned: a ControlPlane
    transaction between ticks (endpoint add → window relocation + a weight
    change) must not add a single entry to the jitted serve_step cache —
    the datapath re-reads new buffers, it is never re-traced."""
    cfg, params = setup
    cp = _cp_pool()
    eng = interpose.Engine(cfg, I, C, 16)
    loop = ServeLoop(eng, params, cp, admit_batch=4)
    for r in range(2):
        loop.submit(_req(r))
    loop.tick()
    loop.tick()                                # both cond branches traced
    n0 = loop.serve_step._cache_size()
    assert n0 >= 1
    with cp.transaction():                     # relocates the full window
        cp.add_endpoint("pool", instance=1)
        cp.set_weight("pool", instance=0, weight=2.0)
    loop.submit(_req(7))
    loop.tick()
    loop.tick()
    assert loop.serve_step._cache_size() == n0
    assert int(np.asarray(loop.routing.version)) == 1
    assert int(np.asarray(
        loop.routing.cluster_ep_count)[cp.cluster_id("pool")]) == I + 1


@pytest.mark.parametrize("policy", [POLICY_RR, POLICY_RANDOM,
                                    POLICY_LEAST_REQUEST])
def test_drain_endpoint_stops_new_traffic_mid_serve(setup, policy):
    """The ROADMAP gap, closed: ``drain_endpoint`` on a LOADED endpoint
    must stop new admissions under rr/random/least-request (not just
    WEIGHTED) via the datapath-visible ``ep_drained`` mask, while the
    in-flight connection keeps its slot until it completes."""
    cfg, params = setup
    cp = _cp_pool(policy)
    eng = interpose.Engine(cfg, I, C, max_len=32)      # nothing completes
    loop = ServeLoop(eng, params, cp, admit_batch=2)
    for r in range(2):                                 # one per instance
        loop.submit(_req(r))
    loop.tick()
    slot = cp.endpoint_slot("pool", 1)
    assert int(np.asarray(loop.routing.ep_load)[slot]) == 1
    cp.drain_endpoint("pool", 1)                       # loaded → masked,
    assert cp.endpoint_slot("pool", 1) == slot         # not reaped
    assert int(np.asarray(loop.routing.ep_drained)[slot]) == 1
    for r in range(10, 14):
        loop.submit(_req(r))
    loop.tick()
    loop.tick()
    pool = loop.state.pool
    act = np.asarray(pool.active)
    pe = np.asarray(pool.endpoint)
    # every NEW admission avoided the draining endpoint: it still holds
    # exactly its one pre-drain connection, instance 0 absorbed the rest
    assert int(((pe == slot) & act).sum()) == 1
    assert int(np.asarray(loop.routing.ep_load)[slot]) == 1
    assert int(act.sum()) > 2                          # traffic kept flowing


def test_weight_update_visible_to_all_three_engines(setup):
    """One ControlPlane, three attached engines: a committed weight change
    reaches the XLB device tables and both sidecar host routers alike."""
    cfg, params = setup
    cp = _cp_pool()
    loops = {k: ServeLoop(make_balancer(k, cfg, I, C, 5), params, cp)
             for k in ENGINE_KINDS}
    with cp.transaction():
        cp.set_weight("pool", instance=1, weight=7.5)
    slot = cp.endpoint_slot("pool", 1)
    for kind, lp in loops.items():
        assert float(np.asarray(lp.routing.ep_weight)[slot]) == 7.5, kind
        assert int(np.asarray(lp.routing.version)) == 1, kind


def test_held_request_overflow_is_bounded_and_documented(setup):
    """Regression: ``Engine.admit`` adds ``res.held`` into
    ``metrics.overflow`` on EVERY attempt, so one request re-queued k times
    used to read like k distinct pool exhaustions.  The semantics are now
    pinned (FlowMetrics docstring): ``overflow`` counts hold events per
    attempt — exactly the held request's retry count, bounded by the host's
    64-retry cap — while ``ServeLoop.held_first`` counts the REQUEST once,
    however long it waited."""
    cfg, params = setup
    eng = interpose.Engine(cfg, 1, 1, max_len=5)       # one slot total
    services = [ServiceConfig("svc", rules=[Rule(0, None, "pool")])]
    clusters = [Cluster("pool", endpoints=[0], policy=POLICY_RR)]
    routing, _ = build_state(services, clusters)
    loop = ServeLoop(eng, params, routing, admit_batch=2)
    loop.submit(_req(0))
    loop.submit(_req(1))           # held until request 0 frees the slot
    rep = loop.drain(max_ticks=100)
    assert {r.req_id for r in rep.done} == {0, 1}
    held = next(r for r in rep.done if r.req_id == 1)
    assert held.retries >= 1                    # it really was held
    overflow = int(np.asarray(loop.state.metrics.overflow))
    # one hold event per failed attempt, nothing more: the eventually-
    # admitted request contributes exactly its retry count (< 64), not 64x
    assert overflow == held.retries
    assert loop.held_first == 1 == rep.held_first
    assert rep.held_first < loop.max_retries


def test_retry_backoff_is_capped_exponential_and_deterministic(setup):
    """Satellite regression: held requests back off exponentially (capped)
    with deterministic seeded jitter instead of hammering the admit path
    every tick — and the accounting identity holds at every tick, with the
    backoff waiting set counted as queued."""
    cfg, params = setup

    def run(seed):
        loop = _loop(cfg, params, require_match=True,
                     max_retries=5, backoff_cap=4, backoff_seed=seed)
        loop.submit(_req(0, {"path": "v2"}))
        loop.submit(_req(9))                   # unroutable: retries forever
        drop_tick, attempts = None, []
        for t in range(64):
            loop.tick()
            # the identity holds mid-flight, not just after a drain
            assert 2 == (len(loop.done) + len(loop.dropped)
                         + loop.n_queued + len(loop.inflight)), t
            if loop.dropped and drop_tick is None:
                drop_tick = t
            attempts.append(loop.dropped[0].retries if loop.dropped
                            else None)
        return loop, drop_tick, attempts

    loop_a, drop_a, sched_a = run(seed=3)
    loop_b, drop_b, sched_b = run(seed=3)
    assert drop_a is not None                  # it did give up eventually
    assert (drop_a, sched_a) == (drop_b, sched_b)   # bit-identical replay
    # exponential spacing really happened: 5 attempts with delays
    # ≥ 1,1,2,4 (cap 4) + jitter can't finish in the first 7 ticks
    assert drop_a > 7
    assert loop_a.dropped[0].retries == loop_a.max_retries
    # the routable request was never starved by the backoff machinery
    assert {r.req_id for r in loop_a.done} == {0}


def test_heartbeat_sent_each_tick_when_attached(setup):
    """A ServeLoop driven from a ControlPlane heartbeats its liveness lease
    every tick, so the drain reaper keeps honoring its load votes."""
    cfg, params = setup
    cp_lease = ControlPlane(
        [ServiceConfig("svc", rules=[Rule(0, None, "pool")])],
        [Cluster("pool", endpoints=list(range(I)), policy=POLICY_RR)],
        lease_epochs=1)
    eng = interpose.Engine(cfg, I, C, 5)
    loop = ServeLoop(eng, params, cp_lease, admit_batch=4)
    for _ in range(3):
        cp_lease.advance_epoch()
        loop.tick()
    assert cp_lease._lease_live(loop)          # fresh at every epoch
    for _ in range(3):                         # stop ticking: lease expires
        cp_lease.advance_epoch()
    assert not cp_lease._lease_live(loop)
