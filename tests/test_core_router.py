"""Unit + property tests for the XLB core (router, policies, relay,
request_map, delta refresh)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import delta, policies, relay, request_map, router
from repro.core.routing_table import (Cluster, POLICY_LEAST_REQUEST, POLICY_RR,
                                      POLICY_RANDOM, POLICY_WEIGHTED, Rule,
                                      ServiceConfig, build_state, fnv1a)


@pytest.fixture()
def state():
    services = [
        ServiceConfig("front", rules=[
            Rule(field=0, value="v2", cluster="canary"),
            Rule(field=0, value=None, cluster="stable"),
        ]),
        ServiceConfig("payments", rules=[
            Rule(field=1, value="gold", cluster="gold-pool"),
        ]),
    ]
    clusters = [
        Cluster("canary", endpoints=[0, 1], policy=POLICY_RR),
        Cluster("stable", endpoints=[2, 3, 4], policy=POLICY_LEAST_REQUEST),
        Cluster("gold-pool", endpoints=[5], policy=POLICY_RANDOM),
    ]
    st, ids = build_state(services, clusters)
    return st, ids


def test_content_match_first_rule_wins(state):
    st, ids = state
    feats = jnp.zeros((3, 8), jnp.int32)
    feats = feats.at[0, 0].set(fnv1a("v2"))        # matches canary
    feats = feats.at[1, 0].set(fnv1a("v1"))        # falls to wildcard stable
    svc = jnp.array([0, 0, 1], jnp.int32)
    feats = feats.at[2, 1].set(fnv1a("silver"))    # no match on payments
    cl = router.match_cluster(st, svc, feats)
    assert cl[0] == ids["clusters"]["canary"]
    assert cl[1] == ids["clusters"]["stable"]
    assert cl[2] == -1                             # no_route_match


def test_round_robin_cycles(state):
    st, ids = state
    cl = jnp.full((4,), ids["clusters"]["canary"], jnp.int32)
    sel, st2 = policies.select(st, cl, jax.random.PRNGKey(0))
    # 4 requests over 2 endpoints → each endpoint chosen exactly twice
    counts = np.bincount(np.asarray(sel.endpoint), minlength=6)
    assert counts[0] == 2 and counts[1] == 2
    # cursor advanced by the batch size mod ep_count
    assert st2.rr_cursor[ids["clusters"]["canary"]] == 4 % 2


def test_least_request_prefers_idle(state):
    st, ids = state
    st = st._replace(ep_load=st.ep_load.at[2].set(5).at[3].set(7))
    cl = jnp.full((1,), ids["clusters"]["stable"], jnp.int32)
    sel, _ = policies.select(st, cl, jax.random.PRNGKey(1))
    assert int(sel.endpoint[0]) == 4               # the idle endpoint


def test_load_counting_and_release(state):
    st, ids = state
    cl = jnp.full((6,), ids["clusters"]["stable"], jnp.int32)
    sel, st2 = policies.select(st, cl, jax.random.PRNGKey(2))
    assert int(st2.ep_load.sum()) == 6
    st3 = policies.release(st2, sel.endpoint, jnp.ones((6,), bool))
    assert int(st3.ep_load.sum()) == 0


def test_relay_roundtrip_sort_vs_cumsum_vs_einsum():
    key = jax.random.PRNGKey(0)
    N, D, E, C = 64, 16, 4, 32
    x = jax.random.normal(key, (N, D))
    idx = jax.random.randint(key, (N,), 0, E)
    w = jax.random.uniform(key, (N,))
    outs = []
    for method in ("sort", "cumsum"):
        buf, meta = relay.relay_dispatch(x, idx, E, C, method=method)
        outs.append(relay.relay_combine(buf, meta, w))
    buf, meta, d_oh = relay.relay_dispatch_einsum(x, idx, E, C)
    outs.append(relay.relay_combine_einsum(buf, d_oh, w))
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=1e-5, atol=1e-5)
    # no-drop roundtrip restores weighted rows exactly
    np.testing.assert_allclose(outs[0], x * w[:, None], rtol=1e-5, atol=1e-5)


def test_relay_capacity_drop():
    x = jnp.ones((10, 4))
    idx = jnp.zeros((10,), jnp.int32)              # all to one backend
    buf, meta = relay.relay_dispatch(x, idx, 2, 4)
    assert int(meta.ok.sum()) == 4
    assert float(meta.overflow_frac) == pytest.approx(0.6)
    out = relay.relay_combine(buf, meta)
    assert int((jnp.abs(out).sum(1) > 0).sum()) == 4


def test_slot_allocation_and_response_order():
    free = jnp.array([[True, False, True], [True, True, True]])
    inst = jnp.array([0, 0, 0, 1, -1], jnp.int32)
    a = request_map.allocate_slots(inst, free)
    # instance 0 has 2 free slots → third request held
    assert list(np.asarray(a.ok)) == [True, True, False, True, False]
    assert set(np.asarray(a.slot)[:2].tolist()) == {0, 2}
    pool = jnp.zeros(free.shape, jnp.int32)
    vals = jnp.array([10, 20, 30, 40, 50], jnp.int32)
    pool = request_map.scatter_to_pool(pool, a, vals)
    back = request_map.gather_responses(pool, a, fill=-7)
    assert list(np.asarray(back)) == [10, 20, -7, 40, -7]


def test_delta_refresh_add_remove_endpoint(state):
    st, ids = state
    ci = ids["clusters"]["canary"]
    v0 = int(st.version)
    st2 = delta.add_endpoint(st, ci, ep_slot=6, instance=9)
    assert int(st2.cluster_ep_count[ci]) == 3
    assert int(st2.version) == v0 + 1
    # new endpoint becomes routable without recompilation (same pytree shape)
    assert jax.tree.structure(st) == jax.tree.structure(st2)
    st3 = delta.remove_endpoint(st2, ci, ep_off=0)
    assert int(st3.cluster_ep_count[ci]) == 2


def test_delta_remove_endpoint_zeroes_vacated_slot(state):
    """Regression (swap-with-last hazard): the vacated ``last`` slot used
    to keep the moved endpoint's stale ep_instance/ep_load — a later
    add_endpoint there zeroed live load out from under in-flight
    connections.  Now the swap migrates the load and zeroes the slot."""
    st, ids = state
    ci = ids["clusters"]["canary"]                 # slots 0, 1 (insts 0, 1)
    st = st._replace(ep_load=st.ep_load.at[1].set(3))   # in-flight on slot 1
    st2 = delta.remove_endpoint(st, ci, ep_off=0)
    assert int(st2.ep_instance[0]) == 1            # swapped-in endpoint
    assert int(st2.ep_load[0]) == 3                # load migrated with it
    assert int(st2.ep_instance[1]) == -1           # vacated slot zeroed
    assert int(st2.ep_load[1]) == 0
    assert float(st2.ep_weight[1]) == 1.0
    # release-after-move: the in-flight connection completes against the
    # moved endpoint's NEW slot; a fresh occupant of the vacated slot keeps
    # a clean, untouched counter
    st3 = delta.add_endpoint(st2, ci, ep_slot=1, instance=9)
    st4 = policies.release(st3, jnp.array([0]), jnp.ones((1,), bool))
    assert int(st4.ep_load[0]) == 2
    assert int(st4.ep_load[1]) == 0


def test_delta_remove_rule_clears_vacated_row(state):
    """Same hazard on the rule tables: the vacated last row resets to
    empty-state defaults instead of keeping a stale (field, value, cluster)
    triple a later add_rule could briefly expose."""
    st, ids = state
    si = ids["services"]["front"]                  # rules at slots 0, 1
    st2 = delta.remove_rule(st, si, rule_off=0)
    assert int(st2.svc_rule_count[si]) == 1
    # the wildcard rule compacted into slot 0
    assert int(st2.rule_value[0]) == -1
    assert int(st2.rule_cluster[0]) == ids["clusters"]["stable"]
    # slot 1 vacated and cleared
    assert int(st2.rule_field[1]) == 0
    assert int(st2.rule_value[1]) == -1
    assert int(st2.rule_cluster[1]) == -1


@pytest.mark.parametrize("policy", [POLICY_RR, POLICY_LEAST_REQUEST])
def test_staged_rank_matches_oracle_on_no_route_mix(policy):
    """Regression for the staged-path LB rank skew: NO_ROUTE requests used
    to land in rank bucket 0 (positions_sort over max(cluster, 0)), inflating
    the arrival ranks of genuine cluster-0 traffic and skewing rr /
    least-request offsets away from the fused kernel and the admit_ref
    oracle.  Cluster 0 traffic interleaved with NO_ROUTE rows must now
    match admit_ref bit-exactly."""
    from repro.kernels import ref

    # cluster id 0 gets the policy under test; svc0 has NO wildcard rule, so
    # a field-0 miss is NO_ROUTE
    services = [ServiceConfig("svc0", rules=[
        Rule(field=0, value="v2", cluster="cl0")])]
    clusters = [Cluster("cl0", endpoints=[0, 1, 2], policy=policy)]
    st, _ = build_state(services, clusters)
    # uniform loads: staged least-request (rank-th least loaded) and the
    # oracle's sequential water-filling agree exactly on this start state
    R = 24
    svc = jnp.zeros((R,), jnp.int32)
    feats = jnp.zeros((R, 8), jnp.int32)
    hit = jnp.arange(R) % 2 == 0               # every other row is NO_ROUTE
    feats = feats.at[:, 0].set(jnp.where(hit, fnv1a("v2"), fnv1a("nope")))
    free = jnp.ones((3, 16), bool)

    cl = router.match_cluster(st, svc, feats)
    sel, st2 = policies.select(st, cl, jax.random.PRNGKey(0))
    a = request_map.allocate_slots(sel.instance, free)

    want = ref.admit_ref(jnp.arange(R, dtype=jnp.int32), svc, feats,
                         jnp.ones((R,), jnp.int32), st, free,
                         jnp.zeros((R,), jnp.int32),
                         jnp.zeros((R, 64), jnp.float32))
    np.testing.assert_array_equal(np.asarray(sel.endpoint),
                                  np.asarray(want.endpoint))
    np.testing.assert_array_equal(np.asarray(sel.instance),
                                  np.asarray(want.instance))
    np.testing.assert_array_equal(np.asarray(a.slot), np.asarray(want.slot))
    np.testing.assert_array_equal(np.asarray(a.ok),
                                  np.asarray(want.ok).astype(bool))
    np.testing.assert_array_equal(np.asarray(st2.ep_load),
                                  np.asarray(want.ep_load))
    np.testing.assert_array_equal(np.asarray(st2.rr_cursor),
                                  np.asarray(want.rr_cursor))


def test_staged_empty_cluster_unroutable_matches_oracle():
    """A matched cluster with zero endpoints (delta refresh removed the
    last one) must be unroutable on the staged path — endpoint/instance -1
    and no load touched — exactly as in _admit_kernel and admit_ref."""
    from repro.kernels import ref

    services = [ServiceConfig("svc0", rules=[Rule(0, None, "empty")]),
                ServiceConfig("svc1", rules=[Rule(0, None, "full")])]
    clusters = [Cluster("empty", endpoints=[], policy=POLICY_RR),
                Cluster("full", endpoints=[0, 1], policy=POLICY_RR)]
    st, _ = build_state(services, clusters)
    R = 8
    svc = (jnp.arange(R) % 2).astype(jnp.int32)    # alternate empty/full
    feats = jnp.zeros((R, 8), jnp.int32)
    free = jnp.ones((2, 8), bool)

    cl = router.match_cluster(st, svc, feats)
    sel, st2 = policies.select(st, cl, jax.random.PRNGKey(0))
    want = ref.admit_ref(jnp.arange(R, dtype=jnp.int32), svc, feats,
                         jnp.ones((R,), jnp.int32), st, free,
                         jnp.zeros((R,), jnp.int32),
                         jnp.zeros((R, 64), jnp.float32))
    np.testing.assert_array_equal(np.asarray(sel.endpoint),
                                  np.asarray(want.endpoint))
    np.testing.assert_array_equal(np.asarray(sel.instance),
                                  np.asarray(want.instance))
    np.testing.assert_array_equal(np.asarray(st2.ep_load),
                                  np.asarray(want.ep_load))
    np.testing.assert_array_equal(np.asarray(st2.rr_cursor),
                                  np.asarray(want.rr_cursor))


def test_host_router_weighted_zero_weights_uniform():
    """A weighted cluster whose weights sum to 0 must fall back to uniform
    selection instead of NaN-crashing np.random.choice."""
    from repro.core import sidecar

    services = [ServiceConfig("s", rules=[Rule(0, None, "w")])]
    clusters = [Cluster("w", endpoints=[0, 1], policy=POLICY_WEIGHTED,
                        weights=[0.0, 0.0])]
    st, ids = build_state(services, clusters)
    hr = sidecar.HostRouter(st)
    picks = {hr.select(ids["clusters"]["w"])[0] for _ in range(32)}
    assert picks <= {0, 1} and picks            # valid endpoints, no crash
    assert int(hr.t.ep_load[:2].sum()) == 32    # every pick counted


@pytest.mark.parametrize("policy", [POLICY_RR, POLICY_RANDOM,
                                    POLICY_LEAST_REQUEST, POLICY_WEIGHTED])
def test_staged_select_skips_drained_endpoint(policy):
    """The datapath-visible drain mask on the STAGED path: a drained
    endpoint receives no new traffic under any policy (the pre-mask gap:
    only WEIGHTED honored weight→0), and the survivors absorb the batch."""
    services = [ServiceConfig("s", rules=[Rule(0, None, "pool")])]
    clusters = [Cluster("pool", endpoints=[0, 1, 2], policy=policy,
                        weights=[1.0, 9.0, 1.0])]
    st, ids = build_state(services, clusters)
    st = st._replace(ep_drained=st.ep_drained.at[1].set(1))
    cl = jnp.full((24,), ids["clusters"]["pool"], jnp.int32)
    sel, st2 = policies.select(st, cl, jax.random.PRNGKey(4))
    eps = np.asarray(sel.endpoint)
    assert (eps != 1).all()                        # drained: zero traffic
    assert (eps >= 0).all()                        # cluster still routable
    assert int(st2.ep_load[1]) == 0
    assert int(st2.ep_load[:3].sum()) == 24


def test_staged_select_fully_drained_cluster_unroutable():
    services = [ServiceConfig("s", rules=[Rule(0, None, "pool")])]
    clusters = [Cluster("pool", endpoints=[0, 1], policy=POLICY_RR)]
    st, ids = build_state(services, clusters)
    st = st._replace(ep_drained=st.ep_drained.at[:2].set(1))
    cl = jnp.full((4,), ids["clusters"]["pool"], jnp.int32)
    sel, st2 = policies.select(st, cl, jax.random.PRNGKey(5))
    assert (np.asarray(sel.endpoint) == -1).all()
    assert (np.asarray(sel.instance) == -1).all()
    np.testing.assert_array_equal(np.asarray(st2.ep_load),
                                  np.asarray(st.ep_load))


@pytest.mark.parametrize("policy", [POLICY_RR, POLICY_RANDOM,
                                    POLICY_LEAST_REQUEST, POLICY_WEIGHTED])
def test_host_router_skips_drained_endpoint(policy):
    """Same contract on the sidecar HostRouter (istio/cilium baselines)."""
    from repro.core import sidecar

    services = [ServiceConfig("s", rules=[Rule(0, None, "pool")])]
    clusters = [Cluster("pool", endpoints=[0, 1, 2], policy=policy,
                        weights=[1.0, 9.0, 1.0])]
    st, ids = build_state(services, clusters)
    st = st._replace(ep_drained=st.ep_drained.at[1].set(1))
    hr = sidecar.HostRouter(st)
    picks = [hr.select(ids["clusters"]["pool"])[0] for _ in range(24)]
    assert all(p in (0, 2) for p in picks)
    assert int(hr.t.ep_load[1]) == 0
    # a fully drained cluster is unroutable
    hr.t.ep_drained[[0, 2]] = 1
    assert hr.select(ids["clusters"]["pool"]) == (-1, -1)


def test_weighted_policy_distribution(state):
    st, ids = state
    ci = ids["clusters"]["stable"]
    st = delta.set_policy(st, ci, POLICY_WEIGHTED)
    # weight endpoint 2 much heavier
    st = delta.set_weight(st, 2, 50.0)
    cl = jnp.full((512,), ci, jnp.int32)
    sel, _ = policies.select(st, cl, jax.random.PRNGKey(3))
    counts = np.bincount(np.asarray(sel.endpoint), minlength=6)
    assert counts[2] > 350                         # ~50/52 of traffic
