"""Suite-wide defaults: pin the kernel autotuner so CI is deterministic.

The block-size autotuner (kernels/tune.py) sweeps tile shapes at first use
by *timing* candidates — correct but wall-clock-dependent, so two CI runs
could compile different specializations.  XLB_AUTOTUNE=0 makes every plan
resolve to the static defaults; the autotuner's own tests re-enable it (or
pin explicit choices) via monkeypatch.
"""

import os

os.environ.setdefault("XLB_AUTOTUNE", "0")
