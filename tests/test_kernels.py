"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp ref."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.balancer import PoolState, RequestBatch
from repro.kernels import ops, ref
from repro.core.routing_table import (MAX_EPS_PER_CLUSTER, Cluster,
                                      POLICY_AFFINITY, POLICY_LEAST_REQUEST,
                                      POLICY_MAGLEV, POLICY_RANDOM,
                                      POLICY_RR, POLICY_WEIGHTED, Rule,
                                      ServiceConfig, build_state)


def _rb(rid, svc, feats, msgb, tok=None) -> RequestBatch:
    """Assemble the pytree the ops wrappers take (token only matters for
    the commit path)."""
    return RequestBatch(rid, svc, feats,
                        jnp.zeros_like(rid) if tok is None else tok, msgb)

TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _tol(dtype):
    return TOLS[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,S,H,K,hd", [
    (1, 256, 4, 4, 64),        # MHA
    (2, 256, 8, 2, 64),        # GQA
    (1, 512, 4, 1, 128),       # MQA, rectangular blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(B, S, H, K, hd, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=128,
                              block_k=128)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# --------------------------------------------------------------------------- #
# decode attention
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,S,H,K,hd,bk", [
    (2, 1024, 8, 2, 64, 256),
    (4, 512, 4, 4, 128, 512),
    (1, 2048, 8, 1, 64, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, S, H, K, hd, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    vc = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    lengths = jax.random.randint(ks[3], (B,), 0, S - 1)
    out = ops.decode_attention(q, kc, vc, lengths, block_k=bk)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# --------------------------------------------------------------------------- #
# SSD scan
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,S,nh,hd,N,chunk", [
    (1, 256, 2, 64, 32, 128),
    (2, 256, 4, 32, 64, 64),
    (1, 512, 2, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_scan(B, S, nh, hd, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xdt = jax.random.normal(ks[0], (B, S, nh, hd), dtype) * 0.5
    # negative decay keeps the recurrence stable (dt·A with A<0)
    a_log = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))) * 0.5
    Bm = jax.random.normal(ks[2], (B, S, nh, N), dtype) * 0.3
    Cm = jax.random.normal(ks[3], (B, S, nh, N), dtype) * 0.3
    out = ops.ssd_scan(xdt, a_log, Bm, Cm, chunk=chunk)
    want = ref.ssd_scan_ref(xdt, a_log, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_ssd_scan_matches_model_chunked():
    """Kernel == the model's chunked SSD path (used in mamba2/jamba)."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    B, S, nh, hd, N = 2, 256, 2, 64, 32
    xdt = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    a_log = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))) * 0.5
    Bm = jax.random.normal(ks[2], (B, S, nh, N)) * 0.3
    Cm = jax.random.normal(ks[3], (B, S, nh, N)) * 0.3
    out = ops.ssd_scan(xdt, a_log, Bm, Cm, chunk=64)
    want, _ = ssd_chunked(xdt, a_log, Bm, Cm, chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
# route match (XLB hot path)
# --------------------------------------------------------------------------- #


def _routing_state():
    from repro.core.routing_table import fnv1a
    services = [ServiceConfig(f"svc{i}", rules=[
        Rule(field=0, value="v2", cluster=f"cl{i}a"),
        Rule(field=1, value=None, cluster=f"cl{i}b"),
    ]) for i in range(4)]
    clusters = []
    eid = 0
    for i in range(4):
        clusters += [
            Cluster(f"cl{i}a", endpoints=[eid, eid + 1],
                    policy=POLICY_LEAST_REQUEST),
            Cluster(f"cl{i}b", endpoints=[eid + 2, eid + 3, eid + 4],
                    policy=POLICY_LEAST_REQUEST)]
        eid += 5
    st, _ = build_state(services, clusters)
    # random outstanding-load counters
    load = jax.random.randint(jax.random.PRNGKey(9),
                              st.ep_load.shape, 0, 7)
    return st._replace(ep_load=load.astype(jnp.int32)), fnv1a


@pytest.mark.parametrize("R", [256, 512])
def test_route_match(R):
    st, fnv1a = _routing_state()
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    svc = jax.random.randint(ks[0], (R,), 0, 4)
    feats = jnp.zeros((R, 8), jnp.int32)
    hit = jax.random.bernoulli(ks[1], 0.5, (R,))
    feats = feats.at[:, 0].set(jnp.where(hit, fnv1a("v2"), fnv1a("v9")))
    cluster, ep = ops.route_match(svc, feats, st)
    cl_ref, ep_ref = ref.route_match_ref(svc, feats, st)
    np.testing.assert_array_equal(np.asarray(cluster), np.asarray(cl_ref))
    np.testing.assert_array_equal(np.asarray(ep), np.asarray(ep_ref))


# --------------------------------------------------------------------------- #
# fused admit kernel (XLB full admission datapath)
# --------------------------------------------------------------------------- #


def _admit_state(seed: int = 9, empty_cluster: bool = False):
    """4 services × 2 clusters covering all four LB policies; optionally the
    wildcard cluster of svc3 has no endpoints (ecount == 0)."""
    from repro.core.routing_table import fnv1a
    pols = [POLICY_RR, POLICY_RANDOM, POLICY_LEAST_REQUEST, POLICY_WEIGHTED]
    # svc1/svc2 have no wildcard fallback → field-0 misses are NO_ROUTE
    services = [ServiceConfig(f"svc{i}", rules=[
        Rule(field=0, value="v2", cluster=f"cl{i}a"),
    ] + ([Rule(field=1, value=None, cluster=f"cl{i}b")]
         if i in (0, 3) else [])) for i in range(4)]
    clusters = []
    for i in range(4):
        b_eps = [] if (empty_cluster and i == 3) else [(i * 2 + 2) % 8,
                                                       (i * 2 + 3) % 8,
                                                       (i * 2) % 8]
        clusters += [
            Cluster(f"cl{i}a", endpoints=[(i * 2) % 8, (i * 2 + 1) % 8],
                    policy=pols[i]),
            Cluster(f"cl{i}b", endpoints=b_eps, policy=pols[(i + 1) % 4],
                    weights=[1.0, 6.0, 0.25][:len(b_eps)] or None)]
    st, ids = build_state(services, clusters)
    load = jax.random.randint(jax.random.PRNGKey(seed), st.ep_load.shape,
                              0, 7)
    return st._replace(ep_load=load.astype(jnp.int32)), ids, fnv1a


def _admit_batch(R: int, seed: int, match_p: float = 0.6,
                 valid_p: float = 0.85):
    from repro.core.routing_table import fnv1a
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    svc = jax.random.randint(ks[0], (R,), 0, 4)
    feats = jnp.zeros((R, 8), jnp.int32)
    hit = jax.random.bernoulli(ks[1], match_p, (R,))
    feats = feats.at[:, 0].set(jnp.where(hit, fnv1a("v2"), fnv1a("v9")))
    # svc3's second rule is field-1 wildcard → always matches; knock out
    # some rows entirely by mismatching field 0 AND removing svc-3 rows
    rid = jnp.where(jax.random.bernoulli(ks[2], valid_p, (R,)),
                    jnp.arange(R), -1).astype(jnp.int32)
    msgb = jax.random.randint(ks[3], (R,), 1, 500)
    rnd = jax.random.randint(ks[4], (R,), 0, 1 << 30, dtype=jnp.int32)
    gum = jax.random.gumbel(ks[5], (R, MAX_EPS_PER_CLUSTER), jnp.float32)
    return rid, svc, feats, msgb, rnd, gum


def _assert_admit_matches(got, want):
    for name in got._fields:
        np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                      np.asarray(getattr(want, name)),
                                      err_msg=f"admit field {name!r}")


def _assert_admit_commit_matches(got, want):
    """ops.AdmitCommitOut (nested PoolState) vs the flat kernel-level
    AdmitCommitResult the oracle returns."""
    for name in ("cluster", "endpoint", "instance", "slot", "ok", "ep_load",
                 "rr_cursor", "svc_requests", "svc_tx_bytes", "no_route",
                 "held"):
        np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                      np.asarray(getattr(want, name)),
                                      err_msg=f"admit field {name!r}")
    for name in ("req_id", "endpoint", "svc", "length", "token"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got.pool, name)),
            np.asarray(getattr(want, f"pool_{name}")),
            err_msg=f"pool field {name!r}")
    np.testing.assert_array_equal(np.asarray(got.pool.active),
                                  np.asarray(want.pool_active) > 0,
                                  err_msg="pool field 'active'")


@pytest.mark.parametrize("fold", ["onehot", "segment"])
@pytest.mark.parametrize("R,block_r", [(64, 64), (128, 32), (256, 64)])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_admit_matches_sequential_oracle(R, block_r, seed, fold):
    """Property cross-check: all four policies, NO_ROUTE rows, padding rows,
    partially occupied pools (held requests), multi-tile scratch carry —
    under BOTH aggregation strategies (dense one-hot and segment fold)."""
    st, _, _ = _admit_state(seed=seed + 10)
    rid, svc, feats, msgb, rnd, gum = _admit_batch(R, seed)
    I, C = 8, 4                                # small pool → forces held
    free = jax.random.bernoulli(jax.random.PRNGKey(seed + 20), 0.5, (I, C))
    got = ops.admit(_rb(rid, svc, feats, msgb), st, free, rnd, gum,
                    block_r=block_r, fold=fold)
    want = ref.admit_ref(rid, svc, feats, msgb, st, free, rnd, gum)
    _assert_admit_matches(got, want)
    # the batch actually exercised the interesting paths
    assert int(np.asarray(got.no_route)) > 0
    assert int(np.asarray(got.held)) > 0
    assert int(np.asarray(got.ok).sum()) > 0


def test_admit_ragged_batch_padding():
    """R not a multiple of block_r: the wrapper pads with req_id=-1 rows and
    slices outputs back — padding must stay inert (counters, metrics)."""
    st, _, _ = _admit_state(seed=3)
    R = 40                                     # 40 % 16 != 0
    rid, svc, feats, msgb, rnd, gum = _admit_batch(R, seed=7)
    free = jnp.ones((8, 4), bool)
    got = ops.admit(_rb(rid, svc, feats, msgb), st, free, rnd, gum,
                    block_r=16)
    want = ref.admit_ref(rid, svc, feats, msgb, st, free, rnd, gum)
    _assert_admit_matches(got, want)
    assert got.cluster.shape == (R,)


def test_admit_empty_batch():
    """R == 0 short-circuits: no kernel launch, state passes through."""
    st, _, _ = _admit_state(seed=4)
    z = jnp.zeros((0,), jnp.int32)
    got = ops.admit(_rb(z, z, jnp.zeros((0, 8), jnp.int32), z), st,
                    jnp.ones((8, 4), bool), z,
                    jnp.zeros((0, MAX_EPS_PER_CLUSTER), jnp.float32))
    want = ref.admit_ref(z, z, jnp.zeros((0, 8), jnp.int32), z, st,
                         jnp.ones((8, 4), bool), z,
                         jnp.zeros((0, MAX_EPS_PER_CLUSTER), jnp.float32))
    _assert_admit_matches(got, want)
    np.testing.assert_array_equal(np.asarray(got.ep_load),
                                  np.asarray(st.ep_load))


def test_admit_empty_cluster_unroutable():
    """ecount == 0 clusters yield endpoint/instance/slot = -1, no held or
    no_route counts, and untouched load counters."""
    st, ids, fnv1a = _admit_state(empty_cluster=True)
    R = 64
    svc = jnp.full((R,), 3, jnp.int32)         # svc3 → wildcard → empty cl3b
    feats = jnp.zeros((R, 8), jnp.int32)       # field-0 miss → rule 2
    feats = feats.at[:, 0].set(fnv1a("nope"))
    rid = jnp.arange(R, dtype=jnp.int32)
    msgb = jnp.full((R,), 10, jnp.int32)
    rnd = jnp.zeros((R,), jnp.int32)
    gum = jnp.zeros((R, MAX_EPS_PER_CLUSTER), jnp.float32)
    free = jnp.ones((8, 4), bool)
    got = ops.admit(_rb(rid, svc, feats, msgb), st, free, rnd, gum)
    want = ref.admit_ref(rid, svc, feats, msgb, st, free, rnd, gum)
    _assert_admit_matches(got, want)
    assert np.all(np.asarray(got.cluster) == ids["clusters"]["cl3b"])
    assert np.all(np.asarray(got.endpoint) == -1)
    assert np.all(np.asarray(got.instance) == -1)
    assert np.all(np.asarray(got.ok) == 0)
    assert int(np.asarray(got.no_route)) == 0
    assert int(np.asarray(got.held)) == 0
    np.testing.assert_array_equal(np.asarray(got.ep_load),
                                  np.asarray(st.ep_load))


def test_admit_sequential_least_request_spreads():
    """A burst at one least-request cluster must water-fill across its
    endpoints (the argsort-emulation bug class: whole batch → one endpoint)."""
    services = [ServiceConfig("s", rules=[Rule(0, None, "pool")])]
    clusters = [Cluster("pool", endpoints=[0, 1, 2],
                        policy=POLICY_LEAST_REQUEST)]
    st, _ = build_state(services, clusters)
    st = st._replace(ep_load=st.ep_load.at[0].set(0).at[1].set(4).at[2].set(9))
    R = 32
    rid = jnp.arange(R, dtype=jnp.int32)
    svc = jnp.zeros((R,), jnp.int32)
    feats = jnp.zeros((R, 8), jnp.int32)
    z = jnp.zeros((R,), jnp.int32)
    gum = jnp.zeros((R, MAX_EPS_PER_CLUSTER), jnp.float32)
    free = jnp.ones((3, 32), bool)
    got = ops.admit(_rb(rid, svc, feats, z + 1), st, free, z, gum,
                    block_r=8)
    want = ref.admit_ref(rid, svc, feats, z + 1, st, free, z, gum)
    _assert_admit_matches(got, want)
    # water-filling: loads 0/4/9 + 32 requests → final loads equalise
    final = np.asarray(got.ep_load)[:3]
    assert final.max() - final.min() <= 1
    assert final.sum() == 13 + R


def test_admit_table_blockspec_binds_2d():
    """Index-map regression: every table BlockSpec must emit one block index
    per dim ((0,) * ndim).  The (I, C) free_mask is the 2-D table — a 1-D
    index map would mis-bind rows and corrupt slots on instance > 0."""
    from repro.kernels.route_match import _table_spec
    assert _table_spec((4,)).index_map(7) == (0,)
    assert _table_spec((4, 5)).index_map(7) == (0, 0)
    assert _table_spec((2, 3, 4)).index_map(1) == (0, 0, 0)
    # end-to-end: all traffic to instance 2; its only free slots are 1 and 3
    services = [ServiceConfig("s", rules=[Rule(0, None, "pool")])]
    clusters = [Cluster("pool", endpoints=[2], policy=POLICY_RR)]
    st, _ = build_state(services, clusters)
    R = 8
    rid = jnp.arange(R, dtype=jnp.int32)
    z = jnp.zeros((R,), jnp.int32)
    gum = jnp.zeros((R, MAX_EPS_PER_CLUSTER), jnp.float32)
    free = jnp.zeros((4, 4), bool).at[2, 1].set(True).at[2, 3].set(True)
    got = ops.admit(_rb(rid, z, jnp.zeros((R, 8), jnp.int32), z + 1), st,
                    free, z, gum)
    assert list(np.asarray(got.slot)[:2]) == [1, 3]
    assert int(np.asarray(got.ok).sum()) == 2
    assert int(np.asarray(got.held)) == R - 2


# --------------------------------------------------------------------------- #
# fused admit + pool commit (the full in-kernel connect path)
# --------------------------------------------------------------------------- #


def _pool_arrays(I: int, C: int, seed: int, active_p: float = 0.5):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    active = jax.random.bernoulli(ks[0], active_p, (I, C))
    return (jnp.where(active, jax.random.randint(ks[1], (I, C), 1000, 2000),
                      -1).astype(jnp.int32),
            jnp.where(active, jax.random.randint(ks[2], (I, C), 0, 8),
                      -1).astype(jnp.int32),
            jax.random.randint(ks[3], (I, C), 0, 4, dtype=jnp.int32),
            jax.random.randint(ks[4], (I, C), 0, 9, dtype=jnp.int32),
            jax.random.randint(ks[5], (I, C), 0, 97, dtype=jnp.int32),
            active)


@pytest.mark.parametrize("fold", ["onehot", "segment"])
@pytest.mark.parametrize("R,block_r", [(64, 64), (128, 32), (256, 64)])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_admit_commit_matches_sequential_oracle(R, block_r, seed, fold):
    """Property cross-check of the pool-commit stage: all four policies,
    NO_ROUTE rows, padding rows, held requests, partially occupied pools,
    multi-tile pool writeback carry — under both aggregation strategies."""
    st, _, _ = _admit_state(seed=seed + 10)
    rid, svc, feats, msgb, rnd, gum = _admit_batch(R, seed)
    tok = jax.random.randint(jax.random.PRNGKey(seed + 30), (R,), 0, 97,
                             dtype=jnp.int32)
    I, C = 8, 4                                # small pool → forces held
    pool = _pool_arrays(I, C, seed + 40)
    got = ops.admit_commit(_rb(rid, svc, feats, msgb, tok), st,
                           PoolState(*pool), rnd, gum, block_r=block_r,
                           fold=fold)
    want = ref.admit_commit_ref(rid, svc, feats, msgb, tok, st, *pool,
                                rnd, gum)
    _assert_admit_commit_matches(got, want)
    assert int(np.asarray(got.no_route)) > 0
    assert int(np.asarray(got.held)) > 0
    assert int(np.asarray(got.ok).sum()) > 0
    # pre-existing connections survive the batch untouched
    pre = np.asarray(pool[5])
    np.testing.assert_array_equal(np.asarray(got.pool.req_id)[pre],
                                  np.asarray(pool[0])[pre])


def test_admit_commit_pool_matches_staged_scatter():
    """Fused pool commit ≡ the staged scatter_to_pool chain on the same
    AdmitResult (the 6-scatter path the kernel replaced)."""
    from repro.core import request_map
    st, _, _ = _admit_state(seed=5)
    R = 96
    rid, svc, feats, msgb, rnd, gum = _admit_batch(R, seed=11)
    tok = jax.random.randint(jax.random.PRNGKey(12), (R,), 0, 97,
                             dtype=jnp.int32)
    pool = _pool_arrays(8, 4, seed=13)
    got = ops.admit_commit(_rb(rid, svc, feats, msgb, tok), st,
                           PoolState(*pool), rnd, gum, block_r=32)
    base = ops.admit(_rb(rid, svc, feats, msgb), st, ~pool[5], rnd, gum,
                     block_r=32)
    for name in base._fields:
        np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                      np.asarray(getattr(base, name)),
                                      err_msg=f"admit field {name!r}")
    assign = request_map.SlotAssignment(base.instance, base.slot, base.ok > 0)
    staged = [request_map.scatter_to_pool(pool[0], assign, rid),
              request_map.scatter_to_pool(pool[1], assign, base.endpoint),
              request_map.scatter_to_pool(pool[2], assign, svc),
              request_map.scatter_to_pool(pool[3], assign,
                                          jnp.zeros_like(rid)),
              request_map.scatter_to_pool(pool[4], assign, tok),
              request_map.scatter_to_pool(pool[5], assign,
                                          jnp.ones_like(rid) > 0)]
    fused = [got.pool.req_id, got.pool.endpoint, got.pool.svc,
             got.pool.length, got.pool.token, got.pool.active]
    for f, s, name in zip(fused, staged, ("req_id", "endpoint", "svc",
                                          "length", "token", "active")):
        np.testing.assert_array_equal(np.asarray(f), np.asarray(s),
                                      err_msg=f"pool field {name!r}")


def test_admit_integer_free_mask_and_rogue_svc():
    """Contract edges: an integer free_mask cell > 1 still means one free
    slot (no double-counted capacity), and svc >= MAX_SERVICES is dropped
    from the per-service metrics (the staged scatter's mode='drop') instead
    of being folded into service S-1 — both bit-exact vs the oracle."""
    from repro.core.routing_table import MAX_SERVICES
    # every service (incl. S-1, the clip target) routes to the pool, so the
    # rogue id really gets admitted and only the metric accounting differs
    services = [ServiceConfig(f"s{i}", rules=[Rule(0, None, "pool")])
                for i in range(MAX_SERVICES)]
    clusters = [Cluster("pool", endpoints=[0], policy=POLICY_RR)]
    st, _ = build_state(services, clusters)
    R = 4
    rid = jnp.arange(R, dtype=jnp.int32)
    # one rogue service id beyond the table (clips to S-1 for routing)
    svc = jnp.array([0, MAX_SERVICES + 3, 0, 0], jnp.int32)
    z = jnp.zeros((R,), jnp.int32)
    gum = jnp.zeros((R, MAX_EPS_PER_CLUSTER), jnp.float32)
    free = jnp.array([[0, 2, 0, 3]], jnp.int32)    # 2 free slots, not 5
    got = ops.admit(_rb(rid, svc, jnp.zeros((R, 8), jnp.int32), z + 7),
                    st, free, z, gum)
    want = ref.admit_ref(rid, svc, jnp.zeros((R, 8), jnp.int32), z + 7, st,
                         free, z, gum)
    _assert_admit_matches(got, want)
    assert int(np.asarray(got.ok).sum()) == 2      # capacity is 2, not 5
    assert list(np.asarray(got.slot)[:2]) == [1, 3]
    # rogue-svc request admitted but not counted under any service
    assert int(np.asarray(got.svc_requests).sum()) == 1
    assert int(np.asarray(got.svc_tx_bytes).sum()) == 7


def test_admit_commit_empty_batch_pool_passthrough():
    st, _, _ = _admit_state(seed=6)
    z = jnp.zeros((0,), jnp.int32)
    pool = _pool_arrays(8, 4, seed=14)
    got = ops.admit_commit(_rb(z, z, jnp.zeros((0, 8), jnp.int32), z, z),
                           st, PoolState(*pool), z,
                           jnp.zeros((0, MAX_EPS_PER_CLUSTER), jnp.float32))
    np.testing.assert_array_equal(np.asarray(got.pool.req_id),
                                  np.asarray(pool[0]))
    np.testing.assert_array_equal(np.asarray(got.pool.active),
                                  np.asarray(pool[5]))
    np.testing.assert_array_equal(np.asarray(got.ep_load),
                                  np.asarray(st.ep_load))


# --------------------------------------------------------------------------- #
# fused completion kernel (the in-kernel close path)
# --------------------------------------------------------------------------- #


def _complete_case(I, C, seed, eos=1, active_p=0.6):
    from repro.core.routing_table import MAX_ENDPOINTS, MAX_SERVICES
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    pool = _pool_arrays(I, C, seed, active_p=active_p)
    # endpoints of active slots must carry load to release
    load = jax.random.randint(ks[6], (MAX_ENDPOINTS,), 3, 9, dtype=jnp.int32)
    rx = jax.random.randint(ks[7], (MAX_SERVICES,), 0, 100, dtype=jnp.int32)
    # ~25% of lanes emit EOS this step; lengths near max force length-done
    nxt = jnp.where(jax.random.bernoulli(ks[0], 0.25, (I, C)), eos,
                    jax.random.randint(ks[1], (I, C), 2, 97)).astype(jnp.int32)
    return pool, nxt, load, rx


@pytest.mark.parametrize("fold", ["onehot", "segment"])
@pytest.mark.parametrize("I,C,block_i", [(2, 8, 2), (8, 16, 2), (8, 64, 8)])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_complete_matches_sequential_oracle(I, C, block_i, seed, fold):
    """Property cross-check: EOS and length-budget completion, inactive
    lanes, load release, per-service rx metrics, multi-tile scratch carry —
    under both aggregation strategies."""
    pool, nxt, load, rx = _complete_case(I, C, seed)
    # mix of lengths: some hit the max_len budget regardless of token
    max_len = 8
    got = ops.complete(PoolState(*pool), nxt, load, rx, eos=1,
                       max_len=max_len, block_i=block_i, fold=fold)
    want = ref.complete_ref(*pool, nxt, load, rx, eos=1, max_len=max_len)
    for name in ("req_id", "endpoint", "svc", "length", "token"):
        np.testing.assert_array_equal(np.asarray(getattr(got.pool, name)),
                                      np.asarray(getattr(want, name)),
                                      err_msg=f"complete field {name!r}")
    np.testing.assert_array_equal(np.asarray(got.pool.active),
                                  np.asarray(want.active) > 0)
    np.testing.assert_array_equal(np.asarray(got.done),
                                  np.asarray(want.done) > 0)
    np.testing.assert_array_equal(np.asarray(got.ep_load),
                                  np.asarray(want.ep_load))
    np.testing.assert_array_equal(np.asarray(got.rx_bytes),
                                  np.asarray(want.rx_bytes))
    assert int(np.asarray(got.done).sum()) > 0
    # inactive lanes never touch counters/metrics
    inact = ~np.asarray(pool[5])
    np.testing.assert_array_equal(np.asarray(got.done)[inact], 0)


def test_complete_all_inactive_is_noop():
    from repro.core.routing_table import MAX_ENDPOINTS, MAX_SERVICES
    I, C = 4, 8
    pool = (jnp.full((I, C), -1, jnp.int32), jnp.full((I, C), -1, jnp.int32),
            jnp.zeros((I, C), jnp.int32), jnp.zeros((I, C), jnp.int32),
            jnp.zeros((I, C), jnp.int32), jnp.zeros((I, C), bool))
    load = jnp.arange(MAX_ENDPOINTS, dtype=jnp.int32)
    rx = jnp.arange(MAX_SERVICES, dtype=jnp.int32)
    nxt = jnp.ones((I, C), jnp.int32)          # EOS everywhere — but inactive
    got = ops.complete(PoolState(*pool), nxt, load, rx, eos=1, max_len=4)
    assert int(np.asarray(got.done).sum()) == 0
    np.testing.assert_array_equal(np.asarray(got.ep_load), np.asarray(load))
    np.testing.assert_array_equal(np.asarray(got.rx_bytes), np.asarray(rx))
    np.testing.assert_array_equal(np.asarray(got.pool.token),
                                  np.asarray(pool[4]))


def test_complete_releases_load_exactly_once():
    """Every done slot with a real endpoint decrements exactly one counter
    (sum check across a multi-tile grid)."""
    I, C = 8, 8
    pool, nxt, load, rx = _complete_case(I, C, seed=7, active_p=0.9)
    got = ops.complete(PoolState(*pool), nxt, load, rx, eos=1, max_len=6,
                       block_i=2)
    done = np.asarray(got.done)
    eps = np.asarray(pool[1])
    n_rel = int(((eps >= 0) & done).sum())
    assert int(np.asarray(load).sum() - np.asarray(got.ep_load).sum()) == n_rel


# --------------------------------------------------------------------------- #
# segment-fold kernels at engine scale (ISSUE 4 acceptance shapes)
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("fold", ["onehot", "segment"])
def test_admit_large_batch_multi_tile_oracle(fold):
    """Batch 4096 over a 16×256 pool, 8-tile grid: the acceptance-criteria
    shape for the segment-fold rewrite.  All four policies, NO_ROUTE rows,
    held requests, cross-tile cursor/load/rank carry — bit-exact."""
    st, _, _ = _admit_state(seed=31)
    R = 4096
    rid, svc, feats, msgb, rnd, gum = _admit_batch(R, seed=32)
    I, C = 16, 256
    # sparse free mask: routable traffic overflows capacity → held > 0
    free = jax.random.bernoulli(jax.random.PRNGKey(33), 0.15, (I, C))
    got = ops.admit(_rb(rid, svc, feats, msgb), st, free, rnd, gum,
                    block_r=512, fold=fold)
    want = ref.admit_ref(rid, svc, feats, msgb, st, free, rnd, gum)
    _assert_admit_matches(got, want)
    assert int(np.asarray(got.no_route)) > 0
    assert int(np.asarray(got.held)) > 0
    assert int(np.asarray(got.ok).sum()) > 100


@pytest.mark.parametrize("fold", ["onehot", "segment"])
def test_admit_commit_large_pool_oracle(fold):
    """Pool commit over the 16×256 grid with a multi-tile batch: the
    scatter-set (segment) and one-hot (onehot) writebacks both land every
    admitted request at its (instance, slot) and leave pre-existing
    connections untouched."""
    st, _, _ = _admit_state(seed=41)
    R = 1024
    rid, svc, feats, msgb, rnd, gum = _admit_batch(R, seed=42)
    tok = jax.random.randint(jax.random.PRNGKey(43), (R,), 0, 97,
                             dtype=jnp.int32)
    pool = _pool_arrays(16, 256, seed=44, active_p=0.9)
    got = ops.admit_commit(_rb(rid, svc, feats, msgb, tok), st,
                           PoolState(*pool), rnd, gum, block_r=256,
                           fold=fold)
    want = ref.admit_commit_ref(rid, svc, feats, msgb, tok, st, *pool,
                                rnd, gum)
    _assert_admit_commit_matches(got, want)
    assert int(np.asarray(got.ok).sum()) > 0
    pre = np.asarray(pool[5])
    np.testing.assert_array_equal(np.asarray(got.pool.req_id)[pre],
                                  np.asarray(pool[0])[pre])


@pytest.mark.parametrize("fold", ["onehot", "segment"])
def test_complete_large_pool_oracle(fold):
    """Completion over the 16×256 pool (the BENCH_step scale) with a
    multi-tile grid: load release and rx metrics stay bit-exact when the
    (N, E) one-hot is replaced by the scatter fold."""
    pool, nxt, load, rx = _complete_case(16, 256, seed=51, active_p=0.7)
    got = ops.complete(PoolState(*pool), nxt, load, rx, eos=1, max_len=8,
                       block_i=8, fold=fold)
    want = ref.complete_ref(*pool, nxt, load, rx, eos=1, max_len=8)
    np.testing.assert_array_equal(np.asarray(got.ep_load),
                                  np.asarray(want.ep_load))
    np.testing.assert_array_equal(np.asarray(got.rx_bytes),
                                  np.asarray(want.rx_bytes))
    np.testing.assert_array_equal(np.asarray(got.done),
                                  np.asarray(want.done) > 0)
    np.testing.assert_array_equal(np.asarray(got.pool.req_id),
                                  np.asarray(want.req_id))
    assert int(np.asarray(got.done).sum()) > 100


@pytest.mark.parametrize("fold", ["onehot", "segment"])
@pytest.mark.parametrize("I,C,block_i,seed", [(2, 8, 2, 0), (8, 16, 2, 1),
                                              (8, 64, 8, 2)])
def test_complete_health_ewmas_match_oracle(I, C, block_i, seed, fold):
    """The closed-loop health accumulators (DESIGN.md §8): the in-kernel
    epilogue's completion count and occupancy/throughput EWMAs — updated
    from random nonzero carried bases — must be BIT-exact against the
    sequential oracle under both folds and multi-tile grids, or the
    circuit-breaker sees different fleets on different backends."""
    from repro.core.routing_table import MAX_ENDPOINTS
    pool, nxt, load, rx = _complete_case(I, C, seed)
    ks = jax.random.split(jax.random.PRNGKey(100 + seed), 2)
    ewl = jax.random.uniform(ks[0], (MAX_ENDPOINTS,), jnp.float32, 0.0, 6.0)
    ewt = jax.random.uniform(ks[1], (MAX_ENDPOINTS,), jnp.float32, 0.0, 2.0)
    got = ops.complete(PoolState(*pool), nxt, load, rx, ewl, ewt, eos=1,
                       max_len=8, block_i=block_i, fold=fold)
    want = ref.complete_ref(*pool, nxt, load, rx, ewl, ewt, eos=1, max_len=8)
    np.testing.assert_array_equal(np.asarray(got.done_cnt),
                                  np.asarray(want.done_cnt))
    np.testing.assert_array_equal(np.asarray(got.ep_inflight_ewma),
                                  np.asarray(want.inflight_ewma))
    np.testing.assert_array_equal(np.asarray(got.ep_tput_ewma),
                                  np.asarray(want.tput_ewma))
    # the count is the released mass: load0 - load == done_cnt summed
    assert int(np.asarray(got.done_cnt).sum()) == \
        int((np.asarray(load) - np.asarray(got.ep_load)).sum())
    assert int(np.asarray(got.done_cnt).sum()) > 0
    # default bases (None) are zeros — the cold-start path stays exact too
    cold = ops.complete(PoolState(*pool), nxt, load, rx, eos=1,
                        max_len=8, block_i=block_i, fold=fold)
    cold_want = ref.complete_ref(*pool, nxt, load, rx, eos=1, max_len=8)
    np.testing.assert_array_equal(np.asarray(cold.ep_inflight_ewma),
                                  np.asarray(cold_want.inflight_ewma))
    np.testing.assert_array_equal(np.asarray(cold.ep_tput_ewma),
                                  np.asarray(cold_want.tput_ewma))


# --------------------------------------------------------------------------- #
# datapath-visible drain mask (every selection path consults ep_drained)
# --------------------------------------------------------------------------- #


def _drain_state(policy):
    """One cluster of three endpoints under ``policy``; endpoint at window
    offset 1 (global slot 1) is draining."""
    services = [ServiceConfig("s", rules=[Rule(0, None, "pool")])]
    clusters = [Cluster("pool", endpoints=[0, 1, 2], policy=policy,
                        weights=[1.0, 9.0, 1.0])]
    st, _ = build_state(services, clusters)
    return st._replace(ep_drained=st.ep_drained.at[1].set(1))


@pytest.mark.parametrize("fold", ["onehot", "segment"])
@pytest.mark.parametrize("policy", [POLICY_RR, POLICY_RANDOM,
                                    POLICY_LEAST_REQUEST, POLICY_WEIGHTED,
                                    POLICY_MAGLEV, POLICY_AFFINITY])
def test_admit_drained_endpoint_gets_no_traffic(policy, fold):
    """The ControlPlane drain mask stops NEW traffic under EVERY policy in
    the fused kernel (the pre-mask gap: only WEIGHTED honored weight→0) —
    and stays bit-exact vs the oracle, including across tile boundaries
    (the raw-cursor carry).  For maglev/affinity the drain bit was raised
    WITHOUT rebuilding the Maglev table (``_drain_state`` flips the mask
    post-build), so the table still claims the drained offset — this pins
    the defensive drained-check-before-table-trust in every lowering."""
    st = _drain_state(policy)
    R = 32
    rid = jnp.arange(R, dtype=jnp.int32)
    z = jnp.zeros((R,), jnp.int32)
    # varied features → varied flow keys, so the hash policies spray the
    # whole table instead of collapsing onto one entry
    feats = jax.random.randint(jax.random.PRNGKey(9), (R, 8), 0, 997,
                               dtype=jnp.int32)
    rnd = jax.random.randint(jax.random.PRNGKey(7), (R,), 0, 1 << 30,
                             dtype=jnp.int32)
    gum = jax.random.gumbel(jax.random.PRNGKey(8),
                            (R, MAX_EPS_PER_CLUSTER), jnp.float32)
    free = jnp.ones((3, 16), bool)
    got = ops.admit(_rb(rid, z, feats, z + 1), st,
                    free, rnd, gum, block_r=8, fold=fold)
    want = ref.admit_ref(rid, z, feats, z + 1, st,
                         free, rnd, gum)
    _assert_admit_matches(got, want)
    eps = np.asarray(got.endpoint)
    assert (eps != 1).all()                    # drained slot: zero traffic
    assert (eps >= 0).all()                    # but the cluster stays up
    assert int(np.asarray(got.ep_load)[1]) == int(np.asarray(st.ep_load)[1])


@pytest.mark.parametrize("fold", ["onehot", "segment"])
@pytest.mark.parametrize("policy", [POLICY_RR, POLICY_MAGLEV,
                                    POLICY_AFFINITY])
def test_admit_fully_drained_cluster_unroutable(policy, fold):
    """Every endpoint draining ≡ empty cluster: unroutable, no counters
    touched, no held/no_route miscounts — bit-exact vs the oracle.  Under
    the hash policies the un-rebuilt Maglev table still claims both
    offsets, so this pins the drain mask beating the table lookup (a
    drained entry must yield NO_ROUTE, never a drained endpoint)."""
    services = [ServiceConfig("s", rules=[Rule(0, None, "pool")])]
    clusters = [Cluster("pool", endpoints=[0, 1], policy=policy)]
    st, _ = build_state(services, clusters)
    st = st._replace(ep_drained=st.ep_drained.at[:2].set(1))
    R = 8
    rid = jnp.arange(R, dtype=jnp.int32)
    z = jnp.zeros((R,), jnp.int32)
    feats = jax.random.randint(jax.random.PRNGKey(5), (R, 8), 0, 997,
                               dtype=jnp.int32)
    gum = jnp.zeros((R, MAX_EPS_PER_CLUSTER), jnp.float32)
    free = jnp.ones((2, 4), bool)
    got = ops.admit(_rb(rid, z, feats, z + 1), st,
                    free, z, gum, fold=fold)
    want = ref.admit_ref(rid, z, feats, z + 1, st,
                         free, z, gum)
    _assert_admit_matches(got, want)
    assert (np.asarray(got.endpoint) == -1).all()
    assert int(np.asarray(got.held)) == 0
    assert int(np.asarray(got.no_route)) == 0
    np.testing.assert_array_equal(np.asarray(got.ep_load),
                                  np.asarray(st.ep_load))


def _hash_state(seed: int = 11):
    """Two wildcard services: svc0 → 4-endpoint MAGLEV cluster, svc1 →
    3-endpoint AFFINITY cluster; one maglev endpoint drained post-build
    (table un-rebuilt → the in-kernel fallback path fires for its keys)."""
    services = [ServiceConfig("s0", rules=[Rule(1, None, "mg")]),
                ServiceConfig("s1", rules=[Rule(1, None, "af")])]
    clusters = [Cluster("mg", endpoints=[0, 1, 2, 3], policy=POLICY_MAGLEV),
                Cluster("af", endpoints=[4, 5, 6], policy=POLICY_AFFINITY)]
    st, ids = build_state(services, clusters)
    load = jax.random.randint(jax.random.PRNGKey(seed), st.ep_load.shape,
                              0, 7)
    st = st._replace(ep_load=load.astype(jnp.int32),
                     ep_drained=st.ep_drained.at[2].set(1))
    return st, ids


def _hash_batch(R: int, seed: int):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    svc = jax.random.randint(ks[0], (R,), 0, 2)
    feats = jax.random.randint(ks[1], (R, 8), 0, 61, dtype=jnp.int32)
    rid = jnp.where(jax.random.bernoulli(ks[2], 0.9, (R,)),
                    jnp.arange(R), -1).astype(jnp.int32)
    rnd = jax.random.randint(ks[3], (R,), 0, 1 << 30, dtype=jnp.int32)
    gum = jax.random.gumbel(ks[4], (R, MAX_EPS_PER_CLUSTER), jnp.float32)
    return rid, svc, feats, rnd, gum


@pytest.mark.parametrize("fold", ["onehot", "segment"])
@pytest.mark.parametrize("R,block_r", [(64, 64), (128, 32)])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_admit_hash_policies_match_oracle(R, block_r, seed, fold):
    """Maglev + affinity vs the sequential oracle under both folds and
    multi-tile scratch carry: table hits, drained-entry fallbacks, affinity
    cache writes with intra-batch slot contention (first writer wins) —
    every output field including the aff_key/aff_ep cache arrays."""
    st, _ = _hash_state(seed=seed + 30)
    rid, svc, feats, rnd, gum = _hash_batch(R, seed)
    free = jax.random.bernoulli(jax.random.PRNGKey(seed + 40), 0.7, (7, 8))
    got = ops.admit(_rb(rid, svc, feats, jnp.abs(rid) + 1), st, free, rnd,
                    gum, block_r=block_r, fold=fold)
    want = ref.admit_ref(rid, svc, feats, jnp.abs(rid) + 1, st, free, rnd,
                         gum)
    _assert_admit_matches(got, want)
    assert int(np.asarray(got.ok).sum()) > 0
    # the batch populated the affinity cache
    assert int((np.asarray(got.aff_ep) >= 0).sum()) > 0


@pytest.mark.parametrize("fold", ["onehot", "segment"])
def test_admit_affinity_sticks_across_batches(fold):
    """Sticky sessions: a key cached by batch 1 routes to the SAME endpoint
    in batch 2 even after the Maglev table is torn out from under it (the
    hit path never consults the table) — the cache, not hash luck, owns
    the repeat-flow routing decision."""
    from repro.core.policy_defs import AFFINITY_SLOTS, flow_hash
    services = [ServiceConfig("s", rules=[Rule(1, None, "af")])]
    clusters = [Cluster("af", endpoints=[0, 1, 2, 3],
                        policy=POLICY_AFFINITY)]
    st, _ = build_state(services, clusters)
    R = 48
    rid = jnp.arange(R, dtype=jnp.int32)
    z = jnp.zeros((R,), jnp.int32)
    feats = jax.random.randint(jax.random.PRNGKey(3), (R, 8), 0, 997,
                               dtype=jnp.int32)
    gum = jnp.zeros((R, MAX_EPS_PER_CLUSTER), jnp.float32)
    free = jnp.ones((4, 16), bool)
    one = ops.admit(_rb(rid, z, feats, z + 1), st, free, z, gum, fold=fold)
    st2 = st._replace(ep_load=one.ep_load, rr_cursor=one.rr_cursor,
                      aff_key=one.aff_key, aff_ep=one.aff_ep,
                      maglev_table=jnp.full_like(st.maglev_table, -1))
    two = ops.admit(_rb(rid, z, feats, z + 1), st2, free, z, gum, fold=fold)
    keys = np.asarray(flow_hash(np.asarray(feats)))
    ak = np.asarray(one.aff_key)
    cached = ak[keys % AFFINITY_SLOTS] == keys   # rows batch 1 cached
    assert cached.sum() > 0
    e1, e2 = np.asarray(one.endpoint), np.asarray(two.endpoint)
    np.testing.assert_array_equal(e1[cached], e2[cached])
    assert (e2[cached] >= 0).all()


# --------------------------------------------------------------------------- #
# relay slot assignment
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("N,E,bn", [(1024, 16, 256), (2048, 160, 1024),
                                    (512, 4, 512)])
def test_relay_slots(N, E, bn):
    idx = jax.random.randint(jax.random.PRNGKey(5), (N,), 0, E)
    slot, load = ops.relay_slots(idx, E, block_n=bn)
    slot_ref, load_ref = ref.relay_slots_ref(idx, E)
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(slot_ref))
    np.testing.assert_array_equal(np.asarray(load), np.asarray(load_ref))


@pytest.mark.parametrize("N,E,bn", [
    (1536, 16, 1024),     # the reported crash: 1536 % 1024 != 0
    (1, 4, 1024),         # single row under the default block
    (7, 3, 4),            # N > bn with a ragged tail
    (1000, 8, 256),       # several full tiles + a partial one
    (5, 2, 8),            # block_n clamps to N, then N % block == 0
])
def test_relay_slots_non_divisible_n(N, E, bn):
    """Regression: ``relay_slots`` used to hard-assert N % block_n == 0
    after clamping — any non-tile-divisible N crashed instead of padding.
    Padded rows carry the sentinel destination (matches nothing, counts no
    load) and are sliced off, so awkward N is bit-exact vs the oracle."""
    idx = jax.random.randint(jax.random.PRNGKey(11), (N,), 0, E)
    slot, load = ops.relay_slots(idx, E, block_n=bn)
    slot_ref, load_ref = ref.relay_slots_ref(idx, E)
    assert slot.shape == (N,)
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(slot_ref))
    np.testing.assert_array_equal(np.asarray(load), np.asarray(load_ref))
