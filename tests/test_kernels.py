"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp ref."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.core.routing_table import (Cluster, POLICY_LEAST_REQUEST, Rule,
                                      ServiceConfig, build_state)

TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _tol(dtype):
    return TOLS[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,S,H,K,hd", [
    (1, 256, 4, 4, 64),        # MHA
    (2, 256, 8, 2, 64),        # GQA
    (1, 512, 4, 1, 128),       # MQA, rectangular blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(B, S, H, K, hd, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=128,
                              block_k=128)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# --------------------------------------------------------------------------- #
# decode attention
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,S,H,K,hd,bk", [
    (2, 1024, 8, 2, 64, 256),
    (4, 512, 4, 4, 128, 512),
    (1, 2048, 8, 1, 64, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, S, H, K, hd, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kc = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    vc = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    lengths = jax.random.randint(ks[3], (B,), 0, S - 1)
    out = ops.decode_attention(q, kc, vc, lengths, block_k=bk)
    want = ref.decode_attention_ref(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# --------------------------------------------------------------------------- #
# SSD scan
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,S,nh,hd,N,chunk", [
    (1, 256, 2, 64, 32, 128),
    (2, 256, 4, 32, 64, 64),
    (1, 512, 2, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_scan(B, S, nh, hd, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    xdt = jax.random.normal(ks[0], (B, S, nh, hd), dtype) * 0.5
    # negative decay keeps the recurrence stable (dt·A with A<0)
    a_log = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))) * 0.5
    Bm = jax.random.normal(ks[2], (B, S, nh, N), dtype) * 0.3
    Cm = jax.random.normal(ks[3], (B, S, nh, N), dtype) * 0.3
    out = ops.ssd_scan(xdt, a_log, Bm, Cm, chunk=chunk)
    want = ref.ssd_scan_ref(xdt, a_log, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_ssd_scan_matches_model_chunked():
    """Kernel == the model's chunked SSD path (used in mamba2/jamba)."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    B, S, nh, hd, N = 2, 256, 2, 64, 32
    xdt = jax.random.normal(ks[0], (B, S, nh, hd)) * 0.5
    a_log = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh))) * 0.5
    Bm = jax.random.normal(ks[2], (B, S, nh, N)) * 0.3
    Cm = jax.random.normal(ks[3], (B, S, nh, N)) * 0.3
    out = ops.ssd_scan(xdt, a_log, Bm, Cm, chunk=64)
    want, _ = ssd_chunked(xdt, a_log, Bm, Cm, chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
# route match (XLB hot path)
# --------------------------------------------------------------------------- #


def _routing_state():
    from repro.core.routing_table import fnv1a
    services = [ServiceConfig(f"svc{i}", rules=[
        Rule(field=0, value="v2", cluster=f"cl{i}a"),
        Rule(field=1, value=None, cluster=f"cl{i}b"),
    ]) for i in range(4)]
    clusters = []
    eid = 0
    for i in range(4):
        clusters += [
            Cluster(f"cl{i}a", endpoints=[eid, eid + 1],
                    policy=POLICY_LEAST_REQUEST),
            Cluster(f"cl{i}b", endpoints=[eid + 2, eid + 3, eid + 4],
                    policy=POLICY_LEAST_REQUEST)]
        eid += 5
    st, _ = build_state(services, clusters)
    # random outstanding-load counters
    load = jax.random.randint(jax.random.PRNGKey(9),
                              st.ep_load.shape, 0, 7)
    return st._replace(ep_load=load.astype(jnp.int32)), fnv1a


@pytest.mark.parametrize("R", [256, 512])
def test_route_match(R):
    st, fnv1a = _routing_state()
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    svc = jax.random.randint(ks[0], (R,), 0, 4)
    feats = jnp.zeros((R, 8), jnp.int32)
    hit = jax.random.bernoulli(ks[1], 0.5, (R,))
    feats = feats.at[:, 0].set(jnp.where(hit, fnv1a("v2"), fnv1a("v9")))
    cluster, ep = ops.route_match(svc, feats, st)
    cl_ref, ep_ref = ref.route_match_ref(svc, feats, st)
    np.testing.assert_array_equal(np.asarray(cluster), np.asarray(cl_ref))
    np.testing.assert_array_equal(np.asarray(ep), np.asarray(ep_ref))


# --------------------------------------------------------------------------- #
# relay slot assignment
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("N,E,bn", [(1024, 16, 256), (2048, 160, 1024),
                                    (512, 4, 512)])
def test_relay_slots(N, E, bn):
    idx = jax.random.randint(jax.random.PRNGKey(5), (N,), 0, E)
    slot, load = ops.relay_slots(idx, E, block_n=bn)
    slot_ref, load_ref = ref.relay_slots_ref(idx, E)
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(slot_ref))
    np.testing.assert_array_equal(np.asarray(load), np.asarray(load_ref))
