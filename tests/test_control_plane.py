"""ControlPlane transaction semantics (core/control.py).

Pins the acceptance contract of the control-plane redesign: bit-exact
builds vs ``build_state``, one version bump per transaction, observable
bottom-up-add / top-down-delete ordering, drain-before-remove, free-list
window reuse, and swap-with-last hygiene (load migration + vacated-slot
zeroing + endpoint-reference remap)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.control import ControlPlane, apply_plan, remap_endpoints
from repro.core.routing_table import (Cluster, POLICY_LEAST_REQUEST,
                                      POLICY_RANDOM, POLICY_RR,
                                      POLICY_WEIGHTED, Rule, ServiceConfig,
                                      build_state, fnv1a)


class Consumer:
    """Minimal ControlPlane consumer: a live RoutingState + the plan hook."""

    def __init__(self, cp: ControlPlane):
        self.routing = cp.snapshot()
        self.plans = []
        cp.attach(self)

    def apply_refresh(self, plan):
        self.routing = apply_plan(self.routing, plan)
        self.plans.append(plan)

    def set_load(self, slot: int, n: int):
        self.routing = self.routing._replace(
            ep_load=self.routing.ep_load.at[slot].set(n))


SERVICES = [
    ServiceConfig("front", rules=[
        Rule(field=0, value="v2", cluster="canary"),
        Rule(field=0, value=None, cluster="stable"),
    ]),
    ServiceConfig("payments", rules=[
        Rule(field=1, value="gold", cluster="gold-pool"),
    ]),
]
CLUSTERS = [
    Cluster("canary", endpoints=[0, 1], policy=POLICY_RR),
    Cluster("stable", endpoints=[2, 3, 4], policy=POLICY_LEAST_REQUEST),
    Cluster("gold-pool", endpoints=[5], policy=POLICY_RANDOM),
]


def _cp():
    return ControlPlane(SERVICES, CLUSTERS)


def test_build_bit_exact_vs_build_state():
    """The acceptance contract: an initial ControlPlane build is bit-exact
    against an equivalent full ``build_state`` rebuild (and keeps the
    name→id maps build_state returned once and lost)."""
    cp = _cp()
    st, ids = build_state(SERVICES, CLUSTERS)
    snap = cp.snapshot()
    for name in st._fields:
        np.testing.assert_array_equal(np.asarray(getattr(snap, name)),
                                      np.asarray(getattr(st, name)),
                                      err_msg=f"field {name!r}")
    assert cp.ids == ids
    assert cp.cluster_id("stable") == ids["clusters"]["stable"]
    assert cp.service_id("payments") == ids["services"]["payments"]


def test_one_version_bump_per_transaction():
    cp = _cp()
    c = Consumer(cp)
    with cp.transaction():
        cp.add_endpoint("stable", instance=9)
        cp.set_policy("canary", POLICY_WEIGHTED)
        cp.set_weight("canary", instance=0, weight=3.0)
        cp.upsert_rule("payments", 1, "silver", "stable")
    assert cp.version == 1
    assert int(c.routing.version) == 1            # one bump for four deltas
    assert len(c.plans) == 1                      # one buffer swap
    # all four deltas landed atomically
    r = c.routing
    sid, cid = cp.service_id("payments"), cp.cluster_id("canary")
    assert int(r.cluster_ep_count[cp.cluster_id("stable")]) == 4
    assert int(r.cluster_policy[cid]) == POLICY_WEIGHTED
    assert int(r.svc_rule_count[sid]) == 2
    # an empty transaction is a no-op: no bump, no swap
    with cp.transaction():
        pass
    assert cp.version == 1 and len(c.plans) == 1


def test_ordering_bottom_up_add_top_down_delete():
    """The paper's §4.2 discipline, observable via the commit journal: an
    add writes the endpoint row before the cluster count that exposes it; a
    delete shrinks the count before compacting rows."""
    cp = _cp()
    with cp.transaction():
        slot = cp.add_endpoint("stable", instance=9)
    log = cp.last_commit_log
    row = log.index(("ep_row", slot, 9))
    count = log.index(("cluster_count", cp.cluster_id("stable"), +1))
    assert row < count, log

    with cp.transaction():
        cp.remove_endpoint("stable", instance=9)
    log = cp.last_commit_log
    assert log[0] == ("cluster_count", cp.cluster_id("stable"), -1), log
    assert any(op[0] == "ep_clear" for op in log[1:])


def test_drain_before_remove():
    """drain: weight drops to 0 at once, the row survives while any
    consumer still counts load against it, and a later commit reaps it."""
    cp = _cp()
    c = Consumer(cp)
    slot = cp.endpoint_slot("stable", 3)
    c.set_load(slot, 2)                            # in-flight connections
    cp.drain_endpoint("stable", 3)
    assert float(c.routing.ep_weight[slot]) == 0.0
    assert cp.endpoint_slot("stable", 3) == slot   # still present
    cp.reap()                                      # still loaded: no-op
    assert cp.endpoint_slot("stable", 3) == slot
    v = cp.version
    c.set_load(slot, 0)                            # connections completed
    cp.reap()
    assert cp.endpoint_slot("stable", 3) < 0       # reaped
    assert ("reap", "stable", 3) in cp.last_commit_log
    assert cp.version == v + 1
    assert int(c.routing.cluster_ep_count[cp.cluster_id("stable")]) == 2


def test_drain_of_idle_endpoint_reaps_same_commit():
    cp = _cp()
    c = Consumer(cp)
    cp.drain_endpoint("stable", 3)                 # no load anywhere
    assert cp.endpoint_slot("stable", 3) < 0
    assert cp.version == 1                         # drain+reap, one commit


def test_swap_with_last_migrates_load_and_zeroes_vacated_slot():
    """Removing a mid-window endpoint compacts by swap-with-last: the moved
    endpoint carries its in-flight load to its new slot, the vacated slot is
    fully zeroed, and pool endpoint references remap old→new."""
    cp = ControlPlane([ServiceConfig("s", rules=[Rule(0, None, "pool")])],
                      [Cluster("pool", endpoints=[0, 1, 2])])
    c = Consumer(cp)
    c.set_load(2, 5)                               # load on instance 2 @ slot 2
    cp.remove_endpoint("pool", 1)                  # slot 1 vacated, 2 → 1
    r = c.routing
    assert list(np.asarray(r.ep_instance[:3])) == [0, 2, -1]
    assert list(np.asarray(r.ep_load[:3])) == [0, 5, 0]
    # a connection pinned to old slot 2 must now release slot 1; one pinned
    # to the removed slot 1 must release nothing
    refs = remap_endpoints(c.plans[-1], jnp.array([2, 1, 0, -1], jnp.int32))
    assert list(np.asarray(refs)) == [1, -1, 0, -1]
    # the vacated slot is reusable with a clean counter
    slot = cp.add_endpoint("pool", instance=7)
    assert slot == 2
    assert int(c.routing.ep_load[2]) == 0


def test_endpoint_window_reuse_via_free_list():
    """Growing a cluster past its window capacity relocates it; the vacated
    extent returns to the free-list and the next allocation reuses it."""
    cp = ControlPlane(
        [ServiceConfig("s", rules=[Rule(0, None, "a")])],
        [Cluster("a", endpoints=[0, 1]), Cluster("b", endpoints=[2, 3])])
    c = Consumer(cp)
    # cluster a is full (cap == 2): the add relocates its window
    with cp.transaction():
        cp.add_endpoint("a", instance=9)
    log = cp.last_commit_log
    assert any(op[0] == "cluster_window" for op in log)
    r = c.routing
    a = cp.cluster_id("a")
    start = int(r.cluster_ep_start[a])
    assert start != 0 and int(r.cluster_ep_count[a]) == 3
    assert [int(r.ep_instance[start + j]) for j in range(3)] == [0, 1, 9]
    # loads of the moved endpoints migrated; old slots zeroed
    assert list(np.asarray(r.ep_instance[:2])) == [-1, -1]
    # a new cluster's window allocates first-fit from the freed extent
    cp.add_cluster("c", endpoints=[5, 6])
    assert int(c.routing.cluster_ep_start[cp.cluster_id("c")]) == 0


def test_upsert_rule_replace_and_append():
    cp = _cp()
    c = Consumer(cp)
    sid = cp.service_id("front")
    # replace: same (field, value) retargets the cluster in place
    cp.upsert_rule("front", 0, "v2", "stable")
    r = c.routing
    assert int(r.svc_rule_count[sid]) == 2
    s0 = int(r.svc_rule_start[sid])
    assert int(r.rule_cluster[s0]) == cp.cluster_id("stable")
    # append: new (field, value) grows the chain (window relocation OK)
    cp.upsert_rule("front", 3, "eu", "gold-pool")
    r = c.routing
    assert int(r.svc_rule_count[sid]) == 3
    s0 = int(r.svc_rule_start[sid])
    assert int(r.rule_value[s0 + 2]) == fnv1a("eu")
    assert int(r.rule_cluster[s0 + 2]) == cp.cluster_id("gold-pool")
    # remove: top-down (count first), vacated row cleared
    cp.remove_rule("front", 3, "eu")
    r = c.routing
    assert int(r.svc_rule_count[sid]) == 2
    assert cp.last_commit_log[0][0] == "svc_count"


def test_add_service_and_cluster_routable():
    cp = _cp()
    c = Consumer(cp)
    with cp.transaction():
        cp.add_cluster("new-pool", policy=POLICY_RR, endpoints=[6, 7])
        cp.add_service("checkout", rules=[Rule(2, None, "new-pool")])
    r = c.routing
    sid = cp.service_id("checkout")
    cid = cp.cluster_id("new-pool")
    assert int(r.svc_rule_count[sid]) == 1
    s0 = int(r.svc_rule_start[sid])
    assert int(r.rule_cluster[s0]) == cid
    e0 = int(r.cluster_ep_start[cid])
    assert [int(r.ep_instance[e0 + j]) for j in range(2)] == [6, 7]
    assert cp.version == 1


def test_transaction_abort_discards_staged_writes():
    cp = _cp()
    c = Consumer(cp)
    with pytest.raises(KeyError):
        with cp.transaction():
            cp.add_endpoint("stable", instance=9)
            cp.remove_endpoint("stable", instance=999)   # no such endpoint
    assert cp.version == 0
    assert int(c.routing.version) == 0
    assert int(c.routing.cluster_ep_count[cp.cluster_id("stable")]) == 3


def test_nested_transaction_raises():
    cp = _cp()
    with pytest.raises(RuntimeError):
        with cp.transaction():
            with cp.transaction():
                pass


def test_apply_plan_preserves_datapath_state():
    """The swap never touches what the datapath owns: rr cursors pass
    through, untouched endpoints keep their live load, version bumps once."""
    cp = _cp()
    c = Consumer(cp)
    c.routing = c.routing._replace(
        rr_cursor=c.routing.rr_cursor.at[0].set(1),
        ep_load=c.routing.ep_load.at[5].set(4))
    cp.set_weight("gold-pool", 5, 9.0)
    r = c.routing
    assert int(r.rr_cursor[0]) == 1
    assert int(r.ep_load[5]) == 4
    assert float(r.ep_weight[5]) == 9.0
    assert int(r.version) == 1


def test_set_weight_cancels_pending_drain():
    """Re-weighting a draining endpoint means the operator changed their
    mind: the reaper must not remove it once its load hits zero."""
    cp = _cp()
    c = Consumer(cp)
    slot = cp.endpoint_slot("stable", 3)
    c.set_load(slot, 1)
    cp.drain_endpoint("stable", 3)
    cp.set_weight("stable", 3, 2.5)            # cancel the drain
    c.set_load(slot, 0)
    cp.reap()
    assert cp.endpoint_slot("stable", 3) == slot   # still present
    assert float(c.routing.ep_weight[slot]) == 2.5


def test_drain_raises_datapath_mask_until_reap():
    """drain_endpoint raises the datapath-visible ``ep_drained`` bit in the
    same commit as the weight drop — every selection path (kernel, staged,
    host router) consults it, so new traffic stops under every policy; the
    reap clears the row, and set_weight cancels the drain AND the mask."""
    cp = _cp()
    c = Consumer(cp)
    slot = cp.endpoint_slot("stable", 3)
    c.set_load(slot, 2)                            # keeps the reaper away
    cp.drain_endpoint("stable", 3)
    assert int(c.routing.ep_drained[slot]) == 1
    assert float(c.routing.ep_weight[slot]) == 0.0
    cp.set_weight("stable", 3, 1.5)                # operator changed mind
    assert int(c.routing.ep_drained[slot]) == 0
    cp.drain_endpoint("stable", 3)                 # drain again, then reap
    c.set_load(slot, 0)
    cp.reap()
    assert cp.endpoint_slot("stable", 3) < 0
    assert int(c.routing.ep_drained[slot]) == 0    # cleared with the row


def test_drain_mask_migrates_with_swap_with_last():
    """Compaction moves a draining endpoint's mask bit along with its row
    (a drain must survive an unrelated removal in the same cluster)."""
    cp = ControlPlane([ServiceConfig("s", rules=[Rule(0, None, "pool")])],
                      [Cluster("pool", endpoints=[0, 1, 2])])
    c = Consumer(cp)
    c.set_load(2, 1)                               # instance 2 stays loaded
    cp.drain_endpoint("pool", 2)                   # slot 2 draining
    cp.remove_endpoint("pool", 0)                  # slot 0 vacated, 2 → 0
    assert cp.endpoint_slot("pool", 2) == 0
    assert int(c.routing.ep_drained[0]) == 1       # mask moved with the row
    assert int(c.routing.ep_drained[2]) == 0       # vacated slot clean


def test_remove_cluster_refuses_while_referenced():
    """A cluster a live rule still routes to cannot be removed — a dangling
    ``rule_cluster`` id would route traffic into another cluster's window."""
    cp = _cp()
    with pytest.raises(RuntimeError, match="referenced"):
        cp.remove_cluster("canary")
    assert cp.cluster_id("canary") == 0            # nothing happened
    assert cp.version == 0


def test_remove_cluster_top_down_then_id_and_window_reuse():
    """remove_cluster journals top-down (count → 0 before the rows clear),
    frees the endpoint extent, and recycles the directory id: the next
    add_cluster reuses both."""
    cp = _cp()
    c = Consumer(cp)
    cp.remove_rule("front", 0, "v2")               # un-reference canary
    cid = cp.cluster_id("canary")
    start = int(c.routing.cluster_ep_start[cid])
    with cp.transaction():
        cp.remove_cluster("canary")
    log = cp.last_commit_log
    assert log[0] == ("cluster_count", cid, 0)     # hidden before teardown
    clears = [i for i, op in enumerate(log) if op[0] == "ep_clear"]
    assert clears and all(i > 0 for i in clears)
    assert log[-1] == ("cluster_remove", cid)
    r = c.routing
    assert int(r.cluster_ep_count[cid]) == 0
    assert list(np.asarray(r.ep_instance[start:start + 2])) == [-1, -1]
    assert "canary" not in cp.ids["clusters"]
    # id + window extent recycle on the next add
    new_cid = cp.add_cluster("blue", endpoints=[7, 8])
    assert new_cid == cid
    assert int(c.routing.cluster_ep_start[new_cid]) == start
    assert [int(c.routing.ep_instance[start + j]) for j in range(2)] == [7, 8]


def test_remove_service_top_down_then_id_and_window_reuse():
    cp = _cp()
    c = Consumer(cp)
    sid = cp.service_id("front")
    start = int(c.routing.svc_rule_start[sid])
    with cp.transaction():
        cp.remove_service("front")
    log = cp.last_commit_log
    assert log[0] == ("svc_count", sid, 0)         # hidden before teardown
    assert any(op[0] == "rule_clear" for op in log[1:])
    assert log[-1] == ("service_remove", sid)
    r = c.routing
    assert int(r.svc_rule_count[sid]) == 0
    assert int(r.rule_cluster[start]) == -1        # rows reset to empty
    assert "front" not in cp.ids["services"]
    # the freed id and rule extent are reused by the next add_service
    new_sid = cp.add_service("storefront",
                             rules=[Rule(0, None, "stable")])
    assert new_sid == sid
    assert int(c.routing.svc_rule_start[new_sid]) == start
    assert int(c.routing.rule_cluster[start]) == cp.cluster_id("stable")


def test_remove_cluster_discards_pending_drains():
    cp = _cp()
    c = Consumer(cp)
    cp.remove_rule("payments", 1, "gold")          # un-reference gold-pool
    c.set_load(cp.endpoint_slot("gold-pool", 5), 3)    # drain stays pending
    cp.drain_endpoint("gold-pool", 5)
    assert cp.endpoint_slot("gold-pool", 5) >= 0   # loaded: not reaped
    # the whole cluster goes away with a drain still pending — the reaper
    # must not resurrect or crash on the dangling (cluster, instance) pair
    cp.remove_cluster("gold-pool")
    cp.reap()                                      # no KeyError, no-op
    assert "gold-pool" not in cp.ids["clusters"]


def test_abandoned_consumer_does_not_pin_drained_endpoint():
    """Consumers are weak-referenced: a dropped loop whose frozen state
    still showed load must not block the reaper (or receive splices)."""
    cp = _cp()
    keep = Consumer(cp)
    ghost = Consumer(cp)
    slot = cp.endpoint_slot("stable", 3)
    ghost.set_load(slot, 7)                    # stale load, then abandoned
    del ghost
    cp.drain_endpoint("stable", 3)             # keep's load is zero
    assert cp.endpoint_slot("stable", 3) < 0   # reaped despite the ghost
    assert int(keep.routing.version) == 1


def test_health_drain_reason_immune_to_set_weight_and_reaper():
    """The distinct-drain-reason bugfix: a circuit-breaker ejection
    (reason="health") must survive both an operator ``set_weight`` — the
    weight is staged, the drained mask stays up — and the reaper (the
    ejection is temporary, the row must not be removed); only
    ``undrain_endpoint`` lifts it.  The journal carries the reason."""
    cp = _cp()
    c = Consumer(cp)
    slot = cp.endpoint_slot("stable", 3)
    cp.drain_endpoint("stable", 3, reason="health")
    assert ("drain", cp.cluster_id("stable"), 3, "health") \
        in cp.last_commit_log
    assert cp.drain_reason("stable", 3) == "health"
    assert int(c.routing.ep_drained[slot]) == 1
    cp.reap()                                  # idle but health-drained:
    assert cp.endpoint_slot("stable", 3) == slot   # never reaped
    cp.set_weight("stable", 3, 2.0)            # operator stages a weight...
    assert int(c.routing.ep_drained[slot]) == 1    # ...but no silent un-eject
    assert cp.drain_reason("stable", 3) == "health"
    assert float(c.routing.ep_weight[slot]) == 2.0
    cp.undrain_endpoint("stable", 3, weight=1.5)   # the breaker's path
    assert ("undrain", cp.cluster_id("stable"), 3) in cp.last_commit_log
    assert cp.drain_reason("stable", 3) is None
    assert int(c.routing.ep_drained[slot]) == 0
    assert float(c.routing.ep_weight[slot]) == 1.5
    # an OPERATOR drain still journals its reason and still cancels on
    # set_weight (the pre-existing contract, unchanged)
    cp.drain_endpoint("stable", 4)
    assert cp.drain_reason("stable", 4) is None    # idle → reaped same commit


def test_expired_lease_does_not_pin_drained_endpoint():
    """Liveness lease: a consumer that stops heartbeating for more than
    ``lease_epochs`` control epochs loses its drain-reaper vote — its
    frozen load can't pin a draining endpoint forever — while a consumer
    that keeps heartbeating retains it."""
    cp = ControlPlane(SERVICES, CLUSTERS, lease_epochs=2)
    keep = Consumer(cp)
    ghost = Consumer(cp)
    slot = cp.endpoint_slot("stable", 3)
    ghost.set_load(slot, 7)                    # abandoned loop, stale load
    keep.set_load(slot, 1)
    cp.drain_endpoint("stable", 3)
    for _ in range(3):                         # both leases now stale...
        cp.advance_epoch()
        cp.heartbeat(keep)                     # ...but keep renews
    cp.reap()
    assert cp.endpoint_slot("stable", 3) == slot   # keep's vote held
    keep.set_load(slot, 0)
    cp.reap()                                  # ghost alone can't pin it
    assert cp.endpoint_slot("stable", 3) < 0


def test_lease_disabled_by_default():
    """lease_epochs=0 (the default): a silent consumer's load still pins a
    draining endpoint — exactly the pre-lease behavior."""
    cp = _cp()
    ghost = Consumer(cp)
    slot = cp.endpoint_slot("stable", 3)
    ghost.set_load(slot, 7)
    cp.drain_endpoint("stable", 3)
    for _ in range(10):
        cp.advance_epoch()                     # no heartbeats at all
    cp.reap()
    assert cp.endpoint_slot("stable", 3) == slot   # still pinned
