"""Per-architecture smoke tests: reduced config, one forward + train-grad +
prefill + decode step on CPU; asserts shapes and no NaNs.  (Deliverable f.)"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, smoke_config
from repro.models import model as M

BATCH, SEQ = 2, 32


def _batch(cfg, key):
    tok = jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
    if cfg.is_encdec:
        batch["enc_frames"] = jax.random.normal(
            key, (BATCH, cfg.enc_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module", params=ASSIGNED_ARCHS)
def arch(request):
    return request.param


@pytest.fixture(scope="module")
def setup(arch):
    cfg = smoke_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key, dtype=jnp.float32)
    return cfg, params, _batch(cfg, key)


def test_forward_shapes(setup):
    cfg, params, batch = setup
    logits, metrics = M.forward(cfg, params, batch["tokens"],
                                enc_frames=batch.get("enc_frames"))
    assert logits.shape == (BATCH, SEQ, cfg.vocab_padded)
    assert jnp.isfinite(logits).all(), "NaN/Inf in logits"


def test_train_step_grad(setup):
    cfg, params, batch = setup
    (loss, aux), grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert jnp.isfinite(loss)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and gnorm > 0


def test_prefill_then_decode_matches_forward(setup):
    """Decode path is numerically consistent with the full forward."""
    cfg, params, batch = setup
    tokens = batch["tokens"]
    full_logits, _ = M.forward(cfg, params, tokens,
                               enc_frames=batch.get("enc_frames"))

    cache = M.init_cache(cfg, BATCH, SEQ + 8, dtype=jnp.float32)
    pre = tokens[:, : SEQ - 1]
    logits_pre, cache = M.prefill(cfg, params, pre, cache,
                                  enc_frames=batch.get("enc_frames"))
    lengths = jnp.full((BATCH,), SEQ - 1, jnp.int32)
    logits_dec, cache = M.decode_step(cfg, params, tokens[:, SEQ - 1:SEQ],
                                      lengths, cache)
    assert logits_dec.shape == (BATCH, cfg.vocab_padded)
    assert jnp.isfinite(logits_dec).all()
    # SSM prefill carries state exactly; attention reads the same KV.
    ref = full_logits[:, -1]
    err = jnp.max(jnp.abs(logits_dec - ref)) / (jnp.max(jnp.abs(ref)) + 1e-6)
    assert err < 5e-2, f"decode vs forward mismatch: rel {err:.3e}"


def test_prefill_last_logits_match_forward(setup):
    cfg, params, batch = setup
    tokens = batch["tokens"]
    full_logits, _ = M.forward(cfg, params, tokens,
                               enc_frames=batch.get("enc_frames"))
    cache = M.init_cache(cfg, BATCH, SEQ + 8, dtype=jnp.float32)
    logits_pre, _ = M.prefill(cfg, params, tokens, cache,
                              enc_frames=batch.get("enc_frames"))
    ref = full_logits[:, -1]
    err = jnp.max(jnp.abs(logits_pre - ref)) / (jnp.max(jnp.abs(ref)) + 1e-6)
    assert err < 1e-3, f"prefill vs forward mismatch: rel {err:.3e}"
