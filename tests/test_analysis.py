"""The datapath verifier (src/repro/analysis/): mutation tests — every
seeded defect must be rejected with its named diagnostic — plus the
clean-pass sweep over every registered policy × fold, the plan-law and
row-schema validators, the sanitizer, and regressions for the OOB bugs
the static pass originally surfaced (route_match svc clamp, relay
sentinel rank, policies cluster clip, delta empty-window removal)."""

from __future__ import annotations

import ast

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import lint as lint_mod
from repro.analysis import verifier as ver
from repro.analysis.invariants import (assert_host, check_plan_wire, guard,
                                       validate_row)
from repro.analysis.verifier import Interval, verify_fn


def _codes(findings):
    return {f.code for f in findings}


# --------------------------------------------------------------------------- #
# Mutation tests: each seeded defect is rejected with a named diagnostic.
# --------------------------------------------------------------------------- #


def test_mutation_unclamped_gather_is_rejected():
    t = jnp.zeros((50,), jnp.int32)
    i = jnp.zeros((8,), jnp.int32)
    out = verify_fn(lambda t, i: t[i], (t, i), name="mut")
    assert _codes(out) == {"oob-gather-bound"}


def test_mutation_wide_clamp_gather_is_rejected():
    # clamped — but against the WRONG bound (table has 50 rows, clamp to 100)
    t = jnp.zeros((50,), jnp.int32)
    i = jnp.zeros((8,), jnp.int32)
    out = verify_fn(lambda t, i: t[jnp.clip(i, 0, 100)], (t, i), name="mut")
    assert _codes(out) == {"oob-gather-bound"}
    # the same gather with the right clamp is proven clean
    ok = verify_fn(lambda t, i: t[jnp.clip(i, 0, 49)], (t, i), name="ok")
    assert ok == []


def test_mutation_promise_scatter_is_rejected():
    t = jnp.zeros((50,), jnp.int32)
    i = jnp.zeros((8,), jnp.int32)
    out = verify_fn(
        lambda t, i: t.at[i].set(1, mode="promise_in_bounds"),
        (t, i), name="mut")
    assert _codes(out) == {"oob-scatter-bound"}
    # an explicit drop mode needs no proof (and the entry-bounds path
    # proves the promise form once the caller declares the index range)
    ok = verify_fn(lambda t, i: t.at[i].set(1, mode="drop"), (t, i),
                   name="ok")
    assert ok == []
    ok2 = verify_fn(
        lambda t, i: t.at[i].set(1, mode="promise_in_bounds"),
        (t, i), bounds=[None, Interval(0, 49)], name="ok2")
    assert ok2 == []


def test_mutation_unclamped_ref_index_is_rejected():
    from jax.experimental import pallas as pl

    def kern(i_ref, t_ref, o_ref):
        o_ref[0] = t_ref[i_ref[0]]        # raw dynamic ref index

    def mut(i, t):
        return pl.pallas_call(
            kern, out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
            interpret=True)(i, t)

    i = jnp.zeros((1,), jnp.int32)
    t = jnp.zeros((50,), jnp.int32)
    out = verify_fn(mut, (i, t), name="mut")
    assert "unclamped-ref-index" in _codes(out)

    def kern_ok(i_ref, t_ref, o_ref):
        o_ref[0] = t_ref[jnp.clip(i_ref[0], 0, 49)]

    def fixed(i, t):
        return pl.pallas_call(
            kern_ok, out_shape=jax.ShapeDtypeStruct((1,), jnp.int32),
            interpret=True)(i, t)

    assert verify_fn(fixed, (i, t), name="ok") == []


def test_mutation_x64_promotion_is_rejected():
    with jax.experimental.enable_x64():
        x = jnp.zeros((4,), jnp.float64)
        out = verify_fn(lambda a: a * 2.0, (x,), name="mut")
    assert "x64-promotion" in _codes(out)


def test_mutation_rng_prim_is_rejected():
    def mut(x):
        return x + jax.lax.rng_uniform(0.0, 1.0, (8,)).astype(jnp.int32)

    out = verify_fn(mut, (jnp.zeros((8,), jnp.int32),), name="mut")
    assert "rng-in-datapath" in _codes(out)


def test_mutation_registry_missing_hook_is_rejected(monkeypatch):
    import dataclasses

    from repro.core import policy_defs

    broken = dataclasses.replace(policy_defs.REGISTRY[0], name="mut",
                                 enum=99, kernel_offset=None)
    monkeypatch.setattr(policy_defs, "REGISTRY",
                        policy_defs.REGISTRY + (broken,))
    assert "policy-missing-hook" in _codes(ver.check_registry())


def test_mutation_registry_bad_merge_is_rejected(monkeypatch):
    import dataclasses

    from repro.core import policy_defs

    broken = dataclasses.replace(policy_defs.REGISTRY[0], name="mut",
                                 enum=99, shard_merge="psum")
    monkeypatch.setattr(policy_defs, "REGISTRY",
                        policy_defs.REGISTRY + (broken,))
    assert "policy-bad-merge" in _codes(ver.check_registry())


def test_mutation_registry_dup_enum_is_rejected(monkeypatch):
    import dataclasses

    from repro.core import policy_defs

    dup = dataclasses.replace(policy_defs.REGISTRY[1], name="mut")
    monkeypatch.setattr(policy_defs, "REGISTRY",
                        policy_defs.REGISTRY + (dup,))
    assert "policy-dup-enum" in _codes(ver.check_registry())


# --------------------------------------------------------------------------- #
# Plan-law mutations (check_plan_wire names the violated law).
# --------------------------------------------------------------------------- #


@pytest.fixture()
def clean_wire():
    from repro.core import control

    cp = control.ControlPlane()
    cp.add_cluster("a", endpoints=[0, 1, 2])
    cp.add_cluster("b", endpoints=[3, 4])
    wire = dict(cp.journal[-1])
    assert check_plan_wire(wire) == []
    return wire


def test_mutation_plan_field_bounds(clean_wire):
    from repro.core.routing_table import MAX_EPS_PER_CLUSTER

    clean_wire["cluster_ep_count"] = np.array(
        clean_wire["cluster_ep_count"]).copy()
    clean_wire["cluster_ep_count"][0] = MAX_EPS_PER_CLUSTER + 7
    errs = check_plan_wire(clean_wire)
    assert any(e.startswith("[field-bounds]") for e in errs)


def test_mutation_plan_window_overlap(clean_wire):
    cs = np.array(clean_wire["cluster_ep_start"]).copy()
    cs[1] = cs[0]                       # cluster b's window over cluster a's
    clean_wire["cluster_ep_start"] = cs
    errs = check_plan_wire(clean_wire)
    assert any(e.startswith("[window-disjoint]") for e in errs)


def test_mutation_plan_broken_permutation(clean_wire):
    src = np.array(clean_wire["ep_src"]).copy()
    dst = np.array(clean_wire["ep_dst"]).copy()
    src[0], dst[1] = 1, 5               # dst[src[0]] != 0
    clean_wire["ep_src"], clean_wire["ep_dst"] = src, dst
    errs = check_plan_wire(clean_wire)
    assert any(e.startswith("[slot-permutation]") for e in errs)


def test_mutation_plan_version_regression(clean_wire):
    clean_wire["base_version"] = clean_wire["version"]
    errs = check_plan_wire(clean_wire)
    assert any(e.startswith("[version-monotone]") for e in errs)


def test_unpack_plan_rejects_mutated_wire(clean_wire):
    from repro.core import control

    clean_wire["rule_cluster"] = np.array(clean_wire["rule_cluster"]).copy()
    clean_wire["rule_cluster"][0] = 10_000
    with pytest.raises(ValueError, match="violates invariants"):
        control.unpack_plan(clean_wire)


# --------------------------------------------------------------------------- #
# AST-lint mutations on synthetic sources.
# --------------------------------------------------------------------------- #


def _lint_src(src, mod="repro.kernels.mut"):
    findings = []
    lint_mod._ModuleLinter(mod, findings).visit(ast.parse(src))
    return findings


def test_mutation_lint_scatter_missing_mode():
    out = _lint_src("y = t.at[i].set(v)\n")
    assert _codes(out) == {"scatter-missing-mode"}
    assert _lint_src("y = t.at[i].set(v, mode='drop')\n") == []
    assert _lint_src("y = t.at[0].set(v)\n") == []    # static index: safe


def test_mutation_lint_nondet_in_datapath():
    assert _codes(_lint_src("x = np.random.rand(4)\n")) \
        == {"nondet-in-datapath"}
    assert _codes(_lint_src("t0 = time.perf_counter()\n")) \
        == {"nondet-in-datapath"}
    assert _lint_src("g = np.random.default_rng(0)\n") == []


def test_mutation_lint_enum_literal_bypass():
    out = _lint_src("ok = policy == 3\n")
    assert _codes(out) == {"enum-literal-bypass"}
    assert _lint_src("ok = policy == policy_defs.POLICY_MAGLEV\n") == []
    assert _lint_src("ok = policy < n_policies\n") == []  # range guard


def test_mutation_lint_partial_policydef():
    src = "P = PolicyDef('x', 9, (), (), 'none', kernel_offset=f)\n"
    assert _codes(_lint_src(src)) == {"policy-missing-hook"}


# --------------------------------------------------------------------------- #
# Row-schema mutations.
# --------------------------------------------------------------------------- #


def test_mutation_scenario_row_rejected():
    from repro.workload import slo

    row = slo.scenario_row("s", "xlb", depth=1, seed=0, arrivals="poisson",
                           n_requests=4, completed=4, dropped=0, ticks=9,
                           samples=[1, 2, 3, 4])
    bad = dict(row)
    bad["completed"] = 9                    # completed + dropped > n_requests
    with pytest.raises(ValueError, match="exceeds n_requests"):
        validate_row(bad, "scenario")
    bad2 = dict(row)
    bad2["surprise"] = 1
    with pytest.raises(ValueError, match="unknown field"):
        validate_row(bad2, "scenario")


# --------------------------------------------------------------------------- #
# Clean pass: every registered policy × fold, zero findings on HEAD.
# --------------------------------------------------------------------------- #


def test_registry_clean():
    assert ver.check_registry() == []


def test_kernel_sweep_clean_all_policies_both_folds():
    # _sweep_state builds one live cluster per REGISTRY policy, so this
    # single sweep proves every registered policy under every lowering the
    # kernels trace, across both folds.
    assert ver.verify_kernels(folds=("segment", "onehot")) == []


def test_lint_clean():
    report, findings = lint_mod.lint_all()
    assert findings == []
    # the import report flags the seed's dead training modules but the
    # datapath must never import them
    assert "repro.runtime.train_loop" in report["dead"]
    assert "repro.kernels.route_match" in report["datapath"]


def test_plan_op_sweep_clean():
    from repro.analysis.__main__ import _plan_ops_findings

    assert _plan_ops_findings() == []


# --------------------------------------------------------------------------- #
# Sanitizer: laws hold on real outputs, violations raise with the law name.
# --------------------------------------------------------------------------- #


def test_guard_passes_on_lawful_ctx():
    guard("admit", dict(load_before=jnp.zeros((4,), jnp.int32),
                        load_after=jnp.array([1, 1, 0, 0], jnp.int32),
                        ok=jnp.array([1, 1, 0, 0], jnp.int32),
                        held=jnp.int32(0),
                        endpoint=jnp.array([0, 1, -1, -1], jnp.int32)))


def test_guard_rejects_load_leak():
    from jax._src.checkify import JaxRuntimeError

    with pytest.raises(JaxRuntimeError,
                       match="load-delta-conservation"):
        guard("admit", dict(load_before=jnp.zeros((4,), jnp.int32),
                            load_after=jnp.array([2, 1, 0, 0], jnp.int32),
                            ok=jnp.array([1, 1, 0, 0], jnp.int32),
                            held=jnp.int32(0),
                            endpoint=jnp.array([0, 1, -1, -1], jnp.int32)))


def test_assert_host_rejects_queue_leak():
    with pytest.raises(AssertionError, match="queue-conservation"):
        assert_host("loop", dict(submitted=5, done=2, dropped=0, queued=1,
                                 inflight=1))
    assert_host("loop", dict(submitted=4, done=2, dropped=0, queued=1,
                             inflight=1))


def test_sanitized_serve_loop_runs(monkeypatch):
    monkeypatch.setenv("XLB_SANITIZE", "1")
    from repro.configs import get_config, smoke_config
    from repro.core import control, interpose
    from repro.core.routing_table import POLICY_RR
    from repro.models import model as M
    from repro.runtime.serve_loop import Request, ServeLoop

    cfg = smoke_config(get_config("xlb-service-model"))
    params = M.init_params(cfg, jax.random.PRNGKey(7), dtype=jnp.float32)
    cp = control.ControlPlane()
    cp.add_cluster("c", policy=POLICY_RR, endpoints=[0, 1])
    cp.add_service("s", rules=[control.Rule(0, None, "c")])
    eng = interpose.Engine(cfg, 2, 2, 8)
    loop = ServeLoop(eng, params, cp, admit_batch=4)
    for r in range(4):
        loop.submit(Request(req_id=r, service=0, headers={}, prompt_token=2))
    rep = loop.drain(max_ticks=200)
    assert len(rep.done) == 4
    assert loop.submitted == 4


# --------------------------------------------------------------------------- #
# Regressions for the audit findings the verifier surfaced (now fixed).
# --------------------------------------------------------------------------- #


def _two_cluster_state():
    from repro.core.routing_table import (Cluster, Rule, ServiceConfig,
                                          build_state)

    state, _ = build_state(
        [ServiceConfig("s0", rules=[Rule(0, None, "a")])],
        [Cluster("a", endpoints=[0, 1, 2]), Cluster("b", endpoints=[3, 4])])
    return state


def test_route_match_out_of_range_service_matches_clamped():
    # the kernel once read the rule tables with a raw svc id — an id past
    # MAX_SERVICES walked other services' rule windows once compiled
    from repro.core.routing_table import MAX_SERVICES
    from repro.kernels import ops

    state = _two_cluster_state()
    feats = jnp.zeros((4, 8), jnp.int32)
    hot = jnp.array([0, MAX_SERVICES - 1, MAX_SERVICES + 17, 2**30],
                    jnp.int32)
    ref = jnp.full((4,), MAX_SERVICES - 1, jnp.int32)
    cl_hot, _ = ops.route_match(hot, feats, state)
    cl_ref, _ = ops.route_match(ref, feats, state)
    np.testing.assert_array_equal(np.asarray(cl_hot[1:]),
                                  np.asarray(cl_ref[1:]))


def test_positions_sort_sentinel_destination_is_safe():
    # shard_admit steers dropped rows to destination == n_dest; the rank
    # gather once read starts[n_dest] out of bounds.  Sentinel rows must
    # not disturb the ranks of real rows.
    from repro.core import relay

    n = 4
    idx = jnp.array([0, n, 2, n, 0, 2], jnp.int32)
    slot, load = jax.jit(relay.positions_sort, static_argnums=1)(idx, n)
    slot = np.asarray(slot)
    assert list(np.asarray(load)) == [2, 0, 2, 0]
    assert slot[0] == 0 and slot[4] == 1          # dest-0 arrival ranks
    assert slot[2] == 0 and slot[5] == 1          # dest-2 arrival ranks


def test_policies_select_clips_out_of_range_cluster():
    # select once only lower-clamped the cluster id: an id past the table
    # walked cluster_ep_start/count out of window
    from repro.core import policies

    state = _two_cluster_state()
    n_cl = state.cluster_ep_start.shape[0]
    key = jax.random.PRNGKey(0)
    sel_oob, _ = policies.select(state,
                                 jnp.array([n_cl + 7], jnp.int32), key)
    sel_last, _ = policies.select(state,
                                  jnp.array([n_cl - 1], jnp.int32), key)
    assert int(sel_oob.endpoint[0]) == int(sel_last.endpoint[0])
    E = state.ep_instance.shape[0]
    assert -1 <= int(sel_oob.endpoint[0]) < E


def test_remove_endpoint_from_empty_cluster_is_noop():
    # a raced double-remove once drove count negative and let the
    # last-slot swap (last = start - 1) corrupt the neighbouring cluster
    from repro.core import delta

    state = _two_cluster_state()
    st = delta.remove_endpoint(state, 1, 0)       # b: 2 eps -> 1
    st = delta.remove_endpoint(st, 1, 0)          # b: 1 ep  -> 0
    before = jax.tree.map(np.asarray, st)
    st2 = delta.remove_endpoint(st, 1, 0)         # b already empty
    assert int(st2.cluster_ep_count[1]) == 0
    assert int(st2.version) == int(st.version) + 1
    for name in ("ep_instance", "ep_load", "ep_weight", "ep_drained",
                 "cluster_ep_start", "cluster_ep_count"):
        np.testing.assert_array_equal(np.asarray(getattr(st2, name)),
                                      getattr(before, name), err_msg=name)
    # cluster a untouched throughout
    np.testing.assert_array_equal(np.asarray(st2.ep_instance[:3]),
                                  np.asarray(state.ep_instance[:3]))
