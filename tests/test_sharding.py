"""Sharding-rule unit tests + property-style invariants (divisibility is the
load-bearing guarantee: jax rejects uneven explicit shardings)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import model as M
from repro.sharding.specs import MeshSpec, fit_spec


@pytest.fixture(scope="module")
def ms():
    # 1-device container: build a FAKE mesh descriptor via numpy devices is
    # not possible; use jax.make_mesh on the single device reshaped (1,1) and
    # monkeypatch shape lookups — instead we test fit_spec against a stub.
    class StubMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    class StubMS(MeshSpec):
        pass
    return MeshSpec.__new__(MeshSpec), StubMesh()


def test_fit_spec_divisibility(ms):
    _, mesh = ms
    assert fit_spec(mesh, (64, 128), [("data",), ("model",)]) == \
        P("data", "model")
    # 56 doesn't divide 16 → replicated
    assert fit_spec(mesh, (56, 128), [("model",), ()]) == P()
    # tuple axes: 512 % (16*16) == 0
    assert fit_spec(mesh, (512,), [(("data", "model"),)]) == \
        P(("data", "model"))
    # axis used once only
    assert fit_spec(mesh, (32, 32), [("model",), ("model",)]) == P("model")
    # fallback order: first candidate that divides wins
    assert fit_spec(mesh, (8, 32), [("model", "data"), ()]) == P()
    assert fit_spec(mesh, (32, 8), [("model",), ("data",)]) == P("model")


def _mk_ms(params_tp_only=False):
    obj = object.__new__(MeshSpec)
    class StubMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    object.__setattr__(obj, "mesh", StubMesh())
    object.__setattr__(obj, "params_tp_only", params_tp_only)
    return obj


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_divide_for_all_archs(arch):
    """PROPERTY: every parameter of every arch gets a spec whose sharded dims
    divide exactly on the 16×16 mesh (else jit would reject it)."""
    cfg = get_config(arch)
    ms = _mk_ms()
    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for kp, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        spec = ms.param_spec(path, leaf.shape)
        for dim, axes in zip(leaf.shape, spec):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            size = int(np.prod([ms.mesh.shape[a] for a in axes]))
            assert dim % size == 0, (arch, path, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["granite-20b", "deepseek-v2-236b",
                                  "mamba2-2.7b", "jamba-v0.1-52b",
                                  "whisper-large-v3"])
def test_cache_specs_divide(arch):
    cfg = get_config(arch)
    ms = _mk_ms()
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 128, 1024))
    specs = ms.cache_pspecs(cfg, cache)
    leaves = jax.tree.leaves(cache)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves)
    for leaf, spec in zip(leaves, spec_leaves):
        for dim, axes in zip(leaf.shape, spec):
            if axes is None:
                continue
            axes = axes if isinstance(axes, tuple) else (axes,)
            size = int(np.prod([ms.mesh.shape[a] for a in axes]))
            assert dim % size == 0, (arch, leaf.shape, spec)


def test_tp_only_variant_drops_dp():
    ms = _mk_ms(params_tp_only=True)
    spec = ms.param_spec("blocks/ffn/w_in", (52, 6144, 24576))
    assert "data" not in str(spec)
    ms2 = _mk_ms(params_tp_only=False)
    spec2 = ms2.param_spec("blocks/ffn/w_in", (52, 6144, 24576))
    assert "data" in str(spec2)


def test_expert_weight_specs():
    ms = _mk_ms()
    # (L, E, D, F): experts → model axis (EP), D → data (fsdp)
    spec = ms.param_spec("blocks/moe/w_in", (59, 160, 5120, 1536))
    assert spec == P(None, "model", "data")
    spec = ms.param_spec("blocks/moe/w_out", (59, 160, 1536, 5120))
    assert spec == P(None, "model", None, "data")


def test_heads_constraint_consistency():
    """q layout must be shardable whenever the scores rule shards K or G —
    the invariant behind the 5× collective win recorded in §Perf."""
    ms = _mk_ms()
    tp = 16
    for K, G in [(1, 48), (8, 6), (8, 8), (128, 1), (8, 7), (20, 1)]:
        expand = (K % tp != 0) and (G % tp != 0)
        if expand:
            H = K * G
            target = -(-H // tp) * tp
            assert target % tp == 0
