"""Distributed-execution tests: run a subprocess with 4 virtual host devices
(XLA_FLAGS must be set before jax init, hence the subprocess) and verify the
expert-parallel a2a relay + sharded train step EXECUTE correctly — the
dry-run only proves they compile."""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, smoke_config
from repro.models import moe as moe_mod
from repro.models import model as M
from repro.models.transformer import RunCtx
from repro.sharding.specs import MeshSpec

from repro.compat import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
ms = MeshSpec(mesh)

# --- 1) EP relay (shard_map + all_to_all) == local scatter dispatch ------- #
cfg = smoke_config(get_config("deepseek-v2-236b"))
key = jax.random.PRNGKey(0)
p = moe_mod.init_moe(key, cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

local_out, local_m = moe_mod.moe_ffn(cfg, p, x, method="sort")

with mesh:
    ep_fn = jax.jit(lambda p, x: moe_mod.moe_ffn(
        cfg, p, x, ep=(mesh, ("data", "model"))))
    ep_out, ep_m = ep_fn(p, x)
np.testing.assert_allclose(np.asarray(local_out), np.asarray(ep_out),
                           rtol=2e-4, atol=2e-4)
assert int(ep_m.load.sum()) == int(local_m.load.sum())
print("EP relay matches local dispatch")

# --- 2) sharded train step executes and matches single-device loss ------- #
ctx = RunCtx(shard=ms.constrain, tp_size=2)
params = M.init_params(cfg, jax.random.PRNGKey(2), dtype=jnp.float32)
tok = jax.random.randint(jax.random.PRNGKey(3), (4, 32), 0, cfg.vocab)
batch = {"tokens": tok, "labels": jnp.roll(tok, -1, 1)}

loss_ref, _ = M.loss_fn(cfg, params, batch)
with mesh:
    p_sh = ms.params_shardings(params)
    params_d = jax.device_put(params, p_sh)
    batch_d = jax.device_put(batch, ms.batch_shardings(batch))
    loss_sh, _ = jax.jit(lambda p, b: M.loss_fn(cfg, p, b, ctx=ctx))(
        params_d, batch_d)
np.testing.assert_allclose(float(loss_ref), float(loss_sh), rtol=2e-4)
print("sharded loss matches single-device loss")

# --- 3) GQA expansion under real sharding (chameleon family) -------------- #
cfg2 = smoke_config(get_config("chameleon-34b"))
params2 = M.init_params(cfg2, jax.random.PRNGKey(4), dtype=jnp.float32)
tok2 = jax.random.randint(jax.random.PRNGKey(5), (4, 32), 0, cfg2.vocab)
ctx2 = RunCtx(shard=ms.constrain, tp_size=2, q_chunk=16)
logits_ref, _ = M.forward(cfg2, params2, tok2)
with mesh:
    logits_sh, _ = jax.jit(lambda p, t: M.forward(cfg2, p, t, ctx=ctx2))(
        jax.device_put(params2, ms.params_shardings(params2)), tok2)
np.testing.assert_allclose(np.asarray(logits_ref), np.asarray(logits_sh),
                           rtol=5e-4, atol=5e-4)
print("sharded+chunked forward matches unsharded")
"""


@pytest.mark.timeout(900)
def test_distributed_execution_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=850,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    assert "EP relay matches local dispatch" in out.stdout
    assert "sharded loss matches single-device loss" in out.stdout
    assert "sharded+chunked forward matches unsharded" in out.stdout
