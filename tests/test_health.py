"""Closed-loop health tests (core/health.py + the fault injector).

Pins the circuit-breaker state machine against a real ControlPlane with
synthesized EWMA observations — ejection with the health drain reason,
hysteresis, the max-ejection-fraction guard, the uniformly-sick fleet
(least-bad endpoints keep serving, never NO_ROUTE), the half-open probe in
both directions — and the fault injector's hold semantics, ending with a
small end-to-end closed loop through a live Engine/ServeLoop."""

import dataclasses
import types
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.core import interpose
from repro.core.control import ControlPlane
from repro.core.health import (CLOSED, HALF_OPEN, OPEN, HealthConfig,
                               HealthPolicy, latency_estimate)
from repro.core.routing_table import (MAX_ENDPOINTS, Cluster,
                                      POLICY_LEAST_REQUEST, POLICY_RR,
                                      POLICY_WEIGHTED, Rule,
                                      ServiceConfig)
from repro.models import model as M
from repro.runtime.serve_loop import (Fault, FaultInjector, Request,
                                      ServeLoop)


def _cp(n=4, lease_epochs=0):
    return ControlPlane(
        [ServiceConfig("svc", rules=[Rule(0, None, "pool")])],
        [Cluster("pool", endpoints=list(range(n)), policy=POLICY_RR)],
        lease_epochs=lease_epochs)


def _obs(cp, lat, tput=None):
    """Synthesize a routing-state stub whose EWMAs encode latency ``lat[i]``
    (ticks) for instance i of "pool": inflight = lat·tput under Little's
    law."""
    infl = np.zeros((MAX_ENDPOINTS,), np.float32)
    tp = np.zeros((MAX_ENDPOINTS,), np.float32)
    for inst, l in lat.items():
        slot = cp.endpoint_slot("pool", inst)
        t = 1.0 if tput is None else tput.get(inst, 1.0)
        tp[slot] = t
        infl[slot] = l * max(t, 1.0 / 64.0)
    return types.SimpleNamespace(ep_inflight_ewma=infl, ep_tput_ewma=tp)


CFG = HealthConfig(k_eject=3.0, k_recover=2.0, trip_after=2, cooldown=3,
                   recover_after=2, probe_patience=4, max_eject_frac=0.5,
                   probe_weight=0.1)


def test_latency_estimate_littles_law_and_stall():
    lat = latency_estimate(np.array([4.0, 8.0, 0.0, 0.01]),
                           np.array([1.0, 0.0, 0.0, 0.0]))
    assert lat[0] == pytest.approx(4.0)
    assert lat[1] == pytest.approx(8.0 * 64)       # stall: tput floor kicks in
    assert lat[2] == 0.0 == lat[3]                 # no data: not judged


def test_outlier_ejected_with_health_reason_one_txn_per_epoch():
    """A 10×-median outlier trips after ``trip_after`` consecutive sick
    epochs: ONE transaction commits the ejection (drain reason="health"),
    and a no-action epoch commits nothing (no spurious version bump)."""
    cp = _cp()
    pol = HealthPolicy(cp, CFG)
    sick = _obs(cp, {0: 4, 1: 4, 2: 4, 3: 40})
    assert pol.epoch(sick) == []                   # sick streak = 1: hold
    assert cp.version == 0
    acts = pol.epoch(sick)                         # streak = trip_after
    assert acts == [("eject", "pool", 3)]
    assert cp.version == 1                         # exactly one commit
    assert cp.drain_reason("pool", 3) == "health"
    assert pol.state_of("pool", 3) == OPEN
    assert pol.ejected() == [("pool", 3)]
    slot = cp.endpoint_slot("pool", 3)
    assert int(cp.snapshot().ep_drained[slot]) == 1
    assert pol.commits == 1 and pol.epochs == 2


def test_hysteresis_no_flap_between_thresholds():
    """Latency between k_recover·med and k_eject·med is neither sick nor
    healthy: the breaker never trips, and one healthy epoch resets a
    partial sick streak (no slow ratchet to ejection)."""
    cp = _cp()
    pol = HealthPolicy(cp, CFG)
    wobbly = _obs(cp, {0: 4, 1: 4, 2: 4, 3: 10})   # 2.5× med: inside band
    for _ in range(6):
        assert pol.epoch(wobbly) == []
    # one sick epoch, then back inside the band: streak resets
    pol.epoch(_obs(cp, {0: 4, 1: 4, 2: 4, 3: 40}))
    for _ in range(4):
        assert pol.epoch(wobbly) == []
    assert pol.state_of("pool", 3) == CLOSED
    assert cp.version == 0                         # not one transaction


def test_max_ejection_fraction_guard():
    """n=4, frac=0.25 → budget 1: with two sick endpoints only the WORST is
    ejected; the runner-up keeps serving (sick streak intact)."""
    cp = _cp()
    pol = HealthPolicy(cp, dataclasses.replace(CFG, max_eject_frac=0.25))
    sick2 = _obs(cp, {0: 4, 1: 4, 2: 30, 3: 40})
    pol.epoch(sick2)
    acts = pol.epoch(sick2)
    assert acts == [("eject", "pool", 3)]          # worst-first, budget 1
    assert pol.state_of("pool", 2) == CLOSED
    # and the budget counts already-open breakers: still nothing next epoch
    assert pol.epoch(sick2) == []
    assert pol.state_of("pool", 2) == CLOSED


def test_uniformly_sick_fleet_never_drained():
    """Every endpoint equally terrible: the leave-one-out median scales
    with the fleet, nobody is an outlier, nothing ejects — the cluster
    keeps serving its least-bad (here: all) endpoints instead of draining
    itself into NO_ROUTE."""
    cp = _cp()
    pol = HealthPolicy(cp, CFG)
    awful = _obs(cp, {i: 400 for i in range(4)})
    for _ in range(8):
        assert pol.epoch(awful) == []
    snap = cp.snapshot()
    slots = [cp.endpoint_slot("pool", i) for i in range(4)]
    assert all(int(snap.ep_drained[s]) == 0 for s in slots)
    assert cp.version == 0


def test_half_open_probe_then_recovery_restores_weight():
    """OPEN → (cooldown) → HALF_OPEN at probe weight → recover_after
    healthy epochs → CLOSED with the pre-ejection weight restored."""
    cp = _cp()
    cp.set_weight("pool", 3, 2.5)                  # non-default: must return
    pol = HealthPolicy(cp, CFG)
    sick = _obs(cp, {0: 4, 1: 4, 2: 4, 3: 40})
    well = _obs(cp, {0: 4, 1: 4, 2: 4, 3: 4})
    pol.epoch(sick)
    pol.epoch(sick)                                # ejected (weight 0)
    assert float(cp.endpoint_weight("pool", 3)) == 0.0
    for _ in range(CFG.cooldown - 1):
        assert pol.epoch(sick) == []               # cooling down
    acts = pol.epoch(sick)                         # cooldown expires
    assert acts == [("probe", "pool", 3, CFG.probe_weight)]
    assert pol.state_of("pool", 3) == HALF_OPEN
    assert float(cp.endpoint_weight("pool", 3)) == \
        pytest.approx(CFG.probe_weight)
    assert cp.drain_reason("pool", 3) is None      # undrained (trickle)
    pol.epoch(well)                                # healthy probe 1
    acts = pol.epoch(well)                         # healthy probe 2: close
    assert acts == [("close", "pool", 3, 2.5)]
    assert pol.state_of("pool", 3) == CLOSED
    assert float(cp.endpoint_weight("pool", 3)) == 2.5


def test_half_open_still_sick_reejects_and_cooldown_restarts():
    cp = _cp()
    pol = HealthPolicy(cp, CFG)
    sick = _obs(cp, {0: 4, 1: 4, 2: 4, 3: 40})
    for _ in range(2 + CFG.cooldown):
        pol.epoch(sick)                            # eject, cooldown, probe
    assert pol.state_of("pool", 3) == HALF_OPEN
    acts = pol.epoch(sick)                         # probe fails immediately
    assert acts == [("eject", "pool", 3)]
    assert pol.state_of("pool", 3) == OPEN
    assert cp.drain_reason("pool", 3) == "health"
    # the full cooldown runs again before the next probe
    for _ in range(CFG.cooldown - 1):
        assert pol.epoch(sick) == []
    assert pol.epoch(sick)[0][0] == "probe"


def test_half_open_probe_patience_exhausted_reejects():
    """A probe that neither recovers nor clearly sickens (latency inside
    the hysteresis band) re-ejects after ``probe_patience`` epochs instead
    of trickling forever."""
    cp = _cp()
    pol = HealthPolicy(cp, CFG)
    sick = _obs(cp, {0: 4, 1: 4, 2: 4, 3: 40})
    limbo = _obs(cp, {0: 4, 1: 4, 2: 4, 3: 10})    # 2.5× med: in the band
    for _ in range(2 + CFG.cooldown):
        pol.epoch(sick)
    assert pol.state_of("pool", 3) == HALF_OPEN
    for _ in range(CFG.probe_patience - 1):
        assert pol.epoch(limbo) == []
    assert pol.epoch(limbo) == [("eject", "pool", 3)]


# --------------------------------------------------------------------------- #
# fault injector semantics
# --------------------------------------------------------------------------- #


class _Pool(NamedTuple):
    length: object
    active: object


def test_fault_schedules():
    slow = Fault(0, "slow", factor=4, start=10, end=30)
    assert not slow.holds(9) and not slow.holds(30)
    held = [t for t in range(10, 30) if slow.holds(t)]
    assert len(held) == 15                         # 3 of every 4 ticks held
    assert all(not slow.holds(t) for t in (10, 14, 18, 22, 26))
    stall = Fault(1, "stall", start=5, end=None)
    assert all(stall.holds(t) for t in range(5, 100))
    flap = Fault(2, "flap", start=0, period=3)
    assert [flap.holds(t) for t in range(8)] == \
        [True] * 3 + [False] * 3 + [True] * 2
    inj = FaultInjector([slow, stall])
    assert inj.active(11) == [0, 1] and inj.active(10) == [1]
    assert inj.clear_tick() is None                # the stall never clears
    assert FaultInjector([slow]).clear_tick() == 30


def test_fault_apply_rolls_back_length_on_both_pool_kinds():
    """Held instances' active slots lose one tick of progress (floored at
    0); other instances and inactive slots are untouched — for the numpy
    pool in place, for the jax pool functionally."""
    inj = FaultInjector([Fault(1, "stall")])
    ln = np.array([[3, 5], [2, 0]], np.int32)
    act = np.array([[True, True], [True, True]])
    pool = _Pool(ln, act)
    out = inj.apply(pool, tick=0)
    assert out is pool                             # numpy: mutated in place
    np.testing.assert_array_equal(pool.length, [[3, 5], [1, 0]])
    jpool = _Pool(jnp.array([[3, 5], [2, 0]], jnp.int32),
                  jnp.array([[True, True], [True, False]]))
    jout = inj.apply(jpool, tick=0)
    np.testing.assert_array_equal(np.asarray(jout.length), [[3, 5], [1, 0]])
    np.testing.assert_array_equal(
        np.asarray(FaultInjector([Fault(0, "slow", factor=2)])
                   .apply(jpool, 1).length), [[2, 4], [2, 0]])


# --------------------------------------------------------------------------- #
# end-to-end: live engine + fault + daemon
# --------------------------------------------------------------------------- #


def test_closed_loop_ejects_and_recovers_through_live_engine():
    """The whole loop on a real datapath: a stalled instance's EWMAs (built
    by the completion kernel, nothing host-side) trip its breaker; after the
    fault clears, the half-open probe re-admits it — zero operator
    transactions, every commit authored by the daemon."""
    cfg = smoke_config(get_config("xlb-service-model"))
    params = M.init_params(cfg, jax.random.PRNGKey(7), dtype=jnp.float32)
    I, C, max_len = 2, 4, 3
    cp = ControlPlane(
        [ServiceConfig("svc", rules=[Rule(0, None, "pool")])],
        [Cluster("pool", endpoints=list(range(I)),
                 policy=POLICY_LEAST_REQUEST)])
    eng = interpose.Engine(cfg, I, C, max_len, eos=-1)  # length-driven done
    inj = FaultInjector([Fault(1, "stall", start=10, end=60)])
    loop = ServeLoop(eng, params, cp, admit_batch=2, fault=inj,
                     max_retries=16, backoff_cap=4)
    pol = HealthPolicy(cp, HealthConfig(
        trip_after=2, cooldown=4, recover_after=2, probe_patience=6,
        probe_weight=0.25), clusters=["pool"])
    rid = 0
    ejected_at = unejected_at = None
    for t in range(110):
        loop.submit(Request(req_id=rid, service=0, headers={},
                            prompt_token=3 + rid % 5))
        rid += 1
        loop.tick()
        if t % 4 == 3:
            pol.epoch(loop.routing)
            st = pol.state_of("pool", 1)
            if st == OPEN and ejected_at is None:
                ejected_at = t
            if ejected_at is not None and unejected_at is None \
                    and st == CLOSED:
                unejected_at = t
    assert ejected_at is not None and 10 < ejected_at < 60
    assert unejected_at is not None and unejected_at > 60
    assert pol.state_of("pool", 1) == CLOSED       # auto un-drain complete
    assert cp.drain_reason("pool", 1) is None
    slot = cp.endpoint_slot("pool", 1)
    assert int(cp.snapshot().ep_drained[slot]) == 0
    assert float(cp.endpoint_weight("pool", 1)) == 1.0   # weight restored
    # zero operator transactions: every version bump came from the daemon
    assert cp.version == pol.commits > 0


# --------------------------------------------------------------------------- #
# Graded-weight mode
# --------------------------------------------------------------------------- #


def _cp_weighted(n=3):
    return ControlPlane(
        [ServiceConfig("svc", rules=[Rule(0, None, "pool")])],
        [Cluster("pool", endpoints=list(range(n)),
                 policy=POLICY_WEIGHTED)])


def test_graded_weights_monotone_in_latency_one_txn():
    """Graded mode demotes proportionally to the in-kernel latency EWMA
    ratio: slower endpoint => lower committed weight (floored), fast
    endpoints stay at full weight — and the whole grade commits as ONE
    transaction per epoch."""
    cp = _cp_weighted(3)
    pol = HealthPolicy(cp, HealthConfig(
        graded_weights=True, graded_alpha=1.0, graded_deadband=0.01,
        graded_floor=0.25), clusters=["pool"])
    acts = pol.epoch(_obs(cp, {0: 1.0, 1: 2.0, 2: 4.0}))
    assert all(a[0] == "weight" for a in acts)
    assert cp.version == 1                       # one txn for the epoch
    w = [cp.endpoint_weight("pool", i) for i in range(3)]
    assert w[0] >= w[1] > w[2]                   # monotone in latency
    assert w[0] == pytest.approx(1.0)            # med/lat clipped at 1.0
    # ep2: leave-one-out median(1, 2) / 4 = 0.375
    assert w[2] == pytest.approx(0.375)
    assert w[2] >= 0.25                          # floor respected


def test_graded_weights_converge_then_stop_committing():
    """No-flap: under a steady latency profile the EWMA-smoothed weights
    descend monotonically to the target and, once inside the deadband,
    epochs stop producing transactions entirely."""
    cp = _cp_weighted(3)
    pol = HealthPolicy(cp, HealthConfig(
        k_eject=20.0,                      # breaker stays out of the way
        graded_weights=True, graded_alpha=0.5, graded_deadband=0.02,
        graded_floor=0.1), clusters=["pool"])
    obs = _obs(cp, {0: 1.0, 1: 1.0, 2: 8.0})     # target for ep2: 1/8
    seen = []
    for _ in range(16):
        pol.epoch(obs)
        seen.append(float(cp.endpoint_weight("pool", 2)))
    assert seen == sorted(seen, reverse=True)    # monotone descent, no flap
    assert seen[-1] == pytest.approx(0.125, abs=0.03)
    commits_settled = pol.commits
    for _ in range(6):                           # steady state: silent
        pol.epoch(obs)
    assert pol.commits == commits_settled
    assert cp.version == commits_settled


def test_graded_weights_skip_non_weighted_and_no_data():
    """Graded mode only touches WEIGHTED clusters (other policies never
    read ep_weight) and never judges endpoints without EWMA data."""
    cp = _cp()                                   # POLICY_RR cluster
    pol = HealthPolicy(cp, HealthConfig(graded_weights=True),
                       clusters=["pool"])
    assert pol.epoch(_obs(cp, {0: 1.0, 1: 1.0, 2: 2.0, 3: 2.0})) == []
    assert cp.version == 0
    cpw = _cp_weighted(3)
    polw = HealthPolicy(cpw, HealthConfig(graded_weights=True),
                        clusters=["pool"])
    assert polw.epoch(_obs(cpw, {})) == []       # no data: nothing moves
    assert cpw.version == 0


def test_graded_weights_never_fight_the_breaker():
    """An OPEN (health-drained) endpoint keeps its staged weight: the
    graded pass skips non-CLOSED endpoints, so ejection and recovery stay
    the breaker's exclusive job."""
    cp = _cp_weighted(3)
    pol = HealthPolicy(cp, HealthConfig(
        trip_after=1, graded_weights=True, graded_alpha=1.0,
        graded_deadband=0.01), clusters=["pool"])
    acts = pol.epoch(_obs(cp, {0: 1.0, 1: 1.0, 2: 50.0}))
    assert ("eject", "pool", 2) in acts
    assert not any(a[0] == "weight" and a[2] == 2 for a in acts)
    assert cp.drain_reason("pool", 2) == "health"
    acts = pol.epoch(_obs(cp, {0: 1.0, 1: 1.0, 2: 50.0}))
    assert not any(a[0] == "weight" and a[2] == 2 for a in acts)
