"""Mesh-sharded admission tests (DESIGN.md §7).

In-process: the M=1 degenerate mesh is bit-exact vs the single-shard fused
kernel, the water-fill offset closed form matches a sequential argmin loop,
the shard-major oracle delegation, engine validation, and the control-plane
plan wire format.

Subprocess (4 virtual host devices, cf. tests/test_distributed.py): the
property sweep the reconciliation pass must survive — M ∈ {2, 4} against
single-shard ``admit_commit`` on the concatenated batch with uneven
per-shard queues, an all-padding shard (the per-shard lax.cond skip path),
drained endpoints visible to every shard, ragged batches, near-full pools
(global held resolution) — plus the 4-shard ``sharded_apply`` round-trip
vs the dense einsum oracle, and a mid-serve ControlPlane transaction
reaching every attached sharded consumer with exactly one version bump.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import make_mesh
from repro.core import control
from repro.core.balancer import PoolState, RequestBatch
from repro.core.routing_table import (MAX_ENDPOINTS, MAX_EPS_PER_CLUSTER,
                                      MAX_SERVICES, N_FEATURES, Cluster,
                                      POLICY_AFFINITY, POLICY_LEAST_REQUEST,
                                      POLICY_MAGLEV, POLICY_RANDOM,
                                      POLICY_RR, POLICY_WEIGHTED, Rule,
                                      ServiceConfig, build_state, fnv1a)
from repro.kernels import ops, ref
from repro.kernels.shard_admit import waterfill_lr


def _rich_state():
    """All six policies + a no-rule service + preloaded counters + a drain
    on an endpoint shared by three clusters + a drained maglev window slot
    whose table row was NOT rebuilt (the defensive fallback path)."""
    svcs = [ServiceConfig("a", rules=[Rule(0, "x", "rr"), Rule(1, "y", "lr"),
                                      Rule(0, None, "wt")]),
            ServiceConfig("b", rules=[Rule(2, "z", "rnd"),
                                      Rule(3, "m", "mg"),
                                      Rule(1, None, "af")])]
    cls = [Cluster("rr", endpoints=[0, 1, 2], policy=POLICY_RR),
           Cluster("lr", endpoints=[1, 2, 3], policy=POLICY_LEAST_REQUEST),
           Cluster("wt", endpoints=[0, 3], policy=POLICY_WEIGHTED,
                   weights=[0.2, 5.0]),
           Cluster("rnd", endpoints=[2, 0], policy=POLICY_RANDOM),
           Cluster("mg", endpoints=[0, 1, 2, 3], policy=POLICY_MAGLEV),
           Cluster("af", endpoints=[3, 1, 2], policy=POLICY_AFFINITY)]
    st, _ = build_state(svcs, cls)
    return st._replace(
        ep_load=st.ep_load.at[:8].set(
            jnp.asarray([3, 0, 2, 1, 0, 0, 0, 0], jnp.int32)),
        rr_cursor=st.rr_cursor.at[0].set(2),
        ep_drained=st.ep_drained.at[1].set(1).at[11].set(1))


def _batch(R, seed, pad_slice=None):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    rid = jnp.where(jax.random.bernoulli(ks[0], 0.85, (R,)),
                    jnp.arange(R, dtype=jnp.int32), -1)
    if pad_slice is not None:
        rid = rid.at[pad_slice].set(-1)
    svc = jax.random.randint(ks[1], (R,), 0, 3, dtype=jnp.int32)
    feats = jnp.zeros((R, N_FEATURES), jnp.int32)
    feats = feats.at[:, 0].set(jnp.where(
        jax.random.bernoulli(ks[2], .5, (R,)), fnv1a("x"), 0))
    feats = feats.at[:, 1].set(jnp.where(
        jax.random.bernoulli(ks[3], .5, (R,)), fnv1a("y"), 0))
    feats = feats.at[:, 2].set(jnp.where(
        jax.random.bernoulli(jax.random.fold_in(ks[2], 1), .5, (R,)),
        fnv1a("z"), 0))
    feats = feats.at[:, 3].set(jnp.where(
        jax.random.bernoulli(jax.random.fold_in(ks[3], 1), .5, (R,)),
        fnv1a("m"), 0))
    # flow-key diversity for the hash policies + repeated flows that land
    # on DIFFERENT shards (same key, same pick — reconciliation agreement)
    feats = feats.at[:, 4].set(
        jax.random.randint(jax.random.fold_in(ks[2], 2), (R,), 0, 997))
    if R >= 8:
        feats = feats.at[1::7].set(feats[0])
        svc = svc.at[1::7].set(svc[0])
    mb = jax.random.randint(ks[4], (R,), 1, 500, dtype=jnp.int32)
    tok = jax.random.randint(ks[5], (R,), 2, 90, dtype=jnp.int32)
    rnd = jax.random.randint(ks[6], (R,), 0, 1 << 30, dtype=jnp.int32)
    gum = jax.random.gumbel(ks[7], (R, MAX_EPS_PER_CLUSTER), jnp.float32)
    return RequestBatch(rid, svc, feats, tok, mb), rnd, gum


def _pool(I, C, seed, p_active=0.5):
    act = jax.random.bernoulli(jax.random.PRNGKey(seed), p_active, (I, C))
    return PoolState(jnp.where(act, 100, -1).astype(jnp.int32),
                     jnp.where(act, 0, -1).astype(jnp.int32),
                     jnp.zeros((I, C), jnp.int32),
                     jnp.zeros((I, C), jnp.int32),
                     jnp.zeros((I, C), jnp.int32), act)


def _complete_case(I, C, seed, max_len=8):
    """A completion step with work on every front: mixed EOS/length done,
    inactive lanes, endpoints spread over 4 slots, 2 services, and random
    nonzero health-EWMA bases (the carried accumulators)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    act = jax.random.bernoulli(ks[0], 0.7, (I, C))
    pool = PoolState(
        jnp.where(act, jnp.arange(I * C, dtype=jnp.int32).reshape(I, C),
                  -1).astype(jnp.int32),
        jnp.where(act, jax.random.randint(ks[1], (I, C), 0, 4), -1)
        .astype(jnp.int32),
        jax.random.randint(ks[2], (I, C), 0, 2, dtype=jnp.int32),
        jax.random.randint(ks[3], (I, C), 1, max_len, dtype=jnp.int32),
        jnp.zeros((I, C), jnp.int32), act)
    nxt = jnp.where(jax.random.bernoulli(ks[4], 0.3, (I, C)), 1,
                    jax.random.randint(ks[5], (I, C), 2, 90)
                    ).astype(jnp.int32)
    load = jnp.zeros((MAX_ENDPOINTS,), jnp.int32).at[:4].set(I * C)
    rx = jnp.zeros((MAX_SERVICES,), jnp.int32).at[:2].set(7)
    ewl = jax.random.uniform(ks[6], (MAX_ENDPOINTS,), jnp.float32, 0.0, 5.0)
    ewt = jax.random.uniform(ks[7], (MAX_ENDPOINTS,), jnp.float32, 0.0, 2.0)
    return pool, nxt, load, rx, ewl, ewt


def _assert_same(want, got, ctx=""):
    for name in want._fields:
        w, g = getattr(want, name), getattr(got, name)
        if name == "pool":
            for f in w._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(w, f)), np.asarray(getattr(g, f)),
                    err_msg=f"{ctx} pool.{f}")
        else:
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g),
                                          err_msg=f"{ctx} {name}")


# --------------------------------------------------------------------------- #
# in-process (single device): the M=1 mesh + the offset closed forms
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("R,seed", [(64, 7), (33, 3)])
def test_sharded_m1_bit_exact(R, seed):
    """The degenerate 1-way mesh must reproduce ``admit_commit`` exactly:
    same kernel, reconciliation pass reduced to identity psums."""
    st = _rich_state()
    reqs, rnd, gum = _batch(R, seed)
    pool = _pool(4, 3, 9)
    want = ops.admit_commit(reqs, st, pool, rnd, gum)
    got = ops.admit_commit_sharded(reqs, st, pool, rnd, gum,
                                   mesh=make_mesh((1,), ("shard",)))
    _assert_same(want, got, f"M=1 R={R}")
    assert int(want.held) > 0          # the scenario really exercises holds


def test_sharded_complete_m1_bit_exact():
    """Completion on the degenerate 1-way mesh reproduces the single-shard
    fused kernel exactly — pool writeback, load release, rx, AND the health
    EWMAs (zero-base per-shard deltas + psum + shared f32 epilogue must
    collapse to the in-kernel epilogue at M=1)."""
    max_len = 8
    pool, nxt, load, rx, ewl, ewt = _complete_case(4, 6, seed=23)
    want = ops.complete(pool, nxt, load, rx, ewl, ewt, eos=1,
                        max_len=max_len)
    got = ops.complete_sharded(pool, nxt, load, rx, ewl, ewt,
                               mesh=make_mesh((1,), ("shard",)),
                               eos=1, max_len=max_len)
    _assert_same(want, got, "complete M=1")
    assert int(np.asarray(want.done_cnt).sum()) > 0


def test_sharded_empty_batch_passthrough():
    st = _rich_state()
    reqs, rnd, gum = _batch(0, 0)
    pool = _pool(4, 3, 9)
    got = ops.admit_commit_sharded(reqs, st, pool, rnd, gum,
                                   mesh=make_mesh((1,), ("shard",)))
    np.testing.assert_array_equal(np.asarray(got.pool.active),
                                  np.asarray(pool.active))
    np.testing.assert_array_equal(np.asarray(got.ep_load),
                                  np.asarray(st.ep_load))


@pytest.mark.parametrize("seed", range(4))
def test_waterfill_matches_sequential_argmin(seed):
    """The closed-form water-fill (the cross-shard least-request offset)
    equals literally running "argmin over eligible, ties by window offset,
    then increment" k times — for random loads, drains and k."""
    rng = np.random.RandomState(seed)
    n_ep = int(rng.randint(1, 7))
    loads = rng.randint(0, 6, size=n_ep)
    drained = rng.rand(n_ep) < 0.25
    if drained.all():
        drained[rng.randint(n_ep)] = False
    k = int(rng.randint(0, 23))
    st, _ = build_state(
        [ServiceConfig("s", rules=[Rule(0, None, "c")])],
        [Cluster("c", endpoints=list(range(n_ep)),
                 policy=POLICY_LEAST_REQUEST)])
    st = st._replace(
        ep_load=st.ep_load.at[:n_ep].set(jnp.asarray(loads, jnp.int32)),
        ep_drained=st.ep_drained.at[:n_ep].set(
            jnp.asarray(drained, jnp.int32)))
    k_cl = jnp.zeros_like(st.rr_cursor).at[0].set(k)
    got = np.asarray(waterfill_lr(st, k_cl))[:n_ep]
    want = loads.copy()
    elig = np.flatnonzero(~drained)
    for _ in range(k):
        j = elig[int(np.argmin(want[elig]))]
        want[j] += 1
    np.testing.assert_array_equal(got, want,
                                  err_msg=f"loads={loads} k={k} dr={drained}")


def test_admit_sharded_ref_is_shard_major():
    """The oracle's documented merge rule: per-shard rows concatenate in
    shard-major order and the whole thing equals ``admit_commit_ref``."""
    st = _rich_state()
    reqs, rnd, gum = _batch(48, 5)
    pool = _pool(4, 3, 11)
    M, R_loc = 4, 12
    shaped = lambda a: np.asarray(a).reshape(M, R_loc, *a.shape[1:])
    r = ref.admit_sharded_ref(
        shaped(reqs.req_id), shaped(reqs.svc), shaped(reqs.features),
        shaped(reqs.msg_bytes), shaped(reqs.token), st, pool.req_id,
        pool.endpoint, pool.svc, pool.length, pool.token, pool.active,
        shaped(rnd), shaped(gum))
    base = ref.admit_commit_ref(
        reqs.req_id, reqs.svc, reqs.features, reqs.msg_bytes, reqs.token,
        st, pool.req_id, pool.endpoint, pool.svc, pool.length, pool.token,
        pool.active, rnd, gum)
    np.testing.assert_array_equal(r.slot.reshape(-1), base.slot)
    np.testing.assert_array_equal(r.ep_load, base.ep_load)
    np.testing.assert_array_equal(r.pool_active, base.pool_active)
    assert r.cluster.shape == (M, R_loc)


class _FakeMesh:
    """Shape-only stand-in so the 2-way divisibility guard is testable on
    one device (the guard fires before any shard_map is built)."""

    shape = {"shard": 2}


def test_engine_shard_validation():
    from repro.configs import get_config, smoke_config
    from repro.core.interpose import Engine
    from repro.kernels import shard_admit
    cfg = smoke_config(get_config("xlb-service-model"))
    with pytest.raises(ValueError, match="shard_mesh"):
        Engine(cfg, 4, 2, 8, shards=2)
    with pytest.raises(ValueError, match="mesh width"):
        Engine(cfg, 4, 2, 8, shards=2,
               shard_mesh=make_mesh((1,), ("shard",)))
    with pytest.raises(ValueError, match="divide"):
        Engine(cfg, 3, 2, 8, shards=2, shard_mesh=_FakeMesh())
    # pool instances not divisible over the mesh axis
    reqs, rnd, gum = _batch(8, 0)
    pool = _pool(3, 2, 0)
    with pytest.raises(ValueError, match="divide"):
        shard_admit.admit_commit_sharded(
            reqs.req_id, reqs.svc, reqs.features, reqs.msg_bytes,
            reqs.token, _rich_state(), pool.req_id, pool.endpoint, pool.svc,
            pool.length, pool.token, pool.active, rnd, gum,
            mesh=_FakeMesh())


def test_refresh_plan_pack_unpack_roundtrip():
    """The fan-out wire format: a committed plan survives pack → unpack
    bit-exactly, so a remote sharded consumer applies the identical splice."""
    cp = control.ControlPlane(
        [ServiceConfig("s", rules=[Rule(0, None, "c")])],
        [Cluster("c", endpoints=[0, 1], policy=POLICY_RR)])
    st0 = cp.snapshot()                   # the remote replica, pre-commit
    with cp.transaction():
        cp.add_endpoint("c", 2)
        cp.drain_endpoint("c", 0)
    plan = cp.last_plan
    back = control.unpack_plan(control.pack_plan(plan))
    for a, b in zip(plan.config, back.config):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(plan.ep_src, back.ep_src)
    np.testing.assert_array_equal(plan.ep_dst, back.ep_dst)
    # journaled plans are versioned (DESIGN.md §11): the splice lands the
    # replica on the control plane's exact version, not a blind +1
    assert back.base_version == 0 and back.version == 1
    st1 = control.apply_plan(st0, back)
    assert int(np.asarray(st1.version)) == cp.version == 1


# --------------------------------------------------------------------------- #
# subprocess: real 4-device mesh (XLA_FLAGS must precede jax init)
# --------------------------------------------------------------------------- #

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["XLB_AUTOTUNE"] = "0"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.core import control, relay
from repro.core.balancer import PoolState, RequestBatch
from repro.core.routing_table import (MAX_EPS_PER_CLUSTER, N_FEATURES,
    Cluster, POLICY_LEAST_REQUEST, POLICY_RANDOM, POLICY_RR,
    POLICY_WEIGHTED, Rule, ServiceConfig, build_state, fnv1a)
from repro.kernels import ops, ref

import test_shard_admit as T          # PYTHONPATH includes tests/

# --- 1) property sweep: M in {2,4} vs single-shard on the concatenation --- #
scenarios = [
    # (R, seed, pad_slice, pool_seed, p_active, label)
    (96, 7, slice(48, 72), 9, 0.4, "all-padding shard @M=4 + near-full"),
    (96, 3, slice(8, 40), 11, 0.2, "uneven queues (mid-batch padding)"),
    (52, 5, None, 13, 0.6, "ragged R=52 (pads to the shard multiple)"),
]
for R, seed, pad, pseed, pact, label in scenarios:
    st = T._rich_state()
    reqs, rnd, gum = T._batch(R, seed, pad_slice=pad)
    pool = T._pool(4, 5, pseed, p_active=pact)
    want = ops.admit_commit(reqs, st, pool, rnd, gum)
    for M in (2, 4):
        mesh = make_mesh((M,), ("shard",))
        got = ops.admit_commit_sharded(reqs, st, pool, rnd, gum, mesh=mesh)
        T._assert_same(want, got, f"M={M} {label}")
    print(f"sweep OK: {label} (held={int(want.held)}, "
          f"no_route={int(want.no_route)})")

# hash policies at volume: the affinity cache fills (intra-batch writes,
# repeated flows split across shards), maglev fallback fires for the
# drained un-rebuilt table slot — and the lowest-shard-wins cache
# reconciliation reproduces the single-shard result bit-exactly
st = T._rich_state()
reqs, rnd, gum = T._batch(128, 41)
pool = T._pool(4, 5, 23, p_active=0.3)
want = ops.admit_commit(reqs, st, pool, rnd, gum)
assert int((np.asarray(want.aff_ep) >= 0).sum()) > 0   # cache populated
for M in (2, 4):
    got = ops.admit_commit_sharded(reqs, st, pool, rnd, gum,
                                   mesh=make_mesh((M,), ("shard",)))
    T._assert_same(want, got, f"hash policies M={M}")
print("sweep OK: maglev+affinity reconcile bit-exact at M in {2,4}")

# fully-drained cluster is unroutable on every shard
st = T._rich_state()
st = st._replace(ep_drained=st.ep_drained.at[6:8].set(1))  # drain 'rnd'
reqs, rnd, gum = T._batch(64, 21)
pool = T._pool(4, 5, 17)
want = ops.admit_commit(reqs, st, pool, rnd, gum)
got = ops.admit_commit_sharded(reqs, st, pool, rnd, gum,
                               mesh=make_mesh((4,), ("shard",)))
T._assert_same(want, got, "fully-drained cluster")
print("sweep OK: fully-drained cluster unroutable on every shard")

# --- 1b) completion sharding: M in {2,4}, health EWMAs bit-exact ---------- #
for I, C, seed in ((8, 6, 23), (4, 16, 29)):
    pool, nxt, load, rx, ewl, ewt = T._complete_case(I, C, seed)
    want = ops.complete(pool, nxt, load, rx, ewl, ewt, eos=1, max_len=8)
    for M in (2, 4):
        got = ops.complete_sharded(pool, nxt, load, rx, ewl, ewt,
                                   mesh=make_mesh((M,), ("shard",)),
                                   eos=1, max_len=8)
        T._assert_same(want, got, f"complete M={M} I={I}")
    assert int(np.asarray(want.done_cnt).sum()) > 0
print("complete OK: sharded health EWMAs bit-exact at M in {2,4}")

# the shard-major oracle pins the sharded op directly
M, R = 4, 64
st = T._rich_state(); reqs, rnd, gum = T._batch(R, 31)
pool = T._pool(4, 5, 19)
sh = lambda a: np.asarray(a).reshape(M, R // M, *a.shape[1:])
r = ref.admit_sharded_ref(sh(reqs.req_id), sh(reqs.svc), sh(reqs.features),
                          sh(reqs.msg_bytes), sh(reqs.token), st,
                          pool.req_id, pool.endpoint, pool.svc, pool.length,
                          pool.token, pool.active, sh(rnd), sh(gum))
got = ops.admit_commit_sharded(reqs, st, pool, rnd, gum,
                               mesh=make_mesh((4,), ("shard",)))
np.testing.assert_array_equal(r.slot.reshape(-1), np.asarray(got.slot))
np.testing.assert_array_equal(r.ep_load, np.asarray(got.ep_load))
np.testing.assert_array_equal(r.pool_active,
                              np.asarray(got.pool.active).astype(np.int32))
print("oracle OK: admit_sharded_ref pins the 4-shard datapath")

# --- 2) sharded_apply round-trip == dense einsum oracle ------------------- #
mesh = make_mesh((4,), ("shard",))
E, C, D, N = 8, 16, 4, 64
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (N, D), jnp.float32)
idx = jax.random.randint(jax.random.PRNGKey(1), (N,), 0, E)
w = jax.random.uniform(jax.random.PRNGKey(2), (N,), jnp.float32)
scale = jnp.arange(1.0, E + 1.0)[:, None]           # per-dest transform

def backend(params, pool):                          # (E_loc, M*C, D)
    return pool * params[:, None, :]

out_sh, meta = jax.jit(shard_map(
    lambda xx, ii, ww, pp: relay.sharded_apply(
        xx, ii, ww, n_dest=E, capacity=C, axis="shard",
        backend_fn=backend, backend_params=pp),
    mesh=mesh, in_specs=(P("shard"), P("shard"), P("shard"), P("shard")),
    out_specs=(P("shard"), relay.RelayMeta(P("shard"), P("shard"),
                                           P("shard"), P(), P())),
    check_vma=False))(x, idx, w, scale)
# dense global oracle at capacity M*C (nothing drops either way)
buf, gmeta, d_oh = relay.relay_dispatch_einsum(x, idx, E, 4 * C)
want = relay.relay_combine_einsum(buf * scale[:, None, :], d_oh, w)
np.testing.assert_allclose(np.asarray(out_sh), np.asarray(want),
                           rtol=1e-5, atol=1e-5)
# meta.load is GLOBAL pre-drop (psum'd), ok per-source (nothing dropped)
np.testing.assert_array_equal(np.asarray(meta.load),
                              np.asarray(jnp.bincount(idx, length=E)))
assert bool(np.all(np.asarray(meta.ok)))
assert float(np.asarray(meta.overflow_frac)) == 0.0
print("relay OK: sharded round-trip matches the einsum oracle, global load")

# --- 3) mid-serve ControlPlane txn -> every sharded consumer, one bump ---- #
from repro.configs import get_config, smoke_config
from repro.core.balancer import make_balancer
from repro.launch.mesh import make_shard_mesh
from repro.models import model as Mmod
from repro.runtime.serve_loop import Request, ServeLoop

cfg = smoke_config(get_config("xlb-service-model"))
params = Mmod.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
cp = control.ControlPlane(
    [ServiceConfig("svc", rules=[Rule(0, None, "pool")])],
    [Cluster("pool", endpoints=[0, 1], policy=POLICY_RR)])
eng = make_balancer("xlb", cfg, 2, 2, 8, shards=2,
                    shard_mesh=make_shard_mesh(2))
loop = ServeLoop(eng, params, cp, admit_batch=4)

class RemoteIngress:
    # a second attached consumer: holds its own replicated routing snapshot
    # and applies the SAME shipped plan pytree (pack/unpack wire format)
    def __init__(self, cp):
        self.routing = cp.snapshot()
    def apply_refresh(self, plan):
        plan = control.unpack_plan(control.pack_plan(plan))
        self.routing = control.apply_plan(self.routing, plan)

remote = RemoteIngress(cp)
cp.attach(remote)
for i in range(4):
    loop.submit(Request(req_id=i, service=0, headers={}, prompt_token=3 + i))
loop.tick()
v0 = int(np.asarray(loop.routing.version))
with cp.transaction():                      # one txn, two deltas
    cp.drain_endpoint("pool", 1)
    cp.set_weight("pool", 0, 2.0)
slot = cp.endpoint_slot("pool", 1)
for name, r in (("loop", loop.routing), ("remote", remote.routing)):
    assert int(np.asarray(r.version)) == v0 + 1, name   # exactly one bump
    assert int(np.asarray(r.ep_drained)[slot]) == 1, name
for i in range(4, 10):
    loop.submit(Request(req_id=i, service=0, headers={}, prompt_token=3 + i))
# pre-drain connections may still sit on the drained endpoint; no POST-
# drain admission (req_id >= 4) may ever land there, on any shard's slice
saw_new = False
for _ in range(30):
    loop.tick()
    pe = np.asarray(loop.state.pool.endpoint)
    pr = np.asarray(loop.state.pool.req_id)
    act = np.asarray(loop.state.pool.active)
    assert not bool(((pe == slot) & (pr >= 4) & act).any())
    saw_new = saw_new or bool(((pr >= 4) & act).any())
assert saw_new                                # traffic kept flowing
print("control OK: one bump on all sharded consumers, drain visible")

# --- 4) transport kill/restart: lease expiry x rejoin resync ------------- #
# A sharded ServeLoop attaches through the lossy plan transport.  It holds
# in-flight load on an endpoint the operator drains, then crashes.  Its
# phantom load must stop pinning the drain once the lease expires, and the
# restarted incarnation must land exactly ONE version-consistent resync.
from repro.runtime import transport
from repro.runtime.serve_loop import Fault, FaultInjector

cp2 = control.ControlPlane(
    [ServiceConfig("svc", rules=[Rule(0, None, "pool")])],
    [Cluster("pool", endpoints=[0, 1], policy=POLICY_RR)],
    lease_epochs=2)
hub = transport.Transport(cp2, transport.LossyChannel(seed=5))
rc = hub.consumer("ingress-0")
# instance 1 wedged: its slots never progress, so its in-flight load can
# only ever clear by the lease expiring (the crash scenario under test)
loop2 = ServeLoop(eng, params, rc, admit_batch=4,
                  fault=FaultInjector([Fault(instance=1, kind="stall")]))
t = [0]
def pump(n, dead=False):
    for _ in range(n):
        hub.pump(t[0])
        if not dead:
            loop2.tick()
        t[0] += 1
for i in range(6):
    loop2.submit(Request(req_id=200 + i, service=0, headers={},
                         prompt_token=3 + i))
pump(4)                                       # admit + heartbeat the load in
cp2.drain_endpoint("pool", 1)
pump(3)                                       # plan v1 ships + lands
slot1 = cp2.endpoint_slot("pool", 1)
assert rc.version == cp2.version == 1
assert int(np.asarray(loop2.routing.ep_drained)[slot1]) == 1
proxy = hub.publisher.nodes["ingress-0"].proxy
assert int(proxy.routing.ep_load[slot1]) > 0  # reported load pins the row
cp2.reap()
assert len(cp2.cluster_members("pool")) == 2  # live lease: reap blocked
assert cp2.version == 1                       # blocked reap = no commit
rc.crash()                                    # the host dies mid-drain
# 4 epochs, not lease_epochs+1: a heartbeat already in flight through the
# lossy channel can land after the first advance and refresh the lease one
# epoch later than the crash tick
for _ in range(4):
    cp2.advance_epoch()
    pump(1, dead=True)
assert not cp2.lease_live(proxy)              # lease expired
cp2.reap()                                    # phantom load ignored now
assert len(cp2.cluster_members("pool")) == 1
assert cp2.version == 2
cp2.set_weight("pool", 0, 2.0)                # commits keep landing while
assert cp2.version == 3                       # the node is dead
pump(4, dead=True)                            # dead node: nothing ships
assert hub.publisher.nodes["ingress-0"].acked == 1
rc.restart()                                  # fresh process, version -1
loop3 = ServeLoop(eng, params, rc, admit_batch=4)
for _ in range(12):
    hub.pump(t[0]); loop3.tick(); t[0] += 1
assert rc.resyncs == 1, rc.resyncs            # exactly one resync
assert rc.version == cp2.version == 3
transport.assert_converged(cp2, [rc])
for i in range(4):
    loop3.submit(Request(req_id=300 + i, service=0, headers={},
                         prompt_token=3))
for _ in range(20):
    hub.pump(t[0]); loop3.tick(); t[0] += 1
assert len(loop3.done) == 4                   # resumed serving post-rejoin
print("transport OK: lease unpinned the phantom drain, one resync on rejoin")
"""


@pytest.mark.timeout(900)
def test_sharded_admission_subprocess():
    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + here
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=850,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, \
        f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-4000:]}"
    for marker in ("sweep OK: all-padding shard",
                   "sweep OK: uneven queues",
                   "sweep OK: ragged R=52",
                   "sweep OK: maglev+affinity reconcile",
                   "sweep OK: fully-drained cluster",
                   "complete OK: sharded health EWMAs",
                   "oracle OK: admit_sharded_ref",
                   "relay OK: sharded round-trip",
                   "control OK: one bump",
                   "transport OK: lease unpinned the phantom drain"):
        assert marker in out.stdout, f"missing {marker!r}\n{out.stdout}"
