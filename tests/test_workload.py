"""Workload subsystem tests (src/repro/workload/ + the bench chain driver).

Pins the DESIGN.md §10 contracts: keyed-draw determinism of the arrival
processes and service-time laws, exact per-request delays through the
progress-rollback shaper, chain traversal (end-to-end latency = sum of
per-hop tick latencies), the live-ops scenario ops as single ControlPlane
transactions, elastic ``scale_fleet`` semantics, the out-of-window fault
regression, the scenario-row schema validator, and bit-identical replay of
BENCH_TREND scenario rows under a fixed seed."""

import json
import types

import numpy as np
import pytest

from repro.core.control import ControlPlane
from repro.core.routing_table import (Cluster, POLICY_RR, POLICY_WEIGHTED,
                                      Rule, ServiceConfig)
from repro.runtime.elastic import scale_fleet
from repro.runtime.serve_loop import Fault, FaultInjector
from repro.workload import (BurstyArrivals, DiurnalArrivals,
                            LognormalServiceTimes, Op, ParetoServiceTimes,
                            PoissonArrivals, ScenarioDriver,
                            ServiceTimeShaper, Workload, append_scenario_row,
                            percentiles, rolling_restart, scenario_row,
                            validate_scenario_row)


def _cp(n=3, policy=POLICY_WEIGHTED):
    return ControlPlane(
        [ServiceConfig("svc", rules=[Rule(0, None, "pool")])],
        [Cluster("pool", endpoints=list(range(n)), policy=policy)])


# --------------------------------------------------------------------------- #
# Generators
# --------------------------------------------------------------------------- #


def test_arrivals_keyed_determinism_and_seed_sensitivity():
    """Draws are keyed by (seed, tick): replays are bit-identical, the key
    is the *tick* (not call order), and a different seed is a different
    stream."""
    a = PoissonArrivals(rate=3.0, seed=1)
    trace = [a.arrivals(t) for t in range(64)]
    assert [a.arrivals(t) for t in range(64)] == trace
    # order-free: querying tick 7 in isolation matches the swept value
    assert a.arrivals(7) == trace[7]
    b = PoissonArrivals(rate=3.0, seed=2)
    assert [b.arrivals(t) for t in range(64)] != trace


def test_scale_knob_multiplies_offered_rate():
    base = PoissonArrivals(rate=2.0, seed=3)
    scaled = PoissonArrivals(rate=2.0, seed=3, scale=8.0)
    n_base = sum(base.arrivals(t) for t in range(200))
    n_scaled = sum(scaled.arrivals(t) for t in range(200))
    assert n_scaled > 4 * n_base          # ~8x in expectation


def test_bursty_and_diurnal_shapes():
    b = BurstyArrivals(rate=5.0, seed=0, on_ticks=4, off_ticks=4)
    assert all(b.arrivals(t) == 0 for t in range(4, 8))    # OFF is silent
    assert sum(b.arrivals(t) for t in range(0, 4)) > 0     # ON carries load
    d = DiurnalArrivals(rate=1.0, peak=9.0, period=64)
    assert d.rate_at(0) == pytest.approx(1.0)              # trough
    assert d.rate_at(32) == pytest.approx(9.0)             # peak
    assert d.rate_at(16) == pytest.approx(5.0)             # mid-swing


def test_service_time_laws_keying_and_bounds():
    ln = LognormalServiceTimes(seed=4, median=3.0, sigma=0.8, floor=1, cap=20)
    ts = [ln.ticks(r) for r in range(200)]
    assert ts == [ln.ticks(r) for r in range(200)]         # deterministic
    assert all(1 <= t <= 20 for t in ts)
    assert len(set(ts)) > 3                                # actually spread
    # the same request re-sampled at a different hop draws independently
    assert any(ln.ticks(r, hop=1) != ln.ticks(r, hop=0) for r in range(50))
    pa = ParetoServiceTimes(seed=4, xm=2.0, alpha=1.5, floor=1, cap=50)
    assert all(2 <= pa.ticks(r) <= 50 for r in range(200))


def test_shaper_enforces_exact_extra_ticks():
    """A request whose sampled time exceeds the base occupancy is held for
    exactly the difference — one effective rollback per extra tick."""
    law = LognormalServiceTimes(seed=9, median=6.0, sigma=0.5, cap=16)
    base = 2
    sh = ServiceTimeShaper(law, base_ticks=base, hop=0)
    rid = 5
    extra = max(0, law.ticks(rid, 0) - base)
    assert extra > 0                       # seed chosen to have a real hold
    pool = types.SimpleNamespace(
        req_id=np.array([[rid]], np.int32),
        active=np.array([[True]]),
        length=np.array([[1]], np.int32))
    holds = 0
    for t in range(extra + 5):
        before = pool.length.copy()
        sh.apply(pool, t)
        if pool.length[0, 0] != before[0, 0]:
            holds += 1
            pool.length[0, 0] = before[0, 0]   # engine re-makes the progress
    assert holds == extra
    # an idle slot (length 0) is never charged
    sh2 = ServiceTimeShaper(law, base_ticks=base)
    empty = types.SimpleNamespace(req_id=np.array([[rid]], np.int32),
                                  active=np.array([[True]]),
                                  length=np.array([[0]], np.int32))
    sh2.apply(empty, 0)
    assert empty.length[0, 0] == 0
    assert sh2._extra(rid) == extra        # nothing consumed


# --------------------------------------------------------------------------- #
# Scenario ops
# --------------------------------------------------------------------------- #


def test_canary_shifts_weights_in_one_txn():
    cp = _cp(3)
    drv = ScenarioDriver([cp], [Op(2, "canary", args={"instance": 0,
                                                      "pct": 80.0})])
    drv.apply(1)
    assert cp.version == 0                 # not due yet
    drv.apply(2)
    assert cp.version == 1 and drv.txns == 1     # ONE transaction
    assert cp.endpoint_weight("pool", 0) == pytest.approx(0.8)
    for peer in (1, 2):
        assert cp.endpoint_weight("pool", peer) == pytest.approx(0.1)
    assert drv.done()


def test_blue_green_cutover_single_txn():
    cp = _cp(2)
    ops = [Op(0, "add_endpoint", args={"instance": 2, "weight": 0.0}),
           Op(3, "blue_green", args={"blue": [0, 1], "green": [2]})]
    drv = ScenarioDriver([cp], ops)
    drv.apply(0)
    v = cp.version
    drv.apply(3)
    assert cp.version == v + 1             # cutover is one version bump
    assert cp.endpoint_weight("pool", 2) == pytest.approx(1.0)
    # with no in-flight load the drained blues are reaped at commit; green
    # alone serves either way
    serving = [i for _, i in cp.cluster_members("pool")
               if cp.drain_reason("pool", i) is None]
    assert serving == [2]


def test_rolling_restart_expansion_and_completion():
    cp = _cp(3)
    ops = rolling_restart([0, 1], start=2, dwell=3)
    assert [(o.tick, o.op) for o in ops] == [
        (2, "drain"), (5, "undrain"), (5, "drain"), (8, "undrain")]
    drv = ScenarioDriver([cp], ops)
    for t in range(9):
        drv.apply(t)
        draining = sum(1 for i in (0, 1)
                       if cp.drain_reason("pool", i) is not None)
        assert draining <= 1               # staggered: one down at a time
    assert drv.done() and drv.txns == 4
    for i in (0, 1):
        assert cp.drain_reason("pool", i) is None
        assert cp.endpoint_weight("pool", i) == pytest.approx(1.0)


def test_scale_fleet_up_down_one_txn_each():
    cp = _cp(2)
    v0 = cp.version
    acts = scale_fleet(cp, "pool", 4, max_instances=4)
    assert acts == [("add", 2), ("add", 3)]
    assert cp.version == v0 + 1
    assert sorted(i for _, i in cp.cluster_members("pool")) == [0, 1, 2, 3]
    acts = scale_fleet(cp, "pool", 1, max_instances=4)
    assert acts == [("drain", 1), ("drain", 2), ("drain", 3)]
    serving = [i for _, i in cp.cluster_members("pool")
               if cp.drain_reason("pool", i) is None]
    assert serving == [0]                  # highest-numbered drained first
    # zero-load drains were reaped at commit; scale-up re-adds fresh lanes
    scale_fleet(cp, "pool", 3, max_instances=4)
    serving = [i for _, i in cp.cluster_members("pool")
               if cp.drain_reason("pool", i) is None]
    assert len(serving) == 3
    with pytest.raises(ValueError):
        scale_fleet(cp, "pool", 9, max_instances=4)


def test_scale_fleet_undrains_loaded_endpoint_before_adding():
    """Scale-up prefers reviving a draining endpoint (kept alive by its
    in-flight load) over splicing in a fresh instance lane."""
    cp = _cp(2, policy=POLICY_RR)

    class _Holder:
        def __init__(self):
            self.routing = cp.snapshot()._replace(
                ep_load=np.ones_like(np.asarray(cp.snapshot().ep_load)))

        def apply_refresh(self, plan):
            pass                           # keep the pinned loads

    holder = _Holder()
    cp.attach(holder)                      # load votes pin drained rows
    acts = scale_fleet(cp, "pool", 1, max_instances=4)
    assert acts == [("drain", 1)]
    assert cp.drain_reason("pool", 1) is not None    # survived the reaper
    acts = scale_fleet(cp, "pool", 2, max_instances=4)
    assert acts == [("undrain", 1)]        # revived, no new lane spliced
    assert cp.drain_reason("pool", 1) is None


# --------------------------------------------------------------------------- #
# Fault-window regression (S3)
# --------------------------------------------------------------------------- #


def test_fault_outside_live_window_is_inert():
    """Regression: a flap fault naming an instance lane the pool doesn't
    have (schedule written for a bigger fleet, or racing an elastic scale
    on the same tick) used to IndexError on numpy pools / silently clip on
    jax pools.  It must be inert."""
    inj = FaultInjector([Fault(5, "flap", start=0, period=2),
                         Fault(-3, "stall", start=0)])
    pool = types.SimpleNamespace(
        req_id=np.array([[1, 2]], np.int32),
        active=np.array([[True, True]]),
        length=np.array([[2, 3]], np.int32))
    out = inj.apply(pool, 0)               # both faults hold at tick 0
    assert out is pool
    assert pool.length.tolist() == [[2, 3]]


def test_flap_fault_composes_with_elastic_scale():
    """The full composition the bug report names: flap fault + scale event
    live in the same run (one in-window target, one out-of-window) — the
    chain completes every request."""
    from benchmarks.common import run_chain_scenario
    inj = FaultInjector([Fault(1, "flap", start=0, end=6, period=1),
                         Fault(7, "flap", start=0, period=2)])
    out = run_chain_scenario(
        "istio", depth=1,
        workload=Workload(PoissonArrivals(rate=2.0, seed=5), n_requests=6),
        ops=[Op(1, "scale", args={"target": 1}),
             Op(4, "scale", args={"target": 2})],
        faults={0: inj})
    row = out["row"]
    assert row["completed"] == row["n_requests"] and row["dropped"] == 0
    assert row["txns"] == 2


# --------------------------------------------------------------------------- #
# Chain traversal
# --------------------------------------------------------------------------- #


def test_chain_end_to_end_is_sum_of_hops():
    """Forwarding is synchronous (hop k completion tick == hop k+1 submit
    tick), so end-to-end latency telescopes to the sum of per-hop
    latencies."""
    from benchmarks.common import run_chain_scenario
    res = run_chain_scenario(
        "istio", depth=3,
        workload=Workload(PoissonArrivals(rate=2.0, seed=11),
                          n_requests=10))["result"]
    assert res.completed == 10
    for r in res.done_tick:
        e2e = res.done_tick[r] - res.submit_tick[r]
        hops = sum(res.hop_done[k][r] - res.hop_submit[k][r]
                   for k in range(res.depth))
        assert e2e == hops
        for k in range(res.depth - 1):     # synchronous forwarding
            assert res.hop_submit[k + 1][r] == res.hop_done[k][r]


# --------------------------------------------------------------------------- #
# SLO rows
# --------------------------------------------------------------------------- #


def test_percentiles_empty_and_tail():
    p = percentiles([])
    assert p["n"] == 0 and np.isnan(p["p99"])
    p = percentiles(list(range(1, 101)))
    assert p["p50"] == pytest.approx(50.5)
    assert p["p99"] <= p["p999"] <= 100


def test_scenario_row_schema_validation():
    row = scenario_row("chain", "xlb", depth=3, seed=11, arrivals="poisson",
                       n_requests=10, completed=10, dropped=0, ticks=12,
                       samples=[3, 3, 4])
    validate_scenario_row(row)             # round-trips
    for bad, err in [
        (dict(row, bench="perf"), "bench"),
        (dict(row, completed=20), "exceeds"),
        (dict(row, p99_ticks=1.0), "monotone"),
        (dict(row, depth=True), "depth"),
        (dict(row, surprise=1), "unknown"),
    ]:
        with pytest.raises(ValueError, match=err):
            validate_scenario_row(bad)
    missing = dict(row)
    del missing["seed"]
    with pytest.raises(ValueError, match="missing"):
        validate_scenario_row(missing)
    with pytest.raises(ValueError, match="unknown"):
        scenario_row("chain", "xlb", depth=3, seed=11, arrivals="poisson",
                     n_requests=10, completed=10, dropped=0, ticks=12,
                     samples=[3], bogus_extra=1)


def test_append_scenario_row_stamps_and_appends(tmp_path):
    row = scenario_row("chain", "istio", depth=1, seed=0, arrivals="poisson",
                       n_requests=2, completed=2, dropped=0, ticks=3,
                       samples=[1, 2])
    path = tmp_path / "TREND.jsonl"
    stamped = append_scenario_row(row, path=str(path))
    assert "ts" in stamped and "commit" in stamped
    lines = path.read_text().strip().splitlines()
    assert len(lines) == 1
    back = json.loads(lines[0])
    validate_scenario_row(back)
    assert {k: back[k] for k in row} == row    # payload unchanged by stamp


# --------------------------------------------------------------------------- #
# Deterministic replay (S4)
# --------------------------------------------------------------------------- #


def _replay(workload_fn, **kw):
    from benchmarks.common import run_chain_scenario
    rows = [run_chain_scenario("istio", workload=workload_fn(), **kw)["row"]
            for _ in range(2)]
    assert rows[0] == rows[1]
    assert json.dumps(rows[0]) == json.dumps(rows[1])  # bit-identical JSONL
    return rows[0]


def test_replay_poisson_row_bit_identical():
    r = _replay(lambda: Workload(PoissonArrivals(rate=2.0, seed=11),
                                 n_requests=8), depth=3)
    assert r["completed"] == 8 and r["arrivals"] == "poisson"


def test_replay_bursty_row_bit_identical():
    r = _replay(lambda: Workload(
        BurstyArrivals(rate=4.0, seed=21, on_ticks=3, off_ticks=3),
        service=LognormalServiceTimes(seed=6, median=2.5, sigma=0.6, cap=10),
        n_requests=8), depth=2)
    assert r["arrivals"] == "bursty" and r["service"] == "lognormal"


def test_replay_depth3_chain_with_midrun_canary():
    r = _replay(lambda: Workload(PoissonArrivals(rate=2.0, seed=11),
                                 n_requests=8),
                depth=3, policy=POLICY_WEIGHTED,
                ops=[Op(3, "canary", hop=1,
                        args={"instance": 1, "pct": 75.0})])
    assert r["ops"] == 1 and r["txns"] == 1
    assert r["completed"] == 8


# --------------------------------------------------------------------------- #
# ServeLoop latency samples (S1)
# --------------------------------------------------------------------------- #


def test_serve_loop_records_latency_samples():
    """The runtime loop itself carries per-request tick samples: submit →
    first admitted tick → completion tick, plus the retry count."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, smoke_config
    from repro.core import interpose
    from repro.models import model as M
    from repro.runtime.serve_loop import Request, ServeLoop

    cfg = smoke_config(get_config("xlb-service-model"))
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    cp = _cp(2, policy=POLICY_RR)
    eng = interpose.Engine(cfg, 2, 4, max_len=3, eos=-1)  # length-driven
    loop = ServeLoop(eng, params, cp, admit_batch=4)
    for r in range(6):
        loop.submit(Request(req_id=r, service=0, headers={},
                            prompt_token=3 + r))
    rep = loop.drain(max_ticks=60)
    assert len(rep.done) == 6
    s = loop.latency_samples()
    assert sorted(s["req_id"].tolist()) == list(range(6))
    assert (s["admit_to_done"] >= 0).all()
    # queueing (submit → admit) can only add latency
    assert (s["submit_to_done"] >= s["admit_to_done"]).all()
    assert (s["retries"] >= 0).all()
    # samples are ticks, not wall time: replaying gives identical arrays
    loop2 = ServeLoop(interpose.Engine(cfg, 2, 4, max_len=3, eos=-1),
                      params, _cp(2, policy=POLICY_RR), admit_batch=4)
    for r in range(6):
        loop2.submit(Request(req_id=r, service=0, headers={},
                             prompt_token=3 + r))
    loop2.drain(max_ticks=60)
    s2 = loop2.latency_samples()
    for k in s:
        assert np.array_equal(s[k], s2[k]), k
