"""Fault-tolerant plan transport (runtime/transport.py).

Pins the PR 9 acceptance contract: ``unpack_plan`` rejects every
corruption class with a clear ValueError before anything applies; the
lossy channel is seeded-deterministic (drop / duplicate / delay-reorder /
partition windows); a RemoteConsumer applies plans idempotently keyed by
version (stale and duplicate messages are no-ops, out-of-order plans are
held and chained, a journal gap costs exactly one snapshot resync that
preserves surviving endpoints' live load); the publisher stops shipping
to a lease-dead node and resumes on rejoin with capped-exponential
retry; and a full chaos schedule — crash, restart, partition, loss —
converges bit-exactly and replays byte-identically.

Everything here is engine-free: consumers sink into ``RoutingView``
(plain ``apply_plan`` replicas), so no serving engine is compiled.
"""

import numpy as np
import pytest

from repro.core import control
from repro.core.control import ControlPlane, pack_plan, unpack_plan
from repro.core.routing_table import (Cluster, POLICY_LEAST_REQUEST,
                                      POLICY_RR, POLICY_WEIGHTED, Rule,
                                      ServiceConfig)
from repro.runtime.transport import (CP_NODE, ChannelFault, LossyChannel,
                                     RemoteConsumer, Transport,
                                     convergence_report, snapshot_plan,
                                     snapshot_state)

SERVICES = [
    ServiceConfig("front", rules=[
        Rule(field=0, value="v2", cluster="canary"),
        Rule(field=0, value=None, cluster="stable"),
    ]),
]
CLUSTERS = [
    Cluster("canary", endpoints=[0, 1], policy=POLICY_RR),
    Cluster("stable", endpoints=[2, 3, 4], policy=POLICY_LEAST_REQUEST),
]


def _cp(**kw):
    return ControlPlane(SERVICES, CLUSTERS, **kw)


def _settle(hub, rcs, t0, budget=60):
    """Pump publisher + consumers tick by tick until converged."""
    t = t0
    for _ in range(budget):
        hub.pump(t)
        for rc in rcs:
            rc.pump(t)
        t += 1
        if hub.report()["converged"]:
            return t
    raise AssertionError("transport did not settle: "
                         + "; ".join(hub.report()["issues"]))


# --------------------------------------------------------------------------- #
# unpack_plan input validation (satellite: corruption classes)
# --------------------------------------------------------------------------- #


def _wire():
    cp = _cp()
    cp.set_weight("canary", instance=0, weight=2.0)
    return dict(cp.journal[-1])


def test_unpack_roundtrip_bit_exact():
    wire = _wire()
    plan = unpack_plan(wire)
    back = pack_plan(plan)
    assert set(back) == set(wire)
    for k, v in wire.items():
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(v),
                                      err_msg=f"field {k!r}")
    assert plan.base_version == 0 and plan.version == 1


def test_unpack_rejects_non_dict():
    with pytest.raises(ValueError, match="must be a dict"):
        unpack_plan([("ep_src", np.zeros(4))])


def test_unpack_rejects_missing_fields():
    wire = _wire()
    del wire["ep_src"]
    with pytest.raises(ValueError, match="missing fields.*ep_src"):
        unpack_plan(wire)
    wire = _wire()
    del wire["base_version"]
    with pytest.raises(ValueError, match="missing fields.*base_version"):
        unpack_plan(wire)


def test_unpack_rejects_wrong_shape():
    wire = _wire()
    wire["cluster_ep_count"] = np.asarray(wire["cluster_ep_count"])[:-1]
    with pytest.raises(ValueError, match="cluster_ep_count.*shape"):
        unpack_plan(wire)


def test_unpack_rejects_wrong_dtype_kind():
    wire = _wire()
    wire["ep_weight"] = np.asarray(wire["ep_weight"]).astype(np.int32)
    with pytest.raises(ValueError, match="ep_weight.*dtype"):
        unpack_plan(wire)
    wire = _wire()
    wire["ep_instance"] = np.asarray(wire["ep_instance"]).astype(np.float64)
    with pytest.raises(ValueError, match="ep_instance.*dtype"):
        unpack_plan(wire)


def test_unpack_rejects_bad_version_fields():
    for base, version in [(0, 0), (2, 2), (3, 1)]:
        wire = _wire()
        wire["base_version"], wire["version"] = base, version
        with pytest.raises(ValueError, match="bad version fields"):
            unpack_plan(wire)
    wire = _wire()
    wire["version"] = "2"                  # scalar type confusion
    with pytest.raises(ValueError, match="integer scalar"):
        unpack_plan(wire)
    wire = _wire()
    wire["base_version"] = True            # bool is not an int on the wire
    with pytest.raises(ValueError, match="integer scalar"):
        unpack_plan(wire)
    wire = _wire()
    wire["version"] = -5
    with pytest.raises(ValueError, match="out of range"):
        unpack_plan(wire)


def test_unpack_tolerates_envelope_keys():
    wire = _wire()
    wire["kind"] = "plan"                  # transport envelope rides along
    plan = unpack_plan(wire)
    assert plan.version == 1


def test_snapshot_validation_mirrors_plan_validation():
    cp = _cp()
    snap = cp.packed_snapshot()
    st = snapshot_state(snap)
    assert int(np.asarray(st.version)) == cp.version
    bad = dict(snap)
    del bad["maglev_table"]
    with pytest.raises(ValueError, match="missing fields.*maglev_table"):
        snapshot_state(bad)
    bad = dict(snap)
    bad["version"] = -1                    # a snapshot is always versioned
    with pytest.raises(ValueError, match="bad version"):
        snapshot_state(bad)


# --------------------------------------------------------------------------- #
# LossyChannel: seeded fate, partitions, reordering
# --------------------------------------------------------------------------- #


def test_channel_reliable_delivery_after_min_delay():
    ch = LossyChannel(delay_min=1)
    ch.send("a", {"n": 1}, tick=0)
    assert ch.recv("a", 0) == []           # not matured yet
    assert ch.recv("a", 1) == [{"n": 1}]
    assert ch.stats() == {"sent": 1, "dropped": 0, "partitioned": 0,
                          "duped": 0, "delivered": 1}


def test_channel_fate_is_seed_deterministic():
    def run():
        ch = LossyChannel(seed=7, p_drop=0.4, p_dup=0.3, delay_min=1,
                          delay_max=4)
        for i in range(50):
            ch.send("a", {"n": i}, tick=i)
        got = [m["n"] for t in range(60) for m in ch.recv("a", t)]
        return got, ch.stats()

    g1, s1 = run()
    g2, s2 = run()
    assert (g1, s1) == (g2, s2)
    assert s1["dropped"] > 0 and s1["duped"] > 0
    assert s1["delivered"] == s1["sent"] - s1["dropped"] + s1["duped"]
    assert g1 != sorted(g1)                # random delays did reorder


def test_channel_partition_window():
    ch = LossyChannel(faults=(ChannelFault(2, 5, dst="a"),))
    for t in range(7):
        ch.send("a", {"t": t}, t)
        ch.send("b", {"t": t}, t)          # other dst unaffected
    got_a = [m["t"] for t in range(9) for m in ch.recv("a", t)]
    got_b = [m["t"] for t in range(9) for m in ch.recv("b", t)]
    assert got_a == [0, 1, 5, 6]
    assert got_b == list(range(7))
    assert ch.partitioned == 3


def test_channel_rejects_bad_delay_bounds():
    with pytest.raises(ValueError, match="delay_max"):
        LossyChannel(delay_min=3, delay_max=1)


# --------------------------------------------------------------------------- #
# RemoteConsumer protocol: idempotent versioned application
# --------------------------------------------------------------------------- #


def test_consumer_holds_out_of_order_then_chains():
    cp = _cp()
    ch = LossyChannel(delay_min=0)
    rc = RemoteConsumer("n0", ch, snapshot=cp.packed_snapshot())
    cp.set_weight("canary", instance=0, weight=2.0)    # v1
    cp.set_weight("canary", instance=1, weight=3.0)    # v2
    p1, p2 = cp.journal[-2], cp.journal[-1]
    ch.send("n0", {"kind": "plan", **p2}, 0)           # v2 arrives first
    rc.pump(0)
    assert rc.held == 1 and rc.version == 0
    ch.send("n0", {"kind": "plan", **p1}, 1)           # gap closes
    rc.pump(1)
    assert rc.version == 2 and rc.held == 1 and rc.stale == 0
    assert [(k, b, v) for (_, k, b, v) in rc.history] == \
        [("plan", 0, 1), ("plan", 1, 2)]
    assert float(np.asarray(rc.routing.ep_weight)[
        cp.endpoint_slot("canary", 1)]) == 3.0


def test_consumer_duplicate_and_stale_are_noops():
    cp = _cp()
    ch = LossyChannel(delay_min=0)
    rc = RemoteConsumer("n0", ch, snapshot=cp.packed_snapshot())
    cp.set_weight("canary", instance=0, weight=2.0)
    wire = {"kind": "plan", **cp.journal[-1]}
    for t in range(3):                     # same plan delivered thrice
        ch.send("n0", wire, t)
        rc.pump(t)
    assert rc.version == 1 and rc.stale == 2
    assert len(rc.history) == 1            # applied exactly once


def test_consumer_rejects_corrupt_plan_whole():
    cp = _cp()
    ch = LossyChannel(delay_min=0)
    rc = RemoteConsumer("n0", ch, snapshot=cp.packed_snapshot())
    cp.set_weight("canary", instance=0, weight=2.0)
    wire = {"kind": "plan", **cp.journal[-1]}
    wire["ep_weight"] = np.asarray(wire["ep_weight"])[:3]   # truncated
    ch.send("n0", wire, 0)
    rc.pump(0)
    assert rc.rejected == 1 and rc.version == 0
    assert float(np.asarray(rc.routing.ep_weight)[
        cp.endpoint_slot("canary", 0)]) == 1.0   # nothing half-applied


def test_snapshot_resync_preserves_surviving_load():
    cp = _cp()
    ch = LossyChannel(delay_min=0)
    rc = RemoteConsumer("n0", ch, snapshot=cp.packed_snapshot())
    slot = cp.endpoint_slot("stable", 3)
    load = np.asarray(rc.routing.ep_load).copy()
    load[slot] = 7                         # live in-flight work on the sink
    rc.sink.routing = rc.routing._replace(ep_load=load)
    cp.add_endpoint("canary", instance=9)  # membership change + gap
    cp.set_weight("stable", instance=2, weight=1.5)
    ch.send("n0", {"kind": "snapshot", **cp.packed_snapshot()}, 0)
    rc.pump(0)
    assert rc.resyncs == 1 and rc.version == cp.version
    r = rc.routing
    assert int(np.asarray(r.ep_load)[cp.endpoint_slot("stable", 3)]) == 7
    assert int(np.asarray(r.ep_load)[cp.endpoint_slot("canary", 9)]) == 0
    np.testing.assert_array_equal(
        np.asarray(r.ep_weight), np.asarray(cp.snapshot().ep_weight))


def test_snapshot_plan_applies_on_any_base():
    cp = _cp()
    snap = cp.packed_snapshot()
    plan = snapshot_plan(snap, snapshot_state(snap))
    assert plan.base_version == -1 and plan.version == cp.version


# --------------------------------------------------------------------------- #
# Transport end-to-end: gaps, crashes, lease gating, backoff
# --------------------------------------------------------------------------- #


def test_journal_gap_costs_exactly_one_resync():
    cp = _cp(journal_limit=2)
    hub = Transport(cp, LossyChannel(delay_min=0))
    rc = hub.consumer("n0")
    for i in range(5):                     # journal floor races past acked=0
        cp.set_weight("stable", instance=2, weight=1.0 + 0.1 * (i + 1))
    _settle(hub, [rc], 0)
    rep = hub.assert_converged()
    assert rc.version == 5 and rc.resyncs == 1
    assert hub.publisher.stats()["n0"]["snap_sends"] == 1
    assert rep["head"] == 5


def test_contiguous_suffix_ships_as_plans_not_snapshot():
    cp = _cp(journal_limit=16)
    hub = Transport(cp, LossyChannel(delay_min=0))
    rc = hub.consumer("n0")
    for i in range(4):                     # all four commits still journaled
        cp.set_weight("stable", instance=2, weight=1.0 + 0.1 * (i + 1))
    _settle(hub, [rc], 0)
    hub.assert_converged()
    st = hub.publisher.stats()["n0"]
    assert rc.resyncs == 0 and st["snap_sends"] == 0 and st["plan_sends"] >= 4


def test_crash_restart_rejoins_with_one_resync():
    cp = _cp()
    hub = Transport(cp, LossyChannel(delay_min=1))
    rc = hub.consumer("n0")
    cp.set_weight("canary", instance=0, weight=2.0)
    t = _settle(hub, [rc], 0)
    rc.crash()
    cp.set_weight("canary", instance=1, weight=3.0)    # missed commits
    cp.add_endpoint("stable", instance=8)
    for dt in range(4):                    # plans pile up undelivered
        hub.pump(t + dt)
    rc.restart()
    t = _settle(hub, [rc], t + 4)
    rep = hub.assert_converged()
    assert rc.crashes == 1 and rc.resyncs == 1
    assert rc.version == cp.version == 3
    assert rep["consumers"][0]["alive"]
    # queued pre-crash plans landed on the new incarnation as no-ops
    assert all(v > 0 for (_, _, _, v) in rc.history)


def test_publisher_gates_on_lease_and_resumes_on_rejoin():
    cp = _cp(lease_epochs=2)
    hub = Transport(cp, LossyChannel(delay_min=1))
    rc = hub.consumer("n0")
    cp.set_weight("canary", instance=0, weight=2.0)
    t = _settle(hub, [rc], 0)
    rc.crash()
    hub.pump(t)                            # absorb in-flight heartbeats
    for _ in range(4):                     # heartbeats stop; lease expires
        cp.advance_epoch()
    cp.set_weight("canary", instance=1, weight=3.0)
    st = hub.publisher.stats()["n0"]
    sends_dead = st["plan_sends"] + st["snap_sends"]
    for dt in range(1, 7):                 # dead node: plans stop shipping
        hub.pump(t + dt)
    st = hub.publisher.stats()["n0"]
    assert st["plan_sends"] + st["snap_sends"] == sends_dead
    rc.restart()                           # rejoin: heartbeat re-leases
    t = _settle(hub, [rc], t + 6)
    hub.assert_converged()
    assert cp.lease_live(hub.publisher.nodes["n0"].proxy)
    assert rc.resyncs == 1                 # rejoin landed one resync


def test_retry_backoff_is_capped_and_deterministic():
    def run():
        cp = _cp()                         # lease_epochs=0: lease disabled
        # a black-hole channel: the node never acks, publisher retries
        ch = LossyChannel(p_drop=1.0)
        hub = Transport(cp, ch, retry_base=1, retry_cap=8, seed=5)
        hub.consumer("n0", boot=False)     # cold: acked=-1, snapshot path
        ticks = []
        last = -1
        for t in range(200):
            hub.pump(t)
            s = hub.publisher.stats()["n0"]["snap_sends"]
            if s != last:
                ticks.append(t)
                last = s
        return ticks

    t1, t2 = run(), run()
    assert t1 == t2                        # seeded jitter: replayable
    gaps = [b - a for a, b in zip(t1, t1[1:])]
    assert all(1 <= g <= 16 for g in gaps)  # cap + jitter < 2*cap
    assert max(gaps) > min(gaps)           # backoff actually grew
    assert gaps[-1] >= 8                   # settled at >= cap


def test_heartbeats_carry_load_votes_to_the_reaper():
    cp = _cp()
    hub = Transport(cp, LossyChannel(delay_min=1))
    rc = hub.consumer("n0")
    slot = cp.endpoint_slot("stable", 4)
    load = np.asarray(rc.routing.ep_load).copy()
    load[slot] = 3                         # remote in-flight work
    rc.sink.routing = rc.routing._replace(ep_load=load)
    for t in range(3):                     # heartbeat out, publisher reads
        hub.pump(t)
        rc.pump(t)
    proxy = hub.publisher.nodes["n0"].proxy
    assert int(proxy.routing.ep_load[slot]) == 3
    cp.drain_endpoint("stable", instance=4)
    assert cp.drain_reason("stable", 4) is not None   # load pins the drain
    load = np.asarray(rc.routing.ep_load).copy()
    load[slot] = 0                         # remote work finishes
    rc.sink.routing = rc.routing._replace(ep_load=load)
    for t in range(3, 8):                  # zero-load vote reaches the cp
        hub.pump(t)
        rc.pump(t)
    cp.set_weight("canary", instance=0, weight=1.1)   # next commit reaps
    assert cp.drain_reason("stable", 4) is None
    assert ("stable", 4) not in [("stable", i)
                                 for _, i in cp.cluster_members("stable")]


# --------------------------------------------------------------------------- #
# Chaos convergence: the whole protocol under fire, bit-identical replay
# --------------------------------------------------------------------------- #


def _chaos_run(seed=11):
    cp = _cp(lease_epochs=3, journal_limit=8)
    ch = LossyChannel(seed=seed, p_drop=0.25, p_dup=0.15, delay_min=1,
                      delay_max=3, faults=(ChannelFault(10, 22, dst="n1"),))
    hub = Transport(cp, ch, seed=seed)
    rcs = [hub.consumer("n0"), hub.consumer("n1")]
    for t in range(70):
        if t in (4, 14, 24, 34, 44):
            cp.set_weight("stable", instance=2, weight=1.0 + 0.01 * t)
        if t % 5 == 0:
            cp.advance_epoch()
        if t == 18:
            rcs[0].crash()
        if t == 30:
            rcs[0].restart()
        hub.pump(t)
        for rc in rcs:
            rc.pump(t)
    t = _settle(hub, rcs, 70, budget=80)
    rep = hub.assert_converged()
    return rep, ch.stats(), [rc.history for rc in rcs], \
        {n: dict(s) for n, s in hub.publisher.stats().items()}


def test_chaos_schedule_converges_and_replays_bit_identically():
    r1 = _chaos_run()
    r2 = _chaos_run()
    assert r1 == r2
    rep, stats, histories, _ = r1
    assert rep["converged"] and rep["head"] == 5
    assert stats["dropped"] > 0 and stats["duped"] > 0
    assert stats["partitioned"] > 0
    by_node = {e["node"]: e for e in rep["consumers"]}
    assert by_node["n0"]["crashes"] == 1
    assert by_node["n0"]["resyncs"] <= by_node["n0"]["crashes"] + 1
    assert by_node["n1"]["resyncs"] <= 1   # partition alone: at most a gap
    for hist in histories:                 # applied versions strictly climb
        vs = [v for (_, _, _, v) in hist]
        assert vs == sorted(set(vs))


def test_convergence_report_flags_divergence():
    cp = _cp()
    hub = Transport(cp, LossyChannel(delay_min=0))
    rc = hub.consumer("n0")
    cp.set_weight("canary", instance=0, weight=2.0)   # never delivered
    rep = convergence_report(cp, [rc])
    assert not rep["converged"]
    assert any("at version 0" in s for s in rep["issues"])
    _settle(hub, [rc], 0)
    assert convergence_report(cp, [rc])["converged"]


def test_convergence_report_flags_lost_bump_history():
    cp = _cp()
    rc = RemoteConsumer("n0", LossyChannel(), snapshot=cp.packed_snapshot())
    rc.history = [(0, "plan", 0, 1), (1, "plan", 3, 4)]   # forged gap
    rc.version = cp.version
    rep = convergence_report(cp, [rc])
    assert any("lost bump" in s for s in rep["issues"])
