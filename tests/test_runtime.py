"""Runtime substrate tests: checkpoint atomicity/restore, fault-tolerant
train loop (failure injection → restore + replay), deterministic pipeline,
elastic resharding, optimizer correctness."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_config
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import model as M
from repro.optim import adamw, compression, schedules
from repro.runtime import train_loop
from repro.runtime.checkpoint import Checkpointer


@pytest.fixture()
def cfg():
    return smoke_config(get_config("xlb-service-model"))


def test_pipeline_deterministic_and_host_sharded():
    dc = DataConfig(vocab=128, seq_len=16, global_batch=8, seed=3)
    p = Pipeline(dc)
    b1, b2 = p.batch_at(5), p.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p.batch_at(6)["tokens"], b1["tokens"])
    # two hosts partition the global batch exactly
    h0 = Pipeline(dc, host_id=0, n_hosts=2).batch_at(5)
    h1 = Pipeline(dc, host_id=1, n_hosts=2).batch_at(5)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), b1["tokens"])


def test_checkpoint_atomic_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": [jnp.ones(4)] ,
            "c": {"d": jnp.zeros((3,), jnp.int32)}}
    for step in (10, 20, 30):
        ck.save(step, jax.tree.map(lambda x: x + step, tree), blocking=True)
    assert ck.list_steps() == [20, 30]          # keep=2 GC'd step 10
    restored, step = ck.restore(tree)
    assert step == 30
    np.testing.assert_array_equal(restored["a"], np.arange(6).reshape(2, 3) + 30)
    # torn writes are invisible: a tmp dir without manifest is ignored
    os.makedirs(tmp_path / ".tmp-99-junk")
    assert ck.latest_step() == 30


def test_train_loop_restores_after_injected_failure(cfg, tmp_path):
    tcfg = train_loop.TrainConfig(steps=8, ckpt_every=2,
                                  ckpt_dir=str(tmp_path), log_every=100)
    dc = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
    pipe = Pipeline(dc)
    boom = {"armed": True}

    def fail_injector(step):
        if step == 5 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    out = train_loop.run(cfg, pipe, tcfg, fail_injector=fail_injector)
    assert out["restarts"] == 1
    steps_seen = [h["step"] for h in out["history"]]
    assert steps_seen[-1] == 7                  # completed all steps
    assert 4 in steps_seen and steps_seen.count(4) >= 2  # replayed after restore
    losses = [h["loss"] for h in out["history"]]
    assert np.isfinite(losses).all()


def test_train_loop_loss_decreases(cfg, tmp_path):
    tcfg = train_loop.TrainConfig(steps=12, ckpt_every=50,
                                  ckpt_dir=str(tmp_path), log_every=100,
                                  opt=adamw.AdamWConfig(lr=1e-2))
    pipe = Pipeline(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4))
    out = train_loop.run(cfg, pipe, tcfg)
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0], (losses[0], losses[-1])


def test_adamw_matches_reference_sgd_direction():
    params = {"w": jnp.ones((4,)) * 2.0}
    grads = {"w": jnp.ones((4,))}
    st = adamw.init(params)
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=1e9)
    p2, st2, stats = adamw.apply(params, grads, st, cfg)
    # first Adam step ≈ -lr * sign(g)
    np.testing.assert_allclose(p2["w"], params["w"] - 0.1, rtol=1e-4)
    assert stats["grad_norm"] == pytest.approx(2.0)


def test_int8_error_feedback_is_unbiased_over_steps():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 1e-3
    ef = compression.init(g)
    total_deq = jnp.zeros_like(g)
    steps = 50
    for _ in range(steps):
        q, s, ef = compression.compress_pytree(g, ef)
        total_deq = total_deq + compression.decompress_pytree(q, s)
    # accumulated dequantised ≈ accumulated true gradient (error feedback)
    np.testing.assert_allclose(total_deq / steps, g, atol=2e-5)


def test_elastic_restore_roundtrip(cfg, tmp_path):
    """Checkpoint saved under one layout restores identically (values) under
    a different device placement."""
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"params": params}, blocking=True)
    restored, _ = ck.restore({"params": params})
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_router_bias_least_request_counteracts_imbalance():
    bias = jnp.zeros((4,))
    load = jnp.array([100, 0, 0, 0], jnp.int32)
    for _ in range(10):
        bias = adamw.update_router_bias(bias, load)
    assert bias[0] < bias[1]                     # hot expert biased down


def test_schedule_warmup_cosine_shape():
    s = schedules.warmup_cosine(jnp.arange(0, 1000), warmup=100, total=1000)
    assert s[0] == 0.0
    assert float(s[100]) == pytest.approx(1.0, abs=0.02)
    assert s[-1] < 0.2
