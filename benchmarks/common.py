"""Shared benchmark harness: drive the XLB in-graph engine and the two
sidecar baselines over a ServiceGraph, measuring throughput / latency / CPU.

The per-service application is the tiny dense LM (xlb-service-model); a
request occupies a slot for ``tokens_per_req`` decode steps.  Requests flow
along the graph's call chain: when a request completes at hop i it is
enqueued at hop i+1 (the host moves an opaque token id — never inspecting
payloads for XLB; the sidecar baselines route on the host per hop, paying
the proxy costs they pay in the paper).

All three architectures run through ONE ``Service`` wrapper built on the
``Balancer`` protocol (core/balancer.py) with routing from a per-fleet
``ControlPlane`` — the benchmarks never branch on the mode.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ServiceGraph, get_config, smoke_config
from repro.core.balancer import RequestBatch, make_balancer
from repro.core.control import ControlPlane
from repro.core.routing_table import (Cluster, POLICY_LEAST_REQUEST, Rule,
                                      ServiceConfig)
from repro.models import model as M

CFG = smoke_config(get_config("xlb-service-model"))
KEY = jax.random.PRNGKey(42)
PARAMS = M.init_params(CFG, KEY, dtype=jnp.float32)


def build_cp(n_instances: int,
             policy: int = POLICY_LEAST_REQUEST) -> ControlPlane:
    return ControlPlane(
        [ServiceConfig("svc", rules=[Rule(0, None, "pool")])],
        [Cluster("pool", endpoints=list(range(n_instances)),
                 policy=policy)])


def build_routing(n_instances: int, policy: int = POLICY_LEAST_REQUEST):
    return build_cp(n_instances, policy).snapshot()


def request_batch(req_ids, pad_to: int) -> RequestBatch:
    rid = np.full((pad_to,), -1, np.int32)
    tok = np.zeros((pad_to,), np.int32)
    n = min(len(req_ids), pad_to)
    rid[:n] = req_ids[:n]
    tok[:n] = 3 + (np.asarray(req_ids[:n]) % (CFG.vocab - 3))
    return RequestBatch(
        req_id=jnp.asarray(rid), svc=jnp.zeros((pad_to,), jnp.int32),
        features=jnp.zeros((pad_to, 8), jnp.int32), token=jnp.asarray(tok),
        msg_bytes=jnp.full((pad_to,), 128, jnp.int32))


@dataclasses.dataclass
class HopStats:
    completed: int = 0
    ticks: int = 0
    wall_s: float = 0.0


class Service:
    """One service fleet behind any Balancer (mode: xlb | istio | cilium).

    ``eos`` reaches the engine's completion path (``eos=-1`` makes requests
    purely length-driven — the deterministic setting the degraded scenario
    measures latency in).  ``fault`` is an optional
    ``runtime.serve_loop.FaultInjector`` applied to the pool before every
    step (progress rollback: the fault-injection harness); ``shaper`` is
    the per-request analogue (``workload.generators.ServiceTimeShaper`` —
    heavy-tailed service times through the same rollback model).
    ``batch_fn(req_ids, pad_to)`` builds the admission batch (default: the
    uniform ``request_batch``; a ``Workload.request_batch`` gives per-flow
    feature entropy).  ``shards > 1`` runs the xlb engine's mesh-sharded
    admission datapath (needs that many devices).  Per-request engine-tick
    samples land in ``submit_tick`` / ``admit_tick`` / ``done_tick``."""

    def __init__(self, mode: str, n_instances: int, slots: int,
                 tokens_per_req: int, admit_batch: int = 16, eos: int = 1,
                 fault=None, shaper=None, policy: int = POLICY_LEAST_REQUEST,
                 shards: int = 1, batch_fn=None):
        kw = {}
        if shards > 1:
            if mode != "xlb":
                raise ValueError("shards > 1 needs the in-graph engine "
                                 "(the sidecars route on the host)")
            from repro.launch.mesh import make_shard_mesh
            kw = dict(shards=shards, shard_mesh=make_shard_mesh(shards))
        self.eng = make_balancer(mode, CFG, n_instances, slots,
                                 max_len=tokens_per_req + 1, eos=eos, **kw)
        self.cp = build_cp(n_instances, policy)
        self.state = self.eng.init_state(self.cp.snapshot(),
                                         dtype=jnp.float32)
        self.cp.attach(self)
        self.serve = self.eng.make_jitted(donate=False)
        self.admit_batch = admit_batch
        self.batch_fn = batch_fn or request_batch
        self.queue: list[int] = []
        self.dropped: list[int] = []        # gave up after max retries
        self._retries: dict[int, int] = {}
        self.stats = HopStats()
        self.fault = fault
        self.shaper = shaper
        self.tick_no = 0                    # absolute ticks (never reset —
        #                                     fault schedules key off it)
        # per-request tick samples (workload/slo.py): submit / first slot /
        # completion, all in this service's absolute engine ticks
        self.submit_tick: dict[int, int] = {}
        self.admit_tick: dict[int, int] = {}
        self.done_tick: dict[int, int] = {}

    # control-plane consumer hooks (cp.attach) ------------------------- #
    @property
    def routing(self):
        return self.eng.get_routing(self.state)

    def apply_refresh(self, plan):
        self.state = self.eng.apply_refresh(self.state, plan)

    # ------------------------------------------------------------------ #
    def submit(self, req_ids):
        for r in req_ids:
            r = int(r)
            self.queue.append(r)
            self.submit_tick.setdefault(r, self.tick_no)

    def tick(self) -> list[int]:
        """One engine step. Returns req_ids completed this tick."""
        self.cp.heartbeat(self)             # liveness lease (core/control)
        if self.fault is not None:          # injected faults roll progress
            pool = self.fault.apply(self.state.pool, self.tick_no)
            if pool is not self.state.pool:  # back BEFORE the step, so a
                self.state = self.state._replace(pool=pool)  # held slot
        if self.shaper is not None:         # heavy-tailed service times:
            pool = self.shaper.apply(self.state.pool, self.tick_no)
            if pool is not self.state.pool:  # same rollback model, keyed
                self.state = self.state._replace(pool=pool)  # per req_id
        self.tick_no += 1                   # can't complete this tick
        take = self.queue[: self.admit_batch]
        self.queue = self.queue[self.admit_batch:]
        reqs = self.batch_fn(take, self.admit_batch)
        t0 = time.perf_counter()
        self.state, out = self.serve(PARAMS, self.state, reqs)
        jax.block_until_ready(out["emitted"])
        self.stats.wall_s += time.perf_counter() - t0
        self.stats.ticks += 1
        done = np.asarray(out["done"])
        ids = np.asarray(out["req_id"])          # ids serviced this tick
        finished = [int(x) for x in ids[done & (ids >= 0)]]
        self.stats.completed += len(finished)
        now = self.tick_no - 1                   # tick this step ran at
        for r in finished:
            self.done_tick[r] = now
        # held / unroutable arrivals re-queue (uniform across engines) up
        # to the same 64-retry budget ServeLoop uses; past it they land on
        # ``dropped`` so a misconfigured bench fails visibly instead of
        # spinning to max_ticks
        serviced = set(int(x) for x in ids[ids >= 0])
        for r in serviced:
            self.admit_tick.setdefault(r, now)
        retry = []
        for r in take:
            if r in serviced:
                self._retries.pop(r, None)
                continue
            n = self._retries.get(r, 0) + 1
            if n < 64:
                self._retries[r] = n
                retry.append(r)
            else:
                self._retries.pop(r, None)
                self.dropped.append(r)
        self.queue = retry + self.queue
        return finished

    @property
    def busy(self) -> bool:
        return bool(self.queue) or bool(
            np.asarray(self.state.pool.active).any())


def make_service(mode: str, n_instances: int, slots: int,
                 tokens_per_req: int, admit_batch: int = 16) -> Service:
    return Service(mode, n_instances, slots, tokens_per_req, admit_batch)


# --------------------------------------------------------------------------- #
# Drivers
# --------------------------------------------------------------------------- #


def warm(*svcs):
    """Compile each engine's programs before the timed region (both the
    sidecars and XLB pay their jit compile once, outside measurement)."""
    for s in svcs:
        s.tick()
        s.stats = HopStats()
    return svcs[0] if len(svcs) == 1 else svcs


def run_closed_loop(mode: str, *, n_requests: int, n_instances: int = 2,
                    slots: int = 8, tokens_per_req: int = 4,
                    max_ticks: int = 2000, arrivals_per_tick: int = 0) -> dict:
    """Single-service loop (paper Table 1 / Fig 5 setting).

    ``arrivals_per_tick`` > 0 streams arrivals (open-ish loop) so both the
    host-routed baselines and the in-graph path pay admission repeatedly —
    the paper's persistent-connection request stream."""
    svc = warm(make_service(mode, n_instances, slots, tokens_per_req))
    submit_t = {}
    done_t = {}
    t0 = time.perf_counter()
    pending = list(range(n_requests))
    if not arrivals_per_tick:
        svc.submit(pending)
        submit_t = {r: t0 for r in pending}
        pending = []
    ticks = 0
    while (svc.busy or pending) and ticks < max_ticks:
        if pending:
            wave, pending = (pending[:arrivals_per_tick],
                             pending[arrivals_per_tick:])
            now = time.perf_counter()
            svc.submit(wave)
            submit_t.update({r: now for r in wave})
        for r in svc.tick():
            done_t[r] = time.perf_counter()
        ticks += 1
    wall = time.perf_counter() - t0
    lat = [done_t[r] - submit_t[r] for r in done_t]
    return {
        "mode": mode, "completed": len(done_t), "wall_s": wall,
        "req_per_s": len(done_t) / wall if wall else 0.0,
        "avg_ms": 1e3 * float(np.mean(lat)) if lat else float("nan"),
        "p99_ms": 1e3 * float(np.percentile(lat, 99)) if lat else float("nan"),
        "ticks": ticks,
    }


def run_degraded(mode: str = "xlb", *, n_instances: int = 4, slots: int = 4,
                 tokens_per_req: int = 2, arrivals_per_tick: int = 2,
                 fault_start: int = 40, fault_end: int = 160,
                 factor: int = 10, epoch_interval: int = 6,
                 total_ticks: int = 280, warmup: int = 10) -> dict:
    """The closed-loop health scenario (DESIGN.md §8): one instance goes
    ``factor``× slower mid-run; the HealthPolicy daemon must eject it and,
    once the fault clears, re-admit it — with ZERO operator transactions —
    and tail latency over the post-detection window must recover to the
    healthy baseline.

    Latency is measured in engine ticks (submit tick → completion tick)
    with ``eos=-1`` so completion is purely length-driven — deterministic,
    and immune to host jitter.  The breaker's cooldown is sized so the
    half-open probe lands after the fault clears (the mid-fault re-eject
    cycle is pinned by tests/test_health.py instead — here we measure the
    clean recovery the gate checks)."""
    from repro.core.health import CLOSED, OPEN, HealthConfig, HealthPolicy
    from repro.runtime.serve_loop import Fault, FaultInjector

    sick = n_instances - 1
    inj = FaultInjector([Fault(sick, "slow", factor=factor,
                               start=fault_start, end=fault_end)])
    svc = Service(mode, n_instances, slots, tokens_per_req, eos=-1,
                  fault=inj)
    # first probe at ~eject + cooldown·interval: past fault_end by design
    cooldown = (fault_end - fault_start) // epoch_interval
    pol = HealthPolicy(svc.cp, HealthConfig(
        trip_after=2, cooldown=cooldown, recover_after=2,
        probe_patience=10), clusters=["pool"])
    v0 = svc.cp.version
    submit_t = svc.submit_tick              # per-request engine-tick samples
    done_t = svc.done_tick                  # recorded by the Service itself
    rid = 0
    eject_tick = uneject_tick = None
    for t in range(total_ticks):
        wave = list(range(rid, rid + arrivals_per_tick))
        rid += len(wave)
        svc.submit(wave)
        svc.tick()
        if (t + 1) % epoch_interval == 0:
            pol.epoch(svc.routing)
            st = pol.state_of("pool", sick)
            if st == OPEN and eject_tick is None:
                eject_tick = t
            if eject_tick is not None and uneject_tick is None \
                    and st == CLOSED:
                uneject_tick = t

    from repro.workload.slo import percentiles
    lat = {r: done_t[r] - submit_t[r] for r in done_t}

    def p99(lo, hi):
        xs = [lat[r] for r, d in done_t.items() if lo <= d < hi]
        return percentiles(np.asarray(xs, np.int64))["p99"]

    # stragglers stuck on the slow instance at ejection time finish within
    # ~tokens·factor ticks; the recovered window starts after they clear
    settle = (tokens_per_req + 2) * factor
    detect = eject_tick if eject_tick is not None else fault_end
    healthy = p99(warmup, fault_start)
    degraded = p99(fault_start + 2, min(detect + settle, fault_end))
    recovered = p99(detect + settle, fault_end)
    snap = svc.cp.snapshot()
    ep_slots = [svc.cp.endpoint_slot("pool", i) for i in range(n_instances)]
    end_drained = int(sum(int(np.asarray(snap.ep_drained)[s])
                          for s in ep_slots))
    return {
        "mode": mode, "n_instances": n_instances, "slots": slots,
        "factor": factor, "fault_start": fault_start,
        "fault_end": fault_end, "ticks": total_ticks,
        "completed": len(done_t), "dropped": len(svc.dropped),
        "healthy_p99_ticks": healthy, "degraded_p99_ticks": degraded,
        "recovered_p99_ticks": recovered,
        "recovery_ratio": recovered / healthy if healthy else float("nan"),
        "eject_tick": eject_tick, "uneject_tick": uneject_tick,
        # closed-loop requirement: every commit was authored by the daemon
        "operator_txns": (svc.cp.version - v0) - pol.commits,
        "daemon_txns": pol.commits,
        "end_drained": end_drained,
        "end_state": pol.state_of("pool", sick),
        "end_weight": float(svc.cp.endpoint_weight("pool", sick)),
    }


def run_chain(mode: str, *, chain_len: int, n_requests: int = 16,
              n_instances: int = 2, slots: int = 8, tokens_per_req: int = 2,
              max_ticks: int = 4000) -> dict:
    """Paper Fig 8: requests traverse a chain of services."""
    hops = [make_service(mode, n_instances, slots, tokens_per_req)
            for _ in range(chain_len)]
    warm(*hops)
    hops[0].submit(list(range(n_requests)))
    t0 = time.perf_counter()
    done_t = {}
    ticks = 0
    while any(h.busy for h in hops) and ticks < max_ticks:
        for i, h in enumerate(hops):
            if not h.busy:                       # event-driven: idle hops
                continue                         # launch no program
            finished = h.tick()
            if i + 1 < len(hops):
                hops[i + 1].submit(finished)
            else:
                for r in finished:
                    done_t[r] = time.perf_counter()
        ticks += 1
    wall = time.perf_counter() - t0
    lat = [done_t[r] - t0 for r in done_t]
    return {"mode": mode, "chain": chain_len, "completed": len(done_t),
            "req_per_s": len(done_t) / wall if wall else 0.0,
            "avg_ms": 1e3 * float(np.mean(lat)) if lat else float("nan"),
            "wall_s": wall}


def run_chain_scenario(mode: str, *, depth: int = 3, workload=None,
                       ops=None, label: str = "chain",
                       n_instances: int = 2, slots: int = 8,
                       tokens_per_req: int = 2, admit_batch: int = 8,
                       policy: int = POLICY_LEAST_REQUEST, shards: int = 1,
                       faults: dict | None = None,
                       max_ticks: int = 4000) -> dict:
    """The workload-subsystem chain driver (DESIGN.md §10): a generated
    request stream through a depth-D service chain, each hop behind its own
    balancer, with an optional live-ops scenario replayed mid-load.

    Latency is deterministic engine ticks (``eos=-1``): end-to-end =
    submit at hop 0 → completion at hop D-1, per-hop admit→done recorded
    too.  Returns ``{"result": ChainResult, "row": <scenario row>}`` — the
    row is schema-validated and ready for ``append_scenario_row``.
    ``faults`` maps hop → FaultInjector (composable with the scenario)."""
    from repro.workload import (ChainRunner, PoissonArrivals,
                                ScenarioDriver, Workload, percentiles,
                                scenario_row)
    if workload is None:
        workload = Workload(PoissonArrivals(rate=2.0, seed=11),
                            n_requests=24, vocab=CFG.vocab)
    faults = faults or {}
    hops = [Service(mode, n_instances, slots, tokens_per_req,
                    admit_batch=admit_batch, eos=-1, policy=policy,
                    shards=shards, fault=faults.get(k),
                    shaper=workload.shaper(tokens_per_req, hop=k),
                    batch_fn=workload.request_batch)
            for k in range(depth)]
    warm(*hops)
    scenario = None
    if ops:
        scenario = ScenarioDriver([h.cp for h in hops], ops,
                                  max_instances=n_instances)
    res = ChainRunner(hops, workload, scenario=scenario,
                      max_ticks=max_ticks).run()
    arr = type(workload.arrivals).__name__.removesuffix("Arrivals").lower()
    extra = {"ops": len(ops or []),
             "txns": scenario.txns if scenario else 0,
             "rate": float(workload.arrivals.rate),
             "scale": float(workload.arrivals.scale),
             "per_hop_p99_ticks": [percentiles(res.hop_samples(k))["p99"]
                                   for k in range(depth)]}
    if shards > 1:
        extra["shards"] = shards
    if workload.service is not None:
        extra["service"] = type(workload.service).__name__ \
            .removesuffix("ServiceTimes").lower()
    row = scenario_row(label, mode, depth=depth,
                       seed=workload.arrivals.seed, arrivals=arr,
                       n_requests=res.n_submitted, completed=res.completed,
                       dropped=res.dropped, ticks=res.ticks,
                       samples=res.samples(), **extra)
    return {"result": res, "row": row}


def run_graph(mode: str, graph: ServiceGraph, *, n_requests: int = 12,
              slots: int = 8, tokens_per_req: int = 2,
              max_ticks: int = 4000) -> dict:
    """Paper Fig 11/12: microservice application topologies."""
    insts = {s: max(1, min(graph.instances.get(s, 1), 8))
             for s in graph.services}
    svcs = {s: make_service(mode, insts[s], slots, tokens_per_req)
            for s in graph.services if s != graph.services[0]}
    warm(*svcs.values())
    out_edges = {}
    for a, b in graph.edges:
        out_edges.setdefault(a, []).append(b)
    entry = out_edges[graph.services[0]][0]     # client → first real service
    svcs[entry].submit(list(range(n_requests)))
    inflight = {r: [entry] for r in range(n_requests)}
    done_t = {}
    t0 = time.perf_counter()
    ticks = 0
    while any(s.busy for s in svcs.values()) and ticks < max_ticks:
        for name, s in svcs.items():
            if not s.busy:
                continue
            finished = s.tick()
            nxt = out_edges.get(name, [])
            for r in finished:
                if nxt:                          # fan out to callees
                    for callee in nxt:
                        svcs[callee].submit([r])
                else:
                    done_t[r] = time.perf_counter()
        ticks += 1
    wall = time.perf_counter() - t0
    lat = [done_t[r] - t0 for r in done_t]
    return {"mode": mode, "graph": graph.name, "completed": len(done_t),
            "req_per_s": len(done_t) / wall if wall else 0.0,
            "avg_ms": 1e3 * float(np.mean(lat)) if lat else float("nan"),
            "wall_s": wall}
